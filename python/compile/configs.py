"""Model hyper-parameter presets for the DyMoE reproduction.

Two mini-MoE transformers mirror the paper's two evaluation models in
*architecture shape* (see DESIGN.md §2):

* ``mixtral-mini`` — coarse-grained / low-sparsity (few big experts, top-2),
  standing in for Mixtral-8x7B.
* ``qwen-mini``    — fine-grained / high-sparsity (many small experts,
  top-4 of 32 => 12.5% activation), standing in for Qwen3-30B-A3B.

``tiny`` is a fast config used only by the test-suite.

All dimensions are chosen so that every weight matrix is divisible by the
quantization group size (32) and by the densest packing factor (16 values
per u32 word at 2 bits).
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ffn: int          # per-expert hidden width
    n_experts: int
    top_k: int
    vocab: int
    max_seq: int        # prefill bucket / maximum prompt length
    max_cache: int      # decode KV-cache capacity
    group_size: int = 32  # quantization group size along the input dim
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def expert_params(self) -> int:
        """Parameters in one expert (w1, w3: d->ffn and w2: ffn->d)."""
        return 3 * self.d_model * self.d_ffn

    def validate(self) -> None:
        assert self.d_model % self.n_heads == 0
        for dim in (self.d_model, self.d_ffn):
            assert dim % self.group_size == 0, (self.name, dim)
            assert dim % 16 == 0, "must be divisible by the 2-bit pack factor"
        assert self.top_k <= self.n_experts
        assert self.max_cache >= self.max_seq

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        return d


MIXTRAL_MINI = ModelConfig(
    name="mixtral-mini",
    n_layers=8,
    d_model=256,
    n_heads=8,
    d_ffn=512,
    n_experts=8,
    top_k=2,
    vocab=64,
    max_seq=96,
    max_cache=160,
)

QWEN_MINI = ModelConfig(
    name="qwen-mini",
    n_layers=10,
    d_model=192,
    n_heads=6,
    d_ffn=96,
    n_experts=32,
    top_k=4,
    vocab=64,
    max_seq=96,
    max_cache=160,
)

TINY = ModelConfig(
    name="tiny",
    n_layers=2,
    d_model=32,
    n_heads=2,
    d_ffn=64,
    n_experts=4,
    top_k=2,
    vocab=64,
    max_seq=16,
    max_cache=32,
)

CONFIGS = {c.name: c for c in (MIXTRAL_MINI, QWEN_MINI, TINY)}

# Token-count buckets for the per-expert FFN artifacts.  L3 pads each
# expert's token batch up to the smallest bucket that fits.
EXPERT_BUCKETS = (1, 4, 16, 96)

# Precisions exported as separate artifacts / weight blobs.
PRECISIONS = ("bf16", "int8", "int4", "int2")
QUANT_BITS = {"int8": 8, "int4": 4, "int2": 2}

for _c in CONFIGS.values():
    _c.validate()

"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: pytest (+hypothesis) asserts that
each Pallas kernel under ``interpret=True`` matches these references to
tight tolerances, and the Rust quantizer round-trips against the same
packing scheme (see ``rust/src/quant``).

All math is float32.  The quantization scheme is group-wise symmetric
round-to-nearest ("GPTQ storage format without Hessian compensation",
DESIGN.md §2): along the *input* (contraction) dimension of each weight
matrix, groups of ``group_size`` rows share one f32 scale per output
column.  Quantized values are stored *biased* (q + 2^(bits-1), i.e. in
[0, 2^bits - 1]) and packed little-endian into u32 words, ``32 // bits``
values per word.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Quantization (reference)
# ---------------------------------------------------------------------------

def quant_range(bits: int) -> tuple[int, int]:
    """Symmetric signed range for a given bit-width, e.g. 4 -> (-8, 7)."""
    half = 1 << (bits - 1)
    return -half, half - 1


def quantize_groupwise(w: jnp.ndarray, bits: int, group_size: int):
    """Quantize ``w[K, N]`` along K in groups of ``group_size``.

    Returns ``(q, scales)`` with ``q`` int32 *unbiased* values in the
    symmetric range and ``scales`` f32 of shape ``[K // group_size, N]``.
    """
    K, N = w.shape
    assert K % group_size == 0, (K, group_size)
    lo, hi = quant_range(bits)
    g = w.reshape(K // group_size, group_size, N)
    max_abs = jnp.max(jnp.abs(g), axis=1)                      # [K/G, N]
    scales = jnp.maximum(max_abs / hi, 1e-10)
    q = jnp.clip(jnp.round(g / scales[:, None, :]), lo, hi)
    return q.reshape(K, N).astype(jnp.int32), scales.astype(jnp.float32)


def dequantize_groupwise(q: jnp.ndarray, scales: jnp.ndarray, group_size: int):
    """Inverse of :func:`quantize_groupwise` (up to rounding error)."""
    K, N = q.shape
    g = q.reshape(K // group_size, group_size, N).astype(jnp.float32)
    return (g * scales[:, None, :]).reshape(K, N)


def pack_words(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack unbiased int values ``q[K, N]`` into u32 words ``[K*bits/32, N]``.

    Values are biased by ``2^(bits-1)`` then packed little-endian along K:
    element ``k = r*vpw + j`` occupies bits ``[bits*j, bits*(j+1))`` of
    word ``r`` (``vpw = 32 // bits``).
    """
    vpw = 32 // bits
    K, N = q.shape
    assert K % vpw == 0, (K, vpw)
    offset = 1 << (bits - 1)
    biased = (q + offset).astype(jnp.uint32)
    grouped = biased.reshape(K // vpw, vpw, N)
    word = jnp.zeros((K // vpw, N), dtype=jnp.uint32)
    for j in range(vpw):
        word = word | (grouped[:, j, :] << jnp.uint32(bits * j))
    return word


def unpack_words(words: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of :func:`pack_words`: u32 ``[R, N]`` -> unbiased int32 ``[R*vpw, N]``."""
    vpw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    offset = 1 << (bits - 1)
    R, N = words.shape
    parts = [
        ((words >> jnp.uint32(bits * j)) & mask).astype(jnp.int32) - offset
        for j in range(vpw)
    ]
    return jnp.stack(parts, axis=1).reshape(R * vpw, N)


def quantize_packed(w: jnp.ndarray, bits: int, group_size: int):
    """Full pipeline: f32 weights -> (packed u32 words, f32 scales)."""
    q, s = quantize_groupwise(w, bits, group_size)
    return pack_words(q, bits), s


def dequantize_packed(words: jnp.ndarray, scales: jnp.ndarray, bits: int,
                      group_size: int) -> jnp.ndarray:
    q = unpack_words(words, bits)
    return dequantize_groupwise(q, scales, group_size)


# ---------------------------------------------------------------------------
# Core ops (reference)
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Rotary embedding over ``x[T, H, hd]`` with integer ``positions[T]``."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(angles)[:, None, :]   # [T, 1, half]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def silu(x: jnp.ndarray) -> jnp.ndarray:
    return x * jax.nn.sigmoid(x)


def expert_ffn(x: jnp.ndarray, w1: jnp.ndarray, w3: jnp.ndarray,
               w2: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU expert: ``(silu(x@w1) * (x@w3)) @ w2`` over ``x[T, d]``."""
    return (silu(x @ w1) * (x @ w3)) @ w2


def expert_ffn_quant(x, w1q, w1s, w3q, w3s, w2q, w2s, bits: int,
                     group_size: int):
    """Quantized expert: dequantize packed weights then run the SwiGLU FFN."""
    w1 = dequantize_packed(w1q, w1s, bits, group_size)
    w3 = dequantize_packed(w3q, w3s, bits, group_size)
    w2 = dequantize_packed(w2q, w2s, bits, group_size)
    return expert_ffn(x, w1, w3, w2)


def gate_probs(x: jnp.ndarray, wg: jnp.ndarray) -> jnp.ndarray:
    """Router: softmax gate over experts.  ``x[T, d] @ wg[d, M]``."""
    return jax.nn.softmax(x @ wg, axis=-1)


def attention_prefill(h, seq_len, ln1, wq, wk, wv, wo,
                      n_heads: int, rope_theta: float = 10000.0,
                      rms_eps: float = 1e-5):
    """Causal self-attention over a (padded) prompt.

    Returns ``(attn_out[T, d], token_scores[T], k[T, H, hd], v[T, H, hd])``
    where ``token_scores`` is the Eq.-1 importance signal: the mean
    attention weight each *key* position receives, averaged over heads and
    valid query positions.  Positions >= seq_len are masked out.
    """
    T, d = h.shape
    hd = d // n_heads
    x = rms_norm(h, ln1, rms_eps)
    pos = jnp.arange(T)
    q = rope((x @ wq).reshape(T, n_heads, hd), pos, rope_theta)
    k = rope((x @ wk).reshape(T, n_heads, hd), pos, rope_theta)
    v = (x @ wv).reshape(T, n_heads, hd)

    logits = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(float(hd))
    causal = pos[None, :] <= pos[:, None]                    # [q, k]
    valid = pos < seq_len
    mask = causal[None] & valid[None, None, :] & valid[None, :, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(mask, probs, 0.0)

    out = jnp.einsum("hqk,khd->qhd", probs, v).reshape(T, d) @ wo
    out = jnp.where(valid[:, None], out, 0.0)

    # Eq. 1: s_i = mean over heads (and valid queries) of attention received.
    n_valid = jnp.maximum(seq_len, 1).astype(jnp.float32)
    scores = jnp.sum(probs, axis=(0, 1)) / (n_heads * n_valid)
    return out, scores, k, v


def attention_decode(h, k_cache, v_cache, pos, ln1, wq, wk, wv, wo,
                     n_heads: int, rope_theta: float = 10000.0,
                     rms_eps: float = 1e-5):
    """Single-token attention over a KV cache.

    ``h[1, d]``, caches ``[S, H, hd]``; ``pos`` is the index of the current
    token (cache rows ``< pos`` are valid history).  Returns
    ``(attn_out[1, d], k_new[H, hd], v_new[H, hd])``; the caller writes
    ``k_new/v_new`` into row ``pos``.
    """
    S = k_cache.shape[0]
    d = h.shape[-1]
    hd = d // n_heads
    x = rms_norm(h, ln1, rms_eps)
    p = jnp.asarray(pos, dtype=jnp.int32).reshape(1)
    q = rope((x @ wq).reshape(1, n_heads, hd), p, rope_theta)[0]   # [H, hd]
    k_new = rope((x @ wk).reshape(1, n_heads, hd), p, rope_theta)[0]
    v_new = (x @ wv).reshape(n_heads, hd)

    keys = jax.lax.dynamic_update_index_in_dim(k_cache, k_new, p[0], 0)
    vals = jax.lax.dynamic_update_index_in_dim(v_cache, v_new, p[0], 0)
    logits = jnp.einsum("hd,khd->hk", q, keys) / jnp.sqrt(float(hd))
    valid = jnp.arange(S) <= p[0]
    logits = jnp.where(valid[None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = (jnp.einsum("hk,khd->hd", probs, vals).reshape(1, d)) @ wo
    return out, k_new, v_new


def np_rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)

"""L1 Pallas kernel: the MoE gate (router).

Computes softmax gate probabilities ``softmax(rmsnorm(h) @ Wg)`` for a
block of tokens.  The same kernel doubles as the paper's Eq.-6 look-ahead
predictor: feeding layer-l hidden states through layer-(l+1)'s gate weight
approximates the next layer's routing distribution (the ``gate_probe``
artifact in aot.py).

Top-k selection and renormalization are done by the L3 coordinator (M is
at most a few dozen; sorting on the host is cheaper than a TPU sort and
the indices drive host-side cache/transfer decisions anyway).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(x_ref, ln_ref, wg_ref, o_ref, *, eps: float):
    x = x_ref[...]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    xn = x * jax.lax.rsqrt(var + eps) * ln_ref[...]
    logits = xn @ wg_ref[...]
    o_ref[...] = jax.nn.softmax(logits, axis=-1)


@functools.partial(jax.jit, static_argnames=("eps",))
def gate(x, ln, wg, *, eps: float = 1e-5):
    """Gate probabilities: ``x[T, d], ln[d], wg[d, M] -> probs[T, M]``."""
    T, d = x.shape
    M = wg.shape[1]
    return pl.pallas_call(
        functools.partial(_gate_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((T, M), jnp.float32),
        interpret=True,
    )(x, ln, wg)

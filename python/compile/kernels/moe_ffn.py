"""L1 Pallas kernel: the expert-FFN hot-spot (dense and group-quantized).

This is the paper's compute core: one expert's SwiGLU FFN applied to the
tokens routed to it, with the weights arriving either dense (bf16 tier) or
as packed u32 words + group scales (int8/int4/int2 tiers).  Dequantization
happens *inside* the kernel so the HLO input — and therefore the simulated
host->device transfer in L3 — is the packed representation.

TPU mapping (DESIGN.md §3):

* grid is 1-D over FFN column tiles: each step stages ``x`` (resident),
  a ``[d, BF]`` column slice of w1/w3 and the matching ``[BF, d]`` row
  slice of w2 from HBM into VMEM via BlockSpec;
* the unpack (shift/mask, 32/bits static steps) runs on the VPU, the two
  ``[T,d]x[d,BF]`` matmuls and the ``[T,BF]x[BF,d]`` matmul hit the MXU;
* the output ref accumulates across grid steps (revisited block), which is
  the standard Pallas reduction idiom — no barrier between column tiles.

VMEM budget at mixtral-mini scale (d=256, BF=256, T=96, int4):
x 96*256*4 = 96 KiB, w1q+w3q 2*(32*256*4) = 64 KiB, w2q 32*256*4 = 32 KiB,
scales ~3*8*256*4 = 24 KiB, activations 2*96*256*4 = 192 KiB, out 96 KiB
=> ~0.5 MiB, comfortably inside the ~16 MiB VMEM of a TPU core; the same
shapes at paper scale (d=4096, ffn=14336, BF=512) stay under 13 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import dequant_values


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _ffn_dense_kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    j = pl.program_id(0)
    x = x_ref[...]
    a = _silu(x @ w1_ref[...]) * (x @ w3_ref[...])
    partial = a @ w2_ref[...]

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial


def _ffn_quant_kernel(x_ref, w1q_ref, w1s_ref, w3q_ref, w3s_ref,
                      w2q_ref, w2s_ref, o_ref, *, bits: int, group_size: int):
    j = pl.program_id(0)
    x = x_ref[...]
    w1 = dequant_values(w1q_ref[...], w1s_ref[...], bits, group_size)
    w3 = dequant_values(w3q_ref[...], w3s_ref[...], bits, group_size)
    a = _silu(x @ w1) * (x @ w3)
    w2 = dequant_values(w2q_ref[...], w2s_ref[...], bits, group_size)
    partial = a @ w2

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial


def _ffn_tile(d_ffn: int) -> int:
    """FFN column-tile width; one tile if the expert is narrow."""
    return min(d_ffn, 256)


@functools.partial(jax.jit, static_argnames=())
def expert_ffn_dense(x, w1, w3, w2):
    """Dense SwiGLU expert FFN: ``x[T,d] -> y[T,d]`` (bf16 tier)."""
    T, d = x.shape
    ffn = w1.shape[1]
    bf = _ffn_tile(ffn)
    grid = (ffn // bf,)
    return pl.pallas_call(
        _ffn_dense_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),
            pl.BlockSpec((d, bf), lambda j: (0, j)),
            pl.BlockSpec((d, bf), lambda j: (0, j)),
            pl.BlockSpec((bf, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((T, d), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=True,
    )(x, w1, w3, w2)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def expert_ffn_quant(x, w1q, w1s, w3q, w3s, w2q, w2s, *, bits: int,
                     group_size: int):
    """Group-quantized SwiGLU expert FFN.

    ``x[T, d]``; ``w1q/w3q: u32[d*bits/32, ffn]`` with scales
    ``f32[d/G, ffn]``; ``w2q: u32[ffn*bits/32, d]`` with scales
    ``f32[ffn/G, d]``.  Returns ``y[T, d]`` f32.
    """
    T, d = x.shape
    ffn = w1q.shape[1]
    vpw = 32 // bits
    bf = _ffn_tile(ffn)
    assert bf % vpw == 0 and bf % group_size == 0, (bf, vpw, group_size)
    grid = (ffn // bf,)
    dq = d // vpw          # packed rows of w1/w3
    dg = d // group_size   # scale rows of w1/w3
    return pl.pallas_call(
        functools.partial(_ffn_quant_kernel, bits=bits,
                          group_size=group_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),
            pl.BlockSpec((dq, bf), lambda j: (0, j)),
            pl.BlockSpec((dg, bf), lambda j: (0, j)),
            pl.BlockSpec((dq, bf), lambda j: (0, j)),
            pl.BlockSpec((dg, bf), lambda j: (0, j)),
            pl.BlockSpec((bf // vpw, d), lambda j: (j, 0)),
            pl.BlockSpec((bf // group_size, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((T, d), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), jnp.float32),
        interpret=True,
    )(x, w1q, w1s, w3q, w3s, w2q, w2s)

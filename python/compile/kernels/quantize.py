"""L1 Pallas kernels: group-wise quantization pack / unpack.

TPU mapping (DESIGN.md §3): quantization is a VPU-only job — reshape to
``(groups, group_size, N)`` sublanes, max-reduce for scales, then shift/or
into u32 lanes.  Packing 8x int4 / 16x int2 per u32 lane is exactly what
makes the HBM->VMEM (and in the paper's system, host->device) transfer
volume proportional to bits-per-weight.

All kernels run ``interpret=True`` (see /opt/xla-example/README.md): real
TPU lowering emits Mosaic custom-calls the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _quantize_kernel(w_ref, words_ref, scales_ref, *, bits: int,
                     group_size: int):
    w = w_ref[...]
    K, N = w.shape
    lo, hi = ref.quant_range(bits)
    g = w.reshape(K // group_size, group_size, N)
    scales = jnp.maximum(jnp.max(jnp.abs(g), axis=1) / hi, 1e-10)
    q = jnp.clip(jnp.round(g / scales[:, None, :]), lo, hi).astype(jnp.int32)
    q = q.reshape(K, N)
    scales_ref[...] = scales.astype(jnp.float32)

    vpw = 32 // bits
    offset = 1 << (bits - 1)
    biased = (q + offset).astype(jnp.uint32).reshape(K // vpw, vpw, N)
    word = jnp.zeros((K // vpw, N), dtype=jnp.uint32)
    for j in range(vpw):
        word = word | (biased[:, j, :] << jnp.uint32(bits * j))
    words_ref[...] = word


def _dequantize_kernel(words_ref, scales_ref, w_ref, *, bits: int,
                       group_size: int):
    w_ref[...] = dequant_values(words_ref[...], scales_ref[...], bits,
                                group_size)


def dequant_values(words: jnp.ndarray, scales: jnp.ndarray, bits: int,
                   group_size: int) -> jnp.ndarray:
    """Unpack + rescale on *loaded values* — shared by the FFN kernels.

    This is the in-kernel dequant path: a static ``32/bits``-step shift/mask
    loop on the VPU producing the f32 tile the MXU consumes.
    """
    vpw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    offset = 1 << (bits - 1)
    R, N = words.shape
    parts = [
        ((words >> jnp.uint32(bits * j)) & mask).astype(jnp.int32) - offset
        for j in range(vpw)
    ]
    q = jnp.stack(parts, axis=1).reshape(R * vpw, N).astype(jnp.float32)
    K = R * vpw
    g = q.reshape(K // group_size, group_size, N)
    return (g * scales[:, None, :]).reshape(K, N)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def quantize(w: jnp.ndarray, bits: int, group_size: int):
    """Pallas group-wise quantize: ``w[K, N]`` -> ``(u32[K*bits/32, N], f32[K/G, N])``."""
    K, N = w.shape
    vpw = 32 // bits
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits, group_size=group_size),
        out_shape=(
            jax.ShapeDtypeStruct((K // vpw, N), jnp.uint32),
            jax.ShapeDtypeStruct((K // group_size, N), jnp.float32),
        ),
        interpret=True,
    )(w)


@functools.partial(jax.jit, static_argnames=("bits", "group_size"))
def dequantize(words: jnp.ndarray, scales: jnp.ndarray, bits: int,
               group_size: int):
    """Pallas unpack+rescale: inverse storage transform of :func:`quantize`."""
    R, N = words.shape
    K = R * (32 // bits)
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, bits=bits,
                          group_size=group_size),
        out_shape=jax.ShapeDtypeStruct((K, N), jnp.float32),
        interpret=True,
    )(words, scales)

"""L1 Pallas kernels: causal attention for prefill and decode.

Besides the attention output, the prefill kernel emits the paper's Eq.-1
token-importance signal: the mean attention weight each key position
receives, averaged over heads and valid query rows.  L3 uses it to rank
heavy-hitter tokens for the prefill-phase expert-importance estimator.

The decode kernel attends a single query over a fixed-capacity KV cache
(rows ``< pos`` valid) plus the current token's fresh K/V, avoiding an
in-kernel dynamic cache update: L3 owns the cache and writes row ``pos``
itself from the returned ``k_new``/``v_new``.

TPU mapping: at mini scale the whole ``[H, T, T]`` score tensor fits in
VMEM (8*96*96*4 B = 288 KiB) so the kernel is single-block; at paper scale
this would become a flash-attention grid over KV tiles — the Eq.-1 score
accumulates per KV tile exactly like the softmax denominator, so the
importance signal survives the tiling.  Kernels run ``interpret=True``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_vals(x, positions, theta):
    """RoPE on loaded values: ``x[T, H, hd]`` with ``positions[T]``."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
    cos = jnp.cos(angles)[:, None, :]
    sin = jnp.sin(angles)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _prefill_kernel(h_ref, sl_ref, ln_ref, wq_ref, wk_ref, wv_ref, wo_ref,
                    out_ref, score_ref, k_ref, v_ref, *,
                    n_heads: int, theta: float, eps: float):
    h = h_ref[...]
    T, d = h.shape
    hd = d // n_heads
    seq_len = sl_ref[0]

    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    x = h * jax.lax.rsqrt(var + eps) * ln_ref[...]
    pos = jnp.arange(T, dtype=jnp.int32)
    q = _rope_vals((x @ wq_ref[...]).reshape(T, n_heads, hd), pos, theta)
    k = _rope_vals((x @ wk_ref[...]).reshape(T, n_heads, hd), pos, theta)
    v = (x @ wv_ref[...]).reshape(T, n_heads, hd)

    logits = jnp.einsum("qhd,khd->hqk", q, k) / jnp.sqrt(float(hd))
    causal = pos[None, :] <= pos[:, None]
    valid = pos < seq_len
    mask = causal[None] & valid[None, None, :] & valid[None, :, None]
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(mask, probs, 0.0)

    out = jnp.einsum("hqk,khd->qhd", probs, v).reshape(T, d) @ wo_ref[...]
    out_ref[...] = jnp.where(valid[:, None], out, 0.0)

    n_valid = jnp.maximum(seq_len, 1).astype(jnp.float32)
    score_ref[...] = jnp.sum(probs, axis=(0, 1)) / (n_heads * n_valid)
    k_ref[...] = k
    v_ref[...] = v


def _decode_kernel(h_ref, kc_ref, vc_ref, pos_ref, ln_ref, wq_ref, wk_ref,
                   wv_ref, wo_ref, out_ref, kn_ref, vn_ref, *,
                   n_heads: int, theta: float, eps: float):
    h = h_ref[...]                       # [1, d]
    d = h.shape[-1]
    hd = d // n_heads
    pos = pos_ref[0]
    S = kc_ref.shape[0]

    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    x = h * jax.lax.rsqrt(var + eps) * ln_ref[...]
    p = jnp.full((1,), pos, dtype=jnp.int32)
    q = _rope_vals((x @ wq_ref[...]).reshape(1, n_heads, hd), p, theta)[0]
    k_new = _rope_vals((x @ wk_ref[...]).reshape(1, n_heads, hd), p, theta)[0]
    v_new = (x @ wv_ref[...]).reshape(n_heads, hd)

    scale = 1.0 / jnp.sqrt(float(hd))
    hist = jnp.einsum("hd,khd->hk", q, kc_ref[...]) * scale     # [H, S]
    self_logit = jnp.sum(q * k_new, axis=-1, keepdims=True) * scale  # [H, 1]
    valid = jnp.arange(S, dtype=jnp.int32) < pos
    hist = jnp.where(valid[None, :], hist, -1e30)
    logits = jnp.concatenate([hist, self_logit], axis=-1)       # [H, S+1]
    probs = jax.nn.softmax(logits, axis=-1)
    ctx = (jnp.einsum("hk,khd->hd", probs[:, :S], vc_ref[...])
           + probs[:, S:] * v_new)
    out_ref[...] = ctx.reshape(1, d) @ wo_ref[...]
    kn_ref[...] = k_new
    vn_ref[...] = v_new


@functools.partial(jax.jit, static_argnames=("n_heads", "theta", "eps"))
def attention_prefill(h, seq_len, ln, wq, wk, wv, wo, *, n_heads: int,
                      theta: float = 10000.0, eps: float = 1e-5):
    """Causal prefill attention.

    ``h[T, d]``, ``seq_len: i32[1]`` true prompt length (rest is padding).
    Returns ``(attn_out[T, d], token_scores[T], k[T, H, hd], v[T, H, hd])``.
    """
    T, d = h.shape
    hd = d // n_heads
    return pl.pallas_call(
        functools.partial(_prefill_kernel, n_heads=n_heads, theta=theta,
                          eps=eps),
        out_shape=(
            jax.ShapeDtypeStruct((T, d), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T, n_heads, hd), jnp.float32),
            jax.ShapeDtypeStruct((T, n_heads, hd), jnp.float32),
        ),
        interpret=True,
    )(h, seq_len.astype(jnp.int32), ln, wq, wk, wv, wo)


@functools.partial(jax.jit, static_argnames=("n_heads", "theta", "eps"))
def attention_decode(h, k_cache, v_cache, pos, ln, wq, wk, wv, wo, *,
                     n_heads: int, theta: float = 10000.0, eps: float = 1e-5):
    """Single-token decode attention over a KV cache.

    ``h[1, d]``, caches ``[S, H, hd]``, ``pos: i32[1]``.  Returns
    ``(attn_out[1, d], k_new[H, hd], v_new[H, hd])``.
    """
    d = h.shape[-1]
    hd = d // n_heads
    return pl.pallas_call(
        functools.partial(_decode_kernel, n_heads=n_heads, theta=theta,
                          eps=eps),
        out_shape=(
            jax.ShapeDtypeStruct((1, d), jnp.float32),
            jax.ShapeDtypeStruct((n_heads, hd), jnp.float32),
            jax.ShapeDtypeStruct((n_heads, hd), jnp.float32),
        ),
        interpret=True,
    )(h, k_cache, v_cache, pos.astype(jnp.int32), ln, wq, wk, wv, wo)

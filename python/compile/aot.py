"""AOT export: lower every serving piece to HLO *text* artifacts.

HLO text (NOT ``lowered.compiler_ir(...).serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Per model this writes into ``artifacts/<model>/``:

* ``<artifact>.hlo.txt``  — one module per (function, shape-bucket, precision)
* ``weights.bin``         — flat weight store (see quant.py)
* ``params.npz``          — trained f32 params (cache for re-exports)
* ``manifest.json``       — config + artifact I/O specs + weight sections

plus the shared ``artifacts/eval/suites.json`` eval benchmark and a
``artifacts/.stamp`` sentinel for the Makefile.

Usage: ``python -m compile.aot [--models mixtral-mini,qwen-mini,tiny]
[--out-dir ../artifacts] [--retrain]``
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np  # noqa: F401
from jax._src.lib import xla_client as xc

from . import corpus, model, quant, train
from .configs import CONFIGS, EXPERT_BUCKETS, QUANT_BITS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


_DT = {jnp.float32.dtype: "f32", jnp.int32.dtype: "i32",
       jnp.uint32.dtype: "u32"}


def _spec(name, shape, dtype=jnp.float32):
    return (name, jax.ShapeDtypeStruct(tuple(shape), dtype))


def artifact_defs(cfg: ModelConfig) -> dict:
    """name -> (fn, [(arg_name, ShapeDtypeStruct), ...])"""
    d, f, M = cfg.d_model, cfg.d_ffn, cfg.n_experts
    H, hd = cfg.n_heads, cfg.head_dim
    V, S, C, G = cfg.vocab, cfg.max_seq, cfg.max_cache, cfg.group_size

    defs = {}
    for t in (S, 1):
        defs[f"embed_t{t}"] = (
            model.embed,
            [_spec("tokens", [t], jnp.int32), _spec("emb", [V, d])])
        defs[f"gate_probe_t{t}"] = (
            functools.partial(model.gate_probe, cfg=cfg),
            [_spec("h", [t, d]), _spec("ln2", [d]), _spec("wg", [d, M])])
        defs[f"finalize_t{t}"] = (
            functools.partial(model.finalize, cfg=cfg),
            [_spec("h", [t, d]), _spec("ln_f", [d]), _spec("emb", [V, d])])

    attn_w = [_spec("ln1", [d]), _spec("wq", [d, d]), _spec("wk", [d, d]),
              _spec("wv", [d, d]), _spec("wo", [d, d]), _spec("ln2", [d]),
              _spec("wg", [d, M])]
    defs["attn_prefill"] = (
        functools.partial(model.attn_prefill, cfg=cfg),
        [_spec("h", [S, d]), _spec("seq_len", [1], jnp.int32)] + attn_w)
    defs["attn_decode"] = (
        functools.partial(model.attn_decode, cfg=cfg),
        [_spec("h", [1, d]), _spec("k_cache", [C, H, hd]),
         _spec("v_cache", [C, H, hd]), _spec("pos", [1], jnp.int32)] + attn_w)
    # Fused attention + next-layer gate probe (one exec instead of two).
    probe_w = [_spec("ln2n", [d]), _spec("wgn", [d, M])]
    defs["attn_prefill_probe"] = (
        functools.partial(model.attn_prefill_probe, cfg=cfg),
        [_spec("h", [S, d]), _spec("seq_len", [1], jnp.int32)]
        + attn_w + probe_w)
    defs["attn_decode_probe"] = (
        functools.partial(model.attn_decode_probe, cfg=cfg),
        [_spec("h", [1, d]), _spec("k_cache", [C, H, hd]),
         _spec("v_cache", [C, H, hd]), _spec("pos", [1], jnp.int32)]
        + attn_w + probe_w)

    for t in EXPERT_BUCKETS:
        if t > S:
            continue
        defs[f"expert_bf16_t{t}"] = (
            model.expert_ffn_dense,
            [_spec("x", [t, d]), _spec("w1", [d, f]), _spec("w3", [d, f]),
             _spec("w2", [f, d])])
        for prec, bits in QUANT_BITS.items():
            vpw = 32 // bits
            defs[f"expert_{prec}_t{t}"] = (
                functools.partial(model.expert_ffn_quant, bits=bits,
                                  group_size=G),
                [_spec("x", [t, d]),
                 _spec("w1q", [d // vpw, f], jnp.uint32),
                 _spec("w1s", [d // G, f]),
                 _spec("w3q", [d // vpw, f], jnp.uint32),
                 _spec("w3s", [d // G, f]),
                 _spec("w2q", [f // vpw, d], jnp.uint32),
                 _spec("w2s", [f // G, d])])
    return defs


def lower_artifact(fn, specs):
    lowered = jax.jit(fn).lower(*[s for _, s in specs])
    text = to_hlo_text(lowered)
    out_specs = jax.eval_shape(fn, *[s for _, s in specs])
    if not isinstance(out_specs, (tuple, list)):
        out_specs = (out_specs,)
    return text, out_specs


def export_model(cfg: ModelConfig, out_dir: str, retrain: bool,
                 verbose: bool = True) -> None:
    mdir = os.path.join(out_dir, cfg.name)
    os.makedirs(mdir, exist_ok=True)

    params_path = os.path.join(mdir, "params.npz")
    if os.path.exists(params_path) and not retrain:
        params = train.load_params(params_path, cfg)
        if verbose:
            print(f"[aot] {cfg.name}: loaded cached params", flush=True)
    else:
        params, history = train.train(cfg, verbose=verbose)
        train.save_params(params_path, params)
        with open(os.path.join(mdir, "train_loss.json"), "w") as fh:
            json.dump(history, fh)

    writer = quant.build_weight_store(cfg, params)
    writer.write(os.path.join(mdir, "weights.bin"))

    manifest = {
        "model": cfg.to_dict(),
        "expert_buckets": [t for t in EXPERT_BUCKETS if t <= cfg.max_seq],
        "weights_file": "weights.bin",
        "expert_bytes": quant.expert_logical_bytes(cfg),
        "sections": writer.sections,
        "artifacts": {},
    }
    t0 = time.time()
    for name, (fn, specs) in artifact_defs(cfg).items():
        text, out_specs = lower_artifact(fn, specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(mdir, fname), "w") as fh:
            fh.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [{"name": n, "dtype": _DT[s.dtype], "shape": list(s.shape)}
                       for n, s in specs],
            "outputs": [{"dtype": _DT[s.dtype], "shape": list(s.shape)}
                        for s in out_specs],
        }
    with open(os.path.join(mdir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)

    # Golden end-to-end numerics: a fixed prompt's full-forward logits at
    # the last position, checked by the Rust integration tests against the
    # engine's BF16 serving path.
    rng = np.random.default_rng(123)
    prompt = [1] + list(rng.integers(2, cfg.vocab, size=min(11, cfg.max_seq - 1)))
    logits = model.forward_full(
        params, jnp.asarray(prompt, jnp.int32), cfg)
    golden = {
        "prompt": [int(t) for t in prompt],
        "last_logits": [float(x) for x in np.asarray(logits)[-1]],
    }
    with open(os.path.join(mdir, "golden.json"), "w") as fh:
        json.dump(golden, fh)
    if verbose:
        n = len(manifest["artifacts"])
        print(f"[aot] {cfg.name}: {n} artifacts lowered in "
              f"{time.time()-t0:.1f}s", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="mixtral-mini,qwen-mini,tiny")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--out", default=None, help="stamp file (Makefile)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    for name in args.models.split(","):
        export_model(CONFIGS[name.strip()], args.out_dir, args.retrain)

    eval_dir = os.path.join(args.out_dir, "eval")
    os.makedirs(eval_dir, exist_ok=True)
    suites = corpus.build_suites(seed=7, n_items=60, max_prompt=80)
    corpus.dump_suites(os.path.join(eval_dir, "suites.json"), suites)

    stamp = args.out or os.path.join(args.out_dir, ".stamp")
    with open(stamp, "w") as fh:
        fh.write(f"built {time.time()}\n")
    print(f"[aot] done -> {args.out_dir}", flush=True)


if __name__ == "__main__":
    main()

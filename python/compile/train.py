"""Build-time training run: give the mini-MoE models real structure.

Random weights would make every accuracy experiment degenerate (routing
uniform, quantization insensitive in task terms).  A few hundred Adam
steps on the synthetic pattern corpus are enough for (a) expert
specialisation => skewed, input-dependent gate distributions (paper §3.1),
(b) non-trivial depth sensitivity (§3.2), and (c) meaningful eval-suite
accuracy that degrades under aggressive quantization (Tables 1-2).

Runs ONCE inside ``make artifacts`` (cached in ``artifacts/``); never on
the request path.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .configs import ModelConfig

TRAIN_DEFAULTS = {
    "mixtral-mini": dict(steps=280, batch=6, length=64, lr=3e-3),
    "qwen-mini": dict(steps=280, batch=6, length=64, lr=3e-3),
    "tiny": dict(steps=30, batch=4, length=16, lr=3e-3),
}


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.99, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train(cfg: ModelConfig, seed: int = 0, steps: int | None = None,
          batch: int | None = None, length: int | None = None,
          lr: float | None = None, log_every: int = 20, verbose: bool = True):
    """Train ``cfg`` on the pattern corpus; returns (params, loss_history)."""
    defaults = TRAIN_DEFAULTS.get(cfg.name, TRAIN_DEFAULTS["tiny"])
    steps = steps or defaults["steps"]
    batch = batch or defaults["batch"]
    length = length or defaults["length"]
    base_lr = lr or defaults["lr"]

    params = model.init_params(cfg, seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens, lr):
        (loss, nll), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, tokens, cfg)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss, nll

    history = []
    gen = corpus.batches(seed + 1, batch, length)
    t0 = time.time()
    for i in range(steps):
        tokens = jnp.asarray(next(gen))
        # cosine LR decay with short warmup
        warm = min(1.0, (i + 1) / 20)
        lr_i = base_lr * warm * 0.5 * (1 + np.cos(np.pi * i / steps))
        params, opt, loss, nll = step_fn(params, opt, tokens,
                                         jnp.float32(lr_i))
        history.append(float(nll))
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"[train {cfg.name}] step {i:4d}  nll={float(nll):.4f}  "
                  f"loss={float(loss):.4f}  ({time.time()-t0:.1f}s)",
                  flush=True)
    return params, history


def save_params(path: str, params: dict) -> None:
    flat = {"emb": np.asarray(params["emb"]),
            "ln_f": np.asarray(params["ln_f"])}
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            flat[f"L{i}.{k}"] = np.asarray(v)
    np.savez(path, **flat)


def load_params(path: str, cfg: ModelConfig) -> dict:
    data = np.load(path)
    layers = []
    for i in range(cfg.n_layers):
        layers.append({k: jnp.asarray(data[f"L{i}.{k}"])
                       for k in ("ln1", "wq", "wk", "wv", "wo", "ln2",
                                 "wg", "w1", "w3", "w2")})
    return {"emb": jnp.asarray(data["emb"]),
            "ln_f": jnp.asarray(data["ln_f"]), "layers": layers}

"""Weight-store builder: quantize trained experts, write ``weights.bin``.

The Rust runtime never sees a Python object: it streams *sections* of one
flat binary file (the simulated SSD / host-memory tier) described by the
manifest.  Expert weights exist in four precision tiers:

* ``bf16``  — full-precision tier (stored f32 on disk for CPU numerics;
  accounted 2 bytes/param for I/O, like the paper's BF16 tier);
* ``int8 / int4 / int2`` — group-wise RTN (kernels/ref.py scheme): packed
  u32 words + f32 group scales.  The *packed* bytes are what cross the
  simulated PCIe bus, so I/O volume scales with bits-per-weight exactly as
  in the paper.
"""

import numpy as np

from .configs import ModelConfig, QUANT_BITS
from .kernels import ref


def _np(a) -> np.ndarray:
    return np.ascontiguousarray(np.asarray(a))


class SectionWriter:
    """Accumulates named arrays into one flat little-endian blob."""

    def __init__(self):
        self.sections: dict[str, dict] = {}
        self.chunks: list[bytes] = []
        self.offset = 0

    def add(self, name: str, arr: np.ndarray) -> None:
        assert name not in self.sections, name
        dt = {"float32": "f32", "uint32": "u32", "int32": "i32"}[str(arr.dtype)]
        raw = _np(arr).tobytes()
        self.sections[name] = {
            "dtype": dt,
            "shape": list(arr.shape),
            "offset": self.offset,
            "nbytes": len(raw),
        }
        self.chunks.append(raw)
        self.offset += len(raw)

    def write(self, path: str) -> None:
        with open(path, "wb") as f:
            for c in self.chunks:
                f.write(c)


def quantize_matrix(w: np.ndarray, bits: int, group_size: int):
    """Group-RTN pack via the reference scheme; returns (words u32, scales f32)."""
    words, scales = ref.quantize_packed(np.asarray(w, np.float32), bits,
                                        group_size)
    return _np(words).astype(np.uint32), _np(scales).astype(np.float32)


def expert_logical_bytes(cfg: ModelConfig) -> dict:
    """Transfer bytes per expert per precision tier (the I/O-volume model)."""
    d, f, G = cfg.d_model, cfg.d_ffn, cfg.group_size
    n_params = 3 * d * f
    out = {"bf16": 2 * n_params}
    for prec, bits in QUANT_BITS.items():
        packed = n_params * bits // 8
        scales = ((d // G) * f * 2 + (f // G) * d) * 4
        out[prec] = packed + scales
    return out


def build_weight_store(cfg: ModelConfig, params: dict) -> SectionWriter:
    """Write every tier of every tensor into a SectionWriter."""
    w = SectionWriter()
    w.add("emb", _np(params["emb"]))
    w.add("ln_f", _np(params["ln_f"]))
    for l, layer in enumerate(params["layers"]):
        p = f"L{l}"
        for key in ("ln1", "wq", "wk", "wv", "wo", "ln2", "wg"):
            w.add(f"{p}.{key}", _np(layer[key]))
        for e in range(cfg.n_experts):
            for mat in ("w1", "w3", "w2"):
                full = _np(layer[mat][e])        # [K, N]
                base = f"{p}.E{e}.{mat}"
                w.add(f"{base}.bf16", full)
                for prec, bits in QUANT_BITS.items():
                    words, scales = quantize_matrix(full, bits,
                                                    cfg.group_size)
                    w.add(f"{base}.{prec}.q", words)
                    w.add(f"{base}.{prec}.s", scales)
    return w

"""Synthetic structured corpus: a mixture of pattern languages.

Stands in for the paper's natural-language corpora (DESIGN.md §2).  Each
sequence starts with a *domain tag* token and is drawn from one of several
pattern languages; this drives (a) real expert specialisation during the
build-time training run (so gate distributions are skewed, §3.1 of the
paper), and (b) heavy-hitter token structure (tags / delimiters attract
attention mass).

Token space (vocab = 64)::

    0          PAD
    1          BOS
    2..9       domain tags (one per domain, some reserved)
    10         delimiter '|'
    11..20     digits 0-9
    21..26     brackets ( ) [ ] { }
    27..63     letter pool

Deterministic *eval suites* with known answers stand in for the paper's
MMLU / CMMLU / GSM8K benchmarks:

* ``suite_copy``  (MMLU stand-in)  — repeat a segment after '|';
* ``suite_arith`` (GSM8K stand-in) — continue a (+step mod 10) digit chain;
* ``suite_sort``  (CMMLU stand-in) — emit the sorted version of a segment.

Greedy exact-match on the answer tokens is the "accuracy" metric.
"""

import json
from dataclasses import dataclass

import numpy as np

PAD, BOS, DELIM = 0, 1, 10
TAG_COPY, TAG_ARITH, TAG_SORT, TAG_REPEAT, TAG_MARKOV_A, TAG_MARKOV_B, \
    TAG_SUCC = 2, 3, 4, 5, 6, 7, 8
DIGIT0 = 11          # digits are tokens 11..20
LETTER0, LETTER1 = 27, 63
# Smaller ring for the repeat/succ tasks keeps them in the learnable band
# for a build-time training budget of a few hundred steps.
RING0, RING_N = 27, 16
VOCAB = 64

DOMAINS = ("copy", "arith", "sort", "repeat", "succ", "markov_a", "markov_b")


def _letters(rng: np.random.Generator, n: int) -> np.ndarray:
    return rng.integers(LETTER0, LETTER1 + 1, size=n)


def _seq_copy(rng, total: int) -> np.ndarray:
    seg = _letters(rng, max(2, total // 2 - 1))
    body = np.concatenate([[TAG_COPY], seg, [DELIM], seg])
    return body[:total]


def _seq_arith(rng, total: int) -> np.ndarray:
    start = int(rng.integers(0, 10))
    step = int(rng.integers(1, 4))
    digits = [(start + i * step) % 10 + DIGIT0 for i in range(total - 1)]
    return np.asarray([TAG_ARITH] + digits)[:total]


def _seq_sort(rng, total: int) -> np.ndarray:
    seg = _letters(rng, max(2, total // 2 - 1))
    body = np.concatenate([[TAG_SORT], seg, [DELIM], np.sort(seg)])
    return body[:total]


def _seq_repeat(rng, total: int) -> np.ndarray:
    """A short motif repeated: 'abcabcabc...'."""
    period = int(rng.integers(1, 5))
    motif = rng.integers(RING0, RING0 + RING_N, size=period)
    body = [TAG_REPEAT] + [int(motif[i % period]) for i in range(total - 1)]
    return np.asarray(body)[:total]


def _seq_succ(rng, total: int) -> np.ndarray:
    """Letter-successor chain over a 16-symbol ring (like arith, letters)."""
    start = int(rng.integers(0, RING_N))
    step = int(rng.integers(1, 4))
    body = [TAG_SUCC] + [RING0 + (start + i * step) % RING_N
                         for i in range(total - 1)]
    return np.asarray(body)[:total]


_MARKOV_CACHE: dict = {}


def _markov_matrix(tag: int) -> np.ndarray:
    """A fixed, sparse-ish stochastic matrix over the letter pool per tag."""
    if tag not in _MARKOV_CACHE:
        n = LETTER1 - LETTER0 + 1
        rng = np.random.default_rng(1000 + tag)
        m = rng.dirichlet(np.full(n, 0.05), size=n)
        _MARKOV_CACHE[tag] = m
    return _MARKOV_CACHE[tag]


def _seq_markov(rng, total: int, tag: int) -> np.ndarray:
    m = _markov_matrix(tag)
    n = m.shape[0]
    out = [tag]
    s = int(rng.integers(0, n))
    for _ in range(total - 1):
        out.append(LETTER0 + s)
        s = int(rng.choice(n, p=m[s]))
    return np.asarray(out[:total])


_GEN = {
    "copy": _seq_copy,
    "arith": _seq_arith,
    "sort": _seq_sort,
    "repeat": _seq_repeat,
    "succ": _seq_succ,
    "markov_a": lambda rng, t: _seq_markov(rng, t, TAG_MARKOV_A),
    "markov_b": lambda rng, t: _seq_markov(rng, t, TAG_MARKOV_B),
}


def sample_sequence(rng: np.random.Generator, length: int) -> np.ndarray:
    """One training sequence: BOS + tagged pattern body, exactly ``length``."""
    dom = DOMAINS[int(rng.integers(0, len(DOMAINS)))]
    body = _GEN[dom](rng, length - 1)
    seq = np.concatenate([[BOS], body])
    if len(seq) < length:
        seq = np.concatenate([seq, np.full(length - len(seq), PAD)])
    return seq[:length].astype(np.int32)


def batches(seed: int, batch: int, length: int):
    """Infinite iterator of ``i32[batch, length]`` training batches."""
    rng = np.random.default_rng(seed)
    while True:
        yield np.stack([sample_sequence(rng, length) for _ in range(batch)])


# ---------------------------------------------------------------------------
# Eval suites
# ---------------------------------------------------------------------------

@dataclass
class EvalItem:
    prompt: list          # i32 tokens, starts with BOS
    answer: list          # i32 tokens to be produced greedily


def _make_item_copy(rng, seg_len: int, ans_len: int) -> EvalItem:
    seg = _letters(rng, seg_len).tolist()
    keep = seg_len - ans_len
    prompt = [BOS, TAG_COPY] + seg + [DELIM] + seg[:keep]
    return EvalItem(prompt=prompt, answer=seg[keep:])


def _make_item_arith(rng, pre_len: int, ans_len: int) -> EvalItem:
    start = int(rng.integers(0, 10))
    step = int(rng.integers(1, 4))
    digits = [(start + i * step) % 10 + DIGIT0 for i in range(pre_len + ans_len)]
    return EvalItem(prompt=[BOS, TAG_ARITH] + digits[:pre_len],
                    answer=digits[pre_len:])


def _make_item_repeat(rng, pre_len: int, ans_len: int) -> EvalItem:
    period = int(rng.integers(1, 5))
    motif = rng.integers(RING0, RING0 + RING_N, size=period)
    total = pre_len + ans_len
    body = [int(motif[i % period]) for i in range(total)]
    return EvalItem(prompt=[BOS, TAG_REPEAT] + body[:pre_len],
                    answer=body[pre_len:])


def _make_item_succ(rng, pre_len: int, ans_len: int) -> EvalItem:
    start = int(rng.integers(0, RING_N))
    step = int(rng.integers(1, 4))
    chain = [RING0 + (start + i * step) % RING_N
             for i in range(pre_len + ans_len)]
    return EvalItem(prompt=[BOS, TAG_SUCC] + chain[:pre_len],
                    answer=chain[pre_len:])


def build_suites(seed: int, n_items: int, max_prompt: int) -> dict:
    """Three deterministic eval suites keyed by name.

    Difficulty spans the learnable band of the build-time training run:
    ``suite_repeat`` (easy periodic structure; MMLU stand-in),
    ``suite_succ`` (letter-successor ring; CMMLU stand-in),
    ``suite_arith`` (digit chains; GSM8K stand-in).
    """
    rng = np.random.default_rng(seed)
    suites = {"suite_repeat": [], "suite_arith": [], "suite_succ": []}
    for _ in range(n_items):
        ans = int(rng.integers(2, 5))
        pre = int(rng.integers(10, min(40, max_prompt - 6)))
        suites["suite_repeat"].append(_make_item_repeat(rng, pre, ans))
        suites["suite_arith"].append(
            _make_item_arith(rng, int(rng.integers(8, 24)), ans))
        suites["suite_succ"].append(_make_item_succ(rng, pre, ans))
    return suites


def dump_suites(path: str, suites: dict) -> None:
    payload = {
        name: [{"prompt": it.prompt, "answer": it.answer} for it in items]
        for name, items in suites.items()
    }
    with open(path, "w") as f:
        json.dump(payload, f)

"""L2 JAX model: the mini-MoE transformer, composed from the L1 kernels.

Two families of entry points:

* **Serving pieces** (exported to HLO by ``aot.py``, driven step-by-step by
  the Rust coordinator, which owns routing, expert dispatch and the KV
  cache — that's the whole point of an offloading system):

  - ``embed``           tokens -> hidden states
  - ``attn_prefill``    one layer's attention + gate for a padded prompt
  - ``attn_decode``     one layer's attention + gate for a single token
  - ``gate_probe``      Eq.-6 look-ahead gate predictor for layer l+1
  - ``expert_ffn_*``    one expert applied to a token bucket (see kernels)
  - ``finalize``        final norm + tied unembedding -> logits

* **Full-model reference** (``forward_full``) used for training
  (``train.py``) and as the end-to-end numerics oracle in tests.  It uses
  the *same* reference math (``kernels.ref``) the Pallas kernels are tested
  against, so Rust-driven serving and Python training agree.

Parameter pytree layout (all f32)::

    params = {
      "emb":  [V, d],
      "ln_f": [d],
      "layers": [  # one dict per layer
        { "ln1": [d], "wq|wk|wv|wo": [d, d],
          "ln2": [d], "wg": [d, M],
          "w1": [M, d, ffn], "w3": [M, d, ffn], "w2": [M, ffn, d] }
      ]
    }
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import attention as attn_k
from .kernels import moe_ffn as ffn_k
from .kernels import ref
from .kernels import router as router_k


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)

    def mat(*shape, scale=None):
        scale = scale if scale is not None else 1.0 / np.sqrt(shape[-2])
        return jnp.asarray(
            rng.normal(0.0, scale, size=shape).astype(np.float32))

    d, f, M = cfg.d_model, cfg.d_ffn, cfg.n_experts
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": mat(d, d), "wk": mat(d, d), "wv": mat(d, d), "wo": mat(d, d),
            "ln2": jnp.ones((d,), jnp.float32),
            "wg": mat(d, M, scale=0.02),
            "w1": mat(M, d, f), "w3": mat(M, d, f),
            "w2": mat(M, f, d, scale=1.0 / np.sqrt(f)),
        })
    return {
        "emb": mat(cfg.vocab, d, scale=0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": layers,
    }


# ---------------------------------------------------------------------------
# Serving pieces (each is lowered to one HLO artifact per shape variant)
# ---------------------------------------------------------------------------

def embed(tokens, emb):
    """``tokens: i32[T], emb: f32[V, d] -> h: f32[T, d]``."""
    return jnp.take(emb, tokens, axis=0)


def attn_prefill(h, seq_len, ln1, wq, wk, wv, wo, ln2, wg, *, cfg: ModelConfig):
    """One layer's attention half for a padded prompt.

    Returns ``(h_resid[T,d], moe_in[T,d], gate_probs[T,M], token_scores[T],
    k[T,H,hd], v[T,H,hd])``.  The Rust side routes ``moe_in`` rows through
    experts and accumulates weighted expert outputs onto ``h_resid``.
    """
    out, scores, k, v = attn_k.attention_prefill(
        h, seq_len, ln1, wq, wk, wv, wo,
        n_heads=cfg.n_heads, theta=cfg.rope_theta, eps=cfg.rms_eps)
    h_resid = h + out
    moe_in = ref.rms_norm(h_resid, ln2, cfg.rms_eps)
    probs = router_k.gate(h_resid, ln2, wg, eps=cfg.rms_eps)
    return h_resid, moe_in, probs, scores, k, v


def attn_decode(h, k_cache, v_cache, pos, ln1, wq, wk, wv, wo, ln2, wg, *,
                cfg: ModelConfig):
    """One layer's attention half for a single decode token.

    Returns ``(h_resid[1,d], moe_in[1,d], gate_probs[1,M],
    k_new[H,hd], v_new[H,hd])``.
    """
    out, k_new, v_new = attn_k.attention_decode(
        h, k_cache, v_cache, pos, ln1, wq, wk, wv, wo,
        n_heads=cfg.n_heads, theta=cfg.rope_theta, eps=cfg.rms_eps)
    h_resid = h + out
    moe_in = ref.rms_norm(h_resid, ln2, cfg.rms_eps)
    probs = router_k.gate(h_resid, ln2, wg, eps=cfg.rms_eps)
    return h_resid, moe_in, probs, k_new, v_new


def gate_probe(h_resid, ln2_next, wg_next, *, cfg: ModelConfig):
    """Eq. 6: approximate layer-(l+1) gate probabilities from layer-l state."""
    return router_k.gate(h_resid, ln2_next, wg_next, eps=cfg.rms_eps)


def attn_prefill_probe(h, seq_len, ln1, wq, wk, wv, wo, ln2, wg, ln2n, wgn,
                       *, cfg: ModelConfig):
    """Fused prefill attention + Eq.-6 look-ahead probe for layer l+1.

    One artifact execution instead of two (perf pass, EXPERIMENTS.md
    §Perf): the probe's matmul fuses into the same XLA program.  Extra
    inputs are the *next* layer's ``ln2``/``wg``.
    """
    h_resid, moe_in, probs, scores, k, v = attn_prefill(
        h, seq_len, ln1, wq, wk, wv, wo, ln2, wg, cfg=cfg)
    probe = router_k.gate(h_resid, ln2n, wgn, eps=cfg.rms_eps)
    return h_resid, moe_in, probs, scores, k, v, probe


def attn_decode_probe(h, k_cache, v_cache, pos, ln1, wq, wk, wv, wo, ln2, wg,
                      ln2n, wgn, *, cfg: ModelConfig):
    """Fused decode attention + Eq.-6 look-ahead probe for layer l+1."""
    h_resid, moe_in, probs, k_new, v_new = attn_decode(
        h, k_cache, v_cache, pos, ln1, wq, wk, wv, wo, ln2, wg, cfg=cfg)
    probe = router_k.gate(h_resid, ln2n, wgn, eps=cfg.rms_eps)
    return h_resid, moe_in, probs, k_new, v_new, probe


def expert_ffn_dense(x, w1, w3, w2):
    """bf16-tier expert FFN over a token bucket (see kernels.moe_ffn)."""
    return ffn_k.expert_ffn_dense(x, w1, w3, w2)


def expert_ffn_quant(x, w1q, w1s, w3q, w3s, w2q, w2s, *, bits, group_size):
    """Quantized-tier expert FFN over a token bucket (see kernels.moe_ffn)."""
    return ffn_k.expert_ffn_quant(x, w1q, w1s, w3q, w3s, w2q, w2s,
                                  bits=bits, group_size=group_size)


def finalize(h, ln_f, emb, *, cfg: ModelConfig):
    """Final RMSNorm + tied unembedding: ``h[T, d] -> logits[T, V]``."""
    return ref.rms_norm(h, ln_f, cfg.rms_eps) @ emb.T


# ---------------------------------------------------------------------------
# Full-model reference (training + end-to-end oracle)
# ---------------------------------------------------------------------------

def topk_mask(probs: jnp.ndarray, k: int):
    """Top-k routing weights, renormalized over the selected experts.

    ``probs[..., M] -> weights[..., M]`` with exactly k non-zeros per row.
    Ties broken by expert index (matches the Rust coordinator: stable sort
    descending by probability, ascending by index).
    """
    top_vals, _ = jax.lax.top_k(probs, k)
    thresh = top_vals[..., -1:]
    mask = probs >= thresh
    # Guard degenerate ties producing > k selections: keep the first k.
    csum = jnp.cumsum(mask.astype(jnp.int32), axis=-1)
    mask = mask & (csum <= k)
    w = probs * mask
    return w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)


def moe_block(x: jnp.ndarray, layer: dict, cfg: ModelConfig):
    """Dense-compute MoE block (all experts evaluated, top-k mixed).

    Used for training / reference only: serving evaluates just the routed
    experts through the per-expert artifacts.  Returns ``(y, probs)``.
    """
    probs = ref.gate_probs(x, layer["wg"])            # [T, M]
    w = topk_mask(probs, cfg.top_k)                   # [T, M]
    h1 = jnp.einsum("td,mdf->tmf", x, layer["w1"])
    h3 = jnp.einsum("td,mdf->tmf", x, layer["w3"])
    acts = ref.silu(h1) * h3
    outs = jnp.einsum("tmf,mfd->tmd", acts, layer["w2"])
    return jnp.einsum("tm,tmd->td", w, outs), probs


def forward_full(params: dict, tokens: jnp.ndarray, cfg: ModelConfig,
                 collect_hidden: bool = False):
    """Full forward pass over ``tokens[T]`` -> ``logits[T, V]``.

    With ``collect_hidden=True`` also returns the per-layer residual
    streams (used by the Fig.-6 inter-layer-similarity experiment and the
    look-ahead-predictor accuracy test).
    """
    T = tokens.shape[0]
    h = embed(tokens, params["emb"])
    hiddens = []
    for layer in params["layers"]:
        out, _, k, v = ref.attention_prefill(
            h, jnp.int32(T), layer["ln1"], layer["wq"], layer["wk"],
            layer["wv"], layer["wo"], n_heads=cfg.n_heads,
            rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps)
        h = h + out
        moe_in = ref.rms_norm(h, layer["ln2"], cfg.rms_eps)
        y, _ = moe_block(moe_in, layer, cfg)
        h = h + y
        if collect_hidden:
            hiddens.append(h)
    logits = finalize(h, params["ln_f"], params["emb"], cfg=cfg)
    if collect_hidden:
        return logits, hiddens
    return logits


def loss_fn(params: dict, batch: jnp.ndarray, cfg: ModelConfig,
            aux_weight: float = 0.01):
    """Next-token cross-entropy + router load-balancing auxiliary loss.

    ``batch: i32[B, T]``.  The aux loss is the standard Switch-style
    balance term: M * sum_e(fraction_e * prob_e).
    """
    def one(tokens):
        T = tokens.shape[0]
        h = embed(tokens, params["emb"])
        aux = 0.0
        for layer in params["layers"]:
            out, _, _, _ = ref.attention_prefill(
                h, jnp.int32(T), layer["ln1"], layer["wq"], layer["wk"],
                layer["wv"], layer["wo"], n_heads=cfg.n_heads,
                rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps)
            h2 = h + out
            moe_in = ref.rms_norm(h2, layer["ln2"], cfg.rms_eps)
            y, probs = moe_block(moe_in, layer, cfg)
            w = topk_mask(probs, cfg.top_k)
            frac = jnp.mean((w > 0).astype(jnp.float32), axis=0)   # [M]
            mean_p = jnp.mean(probs, axis=0)
            aux = aux + cfg.n_experts * jnp.sum(frac * mean_p)
            h = h2 + y
        logits = finalize(h, params["ln_f"], params["emb"], cfg=cfg)
        logp = jax.nn.log_softmax(logits[:-1], axis=-1)
        nll = -jnp.take_along_axis(logp, tokens[1:, None], axis=-1).mean()
        return nll, aux / cfg.n_layers

    nll, aux = jax.vmap(one)(batch)
    return jnp.mean(nll) + aux_weight * jnp.mean(aux), jnp.mean(nll)

"""L2 model equivalence: the serving decomposition (what Rust drives,
artifact by artifact) must equal the monolithic reference forward pass.

This is the contract the Rust coordinator relies on: if these pass, any
numerics bug on the Rust side is in Rust, not in the artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, corpus, model  # noqa: F401
from compile.kernels import ref

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=2)


def serve_prefill(params, tokens, precision="bf16"):
    """Emulate the Rust prefill loop with the artifact functions (bf16)."""
    T = len(tokens)
    S = CFG.max_seq
    padded = jnp.asarray(
        np.pad(tokens, (0, S - T)), jnp.int32)
    h = model.embed(padded, params["emb"])
    caches = []
    for layer in params["layers"]:
        h_resid, moe_in, probs, scores, k, v = model.attn_prefill(
            h, jnp.asarray([T], jnp.int32), layer["ln1"], layer["wq"],
            layer["wk"], layer["wv"], layer["wo"], layer["ln2"], layer["wg"],
            cfg=CFG)
        # Rust-side routing: top-k per token, renormalized; dispatch to
        # experts; weighted accumulate.
        w = np.asarray(model.topk_mask(probs, CFG.top_k))
        y = np.zeros((S, CFG.d_model), np.float32)
        for e in range(CFG.n_experts):
            rows = np.flatnonzero(w[:T, e] > 0)
            if len(rows) == 0:
                continue
            x_e = np.asarray(moe_in)[rows]
            out_e = np.asarray(model.expert_ffn_dense(
                jnp.asarray(x_e), layer["w1"][e], layer["w3"][e],
                layer["w2"][e]))
            y[rows] += w[rows, e][:, None] * out_e
        h = h_resid + jnp.asarray(y)
        caches.append((np.asarray(k)[:T], np.asarray(v)[:T]))
    logits = model.finalize(h, params["ln_f"], params["emb"], cfg=CFG)
    return np.asarray(logits)[:T], caches


def test_serving_prefill_equals_forward_full(params):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, CFG.vocab, size=9).astype(np.int32)
    logits_serve, _ = serve_prefill(params, tokens)
    logits_full = np.asarray(
        model.forward_full(params, jnp.asarray(tokens), CFG))
    np.testing.assert_allclose(logits_serve, logits_full, rtol=2e-4,
                               atol=2e-4)


def test_serving_decode_equals_forward_full(params):
    """Prefill T tokens then decode one more; logits for position T must
    match a full forward over T+1 tokens."""
    rng = np.random.default_rng(1)
    T = 7
    tokens = rng.integers(0, CFG.vocab, size=T + 1).astype(np.int32)
    _, caches = serve_prefill(params, tokens[:T])

    # decode step for token T
    C = CFG.max_cache
    h = model.embed(jnp.asarray(tokens[T:T + 1], jnp.int32), params["emb"])
    for li, layer in enumerate(params["layers"]):
        kc = np.zeros((C, CFG.n_heads, CFG.head_dim), np.float32)
        vc = np.zeros_like(kc)
        kc[:T], vc[:T] = caches[li]
        h_resid, moe_in, probs, k_new, v_new = model.attn_decode(
            h, jnp.asarray(kc), jnp.asarray(vc),
            jnp.asarray([T], jnp.int32), layer["ln1"], layer["wq"],
            layer["wk"], layer["wv"], layer["wo"], layer["ln2"], layer["wg"],
            cfg=CFG)
        w = np.asarray(model.topk_mask(probs, CFG.top_k))[0]
        y = np.zeros((1, CFG.d_model), np.float32)
        for e in np.flatnonzero(w > 0):
            out_e = np.asarray(model.expert_ffn_dense(
                moe_in, layer["w1"][e], layer["w3"][e], layer["w2"][e]))
            y += w[e] * out_e
        h = h_resid + jnp.asarray(y)
    logits_dec = np.asarray(
        model.finalize(h, params["ln_f"], params["emb"], cfg=CFG))[0]

    logits_full = np.asarray(
        model.forward_full(params, jnp.asarray(tokens), CFG))[T]
    np.testing.assert_allclose(logits_dec, logits_full, rtol=3e-4, atol=3e-4)


def test_gate_probe_predicts_next_layer(params):
    """Eq. 6: layer-l hidden through layer-(l+1)'s gate approximates the
    true layer-(l+1) routing better than chance (top-k overlap)."""
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, size=12), jnp.int32)
    _, hiddens = model.forward_full(params, tokens, CFG, collect_hidden=True)
    hits, total = 0, 0
    for l in range(CFG.n_layers - 1):
        nxt = params["layers"][l + 1]
        pred = np.asarray(model.gate_probe(
            hiddens[l], nxt["ln2"], nxt["wg"], cfg=CFG))
        # true gate input: residual stream right before layer l+1's MoE —
        # approximated here by the post-layer-(l) hidden + attention of
        # layer l+1.  We check rank correlation of top-1 instead of exact.
        out, _, _, _ = ref.attention_prefill(
            hiddens[l], jnp.int32(12), nxt["ln1"], nxt["wq"], nxt["wk"],
            nxt["wv"], nxt["wo"], CFG.n_heads, CFG.rope_theta, CFG.rms_eps)
        h2 = hiddens[l] + out
        true = np.asarray(ref.gate_probs(
            ref.rms_norm(h2, nxt["ln2"], CFG.rms_eps), nxt["wg"]))
        hits += (pred.argmax(-1) == true.argmax(-1)).sum()
        total += pred.shape[0]
    assert hits / total > 1.5 / CFG.n_experts  # well above chance


def test_eval_suite_items_are_consistent():
    suites = corpus.build_suites(seed=7, n_items=10, max_prompt=40)
    for name, items in suites.items():
        assert len(items) == 10
        for it in items:
            # fits the serving models' prompt bucket + decode budget
            assert len(it.prompt) <= configs.MIXTRAL_MINI.max_seq
            assert len(it.prompt) + len(it.answer) <= configs.MIXTRAL_MINI.max_cache
            assert it.prompt[0] == corpus.BOS
            assert all(0 <= t < corpus.VOCAB for t in it.prompt + it.answer)
    # repeat-suite answers really continue the periodic motif
    it = suites["suite_repeat"][0]
    body = it.prompt[2:] + it.answer
    # find the period: smallest p with body[i] == body[i % p]
    period = next(
        p for p in range(1, 5)
        if all(body[i] == body[i % p] for i in range(len(body)))
    )
    assert period >= 1
    # succ-suite answers continue the ring chain
    it = suites["suite_succ"][0]
    chain = it.prompt[2:] + it.answer
    step = (chain[1] - chain[0]) % corpus.RING_N
    for a, b in zip(chain, chain[1:]):
        assert (b - a) % corpus.RING_N == step % corpus.RING_N

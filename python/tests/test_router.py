"""Router/gate kernel vs reference; top-k mask semantics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model
from compile.kernels import ref
from compile.kernels import router as router_k

CFG = configs.TINY


def test_gate_matches_ref():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (5, CFG.d_model)).astype(np.float32))
    ln = jnp.asarray(rng.normal(1, 0.1, (CFG.d_model,)).astype(np.float32))
    wg = jnp.asarray(
        rng.normal(0, 0.1, (CFG.d_model, CFG.n_experts)).astype(np.float32))
    probs = router_k.gate(x, ln, wg, eps=CFG.rms_eps)
    expected = ref.gate_probs(ref.rms_norm(x, ln, CFG.rms_eps), wg)
    np.testing.assert_allclose(probs, expected, rtol=1e-5, atol=1e-6)


def test_gate_rows_are_distributions():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(0, 3, (9, CFG.d_model)).astype(np.float32))
    ln = jnp.ones((CFG.d_model,), jnp.float32)
    wg = jnp.asarray(
        rng.normal(0, 0.5, (CFG.d_model, CFG.n_experts)).astype(np.float32))
    probs = np.asarray(router_k.gate(x, ln, wg, eps=CFG.rms_eps))
    assert np.all(probs >= 0)
    np.testing.assert_allclose(probs.sum(axis=-1), 1.0, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(k=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
def test_topk_mask_properties(k, seed):
    rng = np.random.default_rng(seed)
    probs = jnp.asarray(rng.dirichlet(np.ones(8), size=6).astype(np.float32))
    w = np.asarray(model.topk_mask(probs, k))
    # exactly k non-zeros per row, normalized, and they are the k largest
    assert np.all((w > 0).sum(axis=-1) == k)
    np.testing.assert_allclose(w.sum(axis=-1), 1.0, rtol=1e-5)
    for row_w, row_p in zip(w, np.asarray(probs)):
        chosen = set(np.flatnonzero(row_w > 0))
        top = set(np.argsort(-row_p, kind="stable")[:k])
        assert chosen == top


def test_topk_mask_renormalizes_selected():
    probs = jnp.asarray([[0.5, 0.3, 0.1, 0.1]], jnp.float32)
    w = np.asarray(model.topk_mask(probs, 2))[0]
    np.testing.assert_allclose(w[0], 0.5 / 0.8, rtol=1e-5)
    np.testing.assert_allclose(w[1], 0.3 / 0.8, rtol=1e-5)
    assert w[2] == 0 and w[3] == 0

"""Attention Pallas kernels vs reference; Eq.-1 importance-score properties."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs, model
from compile.kernels import attention as attn_k
from compile.kernels import ref

CFG = configs.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, seed=1)


def _layer_args(params, i=0):
    l = params["layers"][i]
    return l["ln1"], l["wq"], l["wk"], l["wv"], l["wo"]


@pytest.mark.parametrize("seq_len", (1, 5, CFG.max_seq))
def test_prefill_matches_ref(params, seq_len):
    rng = np.random.default_rng(seq_len)
    h = jnp.asarray(
        rng.normal(0, 1, (CFG.max_seq, CFG.d_model)).astype(np.float32))
    args = _layer_args(params)
    out, sc, k, v = attn_k.attention_prefill(
        h, jnp.asarray([seq_len], jnp.int32), *args,
        n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    outr, scr, kr, vr = ref.attention_prefill(
        h, seq_len, *args, CFG.n_heads, CFG.rope_theta, CFG.rms_eps)
    np.testing.assert_allclose(out, outr, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sc, scr, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-6)


def test_prefill_scores_sum_to_one(params):
    """Eq. 1 scores are a distribution over valid tokens (sum == 1)."""
    rng = np.random.default_rng(0)
    h = jnp.asarray(
        rng.normal(0, 1, (CFG.max_seq, CFG.d_model)).astype(np.float32))
    for seq_len in (2, 7, CFG.max_seq):
        _, sc, _, _ = attn_k.attention_prefill(
            h, jnp.asarray([seq_len], jnp.int32), *_layer_args(params),
            n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
        assert abs(float(jnp.sum(sc)) - 1.0) < 1e-4
        np.testing.assert_allclose(np.asarray(sc[seq_len:]), 0.0, atol=1e-6)


def test_prefill_padding_invariance(params):
    """Garbage in padding rows must not affect valid outputs."""
    rng = np.random.default_rng(5)
    seq_len = 6
    h1 = rng.normal(0, 1, (CFG.max_seq, CFG.d_model)).astype(np.float32)
    h2 = h1.copy()
    h2[seq_len:] = rng.normal(0, 100, h2[seq_len:].shape)
    args = _layer_args(params)
    o1, s1, _, _ = attn_k.attention_prefill(
        jnp.asarray(h1), jnp.asarray([seq_len], jnp.int32), *args,
        n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    o2, s2, _, _ = attn_k.attention_prefill(
        jnp.asarray(h2), jnp.asarray([seq_len], jnp.int32), *args,
        n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    np.testing.assert_allclose(o1[:seq_len], o2[:seq_len], rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(s1[:seq_len], s2[:seq_len], rtol=1e-5,
                               atol=1e-7)


@pytest.mark.parametrize("pos", (0, 3, CFG.max_cache - 1))
def test_decode_matches_ref(params, pos):
    rng = np.random.default_rng(pos)
    S = CFG.max_cache
    kc = jnp.asarray(rng.normal(
        0, 1, (S, CFG.n_heads, CFG.head_dim)).astype(np.float32))
    vc = jnp.asarray(rng.normal(
        0, 1, (S, CFG.n_heads, CFG.head_dim)).astype(np.float32))
    h = jnp.asarray(rng.normal(0, 1, (1, CFG.d_model)).astype(np.float32))
    args = _layer_args(params)
    o, kn, vn = attn_k.attention_decode(
        h, kc, vc, jnp.asarray([pos], jnp.int32), *args,
        n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    orf, knr, vnr = ref.attention_decode(
        h, kc, vc, pos, *args, CFG.n_heads, CFG.rope_theta, CFG.rms_eps)
    np.testing.assert_allclose(o, orf, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(kn, knr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(vn, vnr, rtol=1e-5, atol=1e-6)


def test_decode_ignores_future_cache(params):
    """Cache rows >= pos must not influence the output."""
    rng = np.random.default_rng(9)
    S, pos = CFG.max_cache, 4
    kc = rng.normal(0, 1, (S, CFG.n_heads, CFG.head_dim)).astype(np.float32)
    vc = rng.normal(0, 1, (S, CFG.n_heads, CFG.head_dim)).astype(np.float32)
    kc2, vc2 = kc.copy(), vc.copy()
    kc2[pos:] = 99.0
    vc2[pos:] = -99.0
    h = jnp.asarray(rng.normal(0, 1, (1, CFG.d_model)).astype(np.float32))
    args = _layer_args(params)
    o1, _, _ = attn_k.attention_decode(
        h, jnp.asarray(kc), jnp.asarray(vc), jnp.asarray([pos], jnp.int32),
        *args, n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    o2, _, _ = attn_k.attention_decode(
        h, jnp.asarray(kc2), jnp.asarray(vc2), jnp.asarray([pos], jnp.int32),
        *args, n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-6)


def test_decode_consistent_with_prefill(params):
    """Decoding token t over a cache built from prefill == prefill row t."""
    rng = np.random.default_rng(13)
    T = 8
    h = jnp.asarray(rng.normal(0, 1, (T, CFG.d_model)).astype(np.float32))
    args = _layer_args(params)
    # reference prefill over first T tokens
    out_ref, _, k_ref, v_ref = ref.attention_prefill(
        h, T, *args, CFG.n_heads, CFG.rope_theta, CFG.rms_eps)
    # decode the last token against cache rows 0..T-2
    S = CFG.max_cache
    kc = jnp.zeros((S, CFG.n_heads, CFG.head_dim), jnp.float32)
    vc = jnp.zeros_like(kc)
    kc = kc.at[:T - 1].set(k_ref[:T - 1])
    vc = vc.at[:T - 1].set(v_ref[:T - 1])
    o, kn, vn = attn_k.attention_decode(
        h[T - 1:T], kc, vc, jnp.asarray([T - 1], jnp.int32), *args,
        n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    np.testing.assert_allclose(o[0], out_ref[T - 1], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(kn, k_ref[T - 1], rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seq_len=st.integers(1, CFG.max_seq), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_prefill(seq_len, seed):
    params = model.init_params(CFG, seed=1)
    rng = np.random.default_rng(seed)
    h = jnp.asarray(
        rng.normal(0, 1, (CFG.max_seq, CFG.d_model)).astype(np.float32))
    args = _layer_args(params)
    out, sc, _, _ = attn_k.attention_prefill(
        h, jnp.asarray([seq_len], jnp.int32), *args,
        n_heads=CFG.n_heads, theta=CFG.rope_theta, eps=CFG.rms_eps)
    outr, scr, _, _ = ref.attention_prefill(
        h, seq_len, *args, CFG.n_heads, CFG.rope_theta, CFG.rms_eps)
    np.testing.assert_allclose(out, outr, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(sc, scr, rtol=2e-4, atol=1e-6)

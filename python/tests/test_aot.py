"""AOT export path: artifact definitions, lowering, manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model, quant, train


def test_artifact_defs_cover_all_pieces():
    defs = aot.artifact_defs(configs.TINY)
    names = set(defs)
    for required in ("attn_prefill", "attn_decode", "embed_t1",
                     "finalize_t1", "gate_probe_t1"):
        assert required in names
    for prec in ("bf16", "int8", "int4", "int2"):
        assert f"expert_{prec}_t1" in names
        assert f"expert_{prec}_t4" in names


def test_lower_one_artifact_produces_hlo_text():
    defs = aot.artifact_defs(configs.TINY)
    fn, specs = defs["expert_int4_t1"]
    text, out_specs = aot.lower_artifact(fn, specs)
    assert "HloModule" in text
    assert len(out_specs) == 1
    assert tuple(out_specs[0].shape) == (1, configs.TINY.d_model)


def test_export_tiny_manifest(tmp_path):
    cfg = configs.TINY
    aot.export_model(cfg, str(tmp_path), retrain=False, verbose=False)
    mdir = tmp_path / cfg.name
    manifest = json.loads((mdir / "manifest.json").read_text())
    assert manifest["model"]["name"] == cfg.name
    # every artifact file exists and is HLO text
    for name, meta in manifest["artifacts"].items():
        path = mdir / meta["file"]
        assert path.exists(), name
        assert path.read_text().startswith("HloModule")
    # sections are contiguous and sized consistently with dtype*shape
    secs = sorted(manifest["sections"].values(), key=lambda s: s["offset"])
    expect_off = 0
    for s in secs:
        assert s["offset"] == expect_off
        n_elems = int(np.prod(s["shape"]))
        assert s["nbytes"] == n_elems * 4
        expect_off += s["nbytes"]
    assert (mdir / "weights.bin").stat().st_size == expect_off


def test_weight_store_roundtrip(tmp_path):
    """Sections written by quant.py must deserialize back to the params."""
    cfg = configs.TINY
    params = model.init_params(cfg, seed=0)
    writer = quant.build_weight_store(cfg, params)
    path = tmp_path / "w.bin"
    writer.write(str(path))
    blob = path.read_bytes()

    sec = writer.sections["L0.wq"]
    arr = np.frombuffer(
        blob[sec["offset"]:sec["offset"] + sec["nbytes"]],
        dtype=np.float32).reshape(sec["shape"])
    np.testing.assert_array_equal(arr, np.asarray(params["layers"][0]["wq"]))

    sec = writer.sections["L1.E2.w2.int4.q"]
    arr = np.frombuffer(
        blob[sec["offset"]:sec["offset"] + sec["nbytes"]],
        dtype=np.uint32).reshape(sec["shape"])
    from compile.kernels import ref
    words, _ = ref.quantize_packed(params["layers"][1]["w2"][2], 4,
                                   cfg.group_size)
    np.testing.assert_array_equal(arr, np.asarray(words))


def test_expert_logical_bytes_ordering():
    b = quant.expert_logical_bytes(configs.MIXTRAL_MINI)
    assert b["bf16"] > b["int8"] > b["int4"] > b["int2"]
    n = configs.MIXTRAL_MINI.expert_params
    assert b["bf16"] == 2 * n
    assert b["int8"] > n  # packed + scales overhead


def test_train_smoke_reduces_loss():
    params, history = train.train(configs.TINY, steps=25, batch=4,
                                  length=16, verbose=False)
    assert history[-1] < history[0]

"""Expert-FFN Pallas kernel vs reference oracle (dense + every quant tier)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import configs
from compile.kernels import moe_ffn, ref


def make_weights(rng, d, f, scale=0.2):
    w1 = jnp.asarray(rng.normal(0, scale, (d, f)).astype(np.float32))
    w3 = jnp.asarray(rng.normal(0, scale, (d, f)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(0, scale, (f, d)).astype(np.float32))
    return w1, w3, w2


@pytest.mark.parametrize("t", configs.EXPERT_BUCKETS)
@pytest.mark.parametrize("cfg", [configs.TINY, configs.MIXTRAL_MINI,
                                 configs.QWEN_MINI], ids=lambda c: c.name)
def test_dense_matches_ref(cfg, t):
    if t > cfg.max_seq:
        pytest.skip("bucket larger than model max_seq")
    rng = np.random.default_rng(42)
    d, f = cfg.d_model, cfg.d_ffn
    x = jnp.asarray(rng.normal(0, 1, (t, d)).astype(np.float32))
    w1, w3, w2 = make_weights(rng, d, f)
    y = moe_ffn.expert_ffn_dense(x, w1, w3, w2)
    np.testing.assert_allclose(y, ref.expert_ffn(x, w1, w3, w2),
                               rtol=5e-5, atol=5e-5)


@pytest.mark.parametrize("bits", (8, 4, 2))
@pytest.mark.parametrize("cfg", [configs.TINY, configs.MIXTRAL_MINI,
                                 configs.QWEN_MINI], ids=lambda c: c.name)
def test_quant_matches_ref(cfg, bits):
    rng = np.random.default_rng(bits)
    d, f, G = cfg.d_model, cfg.d_ffn, cfg.group_size
    x = jnp.asarray(rng.normal(0, 1, (4, d)).astype(np.float32))
    w1, w3, w2 = make_weights(rng, d, f)
    w1q, w1s = ref.quantize_packed(w1, bits, G)
    w3q, w3s = ref.quantize_packed(w3, bits, G)
    w2q, w2s = ref.quantize_packed(w2, bits, G)
    y = moe_ffn.expert_ffn_quant(x, w1q, w1s, w3q, w3s, w2q, w2s,
                                 bits=bits, group_size=G)
    yr = ref.expert_ffn_quant(x, w1q, w1s, w3q, w3s, w2q, w2s,
                              bits=bits, group_size=G)
    np.testing.assert_allclose(y, yr, rtol=5e-4, atol=5e-4)


def test_quant_approaches_dense_with_bits():
    """int8 output should be much closer to dense than int2 output."""
    cfg = configs.TINY
    rng = np.random.default_rng(0)
    d, f, G = cfg.d_model, cfg.d_ffn, cfg.group_size
    x = jnp.asarray(rng.normal(0, 1, (8, d)).astype(np.float32))
    w1, w3, w2 = make_weights(rng, d, f)
    y_dense = ref.expert_ffn(x, w1, w3, w2)
    errs = {}
    for bits in (8, 4, 2):
        packed = [ref.quantize_packed(w, bits, G) for w in (w1, w3, w2)]
        y = ref.expert_ffn_quant(x, packed[0][0], packed[0][1],
                                 packed[1][0], packed[1][1],
                                 packed[2][0], packed[2][1],
                                 bits=bits, group_size=G)
        errs[bits] = float(jnp.mean(jnp.abs(y - y_dense)))
    assert errs[8] < errs[4] < errs[2]
    assert errs[8] < 0.05


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from((1, 3, 4, 16)),
    bits=st.sampled_from((8, 4, 2)),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_quant_ffn(t, bits, seed):
    """Sweep token counts / bit-widths / seeds at tiny dims."""
    rng = np.random.default_rng(seed)
    d, f, G = 32, 64, 32
    x = jnp.asarray(rng.normal(0, 1, (t, d)).astype(np.float32))
    w1, w3, w2 = make_weights(rng, d, f)
    packed = [ref.quantize_packed(w, bits, G) for w in (w1, w3, w2)]
    y = moe_ffn.expert_ffn_quant(x, packed[0][0], packed[0][1],
                                 packed[1][0], packed[1][1],
                                 packed[2][0], packed[2][1],
                                 bits=bits, group_size=G)
    yr = ref.expert_ffn_quant(x, packed[0][0], packed[0][1],
                              packed[1][0], packed[1][1],
                              packed[2][0], packed[2][1],
                              bits=bits, group_size=G)
    np.testing.assert_allclose(y, yr, rtol=1e-3, atol=1e-3)


def test_zero_tokens_padding_rows_are_zero_effect():
    """Padded (zero) rows produce zero outputs — L3 relies on this to pad
    expert batches up to the bucket size."""
    cfg = configs.TINY
    rng = np.random.default_rng(3)
    d, f = cfg.d_model, cfg.d_ffn
    w1, w3, w2 = make_weights(rng, d, f)
    x = jnp.zeros((4, d), jnp.float32)
    x = x.at[0].set(jnp.asarray(rng.normal(0, 1, (d,)).astype(np.float32)))
    y = moe_ffn.expert_ffn_dense(x, w1, w3, w2)
    np.testing.assert_allclose(y[1:], 0.0, atol=1e-6)

"""Quantization kernels vs reference: pack/unpack, round-trip error bounds.

Hypothesis sweeps shapes, bit-widths and group sizes; the Rust quantizer
(rust/src/quant) is tested against the same golden vectors emitted by
``test_golden_vectors`` below (kept in sync by construction: both sides
implement the scheme documented in kernels/ref.py).
"""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import quantize as qk

BITS = (8, 4, 2)


def rand_w(rng, K, N, scale=0.5):
    return jnp.asarray(rng.normal(0, scale, (K, N)).astype(np.float32))


@pytest.mark.parametrize("bits", BITS)
@pytest.mark.parametrize("group", (32, 64))
def test_pack_unpack_roundtrip_exact(bits, group):
    rng = np.random.default_rng(bits * 100 + group)
    q = jnp.asarray(
        rng.integers(*ref.quant_range(bits), endpoint=True, size=(64, 48)),
        dtype=jnp.int32)
    words = ref.pack_words(q, bits)
    assert words.dtype == jnp.uint32
    assert words.shape == (64 * bits // 32, 48)
    back = ref.unpack_words(words, bits)
    assert jnp.array_equal(back, q)


@pytest.mark.parametrize("bits", BITS)
def test_kernel_matches_ref(bits):
    rng = np.random.default_rng(bits)
    w = rand_w(rng, 64, 96)
    words_k, scales_k = qk.quantize(w, bits, 32)
    words_r, scales_r = ref.quantize_packed(w, bits, 32)
    assert jnp.array_equal(words_k, words_r)
    np.testing.assert_allclose(scales_k, scales_r, rtol=1e-6)
    deq_k = qk.dequantize(words_k, scales_k, bits, 32)
    deq_r = ref.dequantize_packed(words_r, scales_r, bits, 32)
    np.testing.assert_allclose(deq_k, deq_r, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("bits,bound_steps", [(8, 127), (4, 7), (2, 1)])
def test_roundtrip_error_bound(bits, bound_steps):
    """|w - dq(q(w))| <= scale/2 per element, scale = group_max/half_range."""
    rng = np.random.default_rng(7)
    w = rand_w(rng, 128, 64)
    q, s = ref.quantize_groupwise(w, bits, 32)
    deq = ref.dequantize_groupwise(q, s, 32)
    err = np.abs(np.asarray(deq - w))
    s_full = np.repeat(np.asarray(s), 32, axis=0)
    assert np.all(err <= 0.5 * s_full + 1e-7)


@pytest.mark.parametrize("bits", BITS)
def test_monotone_error_in_bits(bits):
    """Fewer bits => strictly more (or equal) round-trip error."""
    rng = np.random.default_rng(11)
    w = rand_w(rng, 64, 64)
    errs = {}
    for b in BITS:
        _, s = ref.quantize_groupwise(w, b, 32)
        deq = ref.dequantize_groupwise(*ref.quantize_groupwise(w, b, 32), 32)
        errs[b] = float(jnp.mean(jnp.abs(deq - w)))
    assert errs[2] > errs[4] > errs[8]


@settings(max_examples=25, deadline=None)
@given(
    bits=st.sampled_from(BITS),
    kg=st.integers(1, 4),     # K = kg * 32
    n=st.integers(1, 6),      # N = 16 * n
    scale=st.floats(1e-3, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_roundtrip(bits, kg, n, scale, seed):
    rng = np.random.default_rng(seed)
    K, N = 32 * kg, 16 * n
    w = rand_w(rng, K, N, scale)
    words, s = ref.quantize_packed(w, bits, 32)
    assert words.shape == (K * bits // 32, N)
    assert s.shape == (K // 32, N)
    deq = ref.dequantize_packed(words, s, bits, 32)
    # error bounded by half a quantization step everywhere
    s_full = np.repeat(np.asarray(s), 32, axis=0)
    assert np.all(np.abs(np.asarray(deq - w)) <= 0.5 * s_full + 1e-6)


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from(BITS), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_kernel_vs_ref(bits, seed):
    rng = np.random.default_rng(seed)
    w = rand_w(rng, 64, 32)
    wk, sk = qk.quantize(w, bits, 32)
    wr, sr = ref.quantize_packed(w, bits, 32)
    assert jnp.array_equal(wk, wr)
    np.testing.assert_allclose(sk, sr, rtol=1e-6)


def test_golden_vectors(tmp_path):
    """Emit golden pack vectors; the Rust side hard-codes the same case."""
    w = jnp.asarray(np.arange(-16, 16, dtype=np.float32).reshape(32, 1) / 8.0)
    words, scales = ref.quantize_packed(w, 4, 32)
    out = {
        "w_first": float(w[0, 0]),
        "words": np.asarray(words).astype(np.int64).ravel().tolist(),
        "scales": np.asarray(scales).ravel().tolist(),
    }
    # scale = max|w|/7; q[0] = round(-2.0/scale) clipped to [-8, 7]
    s = float(scales[0, 0])
    assert abs(s - 2.0 / 7.0) < 1e-6
    q0 = ref.unpack_words(words, 4)[0, 0]
    assert int(q0) == -7  # round(-2.0 / (2/7)) = -7
    (tmp_path / "golden.json").write_text(json.dumps(out))

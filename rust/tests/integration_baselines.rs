//! Integration: DyMoE vs the offloading baselines on mixtral-mini — the
//! relative-performance *shape* the paper claims (Fig. 10 / Table 3) must
//! hold on our substrate:
//!
//! * cache beats load-on-demand;
//! * prefetch improves on cache-only;
//! * dynamic quantization improves on uniform precision;
//! * DyMoE(4/0) beats every baseline on TTFT and TPOT;
//! * Fiddler's CPU co-execution is the slowest prefill path.

use std::sync::Arc;

use dymoe::baselines::{
    AccelerateStatic, Fiddler, LoadOnDemand, MixtralOffloading, MoeInfinity, Uniform,
};
use dymoe::config::{LowMode, PolicyConfig, SystemConfig};
use dymoe::coordinator::engine::Engine;
use dymoe::coordinator::strategy::{DyMoEStrategy, Strategy};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::workload::TraceGen;

const MODEL: &str = "mixtral-mini";

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", MODEL) {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/{MODEL} missing; run `make artifacts`");
            None
        }
    }
}

/// Mean (TTFT, TPOT) over a short fixed trace.
fn measure(a: &Arc<ModelAssets>, vram_gb: u64, strategy: Box<dyn Strategy>) -> (f64, f64) {
    let sys = SystemConfig::edge_preset(MODEL, vram_gb).unwrap();
    let mut e = Engine::new(a, sys, strategy).unwrap();
    let mut gen = TraceGen::new(11, 80, 12);
    let n = 4;
    let (mut ttft, mut tpot) = (0.0, 0.0);
    for _ in 0..n {
        let r = gen.next_request();
        let o = e.run(&r.prompt, r.max_new).unwrap();
        ttft += o.ttft / n as f64;
        tpot += o.tpot() / n as f64;
    }
    (ttft, tpot)
}

#[test]
fn ablation_ordering_matches_table3() {
    let Some(a) = assets() else { return };
    let vram = 16;

    // Row 1: load on demand (uniform int4, as in the paper's ablation).
    let (t1, p1) = measure(&a, vram, Box::new(LoadOnDemand::new(Precision::Int4)));
    // Row 2: + cache.
    let (t2, p2) = measure(&a, vram, Box::new(Uniform::new(Precision::Int4)));
    // Row 3: + prefetch (cache + prefetch, uniform precision).
    let pol3 = PolicyConfig {
        retention: 1.0,
        dyquant_enabled: false,
        prefetch_enabled: true,
        ..Default::default()
    };
    let (t3, p3) = measure(&a, vram, Box::new(DyMoEStrategy::new(pol3)));
    // Row 5: full DyMoE 4/2.
    let pol5 = PolicyConfig {
        retention: 0.75,
        low_mode: LowMode::Int2,
        ..Default::default()
    };
    let (t5, p5) = measure(&a, vram, Box::new(DyMoEStrategy::new(pol5)));
    // Row 6: full DyMoE 4/0.
    let pol6 = PolicyConfig {
        retention: 0.75,
        low_mode: LowMode::Skip,
        ..Default::default()
    };
    let (t6, p6) = measure(&a, vram, Box::new(DyMoEStrategy::new(pol6)));

    eprintln!("LoD      TTFT={t1:.4} TPOT={p1:.4}");
    eprintln!("cache    TTFT={t2:.4} TPOT={p2:.4}");
    eprintln!("+pref    TTFT={t3:.4} TPOT={p3:.4}");
    eprintln!("dy(4/2)  TTFT={t5:.4} TPOT={p5:.4}");
    eprintln!("dy(4/0)  TTFT={t6:.4} TPOT={p6:.4}");

    // Table 3 ordering (shape, not absolute numbers):
    assert!(t2 < t1 && p2 < p1, "cache must beat load-on-demand");
    assert!(t3 < t2 * 1.02, "prefetch must not hurt TTFT");
    assert!(p3 < p2 * 1.02, "prefetch must not hurt TPOT");
    assert!(t5 < t2 && p5 < p2, "dyquant(4/2)+prefetch must beat cache-only");
    assert!(t6 <= t5 * 1.02 && p6 <= p5 * 1.02, "4/0 must be fastest");
    assert!(t6 < t1 / 1.5 && p6 < p1 / 1.5, "full system >=1.5x over LoD");
}

#[test]
fn dymoe_beats_all_baselines() {
    let Some(a) = assets() else { return };
    let vram = 16;
    let m = a.manifest.model.clone();

    let dymoe = measure(
        &a,
        vram,
        Box::new(DyMoEStrategy::new(PolicyConfig {
            retention: 0.75,
            low_mode: LowMode::Skip,
            ..Default::default()
        })),
    );
    let acc = measure(&a, vram, Box::new(AccelerateStatic::new(Precision::Int4)));
    let mo = measure(
        &a,
        vram,
        Box::new(MixtralOffloading::new(Precision::Int4, m.top_k)),
    );
    let mi = measure(
        &a,
        vram,
        Box::new(MoeInfinity::new(Precision::Int4, m.n_layers, m.n_experts, m.top_k)),
    );
    let fid = measure(&a, vram, Box::new(Fiddler));

    eprintln!("DyMoE(4/0)        TTFT={:.4} TPOT={:.4}", dymoe.0, dymoe.1);
    eprintln!("Accelerate(int4)  TTFT={:.4} TPOT={:.4}", acc.0, acc.1);
    eprintln!("MixtralOff(int4)  TTFT={:.4} TPOT={:.4}", mo.0, mo.1);
    eprintln!("MoE-Inf(int4)     TTFT={:.4} TPOT={:.4}", mi.0, mi.1);
    eprintln!("Fiddler(bf16)     TTFT={:.4} TPOT={:.4}", fid.0, fid.1);

    for (name, (t, p)) in [
        ("Accelerate", acc),
        ("Mixtral-Offloading", mo),
        ("MoE-Infinity", mi),
        ("Fiddler", fid),
    ] {
        assert!(dymoe.0 < t, "DyMoE TTFT must beat {name}: {} vs {t}", dymoe.0);
        assert!(dymoe.1 < p, "DyMoE TPOT must beat {name}: {} vs {p}", dymoe.1);
    }
    // Fiddler's CPU prefill is the paper's worst case (22.7x TTFT gap);
    // require at least a wide margin here.
    assert!(
        fid.0 > dymoe.0 * 4.0,
        "Fiddler prefill should be far slower: {} vs {}",
        fid.0,
        dymoe.0
    );
}

#[test]
fn prefetch_wins_on_trained_model() {
    let Some(a) = assets() else { return };
    let mk = |prefetch: bool| {
        Box::new(DyMoEStrategy::new(PolicyConfig {
            retention: 1.0,
            dyquant_enabled: false,
            prefetch_enabled: prefetch,
            ..Default::default()
        }))
    };
    let with = measure(&a, 16, mk(true));
    let without = measure(&a, 16, mk(false));
    eprintln!("prefetch: TTFT {:.4} -> {:.4}", without.0, with.0);
    eprintln!("prefetch: TPOT {:.4} -> {:.4}", without.1, with.1);
    assert!(with.0 < without.0, "prefetch must cut TTFT");
    assert!(with.1 < without.1 * 1.02, "prefetch must not hurt TPOT");
}

#[test]
fn vram_scaling_improves_latency() {
    let Some(a) = assets() else { return };
    let strat = || {
        Box::new(DyMoEStrategy::new(PolicyConfig {
            retention: 0.75,
            low_mode: LowMode::Int2,
            ..Default::default()
        }))
    };
    let lo = measure(&a, 12, strat());
    let hi = measure(&a, 24, strat());
    eprintln!(
        "12GB TTFT={:.4} TPOT={:.4}; 24GB TTFT={:.4} TPOT={:.4}",
        lo.0, lo.1, hi.0, hi.1
    );
    assert!(hi.0 <= lo.0 && hi.1 <= lo.1, "more VRAM can't be slower");
}

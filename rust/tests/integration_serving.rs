//! Integration: the multi-session serving subsystem.
//!
//! The arrival-trace and policy tests run everywhere; the engine-level
//! tests (interleaving equivalence, end-to-end fleet runs) need the real
//! `tiny` artifacts and skip politely when they are missing (run
//! `make artifacts`), matching the other integration suites.

use std::sync::Arc;

use dymoe::baselines::Uniform;
use dymoe::config::{ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess};
use dymoe::serving::policy::PolicyKind;
use dymoe::serving::{run_fleet, FleetConfig};
use dymoe::workload::TraceGen;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions { collect_logits: true, ..Default::default() },
    )
    .unwrap()
}

// ---------------------------------------------------------------------
// Arrival traces (no artifacts needed)
// ---------------------------------------------------------------------

#[test]
fn arrival_trace_is_deterministic_under_fixed_seed() {
    let mk = || {
        let mut content = TraceGen::new(7, 80, 16);
        ArrivalGen::generate(13, ArrivalProcess::Poisson { rate: 0.5 }, &mut content, 32)
            .unwrap()
    };
    let t1 = mk();
    let t2 = mk();
    assert_eq!(t1.len(), 32);
    for (a, b) in t1.iter().zip(&t2) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.request.prompt, b.request.prompt);
        assert_eq!(a.request.max_new, b.request.max_new);
    }
    // ids are the trace order and arrivals strictly increase
    for (i, w) in t1.windows(2).enumerate() {
        assert_eq!(w[0].id, i);
        assert!(w[1].arrival > w[0].arrival);
    }
}

// ---------------------------------------------------------------------
// Engine-level interleaving (artifacts-gated)
// ---------------------------------------------------------------------

/// Two sessions decoded in alternation must produce exactly the tokens
/// and logits of the same requests run back-to-back: per-session KV is
/// private, and with ample VRAM at uniform precision the shared cache
/// cannot change any execution precision.
#[test]
fn interleaved_sessions_match_back_to_back_numerics() {
    let Some(a) = assets() else { return };
    let p1: Vec<i32> = vec![1, 5, 9, 13, 17];
    let p2: Vec<i32> = vec![1, 30, 41, 52, 33, 44];

    let mut serial = bf16_engine(&a);
    let o1 = serial.run(&p1, 6).unwrap();
    let o2 = serial.run(&p2, 5).unwrap();

    let mut fleet = bf16_engine(&a);
    let mut s1 = fleet.begin_session(&p1, 6, None, 0.0).unwrap();
    let mut s2 = fleet.begin_session(&p2, 5, None, 0.0).unwrap();
    fleet.prefill_session(&mut s1).unwrap();
    fleet.prefill_session(&mut s2).unwrap();
    // strict alternation until both finish
    loop {
        let d1 = if s1.done() { true } else { fleet.decode_session(&mut s1).unwrap() };
        let d2 = if s2.done() { true } else { fleet.decode_session(&mut s2).unwrap() };
        if d1 && d2 {
            break;
        }
    }
    let i1 = s1.into_output();
    let i2 = s2.into_output();

    assert_eq!(o1.tokens, i1.tokens, "session 1 tokens diverged under interleaving");
    assert_eq!(o2.tokens, i2.tokens, "session 2 tokens diverged under interleaving");
    for (serial_logits, fleet_logits) in [(&o1, &i1), (&o2, &i2)] {
        for (x, y) in serial_logits
            .logits_per_step
            .iter()
            .zip(&fleet_logits.logits_per_step)
        {
            let max_err = x
                .iter()
                .zip(y)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-5, "interleaving changed numerics: {max_err}");
        }
    }
}

/// run() is implemented on the session API; a manual single-session
/// drive must reproduce it exactly, timing included.
#[test]
fn single_session_steps_match_run_exactly() {
    let Some(a) = assets() else { return };
    let prompt = [1i32, 4, 8, 12];

    let mut e1 = bf16_engine(&a);
    let o = e1.run(&prompt, 5).unwrap();

    let mut e2 = bf16_engine(&a);
    let arrival = e2.clock();
    let mut s = e2.begin_session(&prompt, 5, None, arrival).unwrap();
    e2.prefill_session(&mut s).unwrap();
    while !s.done() {
        e2.decode_session(&mut s).unwrap();
    }
    let m = s.into_output();
    assert_eq!(o.tokens, m.tokens);
    assert_eq!(o.ttft, m.ttft);
    assert_eq!(o.token_times, m.token_times);
}

// ---------------------------------------------------------------------
// Fleet runs (artifacts-gated)
// ---------------------------------------------------------------------

fn fleet_cfg(policy: PolicyKind, max_sessions: usize) -> FleetConfig {
    fleet_cfg_batched(policy, max_sessions, 1)
}

fn fleet_cfg_batched(
    policy: PolicyKind,
    max_sessions: usize,
    max_decode_batch: usize,
) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch,
            chunk_tokens: 0,
            ..Default::default()
        },
        policy,
        ..Default::default()
    }
}

fn tiny_trace(a: &Arc<ModelAssets>, n: usize, rate: f64) -> Vec<dymoe::serving::arrival::TimedRequest> {
    let m = &a.manifest.model;
    let mut content = TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
}

#[test]
fn fleet_completes_all_requests_and_interleaves() {
    let Some(a) = assets() else { return };
    for policy in PolicyKind::ALL {
        let mut engine = bf16_engine(&a);
        // arrivals far faster than service: the queue must build and the
        // rr/slo policies must actually interleave sessions
        let trace = tiny_trace(&a, 8, 50.0);
        let outcome = run_fleet(&mut engine, trace, &fleet_cfg(policy, 4)).unwrap();
        assert_eq!(outcome.metrics.completed, 8, "{} lost requests", policy.name());
        assert_eq!(outcome.per_request.len(), 8);
        assert!(outcome.metrics.makespan() > 0.0);
        assert!(outcome.metrics.throughput_tps() > 0.0);
        // every in-flight session pays for its private KV cache
        assert!(
            outcome.peak_kv_bytes >= outcome.peak_concurrency as u64,
            "KV accounting missing"
        );
        // every request's fleet TTFT covers its queue delay
        for r in &outcome.per_request {
            assert!(r.ttft >= r.queue_delay - 1e-12);
            assert!(r.tokens >= 1);
            assert!(r.finished_at >= r.arrival);
        }
        match policy {
            PolicyKind::Fifo => {
                assert_eq!(outcome.peak_concurrency, 1, "fifo must not interleave");
                // fifo completes in arrival order
                for w in outcome.per_request.windows(2) {
                    assert!(w[0].arrival <= w[1].arrival);
                }
            }
            PolicyKind::RoundRobin | PolicyKind::SloAware => {
                assert!(
                    outcome.peak_concurrency >= 2,
                    "{} never interleaved (peak {})",
                    policy.name(),
                    outcome.peak_concurrency
                );
                assert!(outcome.peak_concurrency <= 4, "admission limit violated");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Cross-session batched decode (artifacts-gated)
// ---------------------------------------------------------------------

/// One fused decode step over two sessions must emit exactly the tokens
/// (and logits) each session produces when served back-to-back: KV is
/// private per session, expert outputs are row-independent, and with
/// ample VRAM at uniform precision the shared fetch cannot change any
/// execution precision.  Also pins down the dedup accounting.
#[test]
fn decode_batch_of_two_matches_serial_numerics() {
    let Some(a) = assets() else { return };
    let p1: Vec<i32> = vec![1, 5, 9, 13, 17];
    let p2: Vec<i32> = vec![1, 30, 41, 52, 33, 44];
    let new_tokens = 6;

    let mut serial = bf16_engine(&a);
    let o1 = serial.run(&p1, new_tokens).unwrap();
    let o2 = serial.run(&p2, new_tokens).unwrap();

    let mut fleet = bf16_engine(&a);
    let mut s1 = fleet.begin_session(&p1, new_tokens, None, 0.0).unwrap();
    let mut s2 = fleet.begin_session(&p2, new_tokens, None, 0.0).unwrap();
    fleet.prefill_session(&mut s1).unwrap();
    fleet.prefill_session(&mut s2).unwrap();
    // equal token budgets: both sessions finish on the same fused step
    loop {
        let dones = fleet.decode_batch(&mut [&mut s1, &mut s2]).unwrap();
        assert_eq!(dones.len(), 2);
        if dones.iter().all(|&d| d) {
            break;
        }
    }
    // every fused step decoded both sessions
    assert_eq!(fleet.stats.decode_batches as usize, new_tokens - 1);
    assert_eq!(fleet.stats.decode_batch_tokens as usize, 2 * (new_tokens - 1));
    assert!(fleet.stats.routed_pairs >= fleet.stats.unique_expert_loads);
    let dedup = dymoe::serving::metrics::DedupStats::from_delta(
        &dymoe::coordinator::engine::EngineStats::default(),
        &fleet.stats,
    );
    assert!((dedup.mean_batch() - 2.0).abs() < 1e-12, "mean batch {}", dedup.mean_batch());
    assert!(dedup.expert_reuse_ratio() >= 1.0);

    let b1 = s1.into_output();
    let b2 = s2.into_output();
    assert_eq!(o1.tokens, b1.tokens, "session 1 tokens diverged under batching");
    assert_eq!(o2.tokens, b2.tokens, "session 2 tokens diverged under batching");
    for (serial_out, batch_out) in [(&o1, &b1), (&o2, &b2)] {
        assert_eq!(serial_out.logits_per_step.len(), batch_out.logits_per_step.len());
        for (x, y) in serial_out.logits_per_step.iter().zip(&batch_out.logits_per_step) {
            let max_err = x
                .iter()
                .zip(y)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-5, "batching changed numerics: {max_err}");
        }
    }
}

/// `run_fleet` with `--max-decode-batch 1` must reproduce the pre-batching
/// serial interleaved scheduler step for step: an inline replica of that
/// loop (round-robin decode, prefill-prioritized admission, one
/// `decode_session` per tick) serves as the reference, and every
/// completed request must match on TTFT/TPOT/completion time *exactly*
/// (same engine ops in the same order on the same virtual timeline).
#[test]
fn fleet_batch_one_matches_interleaved_reference_loop() {
    let Some(a) = assets() else { return };
    let n = 8;
    let max_sessions = 3;
    // all requests arrive at t = 0 so admission order is the id order
    let trace: Vec<dymoe::serving::arrival::TimedRequest> = tiny_trace(&a, n, 50.0)
        .into_iter()
        .map(|mut t| {
            t.arrival = 0.0;
            t
        })
        .collect();
    let requests: Vec<_> = trace.iter().map(|t| t.request.clone()).collect();

    let mut fleet_engine = bf16_engine(&a);
    let outcome = run_fleet(
        &mut fleet_engine,
        trace,
        &fleet_cfg_batched(PolicyKind::RoundRobin, max_sessions, 1),
    )
    .unwrap();
    assert_eq!(outcome.metrics.completed, n);
    // batch 1 is the serial path: every decode step advances one token
    assert_eq!(outcome.dedup.mean_batch(), 1.0);

    // -- inline PR-1 reference loop ----------------------------------
    struct InFlight {
        id: usize,
        sess: dymoe::coordinator::engine::EngineSession,
    }
    let mut reference = bf16_engine(&a);
    let mut queued: std::collections::VecDeque<(usize, dymoe::workload::Request)> =
        requests.into_iter().enumerate().collect();
    let mut active: Vec<InFlight> = Vec::new();
    let mut cursor: Option<usize> = None;
    let mut recs: Vec<(usize, dymoe::coordinator::engine::RequestOutput)> = Vec::new();
    while !queued.is_empty() || !active.is_empty() {
        // prefill-prioritized admission, oldest first
        if active.len() < max_sessions && !queued.is_empty() {
            let (id, r) = queued.pop_front().unwrap();
            let mut sess = reference.begin_session(&r.prompt, r.max_new, None, 0.0).unwrap();
            reference.prefill_session(&mut sess).unwrap();
            if sess.done() {
                recs.push((id, sess.into_output()));
            } else {
                active.push(InFlight { id, sess });
            }
            continue;
        }
        // round-robin decode over active ids
        let mut ids: Vec<usize> = active.iter().map(|x| x.id).collect();
        ids.sort_unstable();
        let pick = ids.iter().copied().find(|&i| Some(i) > cursor).unwrap_or(ids[0]);
        cursor = Some(pick);
        let pos = active.iter().position(|x| x.id == pick).unwrap();
        let done = reference.decode_session(&mut active[pos].sess).unwrap();
        if done {
            let x = active.swap_remove(pos);
            recs.push((x.id, x.sess.into_output()));
        }
    }

    assert_eq!(recs.len(), outcome.per_request.len());
    for ((ref_id, ref_out), got) in recs.iter().zip(&outcome.per_request) {
        assert_eq!(*ref_id, got.id, "completion order diverged");
        assert_eq!(ref_out.tokens.len(), got.tokens);
        // exact equality: identical engine ops on identical timelines
        assert_eq!(ref_out.start + ref_out.ttft, got.ttft, "TTFT diverged (id {ref_id})");
        assert_eq!(ref_out.tpot(), got.tpot, "TPOT diverged (id {ref_id})");
        let ref_finish = ref_out.start + ref_out.token_times.last().copied().unwrap();
        assert_eq!(ref_finish, got.finished_at, "completion time diverged (id {ref_id})");
    }
}

/// A batched fleet whose sessions never overlap must match the classic
/// back-to-back `run()` numbers per request: with one active session the
/// decode batch is a batch of one.
#[test]
fn fleet_batched_single_active_session_matches_serial_run() {
    let Some(a) = assets() else { return };
    // arrivals 10,000 s apart: every session is guaranteed to run alone
    let trace: Vec<_> = tiny_trace(&a, 3, 1.0)
        .into_iter()
        .enumerate()
        .map(|(i, mut t)| {
            t.arrival = (i + 1) as f64 * 10_000.0;
            t
        })
        .collect();
    let requests: Vec<_> = trace.iter().map(|t| t.request.clone()).collect();

    let mut fleet_engine = bf16_engine(&a);
    let outcome = run_fleet(
        &mut fleet_engine,
        trace,
        &fleet_cfg_batched(PolicyKind::SloAware, 4, 8),
    )
    .unwrap();
    assert_eq!(outcome.peak_concurrency, 1);

    let mut serial = bf16_engine(&a);
    for (r, done) in requests.iter().zip(&outcome.per_request) {
        let o = serial.run(&r.prompt, r.max_new).unwrap();
        assert!(done.queue_delay.abs() < 1e-9, "queueing with disjoint sessions");
        assert!((o.ttft - done.ttft).abs() < 1e-9, "batched-knob fleet TTFT diverged");
        assert!((o.tpot() - done.tpot).abs() < 1e-9, "batched-knob fleet TPOT diverged");
    }
}

/// The point of the tentpole: under concurrency, batched decode shares
/// expert fetches across sessions (reuse ratio above the serial path's
/// 1.0) and lowers mean TPOT, while completing the same work.
#[test]
fn fleet_batched_decode_shares_expert_fetches_and_lowers_tpot() {
    let Some(a) = assets() else { return };
    let n = 8;
    let mk_trace = || tiny_trace(&a, n, 50.0); // dense: queue must build

    let mut serial_engine = bf16_engine(&a);
    let serial = run_fleet(
        &mut serial_engine,
        mk_trace(),
        &fleet_cfg_batched(PolicyKind::SloAware, 4, 1),
    )
    .unwrap();
    let mut batched_engine = bf16_engine(&a);
    let batched = run_fleet(
        &mut batched_engine,
        mk_trace(),
        &fleet_cfg_batched(PolicyKind::SloAware, 4, 4),
    )
    .unwrap();

    assert_eq!(serial.metrics.completed, n);
    assert_eq!(batched.metrics.completed, n);
    // same work per session either way (uniform precision, ample VRAM)
    let count_by_id = |o: &dymoe::serving::FleetOutcome| {
        let mut v: Vec<(usize, usize)> = o.per_request.iter().map(|r| (r.id, r.tokens)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(count_by_id(&serial), count_by_id(&batched));

    // serial decode: every expert load serves exactly one token
    assert!((serial.dedup.expert_reuse_ratio() - 1.0).abs() < 1e-12);
    assert_eq!(serial.dedup.mean_batch(), 1.0);
    // batched decode: fused steps actually formed, fetches actually shared
    assert!(
        batched.dedup.mean_batch() > 1.2,
        "no decode batches formed (mean {})",
        batched.dedup.mean_batch()
    );
    assert!(
        batched.dedup.expert_reuse_ratio() > serial.dedup.expert_reuse_ratio() + 0.05,
        "no cross-session expert sharing: {} vs {}",
        batched.dedup.expert_reuse_ratio(),
        serial.dedup.expert_reuse_ratio()
    );
    assert!(batched.dedup.saved_fetches() > 0);
    // and the shared fetches buy latency: mean TPOT drops
    assert!(
        batched.metrics.tpot.mean() < serial.metrics.tpot.mean(),
        "batched TPOT {} not below serial {}",
        batched.metrics.tpot.mean(),
        serial.metrics.tpot.mean()
    );
}

/// At a vanishing arrival rate every session runs alone, so the fleet
/// path must match the classic back-to-back `serve` numbers per request.
#[test]
fn fleet_at_rate_zero_matches_serial_serving() {
    let Some(a) = assets() else { return };
    // arrivals 10,000 s apart: every session is guaranteed to run alone
    let trace: Vec<_> = tiny_trace(&a, 3, 1.0)
        .into_iter()
        .enumerate()
        .map(|(i, mut t)| {
            t.arrival = (i + 1) as f64 * 10_000.0;
            t
        })
        .collect();
    let requests: Vec<_> = trace.iter().map(|t| t.request.clone()).collect();

    let mut fleet_engine = bf16_engine(&a);
    let outcome = run_fleet(
        &mut fleet_engine,
        trace,
        &fleet_cfg(PolicyKind::SloAware, 4),
    )
    .unwrap();

    let mut serial = bf16_engine(&a);
    for (r, done) in requests.iter().zip(&outcome.per_request) {
        let o = serial.run(&r.prompt, r.max_new).unwrap();
        assert!((done.queue_delay).abs() < 1e-9, "queueing at rate ~ 0");
        assert!(
            (o.ttft - done.ttft).abs() < 1e-9,
            "fleet TTFT {} vs serial {}",
            done.ttft,
            o.ttft
        );
        assert!(
            (o.tpot() - done.tpot).abs() < 1e-9,
            "fleet TPOT {} vs serial {}",
            done.tpot,
            o.tpot()
        );
    }
    assert_eq!(outcome.peak_concurrency, 1);
}

//! Integration: the multi-session serving subsystem.
//!
//! The arrival-trace and policy tests run everywhere; the engine-level
//! tests (interleaving equivalence, end-to-end fleet runs) need the real
//! `tiny` artifacts and skip politely when they are missing (run
//! `make artifacts`), matching the other integration suites.

use std::sync::Arc;

use dymoe::baselines::Uniform;
use dymoe::config::{ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess};
use dymoe::serving::policy::PolicyKind;
use dymoe::serving::{run_fleet, FleetConfig};
use dymoe::workload::TraceGen;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions { collect_logits: true, ..Default::default() },
    )
    .unwrap()
}

// ---------------------------------------------------------------------
// Arrival traces (no artifacts needed)
// ---------------------------------------------------------------------

#[test]
fn arrival_trace_is_deterministic_under_fixed_seed() {
    let mk = || {
        let mut content = TraceGen::new(7, 80, 16);
        ArrivalGen::generate(13, ArrivalProcess::Poisson { rate: 0.5 }, &mut content, 32)
            .unwrap()
    };
    let t1 = mk();
    let t2 = mk();
    assert_eq!(t1.len(), 32);
    for (a, b) in t1.iter().zip(&t2) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.request.prompt, b.request.prompt);
        assert_eq!(a.request.max_new, b.request.max_new);
    }
    // ids are the trace order and arrivals strictly increase
    for (i, w) in t1.windows(2).enumerate() {
        assert_eq!(w[0].id, i);
        assert!(w[1].arrival > w[0].arrival);
    }
}

// ---------------------------------------------------------------------
// Engine-level interleaving (artifacts-gated)
// ---------------------------------------------------------------------

/// Two sessions decoded in alternation must produce exactly the tokens
/// and logits of the same requests run back-to-back: per-session KV is
/// private, and with ample VRAM at uniform precision the shared cache
/// cannot change any execution precision.
#[test]
fn interleaved_sessions_match_back_to_back_numerics() {
    let Some(a) = assets() else { return };
    let p1: Vec<i32> = vec![1, 5, 9, 13, 17];
    let p2: Vec<i32> = vec![1, 30, 41, 52, 33, 44];

    let mut serial = bf16_engine(&a);
    let o1 = serial.run(&p1, 6).unwrap();
    let o2 = serial.run(&p2, 5).unwrap();

    let mut fleet = bf16_engine(&a);
    let mut s1 = fleet.begin_session(&p1, 6, None, 0.0).unwrap();
    let mut s2 = fleet.begin_session(&p2, 5, None, 0.0).unwrap();
    fleet.prefill_session(&mut s1).unwrap();
    fleet.prefill_session(&mut s2).unwrap();
    // strict alternation until both finish
    loop {
        let d1 = if s1.done() { true } else { fleet.decode_session(&mut s1).unwrap() };
        let d2 = if s2.done() { true } else { fleet.decode_session(&mut s2).unwrap() };
        if d1 && d2 {
            break;
        }
    }
    let i1 = s1.into_output();
    let i2 = s2.into_output();

    assert_eq!(o1.tokens, i1.tokens, "session 1 tokens diverged under interleaving");
    assert_eq!(o2.tokens, i2.tokens, "session 2 tokens diverged under interleaving");
    for (serial_logits, fleet_logits) in [(&o1, &i1), (&o2, &i2)] {
        for (x, y) in serial_logits
            .logits_per_step
            .iter()
            .zip(&fleet_logits.logits_per_step)
        {
            let max_err = x
                .iter()
                .zip(y)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-5, "interleaving changed numerics: {max_err}");
        }
    }
}

/// run() is implemented on the session API; a manual single-session
/// drive must reproduce it exactly, timing included.
#[test]
fn single_session_steps_match_run_exactly() {
    let Some(a) = assets() else { return };
    let prompt = [1i32, 4, 8, 12];

    let mut e1 = bf16_engine(&a);
    let o = e1.run(&prompt, 5).unwrap();

    let mut e2 = bf16_engine(&a);
    let arrival = e2.clock();
    let mut s = e2.begin_session(&prompt, 5, None, arrival).unwrap();
    e2.prefill_session(&mut s).unwrap();
    while !s.done() {
        e2.decode_session(&mut s).unwrap();
    }
    let m = s.into_output();
    assert_eq!(o.tokens, m.tokens);
    assert_eq!(o.ttft, m.ttft);
    assert_eq!(o.token_times, m.token_times);
}

// ---------------------------------------------------------------------
// Fleet runs (artifacts-gated)
// ---------------------------------------------------------------------

fn fleet_cfg(policy: PolicyKind, max_sessions: usize) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig { max_sessions, ttft_slo_s: 1e6, tpot_slo_s: 1e6 },
        policy,
    }
}

fn tiny_trace(a: &Arc<ModelAssets>, n: usize, rate: f64) -> Vec<dymoe::serving::arrival::TimedRequest> {
    let m = &a.manifest.model;
    let mut content = TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
}

#[test]
fn fleet_completes_all_requests_and_interleaves() {
    let Some(a) = assets() else { return };
    for policy in PolicyKind::ALL {
        let mut engine = bf16_engine(&a);
        // arrivals far faster than service: the queue must build and the
        // rr/slo policies must actually interleave sessions
        let trace = tiny_trace(&a, 8, 50.0);
        let outcome = run_fleet(&mut engine, trace, &fleet_cfg(policy, 4)).unwrap();
        assert_eq!(outcome.metrics.completed, 8, "{} lost requests", policy.name());
        assert_eq!(outcome.per_request.len(), 8);
        assert!(outcome.metrics.makespan() > 0.0);
        assert!(outcome.metrics.throughput_tps() > 0.0);
        // every in-flight session pays for its private KV cache
        assert!(
            outcome.peak_kv_bytes >= outcome.peak_concurrency as u64,
            "KV accounting missing"
        );
        // every request's fleet TTFT covers its queue delay
        for r in &outcome.per_request {
            assert!(r.ttft >= r.queue_delay - 1e-12);
            assert!(r.tokens >= 1);
            assert!(r.finished_at >= r.arrival);
        }
        match policy {
            PolicyKind::Fifo => {
                assert_eq!(outcome.peak_concurrency, 1, "fifo must not interleave");
                // fifo completes in arrival order
                for w in outcome.per_request.windows(2) {
                    assert!(w[0].arrival <= w[1].arrival);
                }
            }
            PolicyKind::RoundRobin | PolicyKind::SloAware => {
                assert!(
                    outcome.peak_concurrency >= 2,
                    "{} never interleaved (peak {})",
                    policy.name(),
                    outcome.peak_concurrency
                );
                assert!(outcome.peak_concurrency <= 4, "admission limit violated");
            }
        }
    }
}

/// At a vanishing arrival rate every session runs alone, so the fleet
/// path must match the classic back-to-back `serve` numbers per request.
#[test]
fn fleet_at_rate_zero_matches_serial_serving() {
    let Some(a) = assets() else { return };
    // arrivals 10,000 s apart: every session is guaranteed to run alone
    let trace: Vec<_> = tiny_trace(&a, 3, 1.0)
        .into_iter()
        .enumerate()
        .map(|(i, mut t)| {
            t.arrival = (i + 1) as f64 * 10_000.0;
            t
        })
        .collect();
    let requests: Vec<_> = trace.iter().map(|t| t.request.clone()).collect();

    let mut fleet_engine = bf16_engine(&a);
    let outcome = run_fleet(
        &mut fleet_engine,
        trace,
        &fleet_cfg(PolicyKind::SloAware, 4),
    )
    .unwrap();

    let mut serial = bf16_engine(&a);
    for (r, done) in requests.iter().zip(&outcome.per_request) {
        let o = serial.run(&r.prompt, r.max_new).unwrap();
        assert!((done.queue_delay).abs() < 1e-9, "queueing at rate ~ 0");
        assert!(
            (o.ttft - done.ttft).abs() < 1e-9,
            "fleet TTFT {} vs serial {}",
            done.ttft,
            o.ttft
        );
        assert!(
            (o.tpot() - done.tpot).abs() < 1e-9,
            "fleet TPOT {} vs serial {}",
            done.tpot,
            o.tpot()
        );
    }
    assert_eq!(outcome.peak_concurrency, 1);
}

//! Integration: predictive gate-probe dispatch and look-ahead pool
//! pre-staging.
//!
//! Four pillars:
//!
//! 1. **Probe-vs-oracle agreement** — the dispatcher's probe recipe
//!    (pad prompt → `embed_seq` → layer-0 `attn_prefill` →
//!    `predict_prefill`) run at full depth predicts a **superset** of
//!    the experts the engine actually executed at layer 0 for the same
//!    prompt, on deterministic workloads.  The oracle comes from the
//!    recorded timeline (executed-expert stamps), not from the
//!    prediction code, so the agreement is not circular.
//! 2. **Engine-free dispatch model properties** — `predictive` routing
//!    over random views is deterministic, in range, an argmax of the
//!    byte-weighted overlap with backlog tie-breaking, and degrades to
//!    jsq-like load balancing when no summary (or no prediction) is
//!    available.  Runs everywhere, no artifacts needed.
//! 3. **Off-path neutrality** — `rr` / `jsq` / `affinity` dispatch
//!    never builds a probe: their outcomes are digest-identical with
//!    the probe-depth knob at any value, across the event loop, the
//!    retired min-clock loop, and `--parallel` workers, with and
//!    without a host pool attached.
//! 4. **Pre-staging discipline** — a predictive run over a shared pool
//!    actually pre-stages (counters move, used + evicted never exceed
//!    staged, accuracy is a valid ratio), and `--parallel` remains
//!    bit-identical to serial with pre-staging on: pre-stage writes
//!    happen only at single-threaded arrival boundaries.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`), matching the other
//! integration suites.

use std::collections::BTreeSet;
use std::sync::Arc;

use dymoe::baselines::{LoadOnDemand, Uniform};
use dymoe::config::{HostPoolConfig, PoolPolicyKind, ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::coordinator::prefetcher::predict_prefill;
use dymoe::memory::EventKind;
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::TimedRequest;
use dymoe::serving::policy::{DispatchKind, PolicyKind, ReplicaDispatchView};
use dymoe::serving::{run_cluster, run_cluster_minclock, FleetConfig};
use dymoe::util::prop;
use dymoe::workload::Request;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

/// Engine whose every routed expert walks the full transfer chain
/// (no VRAM warm fill, SSD under the host tier), so host-pool and
/// pre-staging traffic is actually exercised.
fn pool_engine(a: &Arc<ModelAssets>) -> Engine {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.policy.ssd_resident = true;
    Engine::with_options(
        a,
        sys,
        Box::new(LoadOnDemand::new(Precision::Int4)),
        EngineOptions::default(),
    )
    .unwrap()
}

/// Strictly serial per replica so routed-expert sequences depend only
/// on dispatch; `host_pool`, `dispatch`, and `probe_depth` set per test.
fn fleet_cfg(
    dispatch: DispatchKind,
    pool: Option<HostPoolConfig>,
    probe_depth: usize,
) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions: 1,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: 1,
            host_pool: pool,
            probe_depth,
            ..Default::default()
        },
        policy: PolicyKind::Fifo,
        dispatch,
    }
}

/// Identical prompts at a fixed arrival gap: every arrival is an event
/// boundary (journals flushed), and repeated prompts make the predicted
/// expert set — and therefore pre-stage reuse — deterministic.
fn staggered_trace(a: &Arc<ModelAssets>, n: usize, gap: f64) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let prompt: Vec<i32> = (0..m.max_seq.min(8)).map(|i| 1 + i as i32).collect();
    let max_new = (m.max_cache - m.max_seq).clamp(1, 2);
    (0..n)
        .map(|id| {
            TimedRequest::new(id, id as f64 * gap, Request { prompt: prompt.clone(), max_new })
        })
        .collect()
}

// ---------------------------------------------------------------------
// Probe-vs-oracle agreement (artifacts-gated)
// ---------------------------------------------------------------------

/// The probe recipe the predictive dispatcher runs — pad the prompt,
/// embed, layer-0 attention prefill, `predict_prefill` — must, at full
/// depth, predict every expert the engine then *actually executes* at
/// layer 0 for the same prompt (the executed set can only shrink below
/// the routed set, never grow past it).  The oracle is read back from
/// the engine's recorded timeline: compute events stamped layer 0 with
/// a non-empty expert set.
#[test]
fn probe_predicts_a_superset_of_layer0_executed_experts() {
    let Some(a) = assets() else { return };
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    let mut engine = Engine::with_options(
        &a,
        sys,
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions { record_timeline: true, ..Default::default() },
    )
    .unwrap();
    let m = engine.model().clone();

    for seed in 0..4usize {
        // Deterministic, seed-varied prompts so different gate routes
        // are exercised.
        let prompt: Vec<i32> = (0..m.max_seq.min(12))
            .map(|i| 1 + ((seed * 31 + i * 7) % 50) as i32)
            .collect();
        let before = engine.timeline.events.len();
        engine.run(&prompt, 1).unwrap();
        let executed: BTreeSet<usize> = engine.timeline.events[before..]
            .iter()
            .filter(|e| matches!(e.kind, EventKind::GpuCompute | EventKind::CpuCompute))
            .filter(|e| e.meta.layer == Some(0) && !e.meta.experts.is_empty())
            .flat_map(|e| e.meta.experts.iter().map(|&x| x as usize))
            .collect();
        assert!(!executed.is_empty(), "seed {seed}: oracle saw no layer-0 expert work");

        let seq_len = prompt.len().min(m.max_seq);
        let mut padded = prompt.clone();
        padded.resize(m.max_seq, 0);
        let h = engine.exec.embed_seq(&padded).unwrap();
        let po = engine.exec.attn_prefill(0, &h, seq_len).unwrap();
        let full: BTreeSet<usize> =
            predict_prefill(&po.gate_probs, seq_len, m.n_experts, m.top_k, m.n_experts)
                .into_iter()
                .collect();
        for e in &executed {
            assert!(
                full.contains(e),
                "seed {seed}: layer-0 executed expert {e} missing from the full-depth \
                 probe prediction {full:?}"
            );
        }

        // A truncated probe keeps the ranking discipline: at most
        // `depth` experts, all of them drawn from the full-depth set.
        let topk = predict_prefill(&po.gate_probs, seq_len, m.n_experts, m.top_k, m.top_k);
        assert!(topk.len() <= m.top_k, "seed {seed}: depth {} overran", m.top_k);
        assert!(
            topk.iter().all(|e| full.contains(e)),
            "seed {seed}: truncated probe predicted outside the full set"
        );
    }
}

// ---------------------------------------------------------------------
// Engine-free dispatch model properties (run everywhere)
// ---------------------------------------------------------------------

/// Predictive routing over random views and predictions: always in
/// range, deterministic (a fresh policy instance agrees), an argmax of
/// the byte-weighted overlap score with smaller-backlog tie-breaking,
/// and — with no prediction at all — a jsq-like backlog argmin.
#[test]
fn prop_predictive_dispatch_is_a_deterministic_overlap_argmax() {
    const N_EXPERTS: usize = 8;
    prop::check("predictive-dispatch", 200, |rng| {
        let n = rng.range(1, 9);
        let views: Vec<ReplicaDispatchView> = (0..n)
            .map(|index| ReplicaDispatchView {
                index,
                clock: rng.f64() * 100.0,
                queued_requests: rng.below(5),
                queued_tokens: rng.below(200),
                active_sessions: rng.below(4),
                active_tokens: rng.below(100),
                // Some replicas carry no summary at all (empty vec):
                // the policy must treat them as zero-overlap, not
                // panic or misindex.
                resident_expert_bytes: if rng.below(4) == 0 {
                    Vec::new()
                } else {
                    (0..N_EXPERTS).map(|_| rng.below(1000) as u64 * 100).collect()
                },
            })
            .collect();
        let predicted: Vec<usize> =
            (0..rng.below(6)).map(|_| rng.below(N_EXPERTS)).collect();
        let req = TimedRequest::new(
            rng.below(1000),
            rng.f64(),
            Request { prompt: vec![1, 2, 3], max_new: 2 },
        );
        let score = |v: &ReplicaDispatchView| -> u64 {
            predicted
                .iter()
                .map(|&e| v.resident_expert_bytes.get(e).copied().unwrap_or(0))
                .sum()
        };

        let mut p = DispatchKind::Predictive.build();
        let pick = p.route_predicted(&req, &views, &predicted);
        assert!(pick < n, "predictive routed out of range: {pick} of {n}");
        assert_eq!(
            pick,
            DispatchKind::Predictive.build().route_predicted(&req, &views, &predicted),
            "predictive routing is not deterministic"
        );

        // argmax of the overlap score, ties to the smaller backlog
        let best = score(&views[pick]);
        for v in &views {
            assert!(score(v) <= best, "predictive skipped a higher-overlap replica");
            if score(v) == best {
                assert!(
                    views[pick].backlog_tokens() <= v.backlog_tokens(),
                    "predictive broke an overlap tie toward a longer backlog"
                );
            }
        }

        // No prediction (plain `route`): every score is zero, so the
        // pick must be a backlog argmin — jsq-like degradation.
        let fallback = DispatchKind::Predictive.build().route(&req, &views);
        assert!(fallback < n);
        for v in &views {
            assert!(
                views[fallback].backlog_tokens() <= v.backlog_tokens(),
                "prediction-free predictive dispatch is not jsq-like"
            );
        }
    });
}

// ---------------------------------------------------------------------
// Off-path digest neutrality (artifacts-gated)
// ---------------------------------------------------------------------

/// The probe machinery must be invisible to every other dispatch
/// policy: `rr` / `jsq` / `affinity` outcomes are digest-identical
/// whatever `--probe-depth` says, across the event loop, the retired
/// min-clock loop, and `--parallel` workers — on the pool-less path
/// and (event loop only; the two loops legitimately differ in flush
/// windows with a pool attached) with a shared host pool.
#[test]
fn non_predictive_dispatch_ignores_the_probe_machinery() {
    let Some(a) = assets() else { return };
    let mk = || staggered_trace(&a, 6, 0.2);
    let non_predictive = [
        DispatchKind::RoundRobin,
        DispatchKind::JoinShortestQueue,
        DispatchKind::ExpertAffinity,
    ];
    for dispatch in non_predictive {
        let label = dispatch.name();

        // pool-less: knob inert, all three loops bit-identical
        let base = fleet_cfg(dispatch, None, 0);
        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let reference = run_cluster(&mut engines, mk(), &base).unwrap();

        let knob = fleet_cfg(dispatch, None, 7);
        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let knobbed = run_cluster(&mut engines, mk(), &knob).unwrap();
        assert_eq!(
            reference.digest(),
            knobbed.digest(),
            "{label}: --probe-depth changed a non-predictive outcome"
        );

        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let minclock = run_cluster_minclock(&mut engines, mk(), &base).unwrap();
        assert_eq!(reference.digest(), minclock.digest(), "{label}: min-clock diverged");

        let mut par = base.clone();
        par.serving.parallel = 2;
        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let parallel = run_cluster(&mut engines, mk(), &par).unwrap();
        assert_eq!(reference.digest(), parallel.digest(), "{label}: parallel diverged");

        // pooled: the probe-depth knob stays inert (non-predictive
        // runs never pre-stage, so the pool sees identical traffic)
        let pool = || Some(HostPoolConfig { capacity_bytes: GB, policy: PoolPolicyKind::Shared });
        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let pooled = run_cluster(&mut engines, mk(), &fleet_cfg(dispatch, pool(), 0)).unwrap();
        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let pooled_knob =
            run_cluster(&mut engines, mk(), &fleet_cfg(dispatch, pool(), 7)).unwrap();
        assert_eq!(
            pooled.digest(),
            pooled_knob.digest(),
            "{label}: --probe-depth changed a pooled non-predictive outcome"
        );
        assert_eq!(pooled.pool.prestaged, 0, "{label}: non-predictive run pre-staged");
        assert_eq!(pooled.pool, pooled_knob.pool, "{label}: pool counters diverged");
    }
}

// ---------------------------------------------------------------------
// Pre-staging discipline (artifacts-gated)
// ---------------------------------------------------------------------

/// A predictive run over a shared pool must actually pre-stage, resolve
/// its flags coherently (used + evicted never exceed staged; accuracy
/// is a ratio), convert pre-staged copies into demand hits, and stay
/// bit-identical — digest *and* pool counters — under `--parallel`,
/// because pre-stage writes land only at single-threaded arrival
/// boundaries with every window journal flushed.
#[test]
fn predictive_prestaging_accounts_and_stays_parallel_deterministic() {
    let Some(a) = assets() else { return };
    let mk = || staggered_trace(&a, 8, 0.15);
    let base = fleet_cfg(
        DispatchKind::Predictive,
        Some(HostPoolConfig { capacity_bytes: GB, policy: PoolPolicyKind::Shared }),
        0,
    );
    let mut serial_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let serial = run_cluster(&mut serial_engines, mk(), &base).unwrap();

    assert_eq!(serial.fleet.metrics.completed, 8);
    assert!(serial.pool.prestaged > 0, "predictive pool run never pre-staged");
    assert!(
        serial.pool.prestage_used + serial.pool.prestage_evicted <= serial.pool.prestaged,
        "pre-stage flags over-resolved: {} used + {} evicted of {} staged",
        serial.pool.prestage_used,
        serial.pool.prestage_evicted,
        serial.pool.prestaged
    );
    assert!(
        serial.pool.prestage_used > 0,
        "identical prompts demand the experts just pre-staged for them, yet none resolved used"
    );
    let acc = serial.pool.prestage_accuracy();
    assert!((0.0..=1.0).contains(&acc), "pre-stage accuracy {acc} out of range");
    assert!(serial.pool.host_hits > 0, "pre-staged copies never served a hit");
    // detach discipline still holds with pre-staging in the mix
    assert!(serial_engines.iter().all(|e| e.host_pool.is_none()), "handle leaked");

    let mut par_cfg = base.clone();
    par_cfg.serving.parallel = 2;
    let mut par_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let parallel = run_cluster(&mut par_engines, mk(), &par_cfg).unwrap();
    assert_eq!(
        parallel.digest(),
        serial.digest(),
        "predictive + pre-staging diverged under --parallel"
    );
    assert_eq!(parallel.pool, serial.pool, "pool counters diverged under --parallel");

    // and the whole thing is run-to-run deterministic
    let mut again_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let again = run_cluster(&mut again_engines, mk(), &base).unwrap();
    assert_eq!(again.digest(), serial.digest(), "predictive run not reproducible");
    assert_eq!(again.pool, serial.pool);
}

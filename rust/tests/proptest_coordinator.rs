//! Property tests over the coordinator invariants (routing, scheduling,
//! caching, timeline) using the in-tree property-test driver
//! (`dymoe::util::prop`; proptest itself is not vendored offline).

use dymoe::coordinator::cache::{Lookup, MixedPrecisionCache};
use dymoe::coordinator::scheduler::{
    assign_precisions, layer_budget, retention, Allocation, Selection,
};
use dymoe::coordinator::{importance, prefetcher, top_k_route};
use dymoe::memory::timeline::Channel;
use dymoe::model::assets::ExpertKey;
use dymoe::quant::Precision;
use dymoe::util::prop::check;

fn rand_probs(rng: &mut dymoe::util::rng::Rng, m: usize) -> Vec<f32> {
    let raw: Vec<f64> = (0..m).map(|_| rng.f64() + 1e-6).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|x| (x / total) as f32).collect()
}

#[test]
fn prop_routing_invariants() {
    check("routing", 200, |rng| {
        let m = rng.range(2, 64);
        let k = rng.range(1, m.min(8));
        let probs = rand_probs(rng, m);
        let route = top_k_route(&probs, k);
        // exactly k distinct experts
        assert_eq!(route.len(), k);
        let mut seen: Vec<usize> = route.iter().map(|&(e, _)| e).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), k);
        // weights positive, normalized
        let total: f32 = route.iter().map(|&(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-4);
        assert!(route.iter().all(|&(_, w)| w > 0.0));
        // selected experts dominate every unselected one
        let min_sel = route
            .iter()
            .map(|&(e, _)| probs[e])
            .fold(f32::INFINITY, f32::min);
        let chosen: std::collections::HashSet<usize> =
            route.iter().map(|&(e, _)| e).collect();
        for (e, &p) in probs.iter().enumerate() {
            if !chosen.contains(&e) {
                assert!(p <= min_sel + 1e-7);
            }
        }
    });
}

#[test]
fn prop_scheduler_budget_exactness() {
    check("scheduler-budget", 200, |rng| {
        let n_layers = rng.range(1, 48);
        let m = rng.range(1, 128);
        let r = rng.f64();
        let layer = rng.below(n_layers);
        for alloc in [Allocation::DepthCosine, Allocation::Equal] {
            let b = layer_budget(alloc, layer, n_layers, r, m);
            assert!((1..=m).contains(&b), "budget {b} outside [1, {m}]");
        }
        // budgets are monotone in depth for the cosine schedule
        let mut prev = usize::MAX;
        for l in 0..n_layers {
            let b = layer_budget(Allocation::DepthCosine, l, n_layers, r, m);
            assert!(b <= prev);
            prev = b;
        }
        // assignment honors the budget exactly
        let imp: Vec<f64> = (0..m).map(|_| rng.f64()).collect();
        let budget = rng.range(1, m);
        for sel in [Selection::Importance, Selection::Random] {
            let p = assign_precisions(
                &imp,
                budget,
                sel,
                Precision::Int4,
                Precision::Int2,
                rng,
            );
            let hi = p.iter().filter(|&&x| x == Precision::Int4).count();
            assert_eq!(hi, budget);
            assert_eq!(p.len(), m);
        }
        // importance selection picks a superset-dominating set
        let p = assign_precisions(
            &imp,
            budget,
            Selection::Importance,
            Precision::Int4,
            Precision::Skip,
            rng,
        );
        let min_hi = imp
            .iter()
            .zip(&p)
            .filter(|(_, &x)| x == Precision::Int4)
            .map(|(i, _)| *i)
            .fold(f64::INFINITY, f64::min);
        for (i, x) in imp.iter().zip(&p) {
            if *x != Precision::Int4 {
                assert!(*i <= min_hi + 1e-12);
            }
        }
    });
}

#[test]
fn prop_retention_bounds_and_monotonicity() {
    check("retention", 300, |rng| {
        let n = rng.range(2, 64);
        let lambda = rng.f64();
        let mut prev = f64::INFINITY;
        for l in 0..n {
            let r = retention(l, n, lambda);
            assert!(r <= prev + 1e-12);
            assert!(r >= lambda - 1e-12 && r <= 1.0 + 1e-12);
            prev = r;
        }
    });
}

#[test]
fn prop_cache_invariants_under_random_workload() {
    check("cache-invariants", 100, |rng| {
        let capacity = rng.range(100, 2000) as u64;
        let mut cache = MixedPrecisionCache::new(capacity);
        let precs = [Precision::Bf16, Precision::Int8, Precision::Int4, Precision::Int2];
        let mut model: std::collections::HashMap<ExpertKey, Precision> =
            std::collections::HashMap::new();
        for _ in 0..300 {
            let key = ExpertKey::new(rng.below(4), rng.below(8));
            let p = precs[rng.below(4)];
            match rng.below(3) {
                0 => {
                    // lookup consistency vs shadow model
                    let got = cache.lookup(key, p);
                    match (model.get(&key), got) {
                        (Some(&mp), Lookup::Hit { prec, .. }) => {
                            assert!(mp.satisfies(p));
                            assert_eq!(prec, mp);
                        }
                        (Some(&mp), Lookup::Miss { promotes }) => {
                            assert!(!mp.satisfies(p));
                            assert!(promotes);
                        }
                        (None, Lookup::Miss { promotes }) => assert!(!promotes),
                        (None, Lookup::Hit { .. }) => panic!("phantom hit"),
                    }
                }
                1 => {
                    let bytes = rng.range(10, 400) as u64;
                    if let Some(evicted) = cache.insert(key, p, bytes, 0.0) {
                        for ev in evicted {
                            model.remove(&ev);
                        }
                        // no-duplication: entry now at >= p
                        let now = cache.contains(key).unwrap();
                        assert!(now.satisfies(p));
                        if let Some(&old) = model.get(&key) {
                            assert_eq!(now, old.max(p));
                        } else {
                            assert_eq!(now, p);
                        }
                        model.insert(key, now);
                    }
                }
                _ => {
                    // pins never change membership, whichever class drops
                    use dymoe::coordinator::cache::PinClass;
                    cache.unpin_all(if rng.below(2) == 0 {
                        PinClass::Warm
                    } else {
                        PinClass::Layer
                    });
                }
            }
            assert!(cache.used_bytes() <= capacity, "capacity violated");
            assert_eq!(cache.len(), model.len(), "shadow divergence");
        }
    });
}

#[test]
fn prop_timeline_channels_never_time_travel() {
    check("timeline", 200, |rng| {
        let mut ch = Channel::default();
        let mut last_demand_end = 0.0_f64;
        let mut clock = 0.0_f64;
        for _ in 0..100 {
            clock += rng.f64() * 0.01;
            let dur = rng.f64() * 0.02;
            if rng.f64() < 0.5 {
                let (s, e) = ch.schedule(clock, dur);
                assert!(s >= clock && s >= last_demand_end - 1e-12);
                assert!((e - s - dur).abs() < 1e-12);
                last_demand_end = e;
            } else {
                let (s, e) = ch.schedule_background(clock, dur);
                assert!(s >= clock);
                assert!(e >= s);
                // background never moves the demand horizon
                assert!(ch.free_at == last_demand_end.max(0.0));
            }
        }
    });
}

#[test]
fn prop_importance_heavy_hitter_counts() {
    check("importance", 150, |rng| {
        let m = rng.range(2, 16);
        let seq = rng.range(1, 40);
        let k_route = rng.range(1, m.min(4));
        let scores: Vec<f32> = (0..seq).map(|_| rng.f64() as f32).collect();
        let routes: Vec<Vec<(usize, f32)>> = (0..seq)
            .map(|_| {
                let probs = rand_probs(rng, m);
                top_k_route(&probs, k_route)
            })
            .collect();
        let frac = rng.f64();
        let imp = importance::prefill_importance(&scores, &routes, m, frac);
        assert_eq!(imp.len(), m);
        assert!(imp.iter().all(|&x| x >= 0.0));
        // total integer part equals heavy-hitter token-route count
        let k = ((seq as f64 * frac).ceil() as usize).clamp(1, seq);
        let heavy = importance::heavy_hitters(&scores, seq, k);
        let expected: usize = heavy.iter().map(|&t| routes[t].len()).sum();
        let total_int: f64 = imp.iter().map(|x| x.floor()).sum();
        assert!(
            (total_int - expected as f64).abs() < 1.0 + m as f64 * 0.01,
            "count mismatch: {total_int} vs {expected}"
        );
    });
}

/// Quantization round-trip: for every stored precision and several group
/// sizes, group-wise RTN keeps each weight within half a quantization
/// step of the original (the documented per-precision bound), values sit
/// in the signed symmetric range, and the packed representation is
/// bit-lossless.
#[test]
fn prop_quant_roundtrip_bound_all_stored_precisions() {
    use dymoe::quant::{
        dequantize_groupwise, pack_words, quant_range, quantize_groupwise, unpack_words,
    };
    check("quant-roundtrip-stored", 80, |rng| {
        let prec = Precision::ALL_STORED[rng.below(Precision::ALL_STORED.len())];
        let bits = prec.bits();
        let vpw = (32 / bits) as usize;
        // group sizes are multiples of 16, so every group also packs into
        // whole u32 words (vpw in {2, 4, 8, 16} divides 16)
        let group = [16usize, 32, 64][rng.below(3)];
        let k = group * rng.range(1, 4);
        let n = rng.range(1, 4);
        let amp = 0.1 + rng.f64() * 4.0;
        let w: Vec<f32> = (0..k * n)
            .map(|_| ((rng.f64() * 2.0 - 1.0) * amp) as f32)
            .collect();

        let (q, s) = quantize_groupwise(&w, k, n, bits, group);
        let (lo, hi) = quant_range(bits);
        assert!(q.iter().all(|&v| (lo..=hi).contains(&v)), "{prec:?} out of range");

        // documented bound: |w - deq(q)| <= scale / 2 per group/column
        let back = dequantize_groupwise(&q, &s, k, n, group);
        for r in 0..k {
            for c in 0..n {
                let err = (back[r * n + c] - w[r * n + c]).abs();
                let scale = s[(r / group) * n + c];
                assert!(
                    err <= 0.5 * scale + 1e-5,
                    "{prec:?} group {group}: err {err} > scale/2 {scale}"
                );
            }
        }

        // pack/unpack is lossless
        let words = pack_words(&q, k, n, bits);
        assert_eq!(words.len(), k / vpw * n);
        assert_eq!(unpack_words(&words, k / vpw, n, bits), q, "{prec:?} pack loss");
    });
}

/// Scheduler liveness and accounting, engine-free: drive every policy
/// (with random decode-batch limits) over random seeded arrival traces
/// through a model of the `run_fleet` loop with synthetic service times.
/// Every admitted session must complete within a bounded number of
/// ticks (no starvation), every action must be legal, and the resulting
/// fleet goodput can never exceed the offered load.
#[test]
fn prop_scheduler_no_starvation_and_goodput_bounded() {
    use dymoe::coordinator::engine::RequestOutput;
    use dymoe::serving::arrival::TenantClass;
    use dymoe::serving::metrics::{FleetMetrics, SloTargets};
    use dymoe::serving::policy::{Action, ActiveInfo, PolicyKind, QueuedInfo, SchedView};

    struct Sim {
        id: usize,
        arrival: f64,
        start: f64,
        ttft: f64,
        target: usize,
        token_times: Vec<f64>,
        last_token_at: f64,
    }

    check("fleet-scheduler", 60, |rng| {
        let n = rng.range(1, 20);
        let policy_kind = PolicyKind::ALL[rng.below(PolicyKind::ALL.len())];
        let max_sessions = rng.range(1, 6);
        let max_batch = rng.range(1, 6);
        let slo = SloTargets { ttft_s: 0.2 + rng.f64(), tpot_s: 0.02 + rng.f64() * 0.2 };

        // random open-loop trace (strictly increasing arrivals)
        let mut t = 0.0;
        let trace: Vec<(usize, f64, usize)> = (0..n)
            .map(|id| {
                t += rng.exponential(0.5 + rng.f64() * 4.0);
                (id, t, rng.range(1, 8))
            })
            .collect();
        let total_tokens: usize = trace.iter().map(|&(_, _, tok)| tok).sum();

        let mut policy = policy_kind.build();
        let mut metrics = FleetMetrics::default();
        let mut next_pending = 0usize;
        let mut queued: Vec<(usize, f64, f64, usize)> = Vec::new(); // id, arrival, deadline, target
        let mut active: Vec<Sim> = Vec::new();
        let mut clock = 0.0f64;
        let mut ticks = 0usize;
        let tick_budget = 4 * (n + total_tokens) + 64;

        loop {
            ticks += 1;
            assert!(
                ticks <= tick_budget,
                "{} starved: {} of {n} done after {ticks} ticks",
                policy_kind.name(),
                metrics.completed
            );
            while next_pending < n && trace[next_pending].1 <= clock {
                let (id, arr, tok) = trace[next_pending];
                queued.push((id, arr, arr + slo.ttft_s, tok));
                next_pending += 1;
            }
            if queued.is_empty() && active.is_empty() {
                if next_pending < n {
                    let (id, arr, tok) = trace[next_pending];
                    queued.push((id, arr, arr + slo.ttft_s, tok));
                    next_pending += 1;
                    clock = clock.max(arr);
                    continue;
                }
                break;
            }

            let queued_info: Vec<QueuedInfo> = queued
                .iter()
                .map(|&(id, arrival, deadline, _)| QueuedInfo {
                    id,
                    arrival,
                    deadline,
                    class: TenantClass::Interactive,
                })
                .collect();
            let active_info: Vec<ActiveInfo> = active
                .iter()
                .map(|s| ActiveInfo {
                    id: s.id,
                    arrival: s.arrival,
                    class: TenantClass::Interactive,
                    emitted: s.token_times.len(),
                    target: s.target,
                    last_token_at: s.last_token_at,
                    prefill_remaining: 0,
                })
                .collect();
            let free_slots = max_sessions.saturating_sub(active.len());
            let view = SchedView {
                now: clock,
                queued: &queued_info,
                active: &active_info,
                free_slots,
            };
            let mut action = policy.next_action(&view);
            if action == Action::Idle {
                // the run_fleet work-conserving fallback
                action = if free_slots > 0 && !queued.is_empty() {
                    Action::Admit(queued[0].0)
                } else if let Some(s) = active.first() {
                    Action::Decode(s.id)
                } else {
                    panic!("policy idle with {} queued and no slots", queued.len());
                };
            }
            match action {
                Action::Admit(id) => {
                    assert!(free_slots > 0, "{} admitted with no free slot", policy_kind.name());
                    let pos = queued
                        .iter()
                        .position(|q| q.0 == id)
                        .unwrap_or_else(|| panic!("admitted unknown session {id}"));
                    let (id, arrival, _, target) = queued.swap_remove(pos);
                    let start = clock.max(arrival);
                    let svc = 0.05 + rng.f64() * 0.1; // synthetic prefill
                    clock = start + svc;
                    let sim = Sim {
                        id,
                        arrival,
                        start,
                        ttft: clock - start,
                        target,
                        token_times: vec![clock - start],
                        last_token_at: clock,
                    };
                    if sim.target <= 1 {
                        finish(&mut metrics, &sim, slo);
                    } else {
                        active.push(sim);
                    }
                }
                Action::Decode(id) => {
                    let batch_ids = if max_batch > 1 && active.len() > 1 {
                        policy.decode_batch(&view, id, max_batch)
                    } else {
                        vec![id]
                    };
                    assert!(!batch_ids.is_empty(), "empty decode batch");
                    assert!(batch_ids.len() <= max_batch.max(1), "batch over limit");
                    assert!(batch_ids.contains(&id), "policy dropped its own pick");
                    let mut seen = std::collections::HashSet::new();
                    for bid in &batch_ids {
                        assert!(seen.insert(*bid), "duplicate {bid} in batch");
                        assert!(
                            active.iter().any(|s| s.id == *bid),
                            "batched inactive session {bid}"
                        );
                    }
                    // synthetic fused step: sublinear in batch size
                    clock += 0.01 + 0.004 * batch_ids.len() as f64;
                    let mut finished: Vec<usize> = Vec::new();
                    for s in active.iter_mut().filter(|s| batch_ids.contains(&s.id)) {
                        s.token_times.push(clock - s.start);
                        s.last_token_at = clock;
                        if s.token_times.len() >= s.target {
                            finished.push(s.id);
                        }
                    }
                    for fid in finished {
                        let pos = active.iter().position(|s| s.id == fid).unwrap();
                        let s = active.swap_remove(pos);
                        finish(&mut metrics, &s, slo);
                    }
                }
                Action::Idle => unreachable!(),
            }
        }

        // liveness: every admitted session completed, with all its tokens
        assert_eq!(metrics.completed, n, "{} lost sessions", policy_kind.name());
        assert_eq!(metrics.tokens_total, total_tokens, "token accounting");
        // goodput can never exceed offered load
        if n >= 2 {
            let span = trace[n - 1].1 - trace[0].1;
            if span > 0.0 {
                let offered = n as f64 / span;
                assert!(
                    metrics.goodput_rps() <= offered + 1e-9,
                    "{}: goodput {} above offered {offered}",
                    policy_kind.name(),
                    metrics.goodput_rps()
                );
            }
        }
    });

    fn finish(
        metrics: &mut dymoe::serving::metrics::FleetMetrics,
        s: &Sim,
        slo: dymoe::serving::metrics::SloTargets,
    ) {
        let out = RequestOutput {
            tokens: vec![0; s.token_times.len()],
            ttft: s.ttft,
            token_times: s.token_times.clone(),
            logits_per_step: Vec::new(),
            prefill_hidden: Vec::new(),
            start: s.start,
        };
        metrics.record(s.id, s.arrival, &out, slo);
    }
}

/// Token-budget (chunked-prefill) scheduler invariants, engine-free:
/// drive every policy's `mixed_tick` over random arrival / prompt-length
/// mixes through a model of the chunked `run_fleet` loop.  Per tick the
/// plan must respect both budgets (at most `chunk_tokens` prefill tokens
/// for one session, at most `max_decode` decode tokens), never decode a
/// session that is not ready, and strictly advance the granted session's
/// cursor (no prefill starvation); across the run every session's chunk
/// sizes must sum to exactly its prompt length (token conservation) and
/// every session must finish within a bounded number of ticks.
#[test]
fn prop_token_budget_scheduler_conserves_tokens_and_advances() {
    use dymoe::serving::arrival::TenantClass;
    use dymoe::serving::policy::{ActiveInfo, PolicyKind, QueuedInfo, SchedView, TickPlan};

    struct Sim {
        id: usize,
        arrival: f64,
        prompt_len: usize,
        cursor: usize,
        chunk_sum: usize,
        emitted: usize,
        target: usize,
        last_token_at: f64,
    }

    check("token-budget-scheduler", 60, |rng| {
        let n = rng.range(1, 16);
        let policy_kind = PolicyKind::ALL[rng.below(PolicyKind::ALL.len())];
        let max_sessions = rng.range(1, 5);
        let max_decode = rng.range(1, 5);
        let chunk_tokens = rng.range(1, 6);

        let mut t = 0.0;
        let trace: Vec<(usize, f64, usize, usize)> = (0..n)
            .map(|id| {
                t += rng.exponential(0.5 + rng.f64() * 4.0);
                (id, t, rng.range(1, 24), rng.range(1, 6)) // prompt len, decode target
            })
            .collect();
        let total_prompt: usize = trace.iter().map(|&(_, _, p, _)| p).sum();
        let total_decode: usize = trace.iter().map(|&(_, _, _, d)| d).sum();

        let mut policy = policy_kind.build();
        let mut next_pending = 0usize;
        let mut queued: Vec<(usize, f64, usize, usize)> = Vec::new();
        let mut active: Vec<Sim> = Vec::new();
        let mut completed = 0usize;
        let mut clock = 0.0f64;
        let mut ticks = 0usize;
        let tick_budget = 4 * (total_prompt + total_decode + n) + 64;

        loop {
            while next_pending < n && trace[next_pending].1 <= clock {
                queued.push(trace[next_pending]);
                next_pending += 1;
            }
            if queued.is_empty() && active.is_empty() {
                if next_pending < n {
                    let r = trace[next_pending];
                    clock = clock.max(r.1);
                    queued.push(r);
                    next_pending += 1;
                    continue;
                }
                break;
            }
            ticks += 1;
            assert!(
                ticks <= tick_budget,
                "{} starved: {completed} of {n} done after {ticks} ticks",
                policy_kind.name()
            );

            let mk_view = |queued: &[(usize, f64, usize, usize)],
                           active: &[Sim],
                           free: usize,
                           now: f64| {
                let q: Vec<QueuedInfo> = queued
                    .iter()
                    .map(|&(id, arrival, _, _)| QueuedInfo {
                        id,
                        arrival,
                        deadline: arrival + 1.0,
                        class: TenantClass::Interactive,
                    })
                    .collect();
                let a: Vec<ActiveInfo> = active
                    .iter()
                    .map(|s| ActiveInfo {
                        id: s.id,
                        arrival: s.arrival,
                        class: TenantClass::Interactive,
                        emitted: s.emitted,
                        target: s.target,
                        last_token_at: s.last_token_at,
                        prefill_remaining: if s.emitted > 0 {
                            0
                        } else {
                            s.prompt_len - s.cursor
                        },
                    })
                    .collect();
                (q, a, free, now)
            };

            // admission fills free slots (no engine work in chunked mode)
            while active.len() < max_sessions && !queued.is_empty() {
                let free = max_sessions - active.len();
                let (q, a, free, now) = mk_view(&queued, &active, free, clock);
                let view = SchedView { now, queued: &q, active: &a, free_slots: free };
                let Some(id) = policy.admit_pick(&view) else { break };
                let pos = queued
                    .iter()
                    .position(|r| r.0 == id)
                    .unwrap_or_else(|| panic!("admitted unknown session {id}"));
                let (id, arrival, prompt_len, target) = queued.swap_remove(pos);
                active.push(Sim {
                    id,
                    arrival,
                    prompt_len,
                    cursor: 0,
                    chunk_sum: 0,
                    emitted: 0,
                    target,
                    last_token_at: arrival,
                });
            }
            assert!(!active.is_empty(), "admission wedged");

            let (q, a, free, now) =
                mk_view(&queued, &active, max_sessions - active.len(), clock);
            let view = SchedView { now, queued: &q, active: &a, free_slots: free };
            let mut plan = policy.mixed_tick(&view, max_decode);
            if plan.is_empty() {
                // the run_fleet work-conserving fallback
                let pre = a.iter().find(|x| x.prefill_remaining > 0).map(|x| x.id);
                let dec: Vec<usize> =
                    a.iter().filter(|x| x.decode_ready()).take(1).map(|x| x.id).collect();
                assert!(
                    pre.is_some() || !dec.is_empty(),
                    "{} idle with runnable sessions",
                    policy_kind.name()
                );
                plan = TickPlan { prefill: pre, decode: dec };
            }

            // ---- budget + legality invariants ------------------------
            assert!(
                plan.decode.len() <= max_decode,
                "{}: decode batch {} over budget {max_decode}",
                policy_kind.name(),
                plan.decode.len()
            );
            let mut seen = std::collections::HashSet::new();
            for id in &plan.decode {
                assert!(seen.insert(*id), "duplicate {id} in decode plan");
                let s = active.iter().find(|s| s.id == *id).expect("decode of inactive");
                assert!(s.emitted > 0, "decoded un-prefilled session {id}");
                assert!(s.emitted < s.target, "decoded finished session {id}");
            }

            let mut advanced = 0usize;
            if let Some(id) = plan.prefill {
                let s = active
                    .iter_mut()
                    .find(|s| s.id == id)
                    .expect("chunked an inactive session");
                assert_eq!(s.emitted, 0, "chunked a prefilled session {id}");
                let before = s.cursor;
                let granted = chunk_tokens.min(s.prompt_len - s.cursor);
                // the cursor strictly advances and never over-runs
                assert!(granted >= 1 && granted <= chunk_tokens);
                s.cursor += granted;
                s.chunk_sum += granted;
                assert!(s.cursor > before && s.cursor <= s.prompt_len);
                advanced += granted;
                if s.cursor == s.prompt_len {
                    // token conservation: chunk sizes tile the prompt
                    assert_eq!(
                        s.chunk_sum, s.prompt_len,
                        "chunks of session {id} do not sum to its prompt"
                    );
                    s.emitted = 1; // first token
                }
            }
            // synthetic fused tick, sublinear in its token budget
            clock += 0.01 + 0.002 * (advanced + plan.decode.len()) as f64;
            let mut finished: Vec<usize> = Vec::new();
            for s in active.iter_mut() {
                if plan.prefill == Some(s.id) && s.emitted == 1 && s.target == 1 {
                    finished.push(s.id);
                    continue;
                }
                if plan.decode.contains(&s.id) {
                    s.emitted += 1;
                    s.last_token_at = clock;
                    if s.emitted >= s.target {
                        finished.push(s.id);
                    }
                }
            }
            for fid in finished {
                let pos = active.iter().position(|s| s.id == fid).unwrap();
                active.swap_remove(pos);
                completed += 1;
            }
        }
        assert_eq!(completed, n, "{} lost sessions", policy_kind.name());
    });
}

#[test]
fn prop_prefетch_predictions_are_valid_experts() {
    check("prefetch", 150, |rng| {
        let m = rng.range(2, 32);
        let t = rng.range(1, m);
        let probs = rand_probs(rng, m);
        let picks = prefetcher::predict_decode(&probs, t);
        assert_eq!(picks.len(), t);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), t, "duplicate predictions");
        // prefill counts respect seq_len
        let seq = rng.range(1, 12);
        let all: Vec<f32> = (0..seq).flat_map(|_| rand_probs(rng, m)).collect();
        let picks = prefetcher::predict_prefill(&all, seq, m, 2.min(m), t);
        assert!(picks.len() <= t);
        assert!(picks.iter().all(|&e| e < m));
    });
}

/// `FleetMetrics::merge` must be merge-order invariant and equivalent
/// to a single collector over the same request records: the cluster
/// folds per-replica collectors in replica order, and none of the
/// summary statistics may depend on that order (or on how the records
/// were partitioned across replicas).  Counters, spans, and order
/// statistics are exact; means are floating-point sums, so they agree
/// to rounding only.
#[test]
fn prop_fleet_metrics_merge_is_order_invariant() {
    use dymoe::coordinator::engine::RequestOutput;
    use dymoe::serving::metrics::{FleetMetrics, SloTargets};

    check("fleet-metrics-merge", 80, |rng| {
        let slo = SloTargets {
            ttft_s: rng.f64() * 4.0 + 0.1,
            tpot_s: rng.f64() + 0.01,
        };
        let n = rng.range(1, 24);
        let mut records: Vec<(usize, f64, RequestOutput)> = Vec::with_capacity(n);
        for id in 0..n {
            let arrival = rng.f64() * 10.0;
            let start = arrival + rng.f64() * 2.0;
            let ttft = rng.f64() * 1.5 + 1e-3;
            let tokens = rng.range(1, 6);
            let mut token_times = vec![ttft];
            for _ in 1..tokens {
                token_times.push(token_times.last().unwrap() + rng.f64() * 0.5 + 1e-4);
            }
            let out = RequestOutput {
                tokens: vec![0; tokens],
                ttft,
                token_times,
                logits_per_step: Vec::new(),
                prefill_hidden: Vec::new(),
                start,
            };
            records.push((id, arrival, out));
        }

        // reference: every record folded into one collector
        let mut reference = FleetMetrics::default();
        for (id, arrival, out) in &records {
            reference.record(*id, *arrival, out, slo);
        }

        // partition the records round-robin across k per-replica
        // collectors (some possibly empty), then merge forward and in
        // reverse — both must equal the single collector
        let k = rng.range(1, 5);
        let mut parts: Vec<FleetMetrics> = (0..k).map(|_| FleetMetrics::default()).collect();
        for (i, (id, arrival, out)) in records.iter().enumerate() {
            parts[i % k].record(*id, *arrival, out, slo);
        }
        let mut fwd = FleetMetrics::default();
        for p in &parts {
            fwd.merge(p);
        }
        let mut rev = FleetMetrics::default();
        for p in parts.iter().rev() {
            rev.merge(p);
        }

        for (label, m) in [("forward", &fwd), ("reverse", &rev)] {
            assert_eq!(m.completed, reference.completed, "{label}: completed");
            assert_eq!(m.ttft_ok, reference.ttft_ok, "{label}: ttft_ok");
            assert_eq!(m.tpot_ok, reference.tpot_ok, "{label}: tpot_ok");
            assert_eq!(m.slo_ok, reference.slo_ok, "{label}: slo_ok");
            assert_eq!(m.tokens_total, reference.tokens_total, "{label}: tokens");
            assert_eq!(m.first_arrival, reference.first_arrival, "{label}: first arrival");
            assert_eq!(m.last_completion, reference.last_completion, "{label}: last completion");
            assert_eq!(m.makespan(), reference.makespan(), "{label}: makespan");
            // order statistics select elements of the sample multiset,
            // which merging only permutes — exact equality
            for p in [0.0, 25.0, 50.0, 95.0, 99.0, 100.0] {
                assert_eq!(
                    m.ttft.percentile(p),
                    reference.ttft.percentile(p),
                    "{label}: ttft p{p}"
                );
                assert_eq!(
                    m.tpot.percentile(p),
                    reference.tpot.percentile(p),
                    "{label}: tpot p{p}"
                );
                assert_eq!(m.e2e.percentile(p), reference.e2e.percentile(p), "{label}: e2e p{p}");
                assert_eq!(
                    m.stall.percentile(p),
                    reference.stall.percentile(p),
                    "{label}: stall p{p}"
                );
                assert_eq!(
                    m.queue_delay.percentile(p),
                    reference.queue_delay.percentile(p),
                    "{label}: queue p{p}"
                );
            }
            // means are fp sums over permuted sample orders: rounding-
            // level agreement
            assert!((m.ttft.mean() - reference.ttft.mean()).abs() < 1e-9, "{label}: ttft mean");
            assert!(
                (m.queue_delay.mean() - reference.queue_delay.mean()).abs() < 1e-9,
                "{label}: queue mean"
            );
            // derived rates follow from the invariants above
            assert!(
                (m.goodput_rps() - reference.goodput_rps()).abs() < 1e-9,
                "{label}: goodput"
            );
            assert_eq!(m.slo_attainment(), reference.slo_attainment(), "{label}: attainment");
        }
        // merging an empty collector is the identity on every counter
        let before = (fwd.completed, fwd.first_arrival, fwd.last_completion);
        fwd.merge(&FleetMetrics::default());
        assert_eq!(before, (fwd.completed, fwd.first_arrival, fwd.last_completion));
    });
}

//! Property tests over the coordinator invariants (routing, scheduling,
//! caching, timeline) using the in-tree property-test driver
//! (`dymoe::util::prop`; proptest itself is not vendored offline).

use dymoe::coordinator::cache::{Lookup, MixedPrecisionCache};
use dymoe::coordinator::scheduler::{
    assign_precisions, layer_budget, retention, Allocation, Selection,
};
use dymoe::coordinator::{importance, prefetcher, top_k_route};
use dymoe::memory::timeline::Channel;
use dymoe::model::assets::ExpertKey;
use dymoe::quant::Precision;
use dymoe::util::prop::check;

fn rand_probs(rng: &mut dymoe::util::rng::Rng, m: usize) -> Vec<f32> {
    let raw: Vec<f64> = (0..m).map(|_| rng.f64() + 1e-6).collect();
    let total: f64 = raw.iter().sum();
    raw.into_iter().map(|x| (x / total) as f32).collect()
}

#[test]
fn prop_routing_invariants() {
    check("routing", 200, |rng| {
        let m = rng.range(2, 64);
        let k = rng.range(1, m.min(8));
        let probs = rand_probs(rng, m);
        let route = top_k_route(&probs, k);
        // exactly k distinct experts
        assert_eq!(route.len(), k);
        let mut seen: Vec<usize> = route.iter().map(|&(e, _)| e).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), k);
        // weights positive, normalized
        let total: f32 = route.iter().map(|&(_, w)| w).sum();
        assert!((total - 1.0).abs() < 1e-4);
        assert!(route.iter().all(|&(_, w)| w > 0.0));
        // selected experts dominate every unselected one
        let min_sel = route
            .iter()
            .map(|&(e, _)| probs[e])
            .fold(f32::INFINITY, f32::min);
        let chosen: std::collections::HashSet<usize> =
            route.iter().map(|&(e, _)| e).collect();
        for (e, &p) in probs.iter().enumerate() {
            if !chosen.contains(&e) {
                assert!(p <= min_sel + 1e-7);
            }
        }
    });
}

#[test]
fn prop_scheduler_budget_exactness() {
    check("scheduler-budget", 200, |rng| {
        let n_layers = rng.range(1, 48);
        let m = rng.range(1, 128);
        let r = rng.f64();
        let layer = rng.below(n_layers);
        for alloc in [Allocation::DepthCosine, Allocation::Equal] {
            let b = layer_budget(alloc, layer, n_layers, r, m);
            assert!((1..=m).contains(&b), "budget {b} outside [1, {m}]");
        }
        // budgets are monotone in depth for the cosine schedule
        let mut prev = usize::MAX;
        for l in 0..n_layers {
            let b = layer_budget(Allocation::DepthCosine, l, n_layers, r, m);
            assert!(b <= prev);
            prev = b;
        }
        // assignment honors the budget exactly
        let imp: Vec<f64> = (0..m).map(|_| rng.f64()).collect();
        let budget = rng.range(1, m);
        for sel in [Selection::Importance, Selection::Random] {
            let p = assign_precisions(
                &imp,
                budget,
                sel,
                Precision::Int4,
                Precision::Int2,
                rng,
            );
            let hi = p.iter().filter(|&&x| x == Precision::Int4).count();
            assert_eq!(hi, budget);
            assert_eq!(p.len(), m);
        }
        // importance selection picks a superset-dominating set
        let p = assign_precisions(
            &imp,
            budget,
            Selection::Importance,
            Precision::Int4,
            Precision::Skip,
            rng,
        );
        let min_hi = imp
            .iter()
            .zip(&p)
            .filter(|(_, &x)| x == Precision::Int4)
            .map(|(i, _)| *i)
            .fold(f64::INFINITY, f64::min);
        for (i, x) in imp.iter().zip(&p) {
            if *x != Precision::Int4 {
                assert!(*i <= min_hi + 1e-12);
            }
        }
    });
}

#[test]
fn prop_retention_bounds_and_monotonicity() {
    check("retention", 300, |rng| {
        let n = rng.range(2, 64);
        let lambda = rng.f64();
        let mut prev = f64::INFINITY;
        for l in 0..n {
            let r = retention(l, n, lambda);
            assert!(r <= prev + 1e-12);
            assert!(r >= lambda - 1e-12 && r <= 1.0 + 1e-12);
            prev = r;
        }
    });
}

#[test]
fn prop_cache_invariants_under_random_workload() {
    check("cache-invariants", 100, |rng| {
        let capacity = rng.range(100, 2000) as u64;
        let mut cache = MixedPrecisionCache::new(capacity);
        let precs = [Precision::Bf16, Precision::Int8, Precision::Int4, Precision::Int2];
        let mut model: std::collections::HashMap<ExpertKey, Precision> =
            std::collections::HashMap::new();
        for _ in 0..300 {
            let key = ExpertKey::new(rng.below(4), rng.below(8));
            let p = precs[rng.below(4)];
            match rng.below(3) {
                0 => {
                    // lookup consistency vs shadow model
                    let got = cache.lookup(key, p);
                    match (model.get(&key), got) {
                        (Some(&mp), Lookup::Hit { prec, .. }) => {
                            assert!(mp.satisfies(p));
                            assert_eq!(prec, mp);
                        }
                        (Some(&mp), Lookup::Miss { promotes }) => {
                            assert!(!mp.satisfies(p));
                            assert!(promotes);
                        }
                        (None, Lookup::Miss { promotes }) => assert!(!promotes),
                        (None, Lookup::Hit { .. }) => panic!("phantom hit"),
                    }
                }
                1 => {
                    let bytes = rng.range(10, 400) as u64;
                    if let Some(evicted) = cache.insert(key, p, bytes, 0.0) {
                        for ev in evicted {
                            model.remove(&ev);
                        }
                        // no-duplication: entry now at >= p
                        let now = cache.contains(key).unwrap();
                        assert!(now.satisfies(p));
                        if let Some(&old) = model.get(&key) {
                            assert_eq!(now, old.max(p));
                        } else {
                            assert_eq!(now, p);
                        }
                        model.insert(key, now);
                    }
                }
                _ => {
                    cache.unpin_all();
                }
            }
            assert!(cache.used_bytes() <= capacity, "capacity violated");
            assert_eq!(cache.len(), model.len(), "shadow divergence");
        }
    });
}

#[test]
fn prop_timeline_channels_never_time_travel() {
    check("timeline", 200, |rng| {
        let mut ch = Channel::default();
        let mut last_demand_end = 0.0_f64;
        let mut clock = 0.0_f64;
        for _ in 0..100 {
            clock += rng.f64() * 0.01;
            let dur = rng.f64() * 0.02;
            if rng.f64() < 0.5 {
                let (s, e) = ch.schedule(clock, dur);
                assert!(s >= clock && s >= last_demand_end - 1e-12);
                assert!((e - s - dur).abs() < 1e-12);
                last_demand_end = e;
            } else {
                let (s, e) = ch.schedule_background(clock, dur);
                assert!(s >= clock);
                assert!(e >= s);
                // background never moves the demand horizon
                assert!(ch.free_at == last_demand_end.max(0.0));
            }
        }
    });
}

#[test]
fn prop_importance_heavy_hitter_counts() {
    check("importance", 150, |rng| {
        let m = rng.range(2, 16);
        let seq = rng.range(1, 40);
        let k_route = rng.range(1, m.min(4));
        let scores: Vec<f32> = (0..seq).map(|_| rng.f64() as f32).collect();
        let routes: Vec<Vec<(usize, f32)>> = (0..seq)
            .map(|_| {
                let probs = rand_probs(rng, m);
                top_k_route(&probs, k_route)
            })
            .collect();
        let frac = rng.f64();
        let imp = importance::prefill_importance(&scores, &routes, m, frac);
        assert_eq!(imp.len(), m);
        assert!(imp.iter().all(|&x| x >= 0.0));
        // total integer part equals heavy-hitter token-route count
        let k = ((seq as f64 * frac).ceil() as usize).clamp(1, seq);
        let heavy = importance::heavy_hitters(&scores, seq, k);
        let expected: usize = heavy.iter().map(|&t| routes[t].len()).sum();
        let total_int: f64 = imp.iter().map(|x| x.floor()).sum();
        assert!(
            (total_int - expected as f64).abs() < 1.0 + m as f64 * 0.01,
            "count mismatch: {total_int} vs {expected}"
        );
    });
}

#[test]
fn prop_prefетch_predictions_are_valid_experts() {
    check("prefetch", 150, |rng| {
        let m = rng.range(2, 32);
        let t = rng.range(1, m);
        let probs = rand_probs(rng, m);
        let picks = prefetcher::predict_decode(&probs, t);
        assert_eq!(picks.len(), t);
        let mut sorted = picks.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), t, "duplicate predictions");
        // prefill counts respect seq_len
        let seq = rng.range(1, 12);
        let all: Vec<f32> = (0..seq).flat_map(|_| rand_probs(rng, m)).collect();
        let picks = prefetcher::predict_prefill(&all, seq, m, 2.min(m), t);
        assert!(picks.len() <= t);
        assert!(picks.iter().all(|&e| e < m));
    });
}

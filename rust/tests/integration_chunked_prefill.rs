//! Integration: chunked prefill and mixed prefill/decode continuous
//! batching.
//!
//! Three pillars:
//!
//! 1. **Equivalence** — under a precision-invariant strategy (uniform
//!    Bf16: no importance decision can change an execution precision),
//!    chunked prefill with *any* chunk size reproduces the monolithic
//!    `prefill_session` hidden states, first token, and TTFT-relevant
//!    KV-cache contents, and the full generation after it is
//!    token-identical; a `chunk_tokens = 0` fleet run is the legacy
//!    monolithic scheduler, tick for tick (zero chunking telemetry,
//!    byte-identical outcomes against the default config).
//! 2. **Head-of-line blocking** — a fleet mixing one long-prompt session
//!    into short-prompt decoders shows strictly lower p99 TPOT and a
//!    strictly smaller worst inter-token stall with chunking on vs off:
//!    the tentpole's actual win.
//! 3. **Token accounting** — the token-budget scheduler conserves prompt
//!    tokens (chunk sizes sum to prompt lengths) and respects its
//!    per-tick budget, measured on the real engine counters.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`), matching the other
//! integration suites.

use std::sync::Arc;

use dymoe::baselines::Uniform;
use dymoe::config::{ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::TimedRequest;
use dymoe::serving::policy::PolicyKind;
use dymoe::serving::{run_fleet, FleetConfig};
use dymoe::workload::Request;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions { collect_logits: true, collect_hidden: true, ..Default::default() },
    )
    .unwrap()
}

fn max_abs_err(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max)
}

// ---------------------------------------------------------------------
// Engine-level equivalence (artifacts-gated)
// ---------------------------------------------------------------------

/// For every chunk size, resumable chunked prefill must reproduce the
/// monolithic `prefill_session`: per-layer hidden states over the
/// prompt's positions, KV-cache contents (what TTFT-relevant state the
/// decode phase reads), the first token, and — after decoding both to
/// completion — the whole token stream and its logits.  Uniform Bf16
/// with ample VRAM pins the numerics: no precision decision can differ.
#[test]
fn chunked_prefill_matches_monolithic_for_all_chunk_sizes() {
    let Some(a) = assets() else { return };
    let prompt: Vec<i32> = vec![1, 5, 9, 13, 17, 30, 41];
    let new_tokens = 5;

    let mut mono = bf16_engine(&a);
    let mut s_mono = mono.begin_session(&prompt, new_tokens, None, 0.0).unwrap();
    mono.prefill_session(&mut s_mono).unwrap();
    let kv_mono = s_mono.kv().clone();
    while !s_mono.done() {
        mono.decode_session(&mut s_mono).unwrap();
    }
    let o_mono = s_mono.into_output();

    let m = a.manifest.model.clone();
    let d = m.d_model;
    let seq = prompt.len();
    for chunk_size in [1usize, 2, 3, seq - 1, seq, seq + 100] {
        let mut eng = bf16_engine(&a);
        let mut s = eng.begin_session(&prompt, new_tokens, None, 0.0).unwrap();
        let mut chunks = 0usize;
        loop {
            let before = s.prefill_cursor();
            let done = eng.prefill_chunk(&mut s, chunk_size).unwrap();
            chunks += 1;
            // the cursor strictly advances by at most the budget
            assert!(s.prefilled() || s.prefill_cursor() > before);
            assert!(s.prefill_cursor() - before <= chunk_size);
            if done {
                break;
            }
        }
        let expected_chunks = (seq + chunk_size - 1) / chunk_size;
        assert_eq!(chunks, expected_chunks, "chunk count (size {chunk_size})");
        assert_eq!(eng.stats.prefill_chunks as usize, chunks);
        assert_eq!(eng.stats.prefill_chunk_tokens as usize, seq, "token conservation");

        // first token + TTFT-relevant KV contents
        assert_eq!(o_mono.tokens[0], s.out.tokens[0], "first token (chunk {chunk_size})");
        let kv = s.kv();
        let re = kv.row_elems();
        for layer in 0..m.n_layers {
            let err_k = max_abs_err(&kv.k[layer][..seq * re], &kv_mono.k[layer][..seq * re]);
            let err_v = max_abs_err(&kv.v[layer][..seq * re], &kv_mono.v[layer][..seq * re]);
            assert!(
                err_k < 1e-5 && err_v < 1e-5,
                "KV diverged at layer {layer} (chunk {chunk_size}): k {err_k} v {err_v}"
            );
        }
        // per-layer prefill hidden states over the prompt's positions
        assert_eq!(s.out.prefill_hidden.len(), o_mono.prefill_hidden.len());
        for (l, (hc, hm)) in
            s.out.prefill_hidden.iter().zip(&o_mono.prefill_hidden).enumerate()
        {
            let err = max_abs_err(&hc[..seq * d], &hm[..seq * d]);
            assert!(err < 1e-5, "hidden diverged at layer {l} (chunk {chunk_size}): {err}");
        }

        // the rest of the generation is token- and logit-identical
        while !s.done() {
            eng.decode_session(&mut s).unwrap();
        }
        let o = s.into_output();
        assert_eq!(o_mono.tokens, o.tokens, "tokens diverged (chunk {chunk_size})");
        for (x, y) in o_mono.logits_per_step.iter().zip(&o.logits_per_step) {
            assert!(max_abs_err(x, y) < 1e-5, "logits diverged (chunk {chunk_size})");
        }
    }
}

/// A chunk budget covering the whole prompt completes in one call and
/// also matches the classic `run()` end to end.
#[test]
fn whole_prompt_chunk_is_one_step_and_matches_run() {
    let Some(a) = assets() else { return };
    let prompt = [1i32, 4, 8, 12, 16];

    let mut classic = bf16_engine(&a);
    let o = classic.run(&prompt, 4).unwrap();

    let mut eng = bf16_engine(&a);
    let mut s = eng.begin_session(&prompt, 4, None, 0.0).unwrap();
    assert!(eng.prefill_chunk(&mut s, usize::MAX).unwrap());
    assert_eq!(eng.stats.prefill_chunks, 1);
    while !s.done() {
        eng.decode_session(&mut s).unwrap();
    }
    assert_eq!(o.tokens, s.into_output().tokens);
}

// ---------------------------------------------------------------------
// Fleet-level equivalence (artifacts-gated)
// ---------------------------------------------------------------------

fn fleet_cfg(policy: PolicyKind, max_sessions: usize, batch: usize, chunk: usize) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: batch,
            chunk_tokens: chunk,
            ..Default::default()
        },
        policy,
        ..Default::default()
    }
}

fn timed(id: usize, arrival: f64, prompt: Vec<i32>, max_new: usize) -> TimedRequest {
    TimedRequest::new(id, arrival, Request { prompt, max_new })
}

/// A mixed short/long trace: `n_short` two-token prompts plus one
/// long-prompt session (the whole `max_seq` bucket), all arriving at
/// t = 0 — the head-of-line scenario.
fn hol_trace(a: &Arc<ModelAssets>, n_short: usize) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let short_new = (m.max_cache - m.max_seq).clamp(1, 8);
    let long_new = (m.max_cache - m.max_seq).clamp(1, 2);
    let mut trace: Vec<TimedRequest> = (0..n_short)
        .map(|i| timed(i, 0.0, vec![1, 10 + (3 * i as i32) % 40], short_new))
        .collect();
    let long_prompt: Vec<i32> = (0..m.max_seq).map(|i| 1 + (i as i32 * 7) % 60).collect();
    trace.push(timed(n_short, 0.0, long_prompt, long_new));
    trace
}

/// `chunk_tokens = 0` dispatches to the untouched monolithic scheduler:
/// the run is byte-identical to the default config (whose default *is*
/// 0) per completed request, and none of the chunking machinery engages
/// (zero chunks, zero mixed ticks — the telemetry regression signal).
/// Together with `fleet_batch_one_matches_interleaved_reference_loop`
/// in `integration_serving.rs`, which pins that same monolithic loop
/// against an inline reference, this enforces the tick-for-tick
/// equivalence of the `--chunk-tokens 0` path.
#[test]
fn chunk_zero_fleet_is_the_monolithic_path_tick_for_tick() {
    let Some(a) = assets() else { return };
    for policy in [PolicyKind::SloAware, PolicyKind::RoundRobin] {
        let mut e1 = bf16_engine(&a);
        let explicit = run_fleet(
            &mut e1,
            hol_trace(&a, 3),
            &fleet_cfg(policy, 4, 2, 0),
        )
        .unwrap();
        let mut e2 = bf16_engine(&a);
        let defaulted = run_fleet(
            &mut e2,
            hol_trace(&a, 3),
            &FleetConfig {
                serving: ServingConfig {
                    max_sessions: 4,
                    ttft_slo_s: 1e6,
                    tpot_slo_s: 1e6,
                    max_decode_batch: 2,
                    ..Default::default()
                },
                policy,
                ..Default::default()
            },
        )
        .unwrap();

        // no chunking machinery on the legacy path
        assert_eq!(explicit.phase.prefill_chunks, 0);
        assert_eq!(explicit.phase.prefill_chunk_tokens, 0);
        assert_eq!(explicit.phase.mixed_steps, 0);

        assert_eq!(explicit.per_request.len(), defaulted.per_request.len());
        for (x, y) in explicit.per_request.iter().zip(&defaulted.per_request) {
            assert_eq!(x.id, y.id, "{}: completion order diverged", policy.name());
            // exact equality: identical engine ops on identical timelines
            assert_eq!(x.ttft, y.ttft, "{}: TTFT diverged (id {})", policy.name(), x.id);
            assert_eq!(x.tpot, y.tpot, "{}: TPOT diverged (id {})", policy.name(), x.id);
            assert_eq!(
                x.finished_at, y.finished_at,
                "{}: completion time diverged (id {})",
                policy.name(),
                x.id
            );
            assert_eq!(x.tokens, y.tokens);
        }
        assert_eq!(explicit.steps, defaulted.steps);
    }
}

/// The head-of-line-blocking regression the tentpole exists to fix: a
/// long prompt admitted among short-prompt decoders.  With monolithic
/// prefill every decoder stalls for the whole long prefill (one huge
/// inter-token gap); with chunking on, prefill proceeds `chunk_tokens`
/// at a time fused with the decoders' tokens, so the worst stall is
/// bounded by a chunk's fused service time and the fleet's p99 TPOT
/// drops strictly.
#[test]
fn hol_blocking_chunked_prefill_lowers_decode_tail() {
    let Some(a) = assets() else { return };
    {
        // the scenario needs a long prompt worth tiling and shorts with
        // several decode tokens to stall; the tiny model provides both
        let m = &a.manifest.model;
        if m.max_seq < 8 || m.max_cache - m.max_seq < 4 {
            eprintln!("tiny model too small for the HOL scenario; skipping");
            return;
        }
    }
    let n_short = 4;
    let sessions = n_short + 1;

    let mut mono_engine = bf16_engine(&a);
    let mono = run_fleet(
        &mut mono_engine,
        hol_trace(&a, n_short),
        &fleet_cfg(PolicyKind::SloAware, sessions, n_short, 0),
    )
    .unwrap();
    let mut chunked_engine = bf16_engine(&a);
    let chunked = run_fleet(
        &mut chunked_engine,
        hol_trace(&a, n_short),
        &fleet_cfg(PolicyKind::SloAware, sessions, n_short, 4),
    )
    .unwrap();

    // same work completed either way
    assert_eq!(mono.metrics.completed, sessions);
    assert_eq!(chunked.metrics.completed, sessions);
    let count_by_id = |o: &dymoe::serving::FleetOutcome| {
        let mut v: Vec<(usize, usize)> =
            o.per_request.iter().map(|r| (r.id, r.tokens)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(count_by_id(&mono), count_by_id(&chunked));

    // chunking actually engaged: the long prompt was tiled (more chunks
    // than sessions means at least one prompt took several), some ticks
    // fused prefill with decode
    assert!(chunked.phase.prefill_chunks > sessions as u64, "long prompt not tiled");
    assert!(chunked.phase.mixed_steps > 0, "no fused prefill+decode ticks");
    assert!(chunked.phase.mean_chunk() <= 4.0 + 1e-12);

    // the win, part 1: the worst prefill-interference stall a decoding
    // session suffers is strictly smaller with chunking on
    let worst_short_stall = |o: &dymoe::serving::FleetOutcome| {
        o.per_request
            .iter()
            .filter(|r| r.id < n_short)
            .map(|r| r.max_stall)
            .fold(0.0f64, f64::max)
    };
    let mono_stall = worst_short_stall(&mono);
    let chunked_stall = worst_short_stall(&chunked);
    assert!(
        chunked_stall < mono_stall,
        "chunking did not bound the interference stall: {chunked_stall} vs {mono_stall}"
    );

    // the win, part 2: strictly lower fleet p99 TPOT
    let mono_p99 = mono.metrics.tpot.percentile(99.0);
    let chunked_p99 = chunked.metrics.tpot.percentile(99.0);
    assert!(
        chunked_p99 < mono_p99,
        "chunking did not improve p99 TPOT: {chunked_p99} vs {mono_p99}"
    );
}

/// Engine-counter token accounting over a chunked fleet run: chunk
/// sizes conserve prompt tokens exactly, the mean chunk respects the
/// budget, and mixed ticks never outnumber chunks.
#[test]
fn chunked_fleet_conserves_prompt_tokens() {
    let Some(a) = assets() else { return };
    for policy in PolicyKind::ALL {
        let chunk_tokens = 3;
        let trace = hol_trace(&a, 3);
        let prompt_tokens: u64 =
            trace.iter().map(|t| t.request.prompt.len() as u64).sum();
        let mut engine = bf16_engine(&a);
        let outcome = run_fleet(
            &mut engine,
            trace,
            &fleet_cfg(policy, 4, 2, chunk_tokens),
        )
        .unwrap();
        assert_eq!(outcome.metrics.completed, 4, "{} lost requests", policy.name());
        assert_eq!(
            outcome.phase.prefill_chunk_tokens, prompt_tokens,
            "{}: chunk tokens != prompt tokens",
            policy.name()
        );
        assert!(outcome.phase.mean_chunk() <= chunk_tokens as f64 + 1e-12);
        assert!(outcome.phase.mixed_steps <= outcome.phase.prefill_chunks);
        // TTFT breakdown holds per request under chunking too
        for r in &outcome.per_request {
            assert!(r.ttft >= r.queue_delay - 1e-12);
            assert!(r.finished_at >= r.arrival);
        }
    }
}

//! Integration: multi-replica cluster serving.
//!
//! Three pillars:
//!
//! 1. **Single-replica equivalence** — `run_cluster` over one engine
//!    with round-robin dispatch reproduces `run_fleet` *tick for tick*
//!    (identical per-request TTFT/TPOT/completion times, step counts,
//!    dedup and phase counters) on both the monolithic
//!    (`chunk_tokens = 0`) and chunked paths.  Together with the
//!    pre-existing reference-loop and chunk-0 equivalence suites this
//!    pins the whole refactor chain: cluster-of-one == `run_fleet` ==
//!    the pre-refactor single-engine scheduler.
//! 2. **Dispatcher properties** — request conservation (every trace id
//!    completes exactly once across replicas) and no-starvation (every
//!    dispatched request completes; nothing queues forever) under every
//!    `DispatchPolicy` x scheduling policy x prefill mode, plus
//!    per-replica admission limits.
//! 3. **Telemetry discipline** — engine reuse across runs reports
//!    per-run deltas (dedup/phase counters and channel utilization), so
//!    cumulative engine counters can never double-count; and replica
//!    scaling actually buys tail latency and goodput on a saturating
//!    trace.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`), matching the other
//! integration suites.  The dispatch-policy model test at the bottom is
//! engine-free and runs everywhere.

use std::sync::Arc;

use dymoe::baselines::Uniform;
use dymoe::config::{ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess, TimedRequest};
use dymoe::serving::policy::{DispatchKind, PolicyKind, ReplicaDispatchView};
use dymoe::serving::{run_cluster, run_fleet, ClusterOutcome, FleetConfig};
use dymoe::util::prop;
use dymoe::workload::{Request, TraceGen};

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions::default(),
    )
    .unwrap()
}

fn cfg(
    policy: PolicyKind,
    dispatch: DispatchKind,
    max_sessions: usize,
    batch: usize,
    chunk: usize,
) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: batch,
            chunk_tokens: chunk,
            ..Default::default()
        },
        policy,
        dispatch,
    }
}

fn tiny_trace(a: &Arc<ModelAssets>, n: usize, rate: f64) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let mut content = TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
}

// ---------------------------------------------------------------------
// Single-replica tick-for-tick equivalence (artifacts-gated)
// ---------------------------------------------------------------------

/// `--replicas 1 --dispatch rr` is the pre-refactor single-engine path:
/// the cluster event loop around one replica must reproduce `run_fleet`
/// *exactly* — same per-request times (f64-equal: identical engine ops
/// on identical virtual timelines), same step counts, same dedup/phase
/// counters, same utilization — for both the monolithic (chunk 0) and
/// chunked (chunk 3) schedulers.
#[test]
fn cluster_of_one_matches_run_fleet_tick_for_tick() {
    let Some(a) = assets() else { return };
    for policy in [PolicyKind::SloAware, PolicyKind::RoundRobin] {
        for chunk in [0usize, 3] {
            let c = cfg(policy, DispatchKind::RoundRobin, 3, 2, chunk);
            let trace = || tiny_trace(&a, 8, 50.0);

            let mut fleet_engine = bf16_engine(&a);
            let fleet = run_fleet(&mut fleet_engine, trace(), &c).unwrap();

            let mut engines = vec![bf16_engine(&a)];
            let cluster = run_cluster(&mut engines, trace(), &c).unwrap();

            let label = format!("{} chunk {chunk}", policy.name());
            assert_eq!(cluster.replicas.len(), 1);
            assert_eq!(cluster.load_imbalance, 1.0, "{label}: one replica is balanced");
            let merged = &cluster.fleet;
            assert_eq!(merged.steps, fleet.steps, "{label}: step counts diverged");
            assert_eq!(merged.peak_concurrency, fleet.peak_concurrency, "{label}");
            assert_eq!(merged.peak_kv_bytes, fleet.peak_kv_bytes, "{label}");
            assert_eq!(merged.dedup.decode_batches, fleet.dedup.decode_batches, "{label}");
            assert_eq!(merged.dedup.routed_pairs, fleet.dedup.routed_pairs, "{label}");
            assert_eq!(
                merged.dedup.unique_expert_loads, fleet.dedup.unique_expert_loads,
                "{label}"
            );
            assert_eq!(merged.phase.prefill_chunks, fleet.phase.prefill_chunks, "{label}");
            assert_eq!(
                merged.phase.prefill_chunk_tokens, fleet.phase.prefill_chunk_tokens,
                "{label}"
            );
            assert_eq!(merged.phase.mixed_steps, fleet.phase.mixed_steps, "{label}");
            assert_eq!(merged.utilization.gpu, fleet.utilization.gpu, "{label}");
            assert_eq!(merged.utilization.pcie, fleet.utilization.pcie, "{label}");

            assert_eq!(merged.per_request.len(), fleet.per_request.len(), "{label}");
            for (x, y) in merged.per_request.iter().zip(&fleet.per_request) {
                assert_eq!(x.id, y.id, "{label}: completion order diverged");
                // exact equality: identical engine ops, identical clocks
                assert_eq!(x.ttft, y.ttft, "{label}: TTFT diverged (id {})", x.id);
                assert_eq!(x.tpot, y.tpot, "{label}: TPOT diverged (id {})", x.id);
                assert_eq!(
                    x.finished_at, y.finished_at,
                    "{label}: completion time diverged (id {})",
                    x.id
                );
                assert_eq!(x.queue_delay, y.queue_delay, "{label}");
                assert_eq!(x.tokens, y.tokens, "{label}");
            }
            // the per-replica breakdown of a one-replica cluster *is*
            // the fleet outcome
            let b = &cluster.replicas[0];
            assert_eq!(b.dispatched, 8, "{label}");
            assert_eq!(b.outcome.metrics.completed, fleet.metrics.completed, "{label}");
            assert_eq!(b.outcome.steps, fleet.steps, "{label}");
        }
    }
}

// ---------------------------------------------------------------------
// Dispatcher conservation / no-starvation (artifacts-gated)
// ---------------------------------------------------------------------

/// Every trace id completes exactly once across the cluster, every
/// dispatched request completes on the replica it was routed to (no
/// starvation under any dispatch x scheduling x prefill-mode combo),
/// and per-replica admission limits hold.
#[test]
fn cluster_conserves_requests_under_every_policy_combo() {
    let Some(a) = assets() else { return };
    let n = 9;
    for replicas in [2usize, 3] {
        for dispatch in DispatchKind::ALL {
            for policy in [PolicyKind::SloAware, PolicyKind::Fifo] {
                for chunk in [0usize, 3] {
                    let c = cfg(policy, dispatch, 2, 2, chunk);
                    let mut engines: Vec<Engine> =
                        (0..replicas).map(|_| bf16_engine(&a)).collect();
                    let cluster =
                        run_cluster(&mut engines, tiny_trace(&a, n, 10.0), &c).unwrap();
                    let label = format!(
                        "{} x {} x chunk {chunk} on {replicas} replicas",
                        dispatch.name(),
                        policy.name()
                    );

                    // conservation: every id exactly once, cluster-wide
                    let mut ids: Vec<usize> =
                        cluster.fleet.per_request.iter().map(|r| r.id).collect();
                    ids.sort_unstable();
                    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "{label}: ids lost/duped");
                    assert_eq!(cluster.fleet.metrics.completed, n, "{label}");

                    // no starvation: each replica completes exactly what
                    // it was dispatched, and dispatch covers the trace
                    let mut dispatched_total = 0;
                    for (i, b) in cluster.replicas.iter().enumerate() {
                        assert_eq!(
                            b.outcome.metrics.completed, b.dispatched,
                            "{label}: replica {i} starved a request"
                        );
                        assert!(
                            b.outcome.peak_concurrency <= 2,
                            "{label}: replica {i} admission limit violated"
                        );
                        dispatched_total += b.dispatched;
                    }
                    assert_eq!(dispatched_total, n, "{label}: dispatch lost requests");

                    // the balance statistic is well-formed
                    assert!(cluster.load_imbalance >= 1.0 - 1e-12, "{label}");
                    assert!(
                        cluster.load_imbalance <= replicas as f64 + 1e-12,
                        "{label}: imbalance {} above replica count",
                        cluster.load_imbalance
                    );

                    // round-robin dispatch is maximally spread by count
                    if dispatch == DispatchKind::RoundRobin {
                        for b in &cluster.replicas {
                            assert!(
                                b.dispatched == n / replicas || b.dispatched == n / replicas + 1,
                                "{label}: rr dispatched {} of {n}",
                                b.dispatched
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Determinism: the same seeded trace on the same cluster config gives
/// byte-identical outcomes (virtual-time co-simulation has no hidden
/// state across runs with fresh engines).
#[test]
fn cluster_runs_are_deterministic() {
    let Some(a) = assets() else { return };
    let run = || -> ClusterOutcome {
        let c = cfg(PolicyKind::SloAware, DispatchKind::JoinShortestQueue, 2, 2, 0);
        let mut engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
        run_cluster(&mut engines, tiny_trace(&a, 8, 20.0), &c).unwrap()
    };
    let x = run();
    let y = run();
    assert_eq!(x.fleet.per_request.len(), y.fleet.per_request.len());
    for (a_, b_) in x.fleet.per_request.iter().zip(&y.fleet.per_request) {
        assert_eq!(a_.id, b_.id);
        assert_eq!(a_.ttft, b_.ttft);
        assert_eq!(a_.finished_at, b_.finished_at);
    }
    assert_eq!(x.load_imbalance, y.load_imbalance);
    assert_eq!(x.fleet.steps, y.fleet.steps);
}

// ---------------------------------------------------------------------
// Replica scaling (artifacts-gated)
// ---------------------------------------------------------------------

/// The cluster's reason to exist: on a trace dense enough to saturate
/// one replica, four replicas complete the same work with strictly
/// lower p99 TTFT and strictly higher goodput.
#[test]
fn replica_scaling_cuts_tail_latency_and_raises_goodput() {
    let Some(a) = assets() else { return };
    let n = 10;
    let mk = || tiny_trace(&a, n, 50.0); // heavy overload for one device
    // Non-binding SLOs: goodput degenerates to completed / makespan, so
    // "strictly higher goodput" is exactly "strictly shorter makespan"
    // — the parallelism win itself, not an SLO-threshold artifact.
    let c1 = FleetConfig {
        serving: ServingConfig {
            max_sessions: 4,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: 4,
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch: DispatchKind::RoundRobin,
    };
    let mut one = vec![bf16_engine(&a)];
    let single = run_cluster(&mut one, mk(), &c1).unwrap();
    let mut four: Vec<Engine> = (0..4).map(|_| bf16_engine(&a)).collect();
    let quad = run_cluster(&mut four, mk(), &c1).unwrap();

    assert_eq!(single.fleet.metrics.completed, n);
    assert_eq!(quad.fleet.metrics.completed, n);
    let p99_1 = single.fleet.metrics.ttft.percentile(99.0);
    let p99_4 = quad.fleet.metrics.ttft.percentile(99.0);
    assert!(
        p99_4 < p99_1,
        "4 replicas did not cut p99 TTFT: {p99_4} vs {p99_1}"
    );
    let gp_1 = single.fleet.metrics.goodput_rps();
    let gp_4 = quad.fleet.metrics.goodput_rps();
    assert!(
        gp_4 > gp_1,
        "4 replicas did not raise goodput: {gp_4} vs {gp_1}"
    );
}

// ---------------------------------------------------------------------
// Telemetry delta discipline on engine reuse (artifacts-gated)
// ---------------------------------------------------------------------

/// Reusing one engine across fleet runs must report **per-run** dedup /
/// phase / busy-time numbers: the run outcomes have to sum to the
/// engine's cumulative counters (no run double-counts an earlier run's
/// work), before *and* after a `reset_stats` between runs.
#[test]
fn engine_reuse_across_runs_reports_per_run_deltas() {
    let Some(a) = assets() else { return };
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 3, 3, 2);
    let mut engine = bf16_engine(&a);

    let run1 = run_fleet(&mut engine, tiny_trace(&a, 6, 20.0), &c).unwrap();
    let busy_mid = engine.busy_totals();
    let run2 = run_fleet(&mut engine, tiny_trace(&a, 6, 20.0), &c).unwrap();
    let busy_end = engine.busy_totals();

    // dedup / phase counters: the two runs partition the cumulative
    // engine counters exactly (a cumulative leak would make run2
    // include run1's work and break the sum)
    assert!(run2.dedup.decode_batches > 0 && run2.phase.prefill_chunks > 0);
    assert_eq!(
        run1.dedup.decode_batches + run2.dedup.decode_batches,
        engine.stats.decode_batches
    );
    assert_eq!(
        run1.dedup.routed_pairs + run2.dedup.routed_pairs,
        engine.stats.routed_pairs
    );
    assert_eq!(
        run1.phase.prefill_chunk_tokens + run2.phase.prefill_chunk_tokens,
        engine.stats.prefill_chunk_tokens
    );
    assert_eq!(
        run1.phase.mixed_steps + run2.phase.mixed_steps,
        engine.stats.mixed_steps
    );

    // utilization: run2's busy fraction reflects run2's busy *delta*
    // only (the cumulative totals would roughly double it)
    let span2 = run2.metrics.makespan();
    assert!(span2 > 0.0);
    let gpu_delta = busy_end.gpu - busy_mid.gpu;
    assert!(
        (run2.utilization.gpu - (gpu_delta / span2).min(1.0)).abs() < 1e-9,
        "run2 gpu utilization {} is not the run's own delta fraction {}",
        run2.utilization.gpu,
        gpu_delta / span2
    );

    // a reset between runs keeps the discipline: counters restart from
    // zero and the next run's deltas match them exactly
    engine.reset_stats();
    assert_eq!(engine.stats.decode_batches, 0);
    let run3 = run_fleet(&mut engine, tiny_trace(&a, 4, 20.0), &c).unwrap();
    assert_eq!(run3.dedup.decode_batches, engine.stats.decode_batches);
    assert_eq!(run3.phase.prefill_chunks, engine.stats.prefill_chunks);
    assert_eq!(run3.metrics.completed, 4);
}

// ---------------------------------------------------------------------
// Engine-free dispatch model properties (run everywhere)
// ---------------------------------------------------------------------

/// Dispatch policies over random replica views: picks are always in
/// range, jsq never routes to a strictly more loaded replica than its
/// pick, rr visits every replica within one cycle, and affinity is a
/// pure function of the prompt.
#[test]
fn prop_dispatch_policies_route_sanely() {
    prop::check("dispatch-routing", 200, |rng| {
        let n = rng.range(1, 9);
        let views: Vec<ReplicaDispatchView> = (0..n)
            .map(|index| ReplicaDispatchView {
                index,
                clock: rng.f64() * 100.0,
                queued_requests: rng.below(5),
                queued_tokens: rng.below(200),
                active_sessions: rng.below(4),
                active_tokens: rng.below(100),
            })
            .collect();
        let prompt: Vec<i32> = (0..rng.range(1, 12)).map(|_| rng.below(60) as i32).collect();
        let req = TimedRequest {
            id: rng.below(1000),
            arrival: rng.f64(),
            request: Request { prompt: prompt.clone(), max_new: rng.range(1, 8) },
        };

        for kind in DispatchKind::ALL {
            let mut p = kind.build();
            let pick = p.route(&req, &views);
            assert!(pick < n, "{} routed out of range: {pick} of {n}", kind.name());
            if kind == DispatchKind::JoinShortestQueue {
                let picked = views[pick].queued_tokens + views[pick].active_tokens;
                for v in &views {
                    assert!(
                        picked <= v.queued_tokens + v.active_tokens,
                        "jsq skipped a less-loaded replica"
                    );
                }
            }
            if kind == DispatchKind::ExpertAffinity {
                // pure in the prompt: rerouting the same request agrees
                assert_eq!(pick, kind.build().route(&req, &views));
            }
        }

        // rr covers every replica in one cycle regardless of load
        let mut rr = DispatchKind::RoundRobin.build();
        let mut seen = vec![false; n];
        for _ in 0..n {
            seen[rr.route(&req, &views)] = true;
        }
        assert!(seen.iter().all(|&s| s), "rr starved a replica in one cycle");
    });
}

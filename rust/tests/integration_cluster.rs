//! Integration: multi-replica cluster serving.
//!
//! Three pillars:
//!
//! 1. **Single-replica equivalence** — `run_cluster` over one engine
//!    with round-robin dispatch reproduces `run_fleet` *tick for tick*
//!    (identical per-request TTFT/TPOT/completion times, step counts,
//!    dedup and phase counters) on both the monolithic
//!    (`chunk_tokens = 0`) and chunked paths.  Together with the
//!    pre-existing reference-loop and chunk-0 equivalence suites this
//!    pins the whole refactor chain: cluster-of-one == `run_fleet` ==
//!    the pre-refactor single-engine scheduler.
//! 2. **Dispatcher properties** — request conservation (every trace id
//!    completes exactly once across replicas) and no-starvation (every
//!    dispatched request completes; nothing queues forever) under every
//!    `DispatchPolicy` x scheduling policy x prefill mode, plus
//!    per-replica admission limits.
//! 3. **Telemetry discipline** — engine reuse across runs reports
//!    per-run deltas (dedup/phase counters and channel utilization), so
//!    cumulative engine counters can never double-count; and replica
//!    scaling actually buys tail latency and goodput on a saturating
//!    trace.
//! 4. **Event-driven scheduler equivalence** — `run_cluster`'s
//!    next-event loop is pinned bit-identical (outcome digest plus
//!    exact per-request fields) to the retired min-clock lockstep loop
//!    (`run_cluster_minclock`) across dispatch x chunk, and the
//!    `--parallel N` worker path is pinned bit-identical to serial;
//!    engines illegally sharing an executor under `parallel > 1` are
//!    rejected loudly.  The churn-schedule halves of both pins live in
//!    `integration_churn.rs`.
//! 5. **Fallback admission order** — the work-conserving Idle fallback
//!    admits the *oldest* queued arrival (FIFO), not whatever
//!    `swap_remove` left in slot 0.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`), matching the other
//! integration suites.  The dispatch-policy and event-queue model tests
//! at the bottom are engine-free and run everywhere.

use std::sync::Arc;

use dymoe::baselines::{LoadOnDemand, Uniform};
use dymoe::config::{
    ChurnEvent, ChurnKind, HostPoolConfig, PoolPolicyKind, ServingConfig, SystemConfig, GB,
};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::memory::PoolStats;
use dymoe::model::assets::ModelAssets;
use dymoe::model::executor::Executor;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess, TimedRequest};
use dymoe::serving::events::{Event, EventPayload, EventQueue};
use dymoe::serving::policy::{
    Action, DispatchKind, PolicyKind, ReplicaDispatchView, SchedPolicy, SchedView, TickPlan,
};
use dymoe::serving::{
    run_cluster, run_cluster_minclock, run_fleet, ClusterOutcome, FleetConfig, Replica,
};
use dymoe::util::prop;
use dymoe::workload::{Request, TraceGen};

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions::default(),
    )
    .unwrap()
}

fn cfg(
    policy: PolicyKind,
    dispatch: DispatchKind,
    max_sessions: usize,
    batch: usize,
    chunk: usize,
) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: batch,
            chunk_tokens: chunk,
            ..Default::default()
        },
        policy,
        dispatch,
    }
}

fn tiny_trace(a: &Arc<ModelAssets>, n: usize, rate: f64) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let mut content = TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
}

// ---------------------------------------------------------------------
// Single-replica tick-for-tick equivalence (artifacts-gated)
// ---------------------------------------------------------------------

/// `--replicas 1 --dispatch rr` is the pre-refactor single-engine path:
/// the cluster event loop around one replica must reproduce `run_fleet`
/// *exactly* — same per-request times (f64-equal: identical engine ops
/// on identical virtual timelines), same step counts, same dedup/phase
/// counters, same utilization — for both the monolithic (chunk 0) and
/// chunked (chunk 3) schedulers.
#[test]
fn cluster_of_one_matches_run_fleet_tick_for_tick() {
    let Some(a) = assets() else { return };
    for policy in [PolicyKind::SloAware, PolicyKind::RoundRobin] {
        for chunk in [0usize, 3] {
            let c = cfg(policy, DispatchKind::RoundRobin, 3, 2, chunk);
            let trace = || tiny_trace(&a, 8, 50.0);

            let mut fleet_engine = bf16_engine(&a);
            let fleet = run_fleet(&mut fleet_engine, trace(), &c).unwrap();

            let mut engines = vec![bf16_engine(&a)];
            let cluster = run_cluster(&mut engines, trace(), &c).unwrap();

            let label = format!("{} chunk {chunk}", policy.name());
            assert_eq!(cluster.replicas.len(), 1);
            assert_eq!(cluster.load_imbalance, 1.0, "{label}: one replica is balanced");
            let merged = &cluster.fleet;
            assert_eq!(merged.steps, fleet.steps, "{label}: step counts diverged");
            assert_eq!(merged.peak_concurrency, fleet.peak_concurrency, "{label}");
            assert_eq!(merged.peak_kv_bytes, fleet.peak_kv_bytes, "{label}");
            assert_eq!(merged.dedup.decode_batches, fleet.dedup.decode_batches, "{label}");
            assert_eq!(merged.dedup.routed_pairs, fleet.dedup.routed_pairs, "{label}");
            assert_eq!(
                merged.dedup.unique_expert_loads, fleet.dedup.unique_expert_loads,
                "{label}"
            );
            assert_eq!(merged.phase.prefill_chunks, fleet.phase.prefill_chunks, "{label}");
            assert_eq!(
                merged.phase.prefill_chunk_tokens, fleet.phase.prefill_chunk_tokens,
                "{label}"
            );
            assert_eq!(merged.phase.mixed_steps, fleet.phase.mixed_steps, "{label}");
            assert_eq!(merged.utilization.gpu, fleet.utilization.gpu, "{label}");
            assert_eq!(merged.utilization.pcie, fleet.utilization.pcie, "{label}");

            assert_eq!(merged.per_request.len(), fleet.per_request.len(), "{label}");
            for (x, y) in merged.per_request.iter().zip(&fleet.per_request) {
                assert_eq!(x.id, y.id, "{label}: completion order diverged");
                // exact equality: identical engine ops, identical clocks
                assert_eq!(x.ttft, y.ttft, "{label}: TTFT diverged (id {})", x.id);
                assert_eq!(x.tpot, y.tpot, "{label}: TPOT diverged (id {})", x.id);
                assert_eq!(
                    x.finished_at, y.finished_at,
                    "{label}: completion time diverged (id {})",
                    x.id
                );
                assert_eq!(x.queue_delay, y.queue_delay, "{label}");
                assert_eq!(x.tokens, y.tokens, "{label}");
            }
            // the per-replica breakdown of a one-replica cluster *is*
            // the fleet outcome
            let b = &cluster.replicas[0];
            assert_eq!(b.dispatched, 8, "{label}");
            assert_eq!(b.outcome.metrics.completed, fleet.metrics.completed, "{label}");
            assert_eq!(b.outcome.steps, fleet.steps, "{label}");
        }
    }
}

// ---------------------------------------------------------------------
// Dispatcher conservation / no-starvation (artifacts-gated)
// ---------------------------------------------------------------------

/// Every trace id completes exactly once across the cluster, every
/// dispatched request completes on the replica it was routed to (no
/// starvation under any dispatch x scheduling x prefill-mode combo),
/// and per-replica admission limits hold.
#[test]
fn cluster_conserves_requests_under_every_policy_combo() {
    let Some(a) = assets() else { return };
    let n = 9;
    for replicas in [2usize, 3] {
        for dispatch in DispatchKind::ALL {
            for policy in [PolicyKind::SloAware, PolicyKind::Fifo] {
                for chunk in [0usize, 3] {
                    let c = cfg(policy, dispatch, 2, 2, chunk);
                    let mut engines: Vec<Engine> =
                        (0..replicas).map(|_| bf16_engine(&a)).collect();
                    let cluster =
                        run_cluster(&mut engines, tiny_trace(&a, n, 10.0), &c).unwrap();
                    let label = format!(
                        "{} x {} x chunk {chunk} on {replicas} replicas",
                        dispatch.name(),
                        policy.name()
                    );

                    // conservation: every id exactly once, cluster-wide
                    let mut ids: Vec<usize> =
                        cluster.fleet.per_request.iter().map(|r| r.id).collect();
                    ids.sort_unstable();
                    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "{label}: ids lost/duped");
                    assert_eq!(cluster.fleet.metrics.completed, n, "{label}");

                    // no starvation: each replica completes exactly what
                    // it was dispatched, and dispatch covers the trace
                    let mut dispatched_total = 0;
                    for (i, b) in cluster.replicas.iter().enumerate() {
                        assert_eq!(
                            b.outcome.metrics.completed, b.dispatched,
                            "{label}: replica {i} starved a request"
                        );
                        assert!(
                            b.outcome.peak_concurrency <= 2,
                            "{label}: replica {i} admission limit violated"
                        );
                        dispatched_total += b.dispatched;
                    }
                    assert_eq!(dispatched_total, n, "{label}: dispatch lost requests");

                    // the balance statistic is well-formed
                    assert!(cluster.load_imbalance >= 1.0 - 1e-12, "{label}");
                    assert!(
                        cluster.load_imbalance <= replicas as f64 + 1e-12,
                        "{label}: imbalance {} above replica count",
                        cluster.load_imbalance
                    );

                    // round-robin dispatch is maximally spread by count
                    if dispatch == DispatchKind::RoundRobin {
                        for b in &cluster.replicas {
                            assert!(
                                b.dispatched == n / replicas || b.dispatched == n / replicas + 1,
                                "{label}: rr dispatched {} of {n}",
                                b.dispatched
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Determinism: the same seeded trace on the same cluster config gives
/// byte-identical outcomes (virtual-time co-simulation has no hidden
/// state across runs with fresh engines).
#[test]
fn cluster_runs_are_deterministic() {
    let Some(a) = assets() else { return };
    let run = || -> ClusterOutcome {
        let c = cfg(PolicyKind::SloAware, DispatchKind::JoinShortestQueue, 2, 2, 0);
        let mut engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
        run_cluster(&mut engines, tiny_trace(&a, 8, 20.0), &c).unwrap()
    };
    let x = run();
    let y = run();
    assert_eq!(x.fleet.per_request.len(), y.fleet.per_request.len());
    for (a_, b_) in x.fleet.per_request.iter().zip(&y.fleet.per_request) {
        assert_eq!(a_.id, b_.id);
        assert_eq!(a_.ttft, b_.ttft);
        assert_eq!(a_.finished_at, b_.finished_at);
    }
    assert_eq!(x.load_imbalance, y.load_imbalance);
    assert_eq!(x.fleet.steps, y.fleet.steps);
}

// ---------------------------------------------------------------------
// Replica scaling (artifacts-gated)
// ---------------------------------------------------------------------

/// The cluster's reason to exist: on a trace dense enough to saturate
/// one replica, four replicas complete the same work with strictly
/// lower p99 TTFT and strictly higher goodput.
#[test]
fn replica_scaling_cuts_tail_latency_and_raises_goodput() {
    let Some(a) = assets() else { return };
    let n = 10;
    let mk = || tiny_trace(&a, n, 50.0); // heavy overload for one device
    // Non-binding SLOs: goodput degenerates to completed / makespan, so
    // "strictly higher goodput" is exactly "strictly shorter makespan"
    // — the parallelism win itself, not an SLO-threshold artifact.
    let c1 = FleetConfig {
        serving: ServingConfig {
            max_sessions: 4,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: 4,
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch: DispatchKind::RoundRobin,
    };
    let mut one = vec![bf16_engine(&a)];
    let single = run_cluster(&mut one, mk(), &c1).unwrap();
    let mut four: Vec<Engine> = (0..4).map(|_| bf16_engine(&a)).collect();
    let quad = run_cluster(&mut four, mk(), &c1).unwrap();

    assert_eq!(single.fleet.metrics.completed, n);
    assert_eq!(quad.fleet.metrics.completed, n);
    let p99_1 = single.fleet.metrics.ttft.percentile(99.0);
    let p99_4 = quad.fleet.metrics.ttft.percentile(99.0);
    assert!(
        p99_4 < p99_1,
        "4 replicas did not cut p99 TTFT: {p99_4} vs {p99_1}"
    );
    let gp_1 = single.fleet.metrics.goodput_rps();
    let gp_4 = quad.fleet.metrics.goodput_rps();
    assert!(
        gp_4 > gp_1,
        "4 replicas did not raise goodput: {gp_4} vs {gp_1}"
    );
}

// ---------------------------------------------------------------------
// Telemetry delta discipline on engine reuse (artifacts-gated)
// ---------------------------------------------------------------------

/// Reusing one engine across fleet runs must report **per-run** dedup /
/// phase / busy-time numbers: the run outcomes have to sum to the
/// engine's cumulative counters (no run double-counts an earlier run's
/// work), before *and* after a `reset_stats` between runs.
#[test]
fn engine_reuse_across_runs_reports_per_run_deltas() {
    let Some(a) = assets() else { return };
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 3, 3, 2);
    let mut engine = bf16_engine(&a);

    let run1 = run_fleet(&mut engine, tiny_trace(&a, 6, 20.0), &c).unwrap();
    let busy_mid = engine.busy_totals();
    let run2 = run_fleet(&mut engine, tiny_trace(&a, 6, 20.0), &c).unwrap();
    let busy_end = engine.busy_totals();

    // dedup / phase counters: the two runs partition the cumulative
    // engine counters exactly (a cumulative leak would make run2
    // include run1's work and break the sum)
    assert!(run2.dedup.decode_batches > 0 && run2.phase.prefill_chunks > 0);
    assert_eq!(
        run1.dedup.decode_batches + run2.dedup.decode_batches,
        engine.stats.decode_batches
    );
    assert_eq!(
        run1.dedup.routed_pairs + run2.dedup.routed_pairs,
        engine.stats.routed_pairs
    );
    assert_eq!(
        run1.phase.prefill_chunk_tokens + run2.phase.prefill_chunk_tokens,
        engine.stats.prefill_chunk_tokens
    );
    assert_eq!(
        run1.phase.mixed_steps + run2.phase.mixed_steps,
        engine.stats.mixed_steps
    );

    // utilization: run2's busy fraction reflects run2's busy *delta*
    // only (the cumulative totals would roughly double it)
    let span2 = run2.metrics.makespan();
    assert!(span2 > 0.0);
    let gpu_delta = busy_end.gpu - busy_mid.gpu;
    assert!(
        (run2.utilization.gpu - (gpu_delta / span2).min(1.0)).abs() < 1e-9,
        "run2 gpu utilization {} is not the run's own delta fraction {}",
        run2.utilization.gpu,
        gpu_delta / span2
    );

    // a reset between runs keeps the discipline: counters restart from
    // zero and the next run's deltas match them exactly
    engine.reset_stats();
    assert_eq!(engine.stats.decode_batches, 0);
    let run3 = run_fleet(&mut engine, tiny_trace(&a, 4, 20.0), &c).unwrap();
    assert_eq!(run3.dedup.decode_batches, engine.stats.decode_batches);
    assert_eq!(run3.phase.prefill_chunks, engine.stats.prefill_chunks);
    assert_eq!(run3.metrics.completed, 4);
}

// ---------------------------------------------------------------------
// Event-driven scheduler vs the retired min-clock loop (artifacts-gated)
// ---------------------------------------------------------------------

/// The next-event scheduler must reproduce the retired min-clock
/// lockstep loop *bit for bit* on churn-free traces, for every dispatch
/// policy and both prefill modes: same outcome digest, and (for a
/// readable failure) the same exact per-request fields, step counts,
/// and balance statistic.
#[test]
fn event_scheduler_matches_minclock_loop_churn_free() {
    let Some(a) = assets() else { return };
    for dispatch in DispatchKind::ALL {
        for chunk in [0usize, 3] {
            let c = cfg(PolicyKind::SloAware, dispatch, 2, 2, chunk);
            let mut ref_engines: Vec<Engine> = (0..3).map(|_| bf16_engine(&a)).collect();
            let reference =
                run_cluster_minclock(&mut ref_engines, tiny_trace(&a, 9, 10.0), &c).unwrap();
            let mut engines: Vec<Engine> = (0..3).map(|_| bf16_engine(&a)).collect();
            let event = run_cluster(&mut engines, tiny_trace(&a, 9, 10.0), &c).unwrap();
            let label = format!("{} chunk {chunk}", dispatch.name());

            assert_eq!(event.fleet.per_request.len(), reference.fleet.per_request.len());
            for (x, y) in event.fleet.per_request.iter().zip(&reference.fleet.per_request) {
                assert_eq!(x.id, y.id, "{label}: completion order diverged");
                assert_eq!(x.ttft, y.ttft, "{label}: TTFT diverged (id {})", x.id);
                assert_eq!(x.tpot, y.tpot, "{label}: TPOT diverged (id {})", x.id);
                assert_eq!(x.finished_at, y.finished_at, "{label} (id {})", x.id);
                assert_eq!(x.queue_delay, y.queue_delay, "{label} (id {})", x.id);
                assert_eq!(x.max_stall, y.max_stall, "{label} (id {})", x.id);
            }
            assert_eq!(event.fleet.steps, reference.fleet.steps, "{label}");
            assert_eq!(event.load_imbalance, reference.load_imbalance, "{label}");
            assert_eq!(
                event.fleet.utilization.gpu, reference.fleet.utilization.gpu,
                "{label}"
            );
            for (x, y) in event.replicas.iter().zip(&reference.replicas) {
                assert_eq!(x.dispatched, y.dispatched, "{label}: dispatch routing diverged");
            }
            assert_eq!(event.digest(), reference.digest(), "{label}: outcome digest diverged");
        }
    }
}

/// `--parallel 4` distributes the inter-boundary advance phases over
/// scoped worker threads; every outcome bit must match the serial run
/// (the partition is a pure wall-clock knob).
#[test]
fn parallel_cluster_is_bit_identical_to_serial() {
    let Some(a) = assets() else { return };
    for dispatch in [DispatchKind::RoundRobin, DispatchKind::JoinShortestQueue] {
        for chunk in [0usize, 3] {
            let base = cfg(PolicyKind::SloAware, dispatch, 2, 2, chunk);
            let mut serial_engines: Vec<Engine> = (0..4).map(|_| bf16_engine(&a)).collect();
            let serial =
                run_cluster(&mut serial_engines, tiny_trace(&a, 10, 20.0), &base).unwrap();

            let mut par_cfg = base.clone();
            par_cfg.serving.parallel = 4;
            let mut par_engines: Vec<Engine> = (0..4).map(|_| bf16_engine(&a)).collect();
            let parallel =
                run_cluster(&mut par_engines, tiny_trace(&a, 10, 20.0), &par_cfg).unwrap();

            let label = format!("{} chunk {chunk}", dispatch.name());
            assert_eq!(parallel.digest(), serial.digest(), "{label}: parallel diverged");
            for (x, y) in parallel.fleet.per_request.iter().zip(&serial.fleet.per_request) {
                assert_eq!((x.id, x.ttft, x.finished_at), (y.id, y.ttft, y.finished_at), "{label}");
            }
            assert_eq!(parallel.fleet.steps, serial.fleet.steps, "{label}");
        }
    }
}

/// Executor state is single-thread confined: a parallel run over
/// engines that share one executor must be rejected up front, not race.
#[test]
fn parallel_run_rejects_engines_sharing_an_executor() {
    let Some(a) = assets() else { return };
    let exec = std::rc::Rc::new(Executor::new(a.clone()).unwrap());
    let mut engines: Vec<Engine> = (0..2)
        .map(|_| {
            Engine::with_executor(
                &a,
                big_vram_sys(),
                Box::new(Uniform::new(Precision::Bf16)),
                EngineOptions::default(),
                exec.clone(),
            )
            .unwrap()
        })
        .collect();
    let mut c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 0);
    c.serving.parallel = 2;
    let err = run_cluster(&mut engines, tiny_trace(&a, 4, 20.0), &c).unwrap_err();
    assert!(
        err.to_string().contains("per-replica executors"),
        "wrong rejection: {err:#}"
    );
    // the same engines run fine serially
    c.serving.parallel = 1;
    let ok = run_cluster(&mut engines, tiny_trace(&a, 4, 20.0), &c).unwrap();
    assert_eq!(ok.fleet.metrics.completed, 4);
}

// ---------------------------------------------------------------------
// Work-conserving fallback admission order (artifacts-gated)
// ---------------------------------------------------------------------

/// A policy that never plans anything, forcing every admission through
/// the replica's work-conserving Idle fallback.
struct AlwaysIdlePolicy;

impl SchedPolicy for AlwaysIdlePolicy {
    fn name(&self) -> &'static str {
        "always-idle"
    }

    fn next_action(&mut self, _view: &SchedView) -> Action {
        Action::Idle
    }

    fn mixed_tick(&mut self, _view: &SchedView, _max_decode: usize) -> TickPlan {
        TickPlan { prefill: None, decode: Vec::new() }
    }
}

/// Regression: the monolithic Idle fallback used to admit
/// `self.queued[0]` — but admission removes entries with `swap_remove`,
/// which parks the *youngest* request in slot 0, so a three-deep queue
/// served A, C, B.  The fallback must admit the oldest arrival (ties by
/// id), i.e. FIFO order.
#[test]
fn idle_fallback_admits_oldest_arrival_not_slot_zero() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let max_new = (m.max_cache - m.max_seq).clamp(1, 2);
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 1, 1, 0);
    let mut engine = bf16_engine(&a);
    let mut replica = Replica::with_policy(&mut engine, &c, Box::new(AlwaysIdlePolicy));
    // Three same-instant arrivals queued before the first tick; with
    // max_sessions = 1 they serve strictly one at a time, so completion
    // order *is* admission order.
    for id in 0..3usize {
        replica.enqueue(TimedRequest::new(
            id,
            0.0,
            Request { prompt: vec![1, 5 + 3 * id as i32], max_new },
        ));
    }
    let mut guard = 0;
    while replica.has_work() {
        replica.tick().unwrap();
        guard += 1;
        assert!(guard < 500, "idle-fallback loop did not converge");
    }
    let done = replica.finish();
    let order: Vec<usize> = done.outcome.per_request.iter().map(|r| r.id).collect();
    assert_eq!(
        order,
        vec![0, 1, 2],
        "fallback admission must follow arrival order, not queue-slot order"
    );
}

// ---------------------------------------------------------------------
// Shared host expert pool (artifacts-gated)
// ---------------------------------------------------------------------

/// Engine whose every routed expert hits the full transfer chain:
/// `LoadOnDemand` bypasses the VRAM cache entirely and `ssd_resident`
/// puts SSD under the host tier, so with `--host-pool` attached each
/// expert use resolves host pool -> SSD.  The `bf16_engine` helper above
/// is useless here — 1 TB of VRAM warm-loads everything and the pool
/// never sees a single lookup.
fn pool_engine(a: &Arc<ModelAssets>) -> Engine {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.policy.ssd_resident = true;
    Engine::with_options(
        a,
        sys,
        Box::new(LoadOnDemand::new(Precision::Int4)),
        EngineOptions::default(),
    )
    .unwrap()
}

/// Strictly serial per replica (FIFO, one session, batch 1) so the two
/// pool policies see the *same* routed-expert sequence and only the
/// host-tier timing differs; `host_pool` set per test.
fn pool_cfg(pool: Option<HostPoolConfig>) -> FleetConfig {
    let mut c = cfg(PolicyKind::Fifo, DispatchKind::RoundRobin, 1, 1, 0);
    c.serving.host_pool = pool;
    c
}

/// Identical prompts at a fixed arrival gap: round-robin alternates the
/// replicas, and every arrival is an event boundary that flushes staged
/// pool fills, so replica 1's requests can reuse what replica 0 staged.
fn staggered_trace(a: &Arc<ModelAssets>, n: usize, gap: f64) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let prompt: Vec<i32> = (0..m.max_seq.min(8)).map(|i| 1 + i as i32).collect();
    let max_new = (m.max_cache - m.max_seq).clamp(1, 2);
    (0..n)
        .map(|id| {
            TimedRequest::new(id, id as f64 * gap, Request { prompt: prompt.clone(), max_new })
        })
        .collect()
}

/// Without `--host-pool` the outcome carries all-zero pool stats and the
/// engines never grow a handle — and the pool-less `ssd_resident`
/// transfer chain (which the pool branch sits in front of) stays pinned
/// bit-identical across the event loop, the retired min-clock loop, and
/// the `--parallel` worker path.  The pre-existing digest pins only
/// cover warm-cache engines that never transfer at all, so this is the
/// neutrality pin for the code path the pool actually touches.
#[test]
fn host_pool_off_path_is_digest_neutral() {
    let Some(a) = assets() else { return };
    let c = pool_cfg(None);
    let mk = || staggered_trace(&a, 6, 0.2);

    let mut serial_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let serial = run_cluster(&mut serial_engines, mk(), &c).unwrap();
    assert_eq!(serial.pool, PoolStats::default(), "no pool, yet stats moved");
    assert!(serial_engines.iter().all(|e| e.host_pool.is_none()));

    let mut minclock_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let minclock = run_cluster_minclock(&mut minclock_engines, mk(), &c).unwrap();
    assert_eq!(serial.digest(), minclock.digest(), "min-clock loop diverged");

    let mut par_cfg = c.clone();
    par_cfg.serving.parallel = 2;
    let mut par_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let parallel = run_cluster(&mut par_engines, mk(), &par_cfg).unwrap();
    assert_eq!(serial.digest(), parallel.digest(), "parallel workers diverged");
    assert_eq!(parallel.pool, PoolStats::default());
}

/// The tentpole claim: at equal total host budget, the shared LRU pool
/// turns the *other* replica's SSD fills into host hits, while the
/// static per-replica split (the independent-caches baseline) pays the
/// fill once per replica.  Same routed work in both runs, so: strictly
/// fewer SSD fills, strictly higher hit rate, and strictly lower mean
/// TTFT for the shared pool.
#[test]
fn host_pool_shared_policy_beats_static_split() {
    let Some(a) = assets() else { return };
    let mk = || staggered_trace(&a, 6, 0.2);
    let run = |policy: PoolPolicyKind| {
        let c = pool_cfg(Some(HostPoolConfig { capacity_bytes: GB, policy }));
        let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
        let out = run_cluster(&mut engines, mk(), &c).unwrap();
        // detach discipline: the run must leave the engines unpooled
        assert!(engines.iter().all(|e| e.host_pool.is_none()), "{}: handle leaked", policy.name());
        out
    };
    let shared = run(PoolPolicyKind::Shared);
    let static_ = run(PoolPolicyKind::Static);

    assert_eq!(shared.fleet.metrics.completed, 6);
    assert_eq!(static_.fleet.metrics.completed, 6);
    // identical routed-expert sequences => identical pool lookup counts
    assert_eq!(
        shared.pool.host_hits + shared.pool.ssd_fills,
        static_.pool.host_hits + static_.pool.ssd_fills,
        "policies saw different lookup totals; the comparison is void"
    );
    assert!(shared.pool.ssd_fills > 0, "pool never exercised");
    assert!(
        shared.pool.ssd_fills < static_.pool.ssd_fills,
        "shared pool did not absorb cross-replica fills: {} vs {}",
        shared.pool.ssd_fills,
        static_.pool.ssd_fills
    );
    assert!(
        shared.pool.hit_rate() > static_.pool.hit_rate(),
        "shared hit rate {:.3} not above static {:.3}",
        shared.pool.hit_rate(),
        static_.pool.hit_rate()
    );
    let ttft_shared = shared.fleet.metrics.ttft.mean();
    let ttft_static = static_.fleet.metrics.ttft.mean();
    assert!(
        ttft_shared < ttft_static,
        "shared pool did not cut mean TTFT: {ttft_shared} vs {ttft_static}"
    );
}

/// With a pool attached, `--parallel` must still be a pure wall-clock
/// knob: replicas journal pool writes privately mid-window and the
/// barrier applies them in replica order on the spawning thread, so
/// every outcome bit — digest *and* the pool counters the digest
/// deliberately excludes — matches the serial run.
#[test]
fn host_pool_parallel_run_is_bit_identical_to_serial() {
    let Some(a) = assets() else { return };
    let mk = || staggered_trace(&a, 8, 0.15);
    let base = pool_cfg(Some(HostPoolConfig {
        capacity_bytes: GB,
        policy: PoolPolicyKind::Shared,
    }));
    let mut serial_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let serial = run_cluster(&mut serial_engines, mk(), &base).unwrap();

    let mut par_cfg = base.clone();
    par_cfg.serving.parallel = 2;
    let mut par_engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let parallel = run_cluster(&mut par_engines, mk(), &par_cfg).unwrap();

    assert_eq!(parallel.digest(), serial.digest(), "pooled parallel run diverged");
    assert_eq!(parallel.pool, serial.pool, "pool counters diverged under --parallel");
    assert!(serial.pool.host_hits > 0, "pin is vacuous: pool never hit");
    for (x, y) in parallel.fleet.per_request.iter().zip(&serial.fleet.per_request) {
        assert_eq!((x.id, x.ttft, x.finished_at), (y.id, y.ttft, y.finished_at));
    }
}

/// The pinned policy freezes first-staged copies: it must complete the
/// trace with zero evictions while still serving host hits, and its
/// staged bytes never exceed the configured budget.
#[test]
fn host_pool_pinned_policy_never_evicts_under_load() {
    let Some(a) = assets() else { return };
    let c = pool_cfg(Some(HostPoolConfig {
        capacity_bytes: GB,
        policy: PoolPolicyKind::Pinned,
    }));
    let mut engines: Vec<Engine> = (0..2).map(|_| pool_engine(&a)).collect();
    let out = run_cluster(&mut engines, staggered_trace(&a, 6, 0.2), &c).unwrap();
    assert_eq!(out.fleet.metrics.completed, 6);
    assert_eq!(out.pool.evictions, 0, "pinned policy evicted");
    assert!(out.pool.host_hits > 0, "pinned pool never served a hit");
    assert!(out.pool.inserted_bytes <= GB, "pinned pool overran its budget");
}

// ---------------------------------------------------------------------
// Engine-free dispatch model properties (run everywhere)
// ---------------------------------------------------------------------

/// Dispatch policies over random replica views: picks are always in
/// range, jsq never routes to a strictly more loaded replica than its
/// pick, rr visits every replica within one cycle, and affinity is a
/// pure function of the prompt.
#[test]
fn prop_dispatch_policies_route_sanely() {
    prop::check("dispatch-routing", 200, |rng| {
        let n = rng.range(1, 9);
        let views: Vec<ReplicaDispatchView> = (0..n)
            .map(|index| ReplicaDispatchView {
                index,
                clock: rng.f64() * 100.0,
                queued_requests: rng.below(5),
                queued_tokens: rng.below(200),
                active_sessions: rng.below(4),
                active_tokens: rng.below(100),
                resident_expert_bytes: Vec::new(),
            })
            .collect();
        let prompt: Vec<i32> = (0..rng.range(1, 12)).map(|_| rng.below(60) as i32).collect();
        let req = TimedRequest::new(
            rng.below(1000),
            rng.f64(),
            Request { prompt: prompt.clone(), max_new: rng.range(1, 8) },
        );

        for kind in DispatchKind::ALL {
            let mut p = kind.build();
            let pick = p.route(&req, &views);
            assert!(pick < n, "{} routed out of range: {pick} of {n}", kind.name());
            if kind == DispatchKind::JoinShortestQueue {
                let picked = views[pick].queued_tokens + views[pick].active_tokens;
                for v in &views {
                    assert!(
                        picked <= v.queued_tokens + v.active_tokens,
                        "jsq skipped a less-loaded replica"
                    );
                }
            }
            if kind == DispatchKind::ExpertAffinity {
                // pure in the prompt: rerouting the same request agrees
                assert_eq!(pick, kind.build().route(&req, &views));
            }
        }

        // rr covers every replica in one cycle regardless of load
        let mut rr = DispatchKind::RoundRobin.build();
        let mut seen = vec![false; n];
        for _ in 0..n {
            seen[rr.route(&req, &views)] = true;
        }
        assert!(seen.iter().all(|&s| s), "rr starved a replica in one cycle");
    });
}

/// The event queue's ordering contract over random interleavings: pops
/// come out sorted by `(virtual time, kind, seq)` — churn before
/// arrival before tick at the same instant, churn ties by schedule
/// order, arrival ties by request id, tick ties by replica index — no
/// matter the push order, including pushes "in the past" after pops.
#[test]
fn prop_event_queue_pops_in_virtual_time_order() {
    fn key(e: &Event) -> (f64, u8, u64) {
        let class = match e.payload {
            EventPayload::Churn(_) => 0u8,
            EventPayload::Arrival(_) => 1,
            EventPayload::Tick { .. } => 2,
        };
        (e.at, class, e.seq)
    }
    prop::check("event-queue-order", 200, |rng| {
        let mut q = EventQueue::new();
        let n = rng.range(3, 40);
        for k in 0..n {
            // coarse time grid to force plenty of same-instant ties
            let at = rng.below(10) as f64 * 0.5;
            match rng.below(3) {
                0 => q.push(Event::churn(
                    k as u64,
                    ChurnEvent { at, replica: rng.below(4), kind: ChurnKind::Fail },
                )),
                1 => q.push(Event::arrival(TimedRequest::new(
                    k,
                    at,
                    Request { prompt: vec![1], max_new: 1 },
                ))),
                _ => q.push(Event::tick(at, rng.below(6))),
            }
        }
        // drain half, then push more (tick entries for lagging replicas
        // land in the past relative to earlier pops)
        let mut popped: Vec<(f64, u8, u64)> = Vec::new();
        for _ in 0..n / 2 {
            popped.push(key(&q.pop().unwrap()));
        }
        let mut sorted_prefix = popped.clone();
        sorted_prefix.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        assert_eq!(popped, sorted_prefix, "pop prefix out of order");
        let extra = rng.range(1, 8);
        for k in 0..extra {
            q.push(Event::tick(rng.below(10) as f64 * 0.5, 6 + k));
        }
        let mut tail: Vec<(f64, u8, u64)> = Vec::new();
        while let Some(e) = q.pop() {
            tail.push(key(&e));
        }
        assert_eq!(tail.len(), n - n / 2 + extra, "queue lost or duplicated events");
        let mut sorted_tail = tail.clone();
        sorted_tail.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        assert_eq!(tail, sorted_tail, "pops after past-time pushes out of order");
        assert!(q.is_empty());
    });
}

//! Integration: the full serving engine over the real `tiny` artifacts —
//! golden numerics vs the Python reference, timeline sanity, cache and
//! prefetch behaviour, forced decoding for eval.

use std::sync::Arc;

use dymoe::config::{LowMode, PolicyConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::coordinator::strategy::DyMoEStrategy;
use dymoe::baselines::Uniform;
use dymoe::model::assets::ModelAssets;
use dymoe::model::sampler;
use dymoe::quant::Precision;
use dymoe::util::json::Json;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    // plenty of VRAM: everything fits, accuracy-only runs
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>, opts: EngineOptions) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        opts,
    )
    .unwrap()
}

#[test]
fn golden_numerics_match_python_reference() {
    let Some(a) = assets() else { return };
    let text = std::fs::read_to_string(a.dir.join("golden.json")).unwrap();
    let g = Json::parse(&text).unwrap();
    let prompt: Vec<i32> = g
        .get("prompt")
        .unwrap()
        .as_usize_vec()
        .unwrap()
        .into_iter()
        .map(|t| t as i32)
        .collect();
    let expected: Vec<f64> = g
        .get("last_logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();

    let mut engine = bf16_engine(
        &a,
        EngineOptions { collect_logits: true, ..Default::default() },
    );
    let out = engine.run(&prompt, 1).unwrap();
    let got = &out.logits_per_step[0];
    assert_eq!(got.len(), expected.len());
    let max_err = got
        .iter()
        .zip(&expected)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0, f64::max);
    // bf16 serving path == python full forward (both f32 math)
    assert!(max_err < 2e-3, "serving path diverges from python: {max_err}");
}

#[test]
fn generation_is_deterministic_and_timed() {
    let Some(a) = assets() else { return };
    let mut e1 = bf16_engine(&a, EngineOptions::default());
    let mut e2 = bf16_engine(&a, EngineOptions::default());
    let prompt = [1i32, 5, 9, 13];
    let o1 = e1.run(&prompt, 6).unwrap();
    let o2 = e2.run(&prompt, 6).unwrap();
    assert_eq!(o1.tokens, o2.tokens);
    assert_eq!(o1.tokens.len(), 6);
    assert!(o1.ttft > 0.0);
    assert_eq!(o1.token_times.len(), 6);
    // token times strictly increase
    for w in o1.token_times.windows(2) {
        assert!(w[1] > w[0], "non-monotone token times");
    }
    assert!(o1.tpot() > 0.0 && o1.tpot() < o1.ttft);
}

#[test]
fn forced_decoding_returns_logits_per_answer_token() {
    let Some(a) = assets() else { return };
    let mut e = bf16_engine(
        &a,
        EngineOptions { collect_logits: true, ..Default::default() },
    );
    let prompt = [1i32, 2, 30, 31];
    let answer = [30i32, 31, 32];
    let out = e.run_forced(&prompt, 0, Some(&answer)).unwrap();
    assert_eq!(out.tokens, answer.to_vec());
    assert_eq!(out.logits_per_step.len(), 3);
    for l in &out.logits_per_step {
        assert_eq!(l.len(), e.model().vocab);
        assert!(sampler::nll(l, 30).is_finite());
    }
}

#[test]
fn teacher_forcing_matches_incremental_prefill() {
    // decode logits for position T must match a fresh prefill of T+1 tokens
    let Some(a) = assets() else { return };
    let mut e = bf16_engine(
        &a,
        EngineOptions { collect_logits: true, ..Default::default() },
    );
    let full = [1i32, 4, 30, 41, 52, 33];
    let t = 4;
    let out = e
        .run_forced(&full[..t], 0, Some(&[full[t], full[t + 1]]))
        .unwrap();
    // out.logits_per_step[1] predicts full[t+1] given prefix full[..t+1]
    let out2 = e.run_forced(&full[..t + 1], 1, None).unwrap();
    let l1 = &out.logits_per_step[1];
    let l2 = &out2.logits_per_step[0];
    let max_err = l1
        .iter()
        .zip(l2)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 2e-3, "decode/prefill divergence {max_err}");
}

#[test]
fn constrained_vram_causes_misses_and_transfers() {
    let Some(a) = assets() else { return };
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    // squeeze: room for only ~4 bf16 experts' worth of paper-scale bytes
    sys.hardware.vram_bytes = sys.paper.non_expert_bytes
        + 4 * 32 * 2 * sys.paper.expert_params(); // 4 experts * grid scale 1/32
    let mut e = Engine::new(
        &a,
        sys,
        Box::new(Uniform::new(Precision::Bf16)),
    )
    .unwrap();
    let prompt = [1i32, 5, 9, 13, 17, 21];
    let out = e.run(&prompt, 4).unwrap();
    assert!(out.ttft > 0.0);
    assert!(e.cache.stats.misses > 0, "expected cache misses");
    assert!(e.stats.transferred_bytes > 0);
    // a second identical request still serves some hits from the warmed
    // cache (LRU cycling under a too-small cache can shift the phase, so
    // we don't require monotone improvement, just a working cache)
    e.cache.stats = Default::default();
    let _ = e.run(&prompt, 4).unwrap();
    assert!(e.cache.stats.hits > 0, "warmed cache served no hits");
}

#[test]
fn dymoe_skip_mode_executes_fewer_experts() {
    let Some(a) = assets() else { return };
    let sys = big_vram_sys();
    let policy = PolicyConfig {
        retention: 0.5,
        low_mode: LowMode::Skip,
        ..Default::default()
    };
    let mut dymoe = Engine::new(&a, sys.clone(), Box::new(DyMoEStrategy::new(policy))).unwrap();
    let mut base = Engine::new(&a, sys, Box::new(Uniform::new(Precision::Int4)))
        .unwrap();
    let prompt = [1i32, 3, 12, 14, 16];
    let _ = dymoe.run(&prompt, 5).unwrap();
    let _ = base.run(&prompt, 5).unwrap();
    assert!(dymoe.stats.skipped_experts > 0, "4/0 must skip sub-criticals");
    assert!(
        dymoe.stats.expert_execs < base.stats.expert_execs,
        "dymoe {} vs base {}",
        dymoe.stats.expert_execs,
        base.stats.expert_execs
    );
}

#[test]
fn dymoe_full_retention_equals_uniform_int4() {
    // r = 1.0 classifies every expert Critical -> DyMoE degenerates to
    // uniform Int4; outputs must match the Uniform(Int4) strategy exactly.
    let Some(a) = assets() else { return };
    let policy = PolicyConfig {
        retention: 1.0,
        low_mode: LowMode::Int2,
        ..Default::default()
    };
    let opts = EngineOptions { collect_logits: true, ..Default::default() };
    let mut dy = Engine::with_options(
        &a,
        big_vram_sys(),
        Box::new(DyMoEStrategy::new(policy)),
        opts.clone(),
    )
    .unwrap();
    let mut u4 = Engine::with_options(
        &a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Int4)),
        opts,
    )
    .unwrap();
    let prompt = [1i32, 2, 30, 35, 40];
    let od = dy.run(&prompt, 3).unwrap();
    let ou = u4.run(&prompt, 3).unwrap();
    assert_eq!(od.tokens, ou.tokens);
    for (a, b) in od.logits_per_step.iter().zip(&ou.logits_per_step) {
        let max_err = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - y).abs())
            .fold(0f32, f32::max);
        assert!(max_err < 1e-4, "r=1.0 DyMoE != uniform int4: {max_err}");
    }
}

#[test]
fn prefetching_overlaps_io_with_compute() {
    let Some(a) = assets() else { return };
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    // tight VRAM: only ~4 of the 8 int4 experts fit (grid ratio 8/256)
    let int4 = dymoe::quant::expert_bytes(
        sys.paper.d_model,
        sys.paper.d_ffn,
        128,
        Precision::Int4,
    );
    // 6 of 8 int4 expert slots: misses exist but prefetch has slack
    sys.hardware.vram_bytes = sys.paper.non_expert_bytes + 32 * 6 * int4;
    let mk = |prefetch: bool| {
        let policy = PolicyConfig {
            retention: 1.0,
            prefetch_enabled: prefetch,
            dyquant_enabled: false,
            // depth must respect the cache size: the tiny model has 4
            // experts/layer and ~4 cache slots, so prefetch top_k = 2
            prefetch_depth: 2,
            ..Default::default()
        };
        Engine::new(
            &a,
            sys.clone(),
            Box::new(DyMoEStrategy::new(policy)),
        )
        .unwrap()
    };
    let mut with = mk(true);
    let mut without = mk(false);
    let prompt: Vec<i32> = (0..12).map(|i| 1 + (i * 3) % 60).collect();
    let ow = with.run(&prompt, 8).unwrap();
    let oo = without.run(&prompt, 8).unwrap();
    // Mechanism checks on the tiny model (the latency *win* is asserted on
    // mixtral-mini in integration_baselines — the tiny model's routing is
    // too noisy for reliable look-ahead predictions):
    assert!(with.prefetch_stats.issued > 0, "prefetcher idle");
    assert!(with.prefetch_stats.useful > 0, "no prefetch ever used");
    assert!(without.prefetch_stats.issued == 0);
    // prefetch keeps the hit rate in the same band (the tiny model's
    // pre-MoE probe predictions are noisy; the trained-model win is
    // asserted in integration_baselines::prefetch_wins_on_trained_model)
    assert!(
        with.cache.stats.hit_rate() >= without.cache.stats.hit_rate() - 0.15,
        "prefetch collapsed hit rate: {} vs {}",
        with.cache.stats.hit_rate(),
        without.cache.stats.hit_rate()
    );
    // and stays within sane bounds on latency even under mispredictions
    assert!(
        ow.tpot() <= oo.tpot() * 2.0,
        "prefetch catastrophically slow: {} vs {}",
        ow.tpot(),
        oo.tpot()
    );
}

/// PrefetchStats invariant regression: after every engine step
/// `issued == useful + wasted` (no look-ahead state leaks across step
/// boundaries), `accuracy()` stays well-defined at zero issued, and
/// `reset_stats` clears the in-flight bookkeeping together with the
/// counters (a stale entry consumed after a reset would otherwise credit
/// useful/wasted with no matching `issued`).
#[test]
fn prefetch_accounting_balances_after_every_step() {
    let Some(a) = assets() else { return };
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    let int4 = dymoe::quant::expert_bytes(
        sys.paper.d_model,
        sys.paper.d_ffn,
        128,
        Precision::Int4,
    );
    // tight VRAM so prefetches actually issue (see
    // prefetching_overlaps_io_with_compute for the sizing)
    sys.hardware.vram_bytes = sys.paper.non_expert_bytes + 32 * 6 * int4;
    let policy = PolicyConfig {
        retention: 1.0,
        prefetch_enabled: true,
        dyquant_enabled: false,
        prefetch_depth: 2,
        ..Default::default()
    };
    let mut e = Engine::new(&a, sys, Box::new(DyMoEStrategy::new(policy))).unwrap();

    // zero issued: accuracy defined, nothing in flight
    assert_eq!(e.prefetch_stats.accuracy(), 0.0);
    assert!(e.prefetch_stats.accuracy().is_finite());
    assert_eq!(e.prefetched_in_flight(), 0);

    let check = |e: &Engine, at: &str| {
        let ps = e.prefetch_stats;
        assert!(ps.balanced(), "{at}: useful+wasted exceeds issued: {ps:?}");
        assert_eq!(
            ps.useful + ps.wasted + e.prefetched_in_flight(),
            ps.issued,
            "{at}: prefetch accounting out of balance: {ps:?}"
        );
        assert_eq!(
            e.prefetched_in_flight(),
            0,
            "{at}: look-ahead state leaked across a step boundary"
        );
    };

    let prompt: Vec<i32> = (0..12).map(|i| 1 + (i * 3) % 60).collect();
    let arrival = e.clock();
    let mut s = e.begin_session(&prompt, 6, None, arrival).unwrap();
    e.prefill_session(&mut s).unwrap();
    check(&e, "after prefill");
    let mut step = 0;
    while !s.done() {
        e.decode_session(&mut s).unwrap();
        step += 1;
        check(&e, &format!("after decode step {step}"));
    }
    assert!(e.prefetch_stats.issued > 0, "prefetcher idle; test is vacuous");

    // reset clears the in-flight bookkeeping with the counters
    e.reset_stats();
    assert_eq!(e.prefetch_stats.issued, 0);
    assert_eq!(e.prefetched_in_flight(), 0);
    assert_eq!(e.prefetch_stats.accuracy(), 0.0);

    // and the invariant survives another full request after the reset
    let out = e.run(&prompt, 4).unwrap();
    assert_eq!(out.tokens.len(), 4);
    check(&e, "after post-reset run");
}

/// The same invariant under cross-session batched decode: one aggregated
/// prefetch decision per layer serves the whole batch and is consumed
/// within the step.
#[test]
fn prefetch_accounting_balances_under_batched_decode() {
    let Some(a) = assets() else { return };
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    let int4 = dymoe::quant::expert_bytes(
        sys.paper.d_model,
        sys.paper.d_ffn,
        128,
        Precision::Int4,
    );
    sys.hardware.vram_bytes = sys.paper.non_expert_bytes + 32 * 6 * int4;
    let policy = PolicyConfig {
        retention: 1.0,
        prefetch_enabled: true,
        dyquant_enabled: false,
        prefetch_depth: 2,
        ..Default::default()
    };
    let mut e = Engine::new(&a, sys, Box::new(DyMoEStrategy::new(policy))).unwrap();
    let p1: Vec<i32> = (0..10).map(|i| 1 + (i * 3) % 60).collect();
    let p2: Vec<i32> = (0..8).map(|i| 1 + (i * 7) % 60).collect();
    let mut s1 = e.begin_session(&p1, 5, None, 0.0).unwrap();
    let mut s2 = e.begin_session(&p2, 5, None, 0.0).unwrap();
    e.prefill_session(&mut s1).unwrap();
    e.prefill_session(&mut s2).unwrap();
    loop {
        let dones = e.decode_batch(&mut [&mut s1, &mut s2]).unwrap();
        let ps = e.prefetch_stats;
        assert!(ps.balanced(), "batched step unbalanced: {ps:?}");
        assert_eq!(ps.useful + ps.wasted + e.prefetched_in_flight(), ps.issued);
        assert_eq!(e.prefetched_in_flight(), 0);
        if dones.iter().all(|&d| d) {
            break;
        }
    }
    assert!(e.prefetch_stats.issued > 0, "prefetcher idle under batching");
}

#[test]
fn timeline_events_recorded_when_requested() {
    let Some(a) = assets() else { return };
    let mut sys = big_vram_sys();
    sys.hardware.vram_bytes = sys.paper.non_expert_bytes + GB;
    let mut e = Engine::with_options(
        &a,
        sys,
        Box::new(Uniform::new(Precision::Int4)),
        EngineOptions { record_timeline: true, ..Default::default() },
    )
    .unwrap();
    let _ = e.run(&[1, 5, 9], 3).unwrap();
    assert!(!e.timeline.events.is_empty());
    let art = e.timeline.render_ascii(60);
    assert!(art.contains("gpu"));
    // compute and transfer events both present under tight VRAM
    use dymoe::memory::EventKind;
    assert!(e.timeline.events.iter().any(|ev| ev.kind == EventKind::GpuCompute));
    assert!(e
        .timeline
        .events
        .iter()
        .any(|ev| ev.kind == EventKind::PcieTransfer));
}

#[test]
fn strict_precision_changes_numerics_not_tokens_necessarily() {
    // With ample VRAM the warm fill holds Int4 copies; a 4/2 policy's
    // Int2 requests are served by conservative reuse unless
    // strict_precision forces the planned tier.  The two modes must
    // produce different logits (Int2 vs Int4 execution) for a policy that
    // actually assigns Int2.
    let Some(a) = assets() else { return };
    let policy = PolicyConfig {
        retention: 0.5,
        low_mode: LowMode::Int2,
        ..Default::default()
    };
    let mk = |strict: bool| {
        Engine::with_options(
            &a,
            big_vram_sys(),
            Box::new(DyMoEStrategy::new(policy.clone())),
            EngineOptions {
                collect_logits: true,
                strict_precision: strict,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let prompt = [1i32, 5, 30, 35, 40, 45, 50];
    let o_strict = mk(true).run(&prompt, 4).unwrap();
    let o_reuse = mk(false).run(&prompt, 4).unwrap();
    let diff: f32 = o_strict.logits_per_step[0]
        .iter()
        .zip(&o_reuse.logits_per_step[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-6, "strict precision had no effect: {diff}");
}

//! Integration: PJRT runtime + executor over the real `tiny` artifacts.
//!
//! Requires `make artifacts` (skipped politely when missing so `cargo
//! test` can run pre-build, but CI always builds artifacts first).

use std::sync::Arc;

use dymoe::model::assets::{ExpertKey, ModelAssets};
use dymoe::model::executor::Executor;
use dymoe::model::kv::KvCache;
use dymoe::quant::Precision;
use dymoe::util::json::Json;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_parses_and_is_consistent() {
    let Some(a) = assets() else { return };
    let m = &a.manifest;
    assert_eq!(m.model.name, "tiny");
    assert!(m.artifacts.contains_key("attn_prefill"));
    assert!(m.artifacts.contains_key("expert_int4_t1"));
    // sections cover every expert at every precision
    for key in a.expert_keys() {
        for p in Precision::ALL_STORED {
            for name in a.expert_section_names(key, p) {
                assert!(m.sections.contains_key(&name), "missing {name}");
            }
        }
    }
    // transfer byte ordering
    assert!(
        m.expert_transfer_bytes(Precision::Bf16)
            > m.expert_transfer_bytes(Precision::Int8)
    );
    assert_eq!(m.expert_transfer_bytes(Precision::Skip), 0);
}

#[test]
fn sections_deserialize_with_expected_shapes() {
    let Some(a) = assets() else { return };
    let m = &a.manifest.model;
    let (emb, shape) = a.f32_section("emb").unwrap();
    assert_eq!(shape, vec![m.vocab, m.d_model]);
    assert_eq!(emb.len(), m.vocab * m.d_model);
    let (words, wshape) = a.u32_section("L0.E0.w1.int4.q").unwrap();
    assert_eq!(wshape, vec![m.d_model * 4 / 32, m.d_ffn]);
    assert!(!words.is_empty());
}

#[test]
fn executor_runs_every_artifact_shape() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let ex = Executor::new(a.clone()).unwrap();

    // embed both shapes
    let toks = vec![1i32; m.max_seq];
    let h = ex.embed_seq(&toks).unwrap();
    assert_eq!(h.len(), m.max_seq * m.d_model);
    let h1 = ex.embed_one(2).unwrap();
    assert_eq!(h1.len(), m.d_model);

    // prefill attention: outputs well-formed
    let po = ex.attn_prefill(0, &h, 5).unwrap();
    assert_eq!(po.gate_probs.len(), m.max_seq * m.n_experts);
    assert_eq!(po.token_scores.len(), m.max_seq);
    let score_sum: f32 = po.token_scores.iter().sum();
    assert!((score_sum - 1.0).abs() < 1e-3, "Eq.1 scores sum {score_sum}");
    for t in 0..5 {
        let row = &po.gate_probs[t * m.n_experts..(t + 1) * m.n_experts];
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "gate row {t} sums to {s}");
    }

    // decode attention over a KV cache built from the prefill K/V
    let mut kv = KvCache::new(m.n_layers, m.max_cache, m.n_heads, m.head_dim);
    kv.write_prefix(0, 5, &po.k, &po.v).unwrap();
    let d0 = ex.attn_decode(0, &h1, &kv, 5).unwrap();
    assert_eq!(d0.gate_probs.len(), m.n_experts);
    assert_eq!(d0.k_new.len(), m.n_heads * m.head_dim);

    // gate probe both shapes
    assert_eq!(ex.gate_probe(1, &h1).unwrap().len(), m.n_experts);
    assert_eq!(
        ex.gate_probe(1, &po.h_resid).unwrap().len(),
        m.max_seq * m.n_experts
    );

    // every expert precision + bucket
    let key = ExpertKey::new(0, 1);
    let row = vec![0.1f32; m.d_model];
    for p in Precision::ALL_STORED {
        let y = ex.expert_ffn(key, p, &[&row]).unwrap();
        assert_eq!(y.len(), 1);
        assert_eq!(y[0].len(), m.d_model);
        assert!(y[0].iter().all(|v| v.is_finite()));
    }
    // multi-token bucket with padding
    let rows = vec![&row[..], &row[..], &row[..]];
    let y3 = ex.expert_ffn(key, Precision::Int4, &rows).unwrap();
    assert_eq!(y3.len(), 3);
    // identical rows must produce identical outputs
    assert_eq!(y3[0], y3[1]);

    // finalize both shapes
    assert_eq!(ex.finalize_one(&h1).unwrap().len(), m.vocab);
    assert_eq!(
        ex.finalize_seq(&po.h_resid).unwrap().len(),
        m.max_seq * m.vocab
    );
}

#[test]
fn quant_precision_ordering_in_expert_outputs() {
    // int8 expert output closer to bf16 than int4, which beats int2
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let ex = Executor::new(a.clone()).unwrap();
    let key = ExpertKey::new(1, 0);
    let row: Vec<f32> = (0..m.d_model).map(|i| ((i as f32) * 0.37).sin()).collect();
    let y16 = ex.expert_ffn(key, Precision::Bf16, &[&row]).unwrap();
    let mut errs = Vec::new();
    for p in [Precision::Int8, Precision::Int4, Precision::Int2] {
        let y = ex.expert_ffn(key, p, &[&row]).unwrap();
        let err: f32 = y[0]
            .iter()
            .zip(&y16[0])
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / y[0].len() as f32;
        errs.push(err);
    }
    assert!(errs[0] < errs[1] && errs[1] < errs[2], "errs {errs:?}");
}

#[test]
fn golden_numerics_available() {
    // golden.json exists and parses; the engine test consumes it.
    let Some(a) = assets() else { return };
    let text = std::fs::read_to_string(a.dir.join("golden.json")).unwrap();
    let g = Json::parse(&text).unwrap();
    let prompt = g.get("prompt").unwrap().as_usize_vec().unwrap();
    let logits = g.get("last_logits").unwrap().as_arr().unwrap();
    assert!(!prompt.is_empty());
    assert_eq!(logits.len(), a.manifest.model.vocab);
}

//! Integration: tenant-class scenarios and preemptive scheduling.
//!
//! Four pillars:
//!
//! 1. **Arrival statistics** — for every `ArrivalProcess` x `Envelope`
//!    combination the thinning sampler's seeded empirical arrival count
//!    matches the analytic mean (the numeric integral of
//!    `rate_at(t) * factor_at(t)` over the realized span) within a
//!    tolerance far wider than the sampling noise, and timestamps are
//!    never non-monotone.  Engine-free, runs everywhere.
//! 2. **Scenario composition** — a mixed scenario emits a sorted,
//!    densely re-id'd trace with the exact apportioned class split,
//!    interactive requests inheriting the fleet SLO (stamp `None`) and
//!    batch requests carrying the relaxed stamped targets.  Engine-free.
//! 3. **Digest neutrality** — a single-class `--scenario steady` trace
//!    is bitwise-identical (via [`ClusterOutcome::digest`]) to the
//!    equivalent `--arrival poisson` run, across the event-driven loop,
//!    the retired min-clock loop, and the `--parallel` worker path.
//!    The tenant-class machinery must be invisible until a scenario
//!    actually mixes classes.
//! 4. **Preemption semantics** — on a hand-built trace where batch
//!    decodes hold every slot when an interactive request arrives, the
//!    class-aware policy preempts a batch decode slot (the class-blind
//!    fifo baseline never does), cuts the interactive TTFT strictly
//!    below fifo's, conserves every batch request (no starvation) and
//!    its emitted tokens (work conservation), and the whole preemptive
//!    path stays bit-identical across the min-clock and `--parallel`
//!    loops.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`), matching the other
//! integration suites.

use std::sync::Arc;

use dymoe::baselines::Uniform;
use dymoe::config::{ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{
    ArrivalGen, ArrivalProcess, Envelope, TenantClass, TimedRequest,
};
use dymoe::serving::metrics::SloTargets;
use dymoe::serving::policy::{DispatchKind, PolicyKind};
use dymoe::serving::{
    run_cluster, run_cluster_minclock, run_fleet, FleetConfig, FleetOutcome, Scenario,
};
use dymoe::workload::{Request, TraceGen};

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions::default(),
    )
    .unwrap()
}

fn cfg(
    policy: PolicyKind,
    dispatch: DispatchKind,
    max_sessions: usize,
    batch: usize,
) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: batch,
            ..Default::default()
        },
        policy,
        dispatch,
    }
}

/// A hand-stamped batch-class request; `slo: None` resolves to the
/// fleet targets, which is all these tests need (priority, not
/// deadlines, drives preemption).
fn batch_req(id: usize, arrival: f64, prompt: Vec<i32>, max_new: usize) -> TimedRequest {
    TimedRequest {
        id,
        arrival,
        class: TenantClass::Batch,
        slo: None,
        request: Request { prompt, max_new },
    }
}

// ---------------------------------------------------------------------
// Arrival statistics (engine-free)
// ---------------------------------------------------------------------

/// For every process x envelope combination, the thinning sampler's
/// empirical arrival count over its realized span matches the analytic
/// mean `∫ rate_at(t) * factor_at(t) dt` — the integral over the span
/// ending at the n-th arrival is Gamma(n)-distributed with mean n and
/// relative std `1/sqrt(n)` (~1.8% here), so the 10% gate is over five
/// sigma wide while still catching any systematic thinning bias.  And
/// the sampler never emits a non-monotone timestamp.
#[test]
fn empirical_arrival_rate_matches_analytic_mean() {
    let n = 3000usize;
    let processes = [
        ArrivalProcess::Poisson { rate: 2.0 },
        ArrivalProcess::Bursty {
            base_rate: 1.0,
            burst_rate: 6.0,
            period: 40.0,
            burst_frac: 0.25,
        },
        ArrivalProcess::Ramp { start_rate: 0.5, end_rate: 4.0, ramp_secs: 300.0 },
    ];
    let envelopes = [
        Envelope::Flat,
        Envelope::Diurnal { period_s: 200.0, amplitude: 0.5 },
        Envelope::Flash { at_s: 100.0, magnitude: 3.0, duration_s: 50.0 },
    ];
    for (pi, &process) in processes.iter().enumerate() {
        for (ei, &envelope) in envelopes.iter().enumerate() {
            let label = format!("process {pi} x envelope {ei}");
            let seed = 0xA11C + 7 * pi as u64 + ei as u64;
            let mut sampler = ArrivalGen::with_envelope(seed, process, envelope).unwrap();
            let mut prev = 0.0;
            for _ in 0..n {
                let t = sampler.next_arrival();
                assert!(t >= prev, "{label}: non-monotone arrival {t} after {prev}");
                prev = t;
            }
            let span = prev;
            assert!(span > 0.0, "{label}: sampler never advanced");
            // midpoint rule; the grid is fine enough that the envelope
            // and burst discontinuities contribute O(dt) error only
            let steps = 200_000usize;
            let dt = span / steps as f64;
            let mut expected = 0.0;
            for k in 0..steps {
                let t = (k as f64 + 0.5) * dt;
                expected += process.rate_at(t) * envelope.factor_at(t) * dt;
            }
            let rel = (expected - n as f64).abs() / n as f64;
            assert!(
                rel < 0.10,
                "{label}: analytic mean {expected:.0} arrivals over {span:.1}s vs {n} \
                 drawn (rel err {rel:.3}) — thinning is biased"
            );
        }
    }
}

// ---------------------------------------------------------------------
// Scenario composition (engine-free)
// ---------------------------------------------------------------------

/// A mixed scenario's merged trace is sorted by arrival with dense
/// re-stamped ids, splits the classes exactly as apportioned, and
/// stamps SLOs per class: interactive `None` (fleet targets), batch the
/// relaxed `fleet x scale` targets.
#[test]
fn mixed_scenario_trace_is_sorted_split_and_slo_stamped() {
    let fleet_slo = SloTargets { ttft_s: 5.0, tpot_s: 0.5 };
    let s = Scenario::from_cli("mixed-flash:0.25:50:3:40", 2.0, fleet_slo, 8.0).unwrap();
    let mut content = TraceGen::new(3, 12, 6);
    let trace = s.generate(0xBEEF, &mut content, 400).unwrap();
    assert_eq!(trace.len(), 400);
    for (i, w) in trace.windows(2).enumerate() {
        assert!(w[0].arrival <= w[1].arrival, "trace not sorted at index {i}");
    }
    for (i, r) in trace.iter().enumerate() {
        assert_eq!(r.id, i, "ids must be dense in arrival order");
    }
    let interactive =
        trace.iter().filter(|r| r.class == TenantClass::Interactive).count();
    assert_eq!(interactive, 100, "mixed:0.25 must apportion exactly 25% interactive");
    for r in &trace {
        match r.class {
            TenantClass::Interactive => {
                assert!(r.slo.is_none(), "interactive must inherit the fleet SLO")
            }
            TenantClass::Batch => {
                let slo = r.slo.expect("batch requests carry a stamped SLO");
                assert!(
                    (slo.ttft_s - 40.0).abs() < 1e-9 && (slo.tpot_s - 4.0).abs() < 1e-9,
                    "batch SLO must be the fleet targets relaxed 8x, got {slo:?}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Single-class digest neutrality (artifacts-gated)
// ---------------------------------------------------------------------

/// `--scenario steady` must be the `--arrival poisson` path bit for
/// bit: same trace, same outcome digest — across the event-driven
/// cluster loop, the retired min-clock loop, and `--parallel` workers.
#[test]
fn steady_scenario_is_digest_neutral_vs_arrival_path() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let (n, rate) = (9usize, 10.0);
    let c = cfg(PolicyKind::SloAware, DispatchKind::JoinShortestQueue, 2, 2);
    let mk_content =
        || TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    let arrival_trace = || {
        let mut content = mk_content();
        ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
    };
    let scenario_trace = || {
        let fleet_slo =
            SloTargets { ttft_s: c.serving.ttft_slo_s, tpot_s: c.serving.tpot_slo_s };
        let s = Scenario::from_cli("steady", rate, fleet_slo, c.serving.batch_slo_scale)
            .unwrap();
        let mut content = mk_content();
        s.generate(21, &mut content, n).unwrap()
    };

    let mut arrival_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let via_arrival = run_cluster(&mut arrival_engines, arrival_trace(), &c).unwrap();
    let mut scenario_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let via_scenario = run_cluster(&mut scenario_engines, scenario_trace(), &c).unwrap();

    assert_eq!(via_scenario.fleet.per_request.len(), via_arrival.fleet.per_request.len());
    for (x, y) in via_scenario
        .fleet
        .per_request
        .iter()
        .zip(&via_arrival.fleet.per_request)
    {
        assert_eq!(x.id, y.id, "completion order diverged");
        assert_eq!(x.ttft, y.ttft, "TTFT diverged (id {})", x.id);
        assert_eq!(x.finished_at, y.finished_at, "completion time diverged (id {})", x.id);
        assert_eq!(x.preemptions, 0, "single-class run must never preempt");
    }
    assert_eq!(
        via_scenario.digest(),
        via_arrival.digest(),
        "steady scenario diverged from --arrival poisson"
    );

    let mut minclock_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let minclock =
        run_cluster_minclock(&mut minclock_engines, scenario_trace(), &c).unwrap();
    assert_eq!(minclock.digest(), via_arrival.digest(), "min-clock loop diverged");

    let mut par_cfg = c.clone();
    par_cfg.serving.parallel = 2;
    let mut par_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let parallel = run_cluster(&mut par_engines, scenario_trace(), &par_cfg).unwrap();
    assert_eq!(parallel.digest(), via_arrival.digest(), "--parallel diverged");
}

// ---------------------------------------------------------------------
// Preemption semantics (artifacts-gated)
// ---------------------------------------------------------------------

/// Two batch requests hold both slots in decode when an interactive
/// request arrives.  The class-aware policy must preempt a batch decode
/// slot (fifo, the class-blind baseline, must not), cutting the
/// interactive TTFT strictly below fifo's, while every batch request
/// still completes with its full token budget (no starvation, work
/// conserved).
#[test]
fn interactive_preempts_batch_decode_and_cuts_ttft() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let batch_new = (m.max_cache - m.max_seq).clamp(1, 6);
    let int_new = (m.max_cache - m.max_seq).clamp(1, 2);
    let mk = || {
        vec![
            batch_req(0, 0.0, vec![1, 7], batch_new),
            batch_req(1, 0.0, vec![1, 9], batch_new),
            TimedRequest::new(2, 0.05, Request { prompt: vec![1, 11], max_new: int_new }),
        ]
    };
    let run = |policy: PolicyKind| {
        let c = cfg(policy, DispatchKind::RoundRobin, 2, 2);
        let mut engine = bf16_engine(&a);
        run_fleet(&mut engine, mk(), &c).unwrap()
    };
    let slo = run(PolicyKind::SloAware);
    let fifo = run(PolicyKind::Fifo);

    // conservation: both classes complete fully under both policies
    for (name, o) in [("slo", &slo), ("fifo", &fifo)] {
        assert_eq!(o.metrics.completed, 3, "{name}: lost a request");
        assert_eq!(
            o.metrics.per_class[&TenantClass::Batch].completed,
            2,
            "{name}: batch class starved"
        );
    }
    assert_eq!(fifo.metrics.preemptions(), 0, "fifo must stay class-blind");
    assert!(
        slo.metrics.preemptions() >= 1,
        "class-aware policy never preempted a batch decode slot"
    );
    let ttft = |o: &FleetOutcome| o.per_request.iter().find(|r| r.id == 2).unwrap().ttft;
    assert!(
        ttft(&slo) < ttft(&fifo),
        "preemption did not cut interactive TTFT: {} vs fifo {}",
        ttft(&slo),
        ttft(&fifo)
    );
    // work conservation: preempted sessions resume with their emitted
    // tokens intact, so batch token totals match the class-blind run
    assert_eq!(
        slo.metrics.per_class[&TenantClass::Batch].tokens_total,
        fifo.metrics.per_class[&TenantClass::Batch].tokens_total,
        "preemption lost emitted batch tokens"
    );
}

/// With preemption firing on both replicas (round-robin lands one batch
/// and one interactive request on each), the cluster loops must stay
/// bit-identical: event-driven == min-clock == `--parallel 2`, digest
/// and per-request fields alike.
#[test]
fn preemptive_cluster_loops_stay_bit_identical() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let batch_new = (m.max_cache - m.max_seq).clamp(1, 6);
    let int_new = (m.max_cache - m.max_seq).clamp(1, 2);
    let mk = || {
        vec![
            batch_req(0, 0.0, vec![1, 7], batch_new),
            batch_req(1, 0.0, vec![1, 9], batch_new),
            TimedRequest::new(2, 0.05, Request { prompt: vec![1, 11], max_new: int_new }),
            TimedRequest::new(3, 0.06, Request { prompt: vec![1, 13], max_new: int_new }),
        ]
    };
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 1, 1);
    let mut serial_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let serial = run_cluster(&mut serial_engines, mk(), &c).unwrap();
    assert_eq!(serial.fleet.metrics.completed, 4);
    assert!(
        serial.fleet.metrics.preemptions() >= 1,
        "pin is vacuous: nothing was preempted"
    );

    let mut minclock_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let minclock = run_cluster_minclock(&mut minclock_engines, mk(), &c).unwrap();
    assert_eq!(
        minclock.digest(),
        serial.digest(),
        "min-clock loop diverged under preemption"
    );

    let mut par_cfg = c.clone();
    par_cfg.serving.parallel = 2;
    let mut par_engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let parallel = run_cluster(&mut par_engines, mk(), &par_cfg).unwrap();
    assert_eq!(parallel.digest(), serial.digest(), "--parallel diverged under preemption");
    for (x, y) in parallel.fleet.per_request.iter().zip(&serial.fleet.per_request) {
        assert_eq!(
            (x.id, x.ttft, x.finished_at, x.preemptions),
            (y.id, y.ttft, y.finished_at, y.preemptions)
        );
    }
}

//! Integration: replica failure & drain with session re-dispatch.
//!
//! Four pillars:
//!
//! 1. **Churn-free neutrality** — the churn-capable event loop with no
//!    events is the plain cluster (the `--replicas 1` tick-for-tick
//!    equivalence to `run_fleet` is pinned in
//!    `integration_cluster.rs`, which runs with an empty churn
//!    schedule); here we additionally pin that a churn event scheduled
//!    *after* all work completes is outcome-neutral — identical
//!    per-request times and step counts to the no-churn run.
//! 2. **Conservation under churn** — with a mid-trace failure, every
//!    trace id still completes exactly once across the cluster, for
//!    every dispatch x scheduling x prefill-mode combination, and the
//!    dispatch counts balance (`sum(dispatched) == requests +
//!    requeued`).
//! 3. **Semantics** — drain stops dispatches and runs down admitted
//!    work; fail evacuates queued *and* in-flight sessions, restarts
//!    them on survivors with their original arrival times (the SLO
//!    cost is visible in TTFT), and counts the discarded tokens; a
//!    schedule that churns every replica while work is outstanding is
//!    an error, not silent loss.
//! 4. **Budget-fallback regression** — with `chunk_tokens = max_seq`
//!    the per-tick decode budget legitimately reaches zero while a
//!    full-bucket prompt holds the chunk grant; the replica's
//!    work-conserving fallback (exercised via a deliberately idle
//!    custom policy) must clamp its decode pick to that budget instead
//!    of tripping the budget ensure and aborting the run.
//! 5. **Scheduler equivalence under churn** — the event-driven
//!    `run_cluster` and its `--parallel` worker path are pinned
//!    bit-identical to the retired min-clock loop on churn schedules
//!    (the churn-free halves of both pins live in
//!    `integration_cluster.rs`).
//! 6. **Capacity accounting** — a failed replica stops accruing
//!    capacity at its failure instant: cluster utilization and the
//!    load-imbalance statistic exclude the dead time instead of
//!    charging full-makespan capacity to a corpse.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`), matching the other
//! integration suites.

use std::sync::Arc;

use dymoe::baselines::{LoadOnDemand, Uniform};
use dymoe::config::{
    ChurnEvent, ChurnKind, HostPoolConfig, PoolPolicyKind, ServingConfig, SystemConfig, GB,
};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess, TimedRequest};
use dymoe::serving::policy::{
    Action, DispatchKind, PolicyKind, SchedPolicy, SchedView, TickPlan,
};
use dymoe::serving::{
    run_cluster, run_cluster_minclock, run_fleet, ClusterOutcome, FleetConfig, Replica,
    ReplicaState,
};
use dymoe::workload::{Request, TraceGen};

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

fn bf16_engine(a: &Arc<ModelAssets>) -> Engine {
    Engine::with_options(
        a,
        big_vram_sys(),
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions::default(),
    )
    .unwrap()
}

fn cfg(
    policy: PolicyKind,
    dispatch: DispatchKind,
    max_sessions: usize,
    batch: usize,
    chunk: usize,
    churn: Vec<ChurnEvent>,
) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: batch,
            chunk_tokens: chunk,
            churn,
            ..Default::default()
        },
        policy,
        dispatch,
    }
}

fn tiny_trace(a: &Arc<ModelAssets>, n: usize, rate: f64) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let mut content = TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
}

fn fail(at: f64, replica: usize) -> ChurnEvent {
    ChurnEvent { at, replica, kind: ChurnKind::Fail }
}

fn drain(at: f64, replica: usize) -> ChurnEvent {
    ChurnEvent { at, replica, kind: ChurnKind::Drain }
}

fn run(
    a: &Arc<ModelAssets>,
    replicas: usize,
    trace: Vec<TimedRequest>,
    c: &FleetConfig,
) -> ClusterOutcome {
    let mut engines: Vec<Engine> = (0..replicas).map(|_| bf16_engine(a)).collect();
    run_cluster(&mut engines, trace, c).unwrap()
}

// ---------------------------------------------------------------------
// Churn-free neutrality (artifacts-gated)
// ---------------------------------------------------------------------

/// A churn event scheduled far beyond the run's makespan fires only
/// after every request completed: the serving outcome must be
/// *identical* to the no-churn run (same per-request times, same step
/// counts), with only the lifecycle state and churn counters differing.
/// Together with `integration_cluster.rs` (which pins the empty-churn
/// loop against `run_fleet` tick for tick), this pins that the churn
/// machinery never perturbs the serving path until an event actually
/// bites.
#[test]
fn late_churn_event_is_outcome_neutral() {
    let Some(a) = assets() else { return };
    let base = cfg(PolicyKind::SloAware, DispatchKind::JoinShortestQueue, 2, 2, 0, vec![]);
    let plain = run(&a, 2, tiny_trace(&a, 8, 20.0), &base);

    for event in [fail(1e9, 0), drain(1e9, 1)] {
        let churned = cfg(
            PolicyKind::SloAware,
            DispatchKind::JoinShortestQueue,
            2,
            2,
            0,
            vec![event],
        );
        let c = run(&a, 2, tiny_trace(&a, 8, 20.0), &churned);
        assert_eq!(c.fleet.steps, plain.fleet.steps, "{:?}", event.kind);
        assert_eq!(c.fleet.per_request.len(), plain.fleet.per_request.len());
        for (x, y) in c.fleet.per_request.iter().zip(&plain.fleet.per_request) {
            assert_eq!(x.id, y.id, "late event reordered completions");
            assert_eq!(x.ttft, y.ttft, "late event changed TTFT (id {})", x.id);
            assert_eq!(x.finished_at, y.finished_at, "late event changed timing");
            assert_eq!(x.retries, 0, "late event requeued a completed request");
        }
        assert_eq!(c.churn.requeued, 0);
        assert_eq!(c.churn.lost_work_tokens, 0);
        match event.kind {
            ChurnKind::Fail => {
                assert_eq!(c.churn.failed, 1);
                assert_eq!(c.replicas[0].state, ReplicaState::Dead);
            }
            ChurnKind::Drain => {
                assert_eq!(c.churn.drained, 1);
                assert_eq!(c.replicas[1].state, ReplicaState::Draining);
            }
        }
    }
    // the no-churn run itself reports quiet churn telemetry
    assert!(!plain.churn.any());
    assert!(plain.replicas.iter().all(|b| b.state == ReplicaState::Live));
}

// ---------------------------------------------------------------------
// Conservation under mid-trace failure (artifacts-gated)
// ---------------------------------------------------------------------

/// A mid-trace failure of replica 0 must conserve requests under every
/// dispatch x scheduling x prefill-mode combination: every trace id
/// completes exactly once cluster-wide, the dispatch counts balance
/// (`sum == requests + requeued`), the per-request retry attribution
/// sums to the requeue count, and the failed replica ends Dead.
#[test]
fn failure_conserves_requests_under_every_policy_combo() {
    let Some(a) = assets() else { return };
    let n = 9;
    // Learn a mid-run instant from a churn-free baseline, then fail
    // replica 0 there in every combination.
    let baseline = run(
        &a,
        2,
        tiny_trace(&a, n, 10.0),
        &cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 0, vec![]),
    );
    let fail_at = baseline.fleet.metrics.makespan() * 0.3;
    assert!(fail_at > 0.0);

    for dispatch in DispatchKind::ALL {
        for policy in [PolicyKind::SloAware, PolicyKind::Fifo] {
            for chunk in [0usize, 3] {
                let c = cfg(policy, dispatch, 2, 2, chunk, vec![fail(fail_at, 0)]);
                let cluster = run(&a, 2, tiny_trace(&a, n, 10.0), &c);
                let label = format!(
                    "{} x {} x chunk {chunk}, fail {fail_at:.3}@0",
                    dispatch.name(),
                    policy.name()
                );

                // conservation: every id exactly once, cluster-wide
                let mut ids: Vec<usize> =
                    cluster.fleet.per_request.iter().map(|r| r.id).collect();
                ids.sort_unstable();
                assert_eq!(ids, (0..n).collect::<Vec<_>>(), "{label}: ids lost/duped");
                assert_eq!(cluster.fleet.metrics.completed, n, "{label}");

                // dispatch balance: originals + re-dispatches
                let total: usize = cluster.replicas.iter().map(|b| b.dispatched).sum();
                assert_eq!(total, n + cluster.churn.requeued, "{label}: dispatch imbalance");

                // retry attribution sums to the requeue count
                let retry_sum: usize =
                    cluster.fleet.per_request.iter().map(|r| r.retries).sum();
                assert_eq!(retry_sum, cluster.churn.requeued, "{label}: retry attribution");
                if cluster.churn.requeued > 0 {
                    assert!(cluster.churn.max_retries >= 1, "{label}");
                }

                assert_eq!(cluster.churn.failed, 1, "{label}");
                assert_eq!(cluster.replicas[0].state, ReplicaState::Dead, "{label}");
                assert_eq!(cluster.replicas[1].state, ReplicaState::Live, "{label}");
                // the survivor completed everything it was handed
                assert_eq!(
                    cluster.replicas[1].outcome.metrics.completed,
                    cluster.replicas[1].dispatched,
                    "{label}: survivor starved a request"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Drain and fail semantics (artifacts-gated)
// ---------------------------------------------------------------------

/// Drain at t=0 means the replica never receives a dispatch; a mid-run
/// drain means it completes exactly what it was handed before the
/// cordon and nothing after.  Either way every request completes and
/// nothing is requeued or lost.
#[test]
fn drain_stops_dispatches_and_runs_down_admitted_work() {
    let Some(a) = assets() else { return };
    let n = 6;

    // drain replica 1 before any arrival: everything serves on replica 0
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 3, 2, 0, vec![drain(0.0, 1)]);
    let cluster = run(&a, 2, tiny_trace(&a, n, 20.0), &c);
    assert_eq!(cluster.fleet.metrics.completed, n);
    assert_eq!(cluster.replicas[1].dispatched, 0, "drained replica was dispatched to");
    assert_eq!(cluster.replicas[0].dispatched, n);
    assert_eq!(cluster.replicas[1].state, ReplicaState::Draining);
    assert_eq!(cluster.churn.drained, 1);
    assert_eq!(cluster.churn.requeued, 0);
    assert_eq!(cluster.churn.lost_work_tokens, 0);

    // mid-trace drain (timed at the median arrival, so dispatches
    // genuinely remain): replica 1 keeps (and finishes) what it already
    // holds, receives nothing new
    let drain_at = {
        let mut arr: Vec<f64> = tiny_trace(&a, n, 20.0).iter().map(|r| r.arrival).collect();
        arr.sort_by(|a, b| a.total_cmp(b));
        arr[n / 2]
    };
    let c = cfg(
        PolicyKind::SloAware,
        DispatchKind::RoundRobin,
        3,
        2,
        0,
        vec![drain(drain_at, 1)],
    );
    let cluster = run(&a, 2, tiny_trace(&a, n, 20.0), &c);
    assert_eq!(cluster.fleet.metrics.completed, n);
    assert_eq!(
        cluster.replicas[1].outcome.metrics.completed, cluster.replicas[1].dispatched,
        "drained replica must run down everything dispatched to it"
    );
    // rr would have split n evenly; the cordon keeps the post-drain
    // arrivals (at least half the trace) off replica 1
    assert!(
        cluster.replicas[1].dispatched < n / 2,
        "mid-trace drain shifted no load off the drained replica: {} of {n}",
        cluster.replicas[1].dispatched
    );
    assert_eq!(cluster.churn.requeued, 0, "drain must not requeue");
}

/// Fail at t=0: the replica dies before any arrival, so everything
/// routes to the survivor with nothing requeued and no work lost —
/// under every dispatch policy (the dispatcher sees only live
/// replicas).
#[test]
fn failure_before_arrivals_diverts_everything_to_survivors() {
    let Some(a) = assets() else { return };
    let n = 6;
    for dispatch in DispatchKind::ALL {
        let c = cfg(PolicyKind::SloAware, dispatch, 3, 2, 0, vec![fail(0.0, 0)]);
        let cluster = run(&a, 2, tiny_trace(&a, n, 20.0), &c);
        let label = dispatch.name();
        assert_eq!(cluster.fleet.metrics.completed, n, "{label}");
        assert_eq!(cluster.replicas[0].dispatched, 0, "{label}: dead replica dispatched to");
        assert_eq!(cluster.replicas[1].dispatched, n, "{label}");
        assert_eq!(cluster.replicas[0].state, ReplicaState::Dead, "{label}");
        assert_eq!(cluster.churn.requeued, 0, "{label}");
        assert_eq!(cluster.churn.lost_work_tokens, 0, "{label}");
    }
}

/// A mid-run failure evacuates in-flight work: the restarted sessions
/// keep their **original** arrival times, so their measured TTFT spans
/// the failure (first token strictly after the event), and the tokens
/// the dead replica had already produced are counted as lost work.
#[test]
fn failure_restarts_keep_original_arrivals_and_count_lost_work() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let max_new = (m.max_cache - m.max_seq).clamp(2, 6);
    // four same-instant arrivals, rr dispatch: two per replica, so at
    // ~40% of the baseline makespan replica 0 is mid-service with more
    // queued behind
    let mk_trace = || -> Vec<TimedRequest> {
        (0..4)
            .map(|i| {
                TimedRequest::new(
                    i,
                    0.0,
                    Request { prompt: vec![1, 5 + (3 * i as i32) % 40, 7], max_new },
                )
            })
            .collect()
    };
    let base_cfg = cfg(PolicyKind::Fifo, DispatchKind::RoundRobin, 1, 1, 0, vec![]);
    let baseline = run(&a, 2, mk_trace(), &base_cfg);
    let fail_at = baseline.fleet.metrics.makespan() * 0.4;
    assert!(fail_at > 0.0);

    let c = cfg(PolicyKind::Fifo, DispatchKind::RoundRobin, 1, 1, 0, vec![fail(fail_at, 0)]);
    let cluster = run(&a, 2, mk_trace(), &c);
    assert_eq!(cluster.fleet.metrics.completed, 4);
    assert!(
        cluster.churn.requeued >= 1,
        "replica 0 held work at {fail_at}, nothing was evacuated"
    );
    // fifo with max_sessions 1 means the in-flight session had emitted
    // tokens (or at least prefilled) by 40% of the makespan
    assert!(
        cluster.churn.lost_work_tokens > 0,
        "mid-service failure discarded no work"
    );
    for r in &cluster.fleet.per_request {
        if r.retries > 0 {
            // restarted from scratch after the failure with the
            // original arrival (0.0): the first token lands after the
            // event, so the measured TTFT honestly spans the churn
            assert!(
                r.arrival + r.ttft > fail_at,
                "requeued request {} reports TTFT {} from before the failure at {fail_at}",
                r.id,
                r.ttft
            );
            assert!(r.finished_at > fail_at);
        }
    }
    // the dead replica completed nothing it still held; the survivor
    // absorbed the evacuees
    assert_eq!(
        cluster.replicas[1].outcome.metrics.completed,
        cluster.replicas[1].dispatched
    );
}

/// Churning every replica while requests are outstanding cannot be
/// served: the run must fail loudly (conservation by error, never by
/// silent loss) — for all-fail, all-drain (queued arrivals have no
/// target), and fail-after-drain schedules.
#[test]
fn churning_every_replica_with_work_outstanding_is_an_error() {
    let Some(a) = assets() else { return };
    for events in [
        vec![fail(0.0, 0), fail(0.0, 1)],
        vec![drain(0.0, 0), drain(0.0, 1)],
        vec![drain(0.0, 0), fail(0.0, 1)],
    ] {
        let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 0, events.clone());
        let mut engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
        let result = run_cluster(&mut engines, tiny_trace(&a, 4, 20.0), &c);
        assert!(result.is_err(), "whole-cluster churn {events:?} served silently");
    }
    // out-of-range targets are rejected up front
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 0, vec![fail(1.0, 7)]);
    let mut engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    assert!(run_cluster(&mut engines, tiny_trace(&a, 4, 20.0), &c).is_err());
    // the dispatcher-less single-replica entry point rejects churn
    // loudly instead of silently serving the schedule churn-free
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 0, vec![fail(1.0, 0)]);
    let mut engine = bf16_engine(&a);
    assert!(run_fleet(&mut engine, tiny_trace(&a, 4, 20.0), &c).is_err());
}

/// Chunked prefill keeps conserving under failure: the same mid-trace
/// failure with `chunk_tokens > 0` evacuates sessions that are
/// *mid-prefill* (cursor > 0, nothing emitted) and restarts them
/// cleanly.
#[test]
fn failure_mid_chunked_prefill_restarts_cleanly() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let long = m.max_seq;
    let max_new = (m.max_cache - m.max_seq).clamp(1, 2);
    // one long prompt per replica, chunked finely so prefill spans many
    // ticks; fail replica 0 early in its prefill
    let mk_trace = || -> Vec<TimedRequest> {
        (0..2)
            .map(|i| {
                TimedRequest::new(
                    i,
                    0.0,
                    Request {
                        prompt: (0..long).map(|t| 1 + ((t + i) as i32 * 7) % 60).collect(),
                        max_new,
                    },
                )
            })
            .collect()
    };
    let base_cfg = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 1, vec![]);
    let baseline = run(&a, 2, mk_trace(), &base_cfg);
    let fail_at = baseline.fleet.metrics.makespan() * 0.2;
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 1, vec![fail(fail_at, 0)]);
    let cluster = run(&a, 2, mk_trace(), &c);
    assert_eq!(cluster.fleet.metrics.completed, 2);
    assert!(cluster.churn.requeued >= 1, "mid-prefill session not evacuated");
    assert!(
        cluster.churn.lost_work_tokens > 0,
        "chunk-prefilled tokens not counted as lost"
    );
}

// ---------------------------------------------------------------------
// Event-driven scheduler equivalence under churn (artifacts-gated)
// ---------------------------------------------------------------------

/// The next-event scheduler must reproduce the retired min-clock loop
/// bit for bit on churn schedules too: mid-run fail, mid-run drain, a
/// fail timed before any arrival, and a combined drain + later fail —
/// each on both prefill modes.  Evacuation re-dispatch, service gating
/// at the failure time, retry attribution, and the churn counters all
/// ride on event order, so digest equality here pins the whole churn
/// path, not just the happy path.
#[test]
fn event_scheduler_matches_minclock_loop_under_churn() {
    let Some(a) = assets() else { return };
    let n = 9;
    let baseline = run(
        &a,
        3,
        tiny_trace(&a, n, 10.0),
        &cfg(PolicyKind::SloAware, DispatchKind::JoinShortestQueue, 2, 2, 0, vec![]),
    );
    let mid = baseline.fleet.metrics.makespan() * 0.3;
    assert!(mid > 0.0);
    let schedules: Vec<Vec<ChurnEvent>> = vec![
        vec![fail(mid, 0)],
        vec![drain(mid, 1)],
        vec![fail(0.0, 0)],
        vec![drain(mid, 1), fail(mid * 1.5, 0)],
    ];
    for schedule in &schedules {
        for chunk in [0usize, 3] {
            let c = cfg(
                PolicyKind::SloAware,
                DispatchKind::JoinShortestQueue,
                2,
                2,
                chunk,
                schedule.clone(),
            );
            let mut ref_engines: Vec<Engine> = (0..3).map(|_| bf16_engine(&a)).collect();
            let reference =
                run_cluster_minclock(&mut ref_engines, tiny_trace(&a, n, 10.0), &c).unwrap();
            let mut engines: Vec<Engine> = (0..3).map(|_| bf16_engine(&a)).collect();
            let event = run_cluster(&mut engines, tiny_trace(&a, n, 10.0), &c).unwrap();
            let label = format!("{schedule:?} chunk {chunk}");

            assert_eq!(event.churn.requeued, reference.churn.requeued, "{label}");
            assert_eq!(
                event.churn.lost_work_tokens, reference.churn.lost_work_tokens,
                "{label}"
            );
            assert_eq!(event.fleet.steps, reference.fleet.steps, "{label}");
            for (x, y) in event.fleet.per_request.iter().zip(&reference.fleet.per_request) {
                assert_eq!(x.id, y.id, "{label}: completion order diverged");
                assert_eq!(x.ttft, y.ttft, "{label}: TTFT diverged (id {})", x.id);
                assert_eq!(x.finished_at, y.finished_at, "{label} (id {})", x.id);
                assert_eq!(x.retries, y.retries, "{label}: retry attribution (id {})", x.id);
            }
            assert_eq!(event.load_imbalance, reference.load_imbalance, "{label}");
            assert_eq!(
                event.fleet.utilization.gpu, reference.fleet.utilization.gpu,
                "{label}"
            );
            assert_eq!(event.digest(), reference.digest(), "{label}: outcome digest diverged");
        }
    }
}

/// `--parallel 4` under a mid-run failure: evacuation, re-dispatch, and
/// the advance phases around the churn boundary must all come out bit
/// -identical to the serial event-driven run.
#[test]
fn parallel_cluster_matches_serial_under_churn() {
    let Some(a) = assets() else { return };
    let n = 9;
    let baseline = run(
        &a,
        3,
        tiny_trace(&a, n, 10.0),
        &cfg(PolicyKind::SloAware, DispatchKind::JoinShortestQueue, 2, 2, 0, vec![]),
    );
    let mid = baseline.fleet.metrics.makespan() * 0.3;
    for chunk in [0usize, 3] {
        let base = cfg(
            PolicyKind::SloAware,
            DispatchKind::JoinShortestQueue,
            2,
            2,
            chunk,
            vec![fail(mid, 0)],
        );
        let mut serial_engines: Vec<Engine> = (0..3).map(|_| bf16_engine(&a)).collect();
        let serial = run_cluster(&mut serial_engines, tiny_trace(&a, n, 10.0), &base).unwrap();

        let mut par_cfg = base.clone();
        par_cfg.serving.parallel = 4;
        let mut par_engines: Vec<Engine> = (0..3).map(|_| bf16_engine(&a)).collect();
        let parallel =
            run_cluster(&mut par_engines, tiny_trace(&a, n, 10.0), &par_cfg).unwrap();

        assert_eq!(
            parallel.digest(),
            serial.digest(),
            "chunk {chunk}: parallel diverged under churn"
        );
        assert_eq!(parallel.churn.requeued, serial.churn.requeued, "chunk {chunk}");
        assert_eq!(parallel.fleet.steps, serial.fleet.steps, "chunk {chunk}");
    }
}

// ---------------------------------------------------------------------
// Affinity dispatch stability under failure (artifacts-gated)
// ---------------------------------------------------------------------

/// Regression, end to end: affinity dispatch used to route
/// `hash % live_replicas`, so one failure re-homed nearly *every*
/// prompt and flushed every survivor's warm expert cache.  With
/// rendezvous hashing over stable replica ids, a mid-run failure may
/// move only the dead replica's sessions: every request whose
/// churn-free home was a survivor must complete on that same replica,
/// untouched (zero retries), while at least one of the dead replica's
/// sessions demonstrably re-homes.  (The engine-free membership sweep
/// lives in `policy.rs`; this pins the property through dispatch,
/// evacuation, and re-dispatch in a real cluster run.)
#[test]
fn affinity_failure_remaps_only_the_dead_replicas_sessions() {
    let Some(a) = assets() else { return };
    let n = 24;
    let base_cfg = cfg(PolicyKind::SloAware, DispatchKind::ExpertAffinity, 2, 2, 0, vec![]);
    let baseline = run(&a, 3, tiny_trace(&a, n, 10.0), &base_cfg);
    assert_eq!(baseline.fleet.metrics.completed, n);
    let mut home = vec![usize::MAX; n];
    for (i, b) in baseline.replicas.iter().enumerate() {
        for r in &b.outcome.per_request {
            home[r.id] = i;
        }
    }
    // non-vacuous: the hash spread the trace over all three replicas
    for t in 0..3usize {
        assert!(
            home.iter().any(|&h| h == t),
            "affinity never homed a prompt on replica {t}; widen the trace"
        );
    }
    let fail_at = baseline.fleet.metrics.makespan() * 0.4;
    assert!(fail_at > 0.0);

    let c = cfg(
        PolicyKind::SloAware,
        DispatchKind::ExpertAffinity,
        2,
        2,
        0,
        vec![fail(fail_at, 0)],
    );
    let churned = run(&a, 3, tiny_trace(&a, n, 10.0), &c);
    assert_eq!(churned.fleet.metrics.completed, n);
    let mut moved_off_dead = 0usize;
    for (i, b) in churned.replicas.iter().enumerate() {
        for r in &b.outcome.per_request {
            if home[r.id] == 0 {
                // the dead replica's sessions either finished before the
                // failure (still on 0) or re-homed to a survivor
                if i != 0 {
                    moved_off_dead += 1;
                }
            } else {
                assert_eq!(
                    i, home[r.id],
                    "request {} was homed on surviving replica {} but completed on {i}: \
                     the failure remapped a survivor's session",
                    r.id, home[r.id]
                );
                assert_eq!(
                    r.retries, 0,
                    "request {} on surviving replica {i} was needlessly requeued",
                    r.id
                );
            }
        }
    }
    assert!(
        moved_off_dead > 0,
        "no session ever moved off the failed replica; the regression pin is vacuous"
    );
}

// ---------------------------------------------------------------------
// Shared host pool under churn (artifacts-gated)
// ---------------------------------------------------------------------

/// A mid-run failure with `--host-pool` attached: the evacuated
/// replica's journal flushes before its lane is returned to the link
/// budget, the survivor keeps resolving through the shared tier, and
/// the whole run — per-request bits *and* pool counters — is
/// deterministic across repeats.
#[test]
fn host_pool_survives_replica_failure_and_stays_deterministic() {
    let Some(a) = assets() else { return };
    let n = 8;
    let m = a.manifest.model.clone();
    let prompt: Vec<i32> = (0..m.max_seq.min(8)).map(|i| 1 + i as i32).collect();
    let max_new = (m.max_cache - m.max_seq).clamp(1, 2);
    let mk_trace = || -> Vec<TimedRequest> {
        (0..n)
            .map(|id| {
                TimedRequest::new(id, id as f64 * 0.2, Request { prompt: prompt.clone(), max_new })
            })
            .collect()
    };
    let pooled = || {
        let mut c = cfg(PolicyKind::Fifo, DispatchKind::RoundRobin, 1, 1, 0, vec![fail(0.5, 0)]);
        c.serving.host_pool = Some(HostPoolConfig {
            capacity_bytes: GB,
            policy: PoolPolicyKind::Shared,
        });
        let mut engines: Vec<Engine> = (0..2)
            .map(|_| {
                let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
                sys.policy.ssd_resident = true;
                Engine::with_options(
                    &a,
                    sys,
                    Box::new(LoadOnDemand::new(Precision::Int4)),
                    EngineOptions::default(),
                )
                .unwrap()
            })
            .collect();
        let out = run_cluster(&mut engines, mk_trace(), &c).unwrap();
        assert!(
            engines.iter().all(|e| e.host_pool.is_none()),
            "run left a pool handle attached to an engine"
        );
        out
    };
    let x = pooled();
    let y = pooled();
    assert_eq!(x.digest(), y.digest(), "pooled churn run is not deterministic");
    assert_eq!(x.pool, y.pool, "pool counters diverged across identical runs");
    assert_eq!(x.fleet.metrics.completed, n);
    assert_eq!(x.churn.failed, 1);
    assert_eq!(x.replicas[0].state, ReplicaState::Dead);
    assert!(x.pool.ssd_fills > 0, "pool never exercised");
    let mut ids: Vec<usize> = x.fleet.per_request.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "churn + pool lost requests");
}

// ---------------------------------------------------------------------
// Zero-completion runs stay finite (artifacts-gated)
// ---------------------------------------------------------------------

/// Regression: an empty trace used to poison the outcome with
/// non-finite floats — `Series::min()` on zero samples returned `+inf`
/// (which JSON cannot represent), and downstream ratios divided by a
/// zero makespan.  Every statistic of a zero-completion run must come
/// out finite (the empty-series sentinel is 0.0), the balance statistic
/// reads perfectly balanced, and the outcome still digests.
#[test]
fn zero_completion_run_reports_finite_stats() {
    let Some(a) = assets() else { return };
    let c = cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 2, 2, 0, vec![drain(0.0, 1)]);
    let mut engines: Vec<Engine> = (0..2).map(|_| bf16_engine(&a)).collect();
    let out = run_cluster(&mut engines, Vec::new(), &c).unwrap();
    assert_eq!(out.fleet.metrics.completed, 0);
    let m = &out.fleet.metrics;
    for (name, v) in [
        ("ttft.min", m.ttft.min()),
        ("ttft.max", m.ttft.max()),
        ("ttft.mean", m.ttft.mean()),
        ("ttft.p99", m.ttft.percentile(99.0)),
        ("tpot.mean", m.tpot.mean()),
        ("queue_delay.min", m.queue_delay.min()),
        ("goodput", m.goodput_rps()),
        ("throughput", m.throughput_tps()),
        ("slo_attainment", m.slo_attainment()),
        ("makespan", m.makespan()),
        ("imbalance", out.load_imbalance),
    ] {
        assert!(v.is_finite(), "{name} is not finite on an empty run: {v}");
    }
    assert_eq!(m.ttft.min(), 0.0, "empty-series min sentinel");
    assert_eq!(out.load_imbalance, 1.0, "an all-idle cluster is balanced");
    assert!(
        out.fleet.utilization.gpu == 0.0 && out.fleet.utilization.pcie == 0.0,
        "zero-span utilization must be the zero default"
    );
    // the digest is well-defined (no NaN bit patterns fed to the hash)
    let _ = out.digest();
    assert_eq!(out.churn.drained, 1);
}

// ---------------------------------------------------------------------
// Capacity accounting for failed replicas (artifacts-gated)
// ---------------------------------------------------------------------

/// Regression: cluster utilization used to divide busy time by
/// `replicas x makespan`, charging a replica that died at t = 0 a full
/// makespan of phantom capacity (halving every busy fraction on a
/// 2-replica cluster), and the load-imbalance statistic averaged the
/// corpse's zero load (reading 2.0 for a perfectly-served trace).  A
/// fail-before-arrivals 2-replica run must report *exactly* the
/// utilization of the equivalent single-replica run, and an imbalance
/// of 1.0.
#[test]
fn dead_replica_stops_accruing_capacity_and_weight() {
    let Some(a) = assets() else { return };
    let n = 6;
    let pair = run(
        &a,
        2,
        tiny_trace(&a, n, 20.0),
        &cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 3, 2, 0, vec![fail(0.0, 0)]),
    );
    let solo = run(
        &a,
        1,
        tiny_trace(&a, n, 20.0),
        &cfg(PolicyKind::SloAware, DispatchKind::RoundRobin, 3, 2, 0, vec![]),
    );
    assert_eq!(pair.fleet.metrics.completed, n);
    assert_eq!(pair.replicas[0].dispatched, 0);
    // The survivor served the whole trace exactly as the single-replica
    // cluster did, and the dead replica contributes zero live capacity,
    // so the busy fractions must agree bit for bit (before the fix the
    // pair read exactly half).
    assert!(solo.fleet.utilization.gpu > 0.0);
    assert_eq!(pair.fleet.utilization.gpu, solo.fleet.utilization.gpu);
    assert_eq!(pair.fleet.utilization.cpu, solo.fleet.utilization.cpu);
    assert_eq!(pair.fleet.utilization.pcie, solo.fleet.utilization.pcie);
    assert_eq!(pair.fleet.utilization.nvme, solo.fleet.utilization.nvme);
    // Live-time-weighted balance: one live replica serving everything is
    // perfectly balanced (the unweighted max/mean over [0, all] read 2.0).
    assert_eq!(pair.load_imbalance, 1.0);
    // The per-replica breakdown still shows the corpse's zero load, so
    // nothing is hidden — only the cluster statistics stop charging it.
    assert_eq!(pair.replicas[0].outcome.metrics.tokens_total, 0);
}

// ---------------------------------------------------------------------
// Zero-decode-budget fallback regression (artifacts-gated)
// ---------------------------------------------------------------------

/// A policy that always returns an empty plan (the "policy bug" the
/// work-conserving fallback exists for).
struct EmptyPlanPolicy;

impl SchedPolicy for EmptyPlanPolicy {
    fn name(&self) -> &'static str {
        "empty"
    }

    fn next_action(&mut self, _view: &SchedView) -> Action {
        Action::Idle
    }

    fn mixed_tick(&mut self, _view: &SchedView, _max_decode: usize) -> TickPlan {
        TickPlan { prefill: None, decode: Vec::new() }
    }
}

/// Regression: with `chunk_tokens = max_seq` a full-bucket prompt's
/// chunk grant drives the per-tick decode budget to zero; the
/// work-conserving fallback must clamp its decode pick to that budget
/// (prefill-only tick) instead of planning one decode session and
/// tripping the `decode batch ... exceeds the per-tick budget` ensure,
/// which aborted a legitimate run.
#[test]
fn chunk_budget_zero_fallback_is_clamped_to_prefill_only() {
    let Some(a) = assets() else { return };
    let m = a.manifest.model.clone();
    let c = cfg(
        PolicyKind::SloAware, // ignored: the policy is injected below
        DispatchKind::RoundRobin,
        4,
        4,
        m.max_seq, // chunk budget == the whole expert token bucket
        vec![],
    );
    let mut engine = bf16_engine(&a);
    let mut replica = Replica::with_policy(&mut engine, &c, Box::new(EmptyPlanPolicy));
    let short_new = (m.max_cache.saturating_sub(2)).clamp(1, 3);
    let long_new = (m.max_cache - m.max_seq).clamp(1, 2);
    // a short prompt that becomes decode-ready after one chunk ...
    replica.enqueue(TimedRequest::new(
        0,
        0.0,
        Request { prompt: vec![1, 5], max_new: short_new },
    ));
    // ... alongside a full-bucket prompt whose chunk grant leaves a
    // zero decode budget while it prefills
    replica.enqueue(TimedRequest::new(
        1,
        0.0,
        Request {
            prompt: (0..m.max_seq).map(|t| 1 + (t as i32 * 7) % 60).collect(),
            max_new: long_new,
        },
    ));
    let mut guard = 0;
    while replica.has_work() {
        replica
            .tick()
            .expect("fallback must clamp decode to the zero budget, not abort the run");
        guard += 1;
        assert!(guard < 500, "chunked fallback loop did not converge");
    }
    let done = replica.finish();
    assert_eq!(done.outcome.metrics.completed, 2);
    assert_eq!(done.state, ReplicaState::Live);
}

//! Integration: cluster-scale Chrome-trace export.
//!
//! Three pillars:
//!
//! 1. **Structural validity** — `chrome_trace` over a real churny
//!    chunked cluster run emits JSON that parses, roundtrips, and
//!    passes the structural linter: one process per replica, monotone
//!    non-negative per-track timestamps, balanced session begin/end
//!    pairs, churn markers as instants, four counter samples per tick.
//! 2. **Conservation** — the trace is the *same data* the telemetry
//!    reports: summed slice durations per channel equal the replica's
//!    `BusyTotals` delta, GPU slices nest inside the run's completion
//!    span, and tick spans / counter samples count the scheduler steps
//!    exactly.
//! 3. **Run-boundary hygiene** — reusing one engine across cluster
//!    runs captures each run's event *suffix* only (the
//!    `events_before` snapshot-delta discipline), so a later trace
//!    never replays an earlier run's work.
//! 4. **Parallel neutrality** — `--parallel` worker execution leaves
//!    every per-replica trace stream (and the emitted Chrome-trace
//!    document) byte-identical to the serial run.
//!
//! Engine-level tests need the real `tiny` artifacts and skip politely
//! when they are missing (run `make artifacts`).  The hand-built
//! writer/linter test at the bottom is engine-free and runs everywhere
//! — it is what the CI smoke step relies on when artifacts are absent.

use std::sync::Arc;

use dymoe::baselines::Uniform;
use dymoe::config::{ChurnEvent, ChurnKind, ServingConfig, SystemConfig, GB};
use dymoe::coordinator::engine::{Engine, EngineOptions};
use dymoe::memory::{BusyTotals, EventKind, Timeline, TracePhase};
use dymoe::model::assets::ModelAssets;
use dymoe::quant::Precision;
use dymoe::serving::arrival::{ArrivalGen, ArrivalProcess, TenantClass, TimedRequest};
use dymoe::serving::metrics::{ChurnStats, CompletedRequest};
use dymoe::serving::policy::{DispatchKind, PolicyKind};
use dymoe::serving::{
    run_cluster, ClusterOutcome, FleetConfig, FleetOutcome, ReplicaBreakdown, ReplicaState,
};
use dymoe::trace::chrome::{chrome_trace, lint};
use dymoe::trace::{TickSample, TraceCapture};
use dymoe::util::json::Json;
use dymoe::workload::TraceGen;

fn assets() -> Option<Arc<ModelAssets>> {
    match ModelAssets::load("artifacts", "tiny") {
        Ok(a) => Some(Arc::new(a)),
        Err(_) => {
            eprintln!("artifacts/tiny missing; run `make artifacts`");
            None
        }
    }
}

fn big_vram_sys() -> SystemConfig {
    let mut sys = SystemConfig::edge_preset("tiny", 24).unwrap();
    sys.hardware.vram_bytes = 1024 * GB;
    sys
}

/// A recording engine (the `--trace-out` configuration).
fn recording_engine(a: &Arc<ModelAssets>, sys: SystemConfig) -> Engine {
    Engine::with_options(
        a,
        sys,
        Box::new(Uniform::new(Precision::Bf16)),
        EngineOptions { record_timeline: true, ..Default::default() },
    )
    .unwrap()
}

fn cfg(chunk: usize, churn: Vec<ChurnEvent>) -> FleetConfig {
    FleetConfig {
        serving: ServingConfig {
            max_sessions: 3,
            ttft_slo_s: 1e6,
            tpot_slo_s: 1e6,
            max_decode_batch: 2,
            chunk_tokens: chunk,
            churn,
            ..Default::default()
        },
        policy: PolicyKind::SloAware,
        dispatch: DispatchKind::RoundRobin,
    }
}

fn tiny_trace(a: &Arc<ModelAssets>, n: usize, rate: f64) -> Vec<TimedRequest> {
    let m = &a.manifest.model;
    let mut content = TraceGen::new(7, m.max_seq.min(16), (m.max_cache - m.max_seq).min(6));
    ArrivalGen::generate(21, ArrivalProcess::Poisson { rate }, &mut content, n).unwrap()
}

fn cat_is(e: &Json, cat: &str) -> bool {
    matches!(e.opt("cat"), Some(Json::Str(c)) if c == cat)
}

// ---------------------------------------------------------------------
// Structural validity on a real churny chunked run (artifacts-gated)
// ---------------------------------------------------------------------

/// The full `--trace-out` pipeline on a chunked two-replica run with a
/// failure: the emitted document parses and roundtrips, lints clean,
/// maps each replica to its own process, records the churn marker as an
/// instant, balances every session's lifecycle events, and counts ticks
/// / counter samples exactly one per scheduler step.
#[test]
fn trace_export_parses_lints_and_maps_replicas() {
    let Some(a) = assets() else { return };
    let churn = vec![ChurnEvent { at: 0.001, replica: 1, kind: ChurnKind::Fail }];
    let c = cfg(3, churn);
    let mut engines: Vec<Engine> =
        (0..2).map(|_| recording_engine(&a, big_vram_sys())).collect();
    let cluster = run_cluster(&mut engines, tiny_trace(&a, 8, 50.0), &c).unwrap();

    let doc = chrome_trace(&cluster);
    let reparsed = Json::parse(&doc.to_string()).expect("trace JSON parses");
    assert_eq!(reparsed, doc, "writer output must roundtrip through the parser");

    let rep = lint(&reparsed).expect("trace lints clean");
    assert_eq!(rep.processes, 2, "one Perfetto process per replica");
    assert!(rep.slices > 0);
    assert!(rep.instants >= 1, "the churn failure must surface as an instant");
    assert_eq!(rep.session_events, 4 * cluster.fleet.per_request.len());
    let samples: usize = cluster.replicas.iter().map(|b| b.trace.samples.len()).sum();
    assert_eq!(rep.counters, 7 * samples, "seven counter tracks per tick sample");

    for (i, b) in cluster.replicas.iter().enumerate() {
        assert_eq!(
            b.trace.samples.len(),
            b.outcome.steps,
            "replica {i}: one counter sample per scheduler step"
        );
        let ticks: Vec<_> =
            b.trace.events.iter().filter(|e| e.kind == EventKind::Tick).collect();
        assert_eq!(ticks.len(), b.outcome.steps, "replica {i}: one tick span per step");
        for t in ticks {
            assert!(
                matches!(t.label.as_str(), "prefill-chunk" | "decode-batch" | "mixed-tick"),
                "replica {i}: tick span labelled {:?}",
                t.label
            );
            assert!(!t.meta.sessions.is_empty(), "replica {i}: tick without sessions");
        }
    }
    // The failed replica still owns its process: no work, but the
    // failure marker lives on *its* timeline.
    assert!(cluster.replicas[1]
        .trace
        .events
        .iter()
        .any(|e| e.kind == EventKind::Marker && e.label == "fail"));
}

// ---------------------------------------------------------------------
// Conservation against BusyTotals (artifacts-gated)
// ---------------------------------------------------------------------

/// The trace reports the same busy time the telemetry does: per
/// channel, summed slice durations equal the replica's `BusyTotals`
/// delta (demand + prefetch transfers together account for the one
/// physical PCIe channel), and every GPU slice ends inside the run's
/// completion span.  Tight VRAM forces real demand transfers so the
/// demand lane is exercised, not vacuously zero.
#[test]
fn trace_slices_conserve_busy_totals() {
    let Some(a) = assets() else { return };
    let mut sys = big_vram_sys();
    sys.hardware.vram_bytes = sys.paper.non_expert_bytes + GB;
    let mut engines = vec![Engine::with_options(
        &a,
        sys,
        Box::new(Uniform::new(Precision::Int4)),
        EngineOptions { record_timeline: true, ..Default::default() },
    )
    .unwrap()];
    let cluster =
        run_cluster(&mut engines, tiny_trace(&a, 6, 20.0), &cfg(0, Vec::new())).unwrap();
    let b = &cluster.replicas[0];

    let sum = |kind: EventKind| -> f64 {
        b.trace
            .events
            .iter()
            .filter(|e| e.kind == kind)
            .map(|e| e.end - e.start)
            .sum()
    };
    // `end - start` re-derives each duration in f64, so allow ulp-level
    // accumulation error relative to the channel's total.
    let close = |got: f64, want: f64| (got - want).abs() <= 1e-6 * want.max(1.0);

    assert!(b.busy.gpu > 0.0);
    let gpu = sum(EventKind::GpuCompute);
    assert!(close(gpu, b.busy.gpu), "gpu slices {gpu} != busy delta {}", b.busy.gpu);
    let demand = sum(EventKind::PcieTransfer);
    let prefetch = sum(EventKind::PciePrefetch);
    assert!(demand > 0.0, "tight VRAM must issue demand transfers");
    assert!(
        close(demand + prefetch, b.busy.pcie),
        "pcie slices {demand} + {prefetch} != busy delta {}",
        b.busy.pcie
    );
    let nvme = sum(EventKind::NvmeStage);
    assert!(close(nvme, b.busy.nvme), "nvme slices {nvme} != busy delta {}", b.busy.nvme);
    let cpu = sum(EventKind::CpuCompute);
    assert!(close(cpu, b.busy.cpu), "cpu slices {cpu} != busy delta {}", b.busy.cpu);

    let last_done = cluster
        .fleet
        .per_request
        .iter()
        .map(|r| r.finished_at)
        .fold(0.0_f64, f64::max);
    for e in b.trace.events.iter().filter(|e| e.kind == EventKind::GpuCompute) {
        assert!(
            e.end <= last_done + 1e-9,
            "gpu slice ending {} outruns the last completion {last_done}",
            e.end
        );
    }
}

// ---------------------------------------------------------------------
// Parallel execution leaves the trace streams untouched (artifacts-gated)
// ---------------------------------------------------------------------

/// `--parallel` is a pure wall-clock knob: a recording churny chunked
/// run on 4 worker threads must produce *identical* per-replica trace
/// streams (every event, every counter sample) and therefore a
/// byte-identical Chrome-trace document, not just matching metrics.
#[test]
fn parallel_run_produces_identical_trace_streams() {
    let Some(a) = assets() else { return };
    let churn = vec![ChurnEvent { at: 0.001, replica: 1, kind: ChurnKind::Fail }];
    let run_with = |parallel: usize| -> ClusterOutcome {
        let mut c = cfg(3, churn.clone());
        c.serving.parallel = parallel;
        let mut engines: Vec<Engine> =
            (0..3).map(|_| recording_engine(&a, big_vram_sys())).collect();
        run_cluster(&mut engines, tiny_trace(&a, 8, 50.0), &c).unwrap()
    };
    let serial = run_with(1);
    let parallel = run_with(4);
    for (i, (x, y)) in parallel.replicas.iter().zip(&serial.replicas).enumerate() {
        assert_eq!(
            x.trace, y.trace,
            "replica {i}: parallel execution perturbed the trace stream"
        );
    }
    assert_eq!(
        chrome_trace(&parallel).to_string(),
        chrome_trace(&serial).to_string(),
        "chrome-trace documents diverged"
    );
}

// ---------------------------------------------------------------------
// Run-boundary hygiene on engine reuse (artifacts-gated)
// ---------------------------------------------------------------------

/// The timeline event log is cumulative over an engine's lifetime (like
/// `BusyTotals`), so each run's capture must be exactly the suffix that
/// run appended: run 1 owns the whole log, run 2 owns `n2 - n1` events
/// starting at the snapshot point, and nothing from run 1 leaks in.
#[test]
fn engine_reuse_scopes_trace_events_per_run() {
    let Some(a) = assets() else { return };
    let c = cfg(2, Vec::new());
    let mut engine = recording_engine(&a, big_vram_sys());

    let run1 =
        run_cluster(std::slice::from_mut(&mut engine), tiny_trace(&a, 4, 20.0), &c).unwrap();
    let n1 = engine.timeline.events.len();
    assert!(n1 > 0);
    assert_eq!(run1.replicas[0].trace.events.len(), n1, "run 1 owns the whole log");

    let run2 =
        run_cluster(std::slice::from_mut(&mut engine), tiny_trace(&a, 4, 20.0), &c).unwrap();
    let n2 = engine.timeline.events.len();
    let cap2 = &run2.replicas[0].trace.events;
    assert_eq!(cap2.len(), n2 - n1, "run 2 captures exactly its own suffix");
    assert!(!cap2.is_empty());
    let first = &engine.timeline.events[n1];
    assert_eq!(cap2[0].kind, first.kind);
    assert_eq!(cap2[0].start, first.start);
    assert_eq!(cap2[0].label, first.label);
}

// ---------------------------------------------------------------------
// Writer / linter on a hand-built cluster (runs everywhere)
// ---------------------------------------------------------------------

/// Engine-free pin of the writer's track mapping: a hand-built one-
/// replica outcome produces exactly the expected lint counts, demand
/// and prefetch transfers land on distinct threads, and the step
/// context (phase / layer) rides on the slice args.
#[test]
fn chrome_writer_lints_without_artifacts() {
    let mut tl = Timeline::new(true);
    tl.ctx_step(&[3], TracePhase::Decode);
    tl.ctx_layer(Some(1));
    tl.ctx_experts(&[2]);
    tl.gpu_compute(0.0, 0.0, 0.5, "ffn");
    tl.pcie_transfer(0.0, 0.1, "demand");
    tl.pcie_prefetch(0.1, 0.2, "bg");
    tl.marker(0.7, "fail");
    tl.tick_span(0.0, 0.5);
    let trace = TraceCapture {
        events: tl.events.clone(),
        samples: vec![TickSample {
            t: 0.5,
            queue_depth: 1,
            active_sessions: 1,
            kv_bytes: 64,
            cache_bytes: 128,
            ..Default::default()
        }],
    };
    let mut outcome = FleetOutcome::default();
    outcome.per_request.push(CompletedRequest {
        id: 3,
        arrival: 0.0,
        class: TenantClass::Interactive,
        queue_delay: 0.1,
        ttft: 0.3,
        tpot: 0.1,
        finished_at: 1.0,
        tokens: 3,
        ttft_ok: true,
        tpot_ok: true,
        max_stall: 0.1,
        retries: 0,
        preemptions: 0,
    });
    let cluster = ClusterOutcome {
        fleet: FleetOutcome::default(),
        replicas: vec![ReplicaBreakdown {
            outcome,
            dispatched: 1,
            busy: BusyTotals::default(),
            state: ReplicaState::Live,
            trace,
        }],
        load_imbalance: 1.0,
        churn: ChurnStats::default(),
        pool: Default::default(),
    };

    let doc = chrome_trace(&cluster);
    let rep = lint(&doc).expect("hand-built trace lints clean");
    assert_eq!(rep.processes, 1);
    assert_eq!(rep.slices, 4, "gpu + demand pcie + prefetch pcie + tick");
    assert_eq!(rep.counters, 7);
    assert_eq!(rep.instants, 1);
    assert_eq!(rep.session_events, 4, "b + admitted + first-token + e");

    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let tid_of = |cat: &str| -> f64 {
        let e = events.iter().find(|e| cat_is(e, cat)).expect(cat);
        e.get("tid").unwrap().as_f64().unwrap()
    };
    assert_ne!(tid_of("pcie"), tid_of("pfch"), "demand and prefetch share a track");

    let gpu = events.iter().find(|e| cat_is(e, "gpu")).unwrap();
    let args = gpu.get("args").unwrap();
    assert_eq!(args.get("phase").unwrap().as_str().unwrap(), "decode-batch");
    assert_eq!(args.get("layer").unwrap().as_f64().unwrap(), 1.0);
    assert_eq!(args.get("sessions").unwrap().as_usize_vec().unwrap(), vec![3]);
    assert_eq!(args.get("experts").unwrap().as_usize_vec().unwrap(), vec![2]);
}

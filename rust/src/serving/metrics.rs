//! Fleet-level SLO metrics: per-session TTFT/TPOT distributions (queue
//! delay included), goodput, SLO attainment, cross-session decode-batch
//! dedup telemetry, per-phase chunked-prefill telemetry (chunk counts,
//! mixed-tick counts, prefill-interference stall), and per-channel
//! resource utilization over one serving run — plus the `merge`
//! operations the cluster layer uses to fold per-replica runs into one
//! cluster-level view.

use std::collections::BTreeMap;

use super::arrival::TenantClass;
use crate::coordinator::engine::{EngineStats, RequestOutput};
use crate::memory::BusyTotals;
use crate::metrics::Series;
use crate::util::table::{fmt_secs, Table};

/// The latency SLOs a request must meet to count toward goodput.
#[derive(Debug, Clone, Copy)]
pub struct SloTargets {
    /// TTFT budget measured from arrival (queueing included), seconds.
    pub ttft_s: f64,
    /// Mean per-output-token budget, seconds.
    pub tpot_s: f64,
}

/// One completed request, fleet view (all times in virtual seconds).
#[derive(Debug, Clone)]
pub struct CompletedRequest {
    pub id: usize,
    pub arrival: f64,
    /// Tenant class the request was served under (legacy single-class
    /// paths report [`TenantClass::Interactive`]).
    pub class: TenantClass,
    /// Prefill start - arrival.
    pub queue_delay: f64,
    /// First token - arrival (queue delay + service TTFT).
    pub ttft: f64,
    pub tpot: f64,
    /// Absolute completion time of the last token.
    pub finished_at: f64,
    pub tokens: usize,
    pub ttft_ok: bool,
    pub tpot_ok: bool,
    /// Longest gap between two consecutive emitted tokens (0 for a
    /// single-token request).  This is the **prefill-interference
    /// delay** a decoding session experiences: under monolithic prefill
    /// a long prompt admitted mid-stream stalls every decoder for its
    /// whole prefill, so the victim's worst gap spans that prefill;
    /// chunked prefill bounds the gap by one chunk's fused service time.
    pub max_stall: f64,
    /// Times this request was re-dispatched after a replica failure (0
    /// on a churn-free run).  Each retry restarted the request from
    /// scratch on a surviving replica while keeping the original
    /// arrival time, so the churn cost is already inside `ttft` /
    /// `queue_delay` — this field just attributes it.  Filled in by the
    /// cluster layer; the single-replica path always reports 0.
    pub retries: usize,
    /// Times this in-flight session was preempted by a higher-priority
    /// class and parked (work conserved: its KV cache and emitted
    /// tokens survive, unlike a churn re-dispatch).  The wait shows up
    /// inside `tpot` / `max_stall`; this field attributes it.  Always 0
    /// on single-class paths.
    pub preemptions: usize,
}

/// Cross-session decode-batch dedup telemetry for one fleet run: how
/// many tokens each expert materialization served once concurrent
/// sessions decode together (the I/O-amplification win batching buys).
#[derive(Debug, Clone, Copy, Default)]
pub struct DedupStats {
    /// Fused decode steps taken (a serial decode is a batch of one).
    pub decode_batches: u64,
    /// Tokens emitted by those steps.
    pub decode_batch_tokens: u64,
    /// Routed `(token, expert)` pairs across all decode layers.
    pub routed_pairs: u64,
    /// Distinct experts materialized for those pairs.
    pub unique_expert_loads: u64,
}

impl DedupStats {
    /// Engine-counter delta over one run (`after - before`).
    /// Saturating, matching the [`PrefetchStats::in_flight`]
    /// convention: if the counters are ever inconsistent (e.g. an
    /// engine `reset_stats` between the snapshots) the delta reads 0
    /// instead of wrapping to ~`u64::MAX`.
    ///
    /// [`PrefetchStats::in_flight`]: crate::coordinator::prefetcher::PrefetchStats::in_flight
    pub fn from_delta(before: &EngineStats, after: &EngineStats) -> DedupStats {
        DedupStats {
            decode_batches: after.decode_batches.saturating_sub(before.decode_batches),
            decode_batch_tokens: after
                .decode_batch_tokens
                .saturating_sub(before.decode_batch_tokens),
            routed_pairs: after.routed_pairs.saturating_sub(before.routed_pairs),
            unique_expert_loads: after
                .unique_expert_loads
                .saturating_sub(before.unique_expert_loads),
        }
    }

    /// Mean decode-batch size over the run (0 when nothing decoded).
    pub fn mean_batch(&self) -> f64 {
        if self.decode_batches == 0 {
            0.0
        } else {
            self.decode_batch_tokens as f64 / self.decode_batches as f64
        }
    }

    /// Routed tokens served per expert materialization: 1.0 when every
    /// expert load serves one token, higher when sessions share fetches.
    /// 0 when nothing decoded.
    pub fn expert_reuse_ratio(&self) -> f64 {
        if self.unique_expert_loads == 0 {
            0.0
        } else {
            self.routed_pairs as f64 / self.unique_expert_loads as f64
        }
    }

    /// Expert fetch/exec operations avoided versus fully serial decode.
    /// Saturating: an inconsistent snapshot reads as 0 saved, never as
    /// a wrapped ~`u64::MAX`.
    pub fn saved_fetches(&self) -> u64 {
        self.routed_pairs.saturating_sub(self.unique_expert_loads)
    }

    /// Fold another run's counters in (cluster merge across replicas).
    pub fn merge(&mut self, other: &DedupStats) {
        self.decode_batches += other.decode_batches;
        self.decode_batch_tokens += other.decode_batch_tokens;
        self.routed_pairs += other.routed_pairs;
        self.unique_expert_loads += other.unique_expert_loads;
    }
}

/// Per-phase chunked-prefill telemetry for one fleet run: how the
/// token-budget scheduler actually split its ticks between prefill
/// chunks, decode batches, and fused mixed steps.  All zero on the
/// monolithic (`chunk_tokens = 0`) path, which is itself the regression
/// signal that the legacy path never engages the chunking machinery.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseStats {
    /// Prefill chunks executed (one per tick that carried prefill work).
    pub prefill_chunks: u64,
    /// Prompt tokens those chunks advanced; sums to the prompt length
    /// of every chunk-prefilled session (token conservation).
    pub prefill_chunk_tokens: u64,
    /// Ticks that fused a prefill chunk with a decode batch in one
    /// per-layer pass.
    pub mixed_steps: u64,
}

impl PhaseStats {
    /// Engine-counter delta over one run (`after - before`).
    /// Saturating, like [`DedupStats::from_delta`]: inconsistent
    /// snapshots (an engine reset in between) read 0, never wrap.
    pub fn from_delta(before: &EngineStats, after: &EngineStats) -> PhaseStats {
        PhaseStats {
            prefill_chunks: after.prefill_chunks.saturating_sub(before.prefill_chunks),
            prefill_chunk_tokens: after
                .prefill_chunk_tokens
                .saturating_sub(before.prefill_chunk_tokens),
            mixed_steps: after.mixed_steps.saturating_sub(before.mixed_steps),
        }
    }

    /// Mean prompt tokens per chunk (0 when nothing chunked).
    pub fn mean_chunk(&self) -> f64 {
        if self.prefill_chunks == 0 {
            0.0
        } else {
            self.prefill_chunk_tokens as f64 / self.prefill_chunks as f64
        }
    }

    /// Fold another run's counters in (cluster merge across replicas).
    pub fn merge(&mut self, other: &PhaseStats) {
        self.prefill_chunks += other.prefill_chunks;
        self.prefill_chunk_tokens += other.prefill_chunk_tokens;
        self.mixed_steps += other.mixed_steps;
    }
}

/// Replica-churn telemetry for one cluster run: what the scheduled
/// failure / drain events ([`crate::config::ChurnEvent`]) actually cost.
/// All zero on a churn-free run — which is itself the regression signal
/// that the churn machinery never engages on the plain serving path.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChurnStats {
    /// Replicas killed by `Fail` events (each counted once).
    pub failed: usize,
    /// Replicas cordoned by `Drain` events (each counted once).
    pub drained: usize,
    /// Sessions evacuated from failed replicas and re-dispatched
    /// (queued and in-flight alike; one session evacuated by two
    /// successive failures counts twice).
    pub requeued: usize,
    /// Tokens of processing discarded by failures: prompt tokens
    /// already prefilled plus output tokens already emitted by
    /// evacuated in-flight sessions, each of which restarts from
    /// scratch on a surviving replica.
    pub lost_work_tokens: u64,
    /// Worst per-request re-dispatch count
    /// ([`CompletedRequest::retries`] maximum).
    pub max_retries: usize,
}

impl ChurnStats {
    /// Any churn at all this run?
    pub fn any(&self) -> bool {
        self.failed > 0 || self.drained > 0
    }
}

/// Busy fractions of the device channels over one run (or one cluster
/// run, where the denominator is `replicas x makespan` — the fraction of
/// the cluster's aggregate channel-seconds actually used).
#[derive(Debug, Clone, Copy, Default)]
pub struct ResourceUtil {
    pub gpu: f64,
    pub cpu: f64,
    pub pcie: f64,
    pub nvme: f64,
}

impl ResourceUtil {
    /// Busy fractions from a busy-seconds **delta** over `span` seconds
    /// across `devices` parallel replicas (clamped to 1; all zero for an
    /// empty span).  Taking a delta rather than `Channel::utilization`'s
    /// cumulative total is what keeps an engine reusable across runs
    /// without double-counting earlier runs' busy time.
    pub fn from_busy(busy: &BusyTotals, span: f64, devices: usize) -> ResourceUtil {
        if span <= 0.0 || devices == 0 {
            return ResourceUtil::default();
        }
        ResourceUtil::from_capacity(busy, span * devices as f64)
    }

    /// Busy fractions over an explicit capacity in channel-seconds —
    /// the sum of per-replica **live intervals** rather than a uniform
    /// `span × devices`.  The cluster layer uses this under churn so a
    /// replica that failed at t≈0 no longer contributes a full
    /// makespan of phantom capacity to the denominator (which
    /// understated post-churn utilization); `from_busy` is the uniform
    /// special case.
    pub fn from_capacity(busy: &BusyTotals, capacity_secs: f64) -> ResourceUtil {
        if capacity_secs <= 0.0 {
            return ResourceUtil::default();
        }
        let frac = |b: f64| (b / capacity_secs).clamp(0.0, 1.0);
        ResourceUtil {
            gpu: frac(busy.gpu),
            cpu: frac(busy.cpu),
            pcie: frac(busy.pcie),
            nvme: frac(busy.nvme),
        }
    }
}

/// `max / mean` of per-replica loads: 1.0 when perfectly balanced, up to
/// `replicas` when one replica carries everything.  Defined as 1.0 for an
/// all-idle cluster (nothing to imbalance).
pub fn load_imbalance(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let mean = loads.iter().sum::<f64>() / loads.len() as f64;
    if mean <= 0.0 {
        return 1.0;
    }
    let max = loads.iter().copied().fold(0.0f64, f64::max);
    max / mean
}

/// Live-time-weighted load imbalance: `max / mean` of per-replica
/// **service rates** (`loads[i] / live_secs[i]`), considering only
/// replicas with positive live time.  Plain [`load_imbalance`] averages
/// over every replica, so a cluster whose survivors are perfectly
/// balanced after an early failure reads as imbalanced (max/mean of
/// `[x, x, 0]` is 1.5); weighting by live time makes a replica that
/// failed at t≈0 drop out and balanced survivors read 1.0.  With equal
/// live times this reduces to `load_imbalance` (max/mean is invariant
/// under a common positive scale).
pub fn load_imbalance_weighted(loads: &[f64], live_secs: &[f64]) -> f64 {
    debug_assert_eq!(loads.len(), live_secs.len());
    let rates: Vec<f64> = loads
        .iter()
        .zip(live_secs)
        .filter(|(_, &live)| live > 0.0)
        .map(|(&load, &live)| load / live)
        .collect();
    load_imbalance(&rates)
}

/// Per-tenant-class latency/SLO aggregates within one fleet run: the
/// distributions behind per-class SLO attainment (a fleet can hit 99%
/// overall while its interactive class burns, which is exactly what
/// class-blind scheduling produces under mixed tenancy).
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    pub ttft: Series,
    pub tpot: Series,
    pub queue_delay: Series,
    pub completed: usize,
    pub ttft_ok: usize,
    pub tpot_ok: usize,
    pub slo_ok: usize,
    pub tokens_total: usize,
    /// Preemption events suffered by this class's completed requests.
    pub preemptions: usize,
}

impl ClassStats {
    /// Fraction of this class's completed requests that met both SLOs.
    pub fn slo_attainment(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.slo_ok as f64 / self.completed as f64
    }

    /// Fold another run's per-class aggregates in (cluster merge).
    pub fn merge(&mut self, other: &ClassStats) {
        for (dst, src) in [
            (&mut self.ttft, &other.ttft),
            (&mut self.tpot, &other.tpot),
            (&mut self.queue_delay, &other.queue_delay),
        ] {
            for &v in src.samples() {
                dst.push(v);
            }
        }
        self.completed += other.completed;
        self.ttft_ok += other.ttft_ok;
        self.tpot_ok += other.tpot_ok;
        self.slo_ok += other.slo_ok;
        self.tokens_total += other.tokens_total;
        self.preemptions += other.preemptions;
    }
}

/// Aggregates over one fleet run.
#[derive(Debug, Clone, Default)]
pub struct FleetMetrics {
    /// Arrival-relative TTFT (what a user of the fleet experiences).
    pub ttft: Series,
    pub tpot: Series,
    pub queue_delay: Series,
    /// Service-side TTFT (prefill start to first token): together with
    /// `queue_delay` this is the TTFT breakdown — `ttft = queue_delay +
    /// prefill_time` per request.
    pub prefill_time: Series,
    /// Per-request worst inter-token gap (`CompletedRequest::max_stall`)
    /// — the prefill-interference delay distribution the HOL-blocking
    /// regression test bounds.
    pub stall: Series,
    /// Arrival-to-last-token latency.
    pub e2e: Series,
    pub completed: usize,
    pub ttft_ok: usize,
    pub tpot_ok: usize,
    pub slo_ok: usize,
    pub tokens_total: usize,
    pub first_arrival: f64,
    pub last_completion: f64,
    /// Per-tenant-class breakdown of the same run (keyed by class; the
    /// legacy single-class paths put everything under
    /// [`TenantClass::Interactive`]).
    pub per_class: BTreeMap<TenantClass, ClassStats>,
}

impl FleetMetrics {
    /// Fold one finished session in; returns its fleet-view record.
    /// Single-class convenience over [`FleetMetrics::record_class`]
    /// (interactive, never preempted) — the legacy call shape.
    pub fn record(
        &mut self,
        id: usize,
        arrival: f64,
        out: &RequestOutput,
        slo: SloTargets,
    ) -> CompletedRequest {
        self.record_class(id, arrival, TenantClass::Interactive, out, slo, 0)
    }

    /// Fold one finished session in under its tenant class; returns its
    /// fleet-view record.
    pub fn record_class(
        &mut self,
        id: usize,
        arrival: f64,
        class: TenantClass,
        out: &RequestOutput,
        slo: SloTargets,
        preemptions: usize,
    ) -> CompletedRequest {
        let queue_delay = out.start - arrival;
        let ttft = queue_delay + out.ttft;
        let tpot = out.tpot();
        let finished_at = out.start + out.token_times.last().copied().unwrap_or(out.ttft);
        let ttft_ok = ttft <= slo.ttft_s;
        let tpot_ok = tpot <= slo.tpot_s;
        let max_stall = out
            .token_times
            .windows(2)
            .map(|w| w[1] - w[0])
            .fold(0.0f64, f64::max);

        if self.completed == 0 || arrival < self.first_arrival {
            self.first_arrival = arrival;
        }
        self.last_completion = self.last_completion.max(finished_at);
        self.ttft.push(ttft);
        self.tpot.push(tpot);
        self.queue_delay.push(queue_delay);
        self.prefill_time.push(out.ttft);
        self.stall.push(max_stall);
        self.e2e.push(finished_at - arrival);
        self.completed += 1;
        self.ttft_ok += ttft_ok as usize;
        self.tpot_ok += tpot_ok as usize;
        self.slo_ok += (ttft_ok && tpot_ok) as usize;
        self.tokens_total += out.tokens.len();

        let c = self.per_class.entry(class).or_default();
        c.ttft.push(ttft);
        c.tpot.push(tpot);
        c.queue_delay.push(queue_delay);
        c.completed += 1;
        c.ttft_ok += ttft_ok as usize;
        c.tpot_ok += tpot_ok as usize;
        c.slo_ok += (ttft_ok && tpot_ok) as usize;
        c.tokens_total += out.tokens.len();
        c.preemptions += preemptions;

        CompletedRequest {
            id,
            arrival,
            class,
            queue_delay,
            ttft,
            tpot,
            finished_at,
            tokens: out.tokens.len(),
            ttft_ok,
            tpot_ok,
            max_stall,
            retries: 0,
            preemptions,
        }
    }

    /// Total preemption events across every class this run.
    pub fn preemptions(&self) -> usize {
        self.per_class.values().map(|c| c.preemptions).sum()
    }

    /// Fold another run's aggregates in (cluster merge across replicas).
    /// Percentiles recompute over the union of samples; the makespan
    /// spans the earliest arrival to the latest completion across both.
    pub fn merge(&mut self, other: &FleetMetrics) {
        if other.completed > 0 {
            if self.completed == 0 {
                self.first_arrival = other.first_arrival;
            } else {
                self.first_arrival = self.first_arrival.min(other.first_arrival);
            }
            self.last_completion = self.last_completion.max(other.last_completion);
        }
        for (dst, src) in [
            (&mut self.ttft, &other.ttft),
            (&mut self.tpot, &other.tpot),
            (&mut self.queue_delay, &other.queue_delay),
            (&mut self.prefill_time, &other.prefill_time),
            (&mut self.stall, &other.stall),
            (&mut self.e2e, &other.e2e),
        ] {
            for &v in src.samples() {
                dst.push(v);
            }
        }
        self.completed += other.completed;
        self.ttft_ok += other.ttft_ok;
        self.tpot_ok += other.tpot_ok;
        self.slo_ok += other.slo_ok;
        self.tokens_total += other.tokens_total;
        for (class, stats) in &other.per_class {
            self.per_class.entry(*class).or_default().merge(stats);
        }
    }

    /// Wall span of the run (first arrival to last completion).
    pub fn makespan(&self) -> f64 {
        (self.last_completion - self.first_arrival).max(0.0)
    }

    /// Requests per second that met *both* SLOs.
    pub fn goodput_rps(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.slo_ok as f64 / span
    }

    /// Emitted tokens per second, SLO-blind.
    pub fn throughput_tps(&self) -> f64 {
        let span = self.makespan();
        if span <= 0.0 {
            return 0.0;
        }
        self.tokens_total as f64 / span
    }

    /// Fraction of completed requests that met both SLOs.
    pub fn slo_attainment(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.slo_ok as f64 / self.completed as f64
    }

    /// One row for the fleet summary table (pairs with
    /// [`FleetMetrics::TABLE_HEADER`]).
    pub fn summary_row(&self, label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            fmt_secs(self.ttft.percentile(50.0)),
            fmt_secs(self.ttft.percentile(95.0)),
            fmt_secs(self.ttft.percentile(99.0)),
            fmt_secs(self.tpot.percentile(50.0)),
            fmt_secs(self.tpot.percentile(99.0)),
            fmt_secs(self.queue_delay.mean()),
            format!("{:.3}", self.goodput_rps()),
            format!("{:.1}", self.throughput_tps()),
            format!("{:.0}%", self.slo_attainment() * 100.0),
        ]
    }

    // NB: the 'static is required — eliding it in an associated const
    // trips the `elided_lifetimes_in_associated_constant` lint.
    pub const TABLE_HEADER: [&'static str; 10] = [
        "policy",
        "TTFT p50",
        "TTFT p95",
        "TTFT p99",
        "TPOT p50",
        "TPOT p99",
        "queue mean",
        "goodput r/s",
        "tok/s",
        "SLO att",
    ];

    /// One table row for a tenant class's share of this run (goodput
    /// and tok/s over the whole run's makespan, so class rows sum to
    /// roughly the fleet row).
    pub fn class_row(&self, class: TenantClass, c: &ClassStats) -> Vec<String> {
        let span = self.makespan();
        let per_span = |n: usize| if span <= 0.0 { 0.0 } else { n as f64 / span };
        vec![
            format!("  {}", class.name()),
            fmt_secs(c.ttft.percentile(50.0)),
            fmt_secs(c.ttft.percentile(95.0)),
            fmt_secs(c.ttft.percentile(99.0)),
            fmt_secs(c.tpot.percentile(50.0)),
            fmt_secs(c.tpot.percentile(99.0)),
            fmt_secs(c.queue_delay.mean()),
            format!("{:.3}", per_span(c.slo_ok)),
            format!("{:.1}", per_span(c.tokens_total)),
            format!("{:.0}%", c.slo_attainment() * 100.0),
        ]
    }

    /// Render a one-run summary table (with per-class breakdown rows
    /// whenever the run actually mixed tenant classes).
    pub fn render(&self, label: &str) -> String {
        let mut t = Table::new("fleet latency summary", &Self::TABLE_HEADER);
        t.row(self.summary_row(label));
        if self.per_class.len() > 1 {
            for (class, c) in &self.per_class {
                t.row(self.class_row(*class, c));
            }
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn out(start: f64, ttft: f64, token_times: Vec<f64>) -> RequestOutput {
        RequestOutput {
            tokens: vec![0; token_times.len()],
            ttft,
            token_times,
            logits_per_step: Vec::new(),
            prefill_hidden: Vec::new(),
            start,
        }
    }

    #[test]
    fn record_accounts_queueing_and_slos() {
        let mut m = FleetMetrics::default();
        let slo = SloTargets { ttft_s: 2.0, tpot_s: 0.5 };
        // arrived at 1.0, served at 1.5, first token 0.8 later -> ttft 1.3
        let r = m.record(0, 1.0, &out(1.5, 0.8, vec![0.8, 1.2, 1.6]), slo);
        assert!((r.queue_delay - 0.5).abs() < 1e-12);
        assert!((r.ttft - 1.3).abs() < 1e-12);
        assert!((r.tpot - 0.4).abs() < 1e-12);
        assert!(r.ttft_ok && r.tpot_ok);
        assert!((r.finished_at - 3.1).abs() < 1e-12);
        // a second request that blows the TTFT SLO
        let r2 = m.record(1, 1.2, &out(4.0, 0.9, vec![0.9]), slo);
        assert!(!r2.ttft_ok);
        assert_eq!(m.completed, 2);
        assert_eq!(m.slo_ok, 1);
        assert!((m.slo_attainment() - 0.5).abs() < 1e-12);
        assert_eq!(m.tokens_total, 4);
        // makespan: first arrival 1.0 -> last completion 4.9
        assert!((m.makespan() - 3.9).abs() < 1e-12);
        assert!(m.goodput_rps() > 0.0 && m.throughput_tps() > 0.0);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = FleetMetrics::default();
        assert_eq!(m.makespan(), 0.0);
        assert_eq!(m.goodput_rps(), 0.0);
        assert_eq!(m.throughput_tps(), 0.0);
        assert_eq!(m.slo_attainment(), 0.0);
        assert_eq!(m.summary_row("x").len(), FleetMetrics::TABLE_HEADER.len());
    }

    #[test]
    fn record_tracks_stall_and_ttft_breakdown() {
        let mut m = FleetMetrics::default();
        let slo = SloTargets { ttft_s: 10.0, tpot_s: 10.0 };
        // token gaps: 0.4, then a 1.6 stall (a monolithic prefill ran in
        // between), then 0.2
        let r = m.record(0, 1.0, &out(1.5, 0.8, vec![0.8, 1.2, 2.8, 3.0]), slo);
        assert!((r.max_stall - 1.6).abs() < 1e-12);
        assert!((m.stall.max() - 1.6).abs() < 1e-12);
        // breakdown: ttft == queue_delay + prefill_time per request
        assert!((r.ttft - (r.queue_delay + 0.8)).abs() < 1e-12);
        assert!((m.prefill_time.mean() - 0.8).abs() < 1e-12);
        // single-token request: no inter-token gap at all
        let r1 = m.record(1, 0.0, &out(0.0, 0.3, vec![0.3]), slo);
        assert_eq!(r1.max_stall, 0.0);
    }

    #[test]
    fn phase_stats_deltas_and_mean_chunk() {
        let zero = PhaseStats::default();
        assert_eq!(zero.mean_chunk(), 0.0);

        let before = EngineStats {
            prefill_chunks: 2,
            prefill_chunk_tokens: 10,
            ..Default::default()
        };
        let after = EngineStats {
            prefill_chunks: 6,
            prefill_chunk_tokens: 26,
            mixed_steps: 3,
            ..Default::default()
        };
        let p = PhaseStats::from_delta(&before, &after);
        assert_eq!(p.prefill_chunks, 4);
        assert_eq!(p.prefill_chunk_tokens, 16);
        assert_eq!(p.mixed_steps, 3);
        assert!((p.mean_chunk() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn merge_unions_samples_and_spans() {
        let slo = SloTargets { ttft_s: 2.0, tpot_s: 0.5 };
        let mut a = FleetMetrics::default();
        a.record(0, 1.0, &out(1.5, 0.8, vec![0.8, 1.2, 1.6]), slo);
        let mut b = FleetMetrics::default();
        b.record(1, 0.5, &out(4.0, 0.9, vec![0.9]), slo);

        // reference: the same two records folded into one collector
        let mut both = FleetMetrics::default();
        both.record(0, 1.0, &out(1.5, 0.8, vec![0.8, 1.2, 1.6]), slo);
        both.record(1, 0.5, &out(4.0, 0.9, vec![0.9]), slo);

        let mut merged = FleetMetrics::default();
        merged.merge(&a);
        merged.merge(&b);
        assert_eq!(merged.completed, both.completed);
        assert_eq!(merged.slo_ok, both.slo_ok);
        assert_eq!(merged.tokens_total, both.tokens_total);
        assert_eq!(merged.first_arrival, both.first_arrival);
        assert_eq!(merged.last_completion, both.last_completion);
        assert_eq!(merged.makespan(), both.makespan());
        assert_eq!(merged.ttft.percentile(99.0), both.ttft.percentile(99.0));
        assert_eq!(merged.tpot.mean(), both.tpot.mean());
        // merging an empty collector is the identity
        let before = merged.completed;
        merged.merge(&FleetMetrics::default());
        assert_eq!(merged.completed, before);
        assert_eq!(merged.first_arrival, both.first_arrival);
    }

    #[test]
    fn per_class_breakdown_records_and_merges() {
        let slo = SloTargets { ttft_s: 2.0, tpot_s: 0.5 };
        let lax = SloTargets { ttft_s: 100.0, tpot_s: 100.0 };
        let mut m = FleetMetrics::default();
        // legacy record() lands under Interactive with 0 preemptions
        let r = m.record(0, 1.0, &out(1.5, 0.8, vec![0.8, 1.2, 1.6]), slo);
        assert_eq!(r.class, TenantClass::Interactive);
        assert_eq!(r.preemptions, 0);
        // a batch request on its own (laxer) SLO, preempted twice
        let rb = m.record_class(
            1,
            1.2,
            TenantClass::Batch,
            &out(4.0, 0.9, vec![0.9]),
            lax,
            2,
        );
        assert_eq!(rb.class, TenantClass::Batch);
        assert!(rb.ttft_ok && rb.tpot_ok, "batch judged on its own SLO");
        assert_eq!(rb.preemptions, 2);
        assert_eq!(m.per_class.len(), 2);
        let i = &m.per_class[&TenantClass::Interactive];
        let b = &m.per_class[&TenantClass::Batch];
        assert_eq!(i.completed, 1);
        assert_eq!(b.completed, 1);
        assert_eq!(b.preemptions, 2);
        assert_eq!(m.preemptions(), 2);
        assert_eq!(i.tokens_total + b.tokens_total, m.tokens_total);
        assert_eq!(i.slo_ok + b.slo_ok, m.slo_ok);
        assert!((b.slo_attainment() - 1.0).abs() < 1e-12);
        // class breakdown survives the cluster merge
        let mut merged = FleetMetrics::default();
        merged.merge(&m);
        merged.merge(&m);
        assert_eq!(merged.per_class[&TenantClass::Batch].completed, 2);
        assert_eq!(merged.per_class[&TenantClass::Batch].preemptions, 4);
        assert_eq!(
            merged.per_class[&TenantClass::Interactive].ttft.percentile(50.0),
            i.ttft.percentile(50.0)
        );
        // and the render gains per-class rows only for mixed runs
        assert!(m.render("slo").contains("interactive"));
        assert!(m.render("slo").contains("batch"));
        let mut single = FleetMetrics::default();
        single.record(0, 1.0, &out(1.5, 0.8, vec![0.8]), slo);
        assert!(!single.render("slo").contains("interactive"));
    }

    #[test]
    fn phase_and_dedup_merge_are_sums() {
        let mut d = DedupStats {
            decode_batches: 1,
            decode_batch_tokens: 2,
            routed_pairs: 4,
            unique_expert_loads: 3,
        };
        let d0 = d;
        d.merge(&d0);
        assert_eq!(d.decode_batches, 2);
        assert_eq!(d.routed_pairs, 8);
        let mut p = PhaseStats { prefill_chunks: 2, prefill_chunk_tokens: 6, mixed_steps: 1 };
        let p0 = p;
        p.merge(&p0);
        assert_eq!(p.prefill_chunks, 4);
        assert_eq!(p.prefill_chunk_tokens, 12);
        assert_eq!(p.mixed_steps, 2);
    }

    /// Counter deltas must saturate, not wrap: an engine `reset_stats`
    /// between the before/after snapshots makes `after < before`, and a
    /// wrapping subtraction would report ~u64::MAX fetches saved.
    #[test]
    fn deltas_saturate_on_inconsistent_snapshots() {
        let before = EngineStats {
            decode_batches: 6,
            decode_batch_tokens: 18,
            routed_pairs: 36,
            unique_expert_loads: 12,
            prefill_chunks: 4,
            prefill_chunk_tokens: 9,
            mixed_steps: 2,
            ..Default::default()
        };
        // engine reset between snapshots: every counter went backwards
        let after = EngineStats::default();
        let d = DedupStats::from_delta(&before, &after);
        assert_eq!(d.decode_batches, 0);
        assert_eq!(d.decode_batch_tokens, 0);
        assert_eq!(d.routed_pairs, 0);
        assert_eq!(d.unique_expert_loads, 0);
        assert_eq!(d.saved_fetches(), 0);
        assert_eq!(d.mean_batch(), 0.0);
        let p = PhaseStats::from_delta(&before, &after);
        assert_eq!(p.prefill_chunks, 0);
        assert_eq!(p.prefill_chunk_tokens, 0);
        assert_eq!(p.mixed_steps, 0);
        // saved_fetches on an internally inconsistent counter pair
        // reads 0, matching the PrefetchStats::in_flight convention
        let broken = DedupStats {
            routed_pairs: 3,
            unique_expert_loads: 5,
            ..Default::default()
        };
        assert_eq!(broken.saved_fetches(), 0);
    }

    #[test]
    fn churn_stats_default_is_quiet() {
        let z = ChurnStats::default();
        assert!(!z.any());
        let f = ChurnStats { failed: 1, ..Default::default() };
        assert!(f.any());
        let d = ChurnStats { drained: 2, ..Default::default() };
        assert!(d.any());
    }

    #[test]
    fn resource_util_is_a_clamped_delta_fraction() {
        let busy = BusyTotals { gpu: 2.0, cpu: 0.0, pcie: 8.0, nvme: 1.0 };
        let u = ResourceUtil::from_busy(&busy, 4.0, 1);
        assert!((u.gpu - 0.5).abs() < 1e-12);
        assert_eq!(u.cpu, 0.0);
        assert_eq!(u.pcie, 1.0, "busy beyond the span clamps to 1");
        assert!((u.nvme - 0.25).abs() < 1e-12);
        // cluster denominator: the same busy time over two devices
        let u2 = ResourceUtil::from_busy(&busy, 4.0, 2);
        assert!((u2.gpu - 0.25).abs() < 1e-12);
        // degenerate spans are all-zero, never NaN
        let z = ResourceUtil::from_busy(&busy, 0.0, 1);
        assert_eq!(z.gpu, 0.0);
        let z = ResourceUtil::from_busy(&busy, 1.0, 0);
        assert_eq!(z.pcie, 0.0);
    }

    #[test]
    fn load_imbalance_is_max_over_mean() {
        assert_eq!(load_imbalance(&[]), 1.0);
        assert_eq!(load_imbalance(&[0.0, 0.0]), 1.0);
        assert_eq!(load_imbalance(&[4.0, 4.0, 4.0, 4.0]), 1.0);
        // one replica carries everything: imbalance = replica count
        assert_eq!(load_imbalance(&[8.0, 0.0, 0.0, 0.0]), 4.0);
        assert!((load_imbalance(&[3.0, 1.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn weighted_imbalance_excludes_dead_and_scales_by_live_time() {
        // A replica that failed at t=0 (zero live time) drops out:
        // balanced survivors read 1.0 where the unweighted statistic
        // reads max/mean of [x, x, 0] = 1.5.
        assert_eq!(load_imbalance(&[6.0, 6.0, 0.0]), 1.5);
        assert_eq!(load_imbalance_weighted(&[6.0, 6.0, 0.0], &[4.0, 4.0, 0.0]), 1.0);
        // Sole survivor after an early failure is balanced by definition.
        assert_eq!(load_imbalance_weighted(&[9.0, 0.0], &[3.0, 0.0]), 1.0);
        // A replica live half the span serving half the tokens has the
        // same rate as a full-span replica: balanced.
        assert!(
            (load_imbalance_weighted(&[4.0, 8.0], &[2.0, 4.0]) - 1.0).abs() < 1e-12
        );
        // Equal live times reduce to the unweighted statistic.
        assert!(
            (load_imbalance_weighted(&[3.0, 1.0], &[2.0, 2.0]) - 1.5).abs() < 1e-12
        );
        // Degenerate: nothing ever live.
        assert_eq!(load_imbalance_weighted(&[0.0, 0.0], &[0.0, 0.0]), 1.0);
    }

    #[test]
    fn capacity_utilization_matches_uniform_special_case() {
        let busy = BusyTotals { gpu: 2.0, cpu: 0.0, pcie: 8.0, nvme: 1.0 };
        let uniform = ResourceUtil::from_busy(&busy, 4.0, 2);
        let explicit = ResourceUtil::from_capacity(&busy, 8.0);
        assert_eq!(uniform.gpu, explicit.gpu);
        assert_eq!(uniform.pcie, explicit.pcie);
        assert_eq!(uniform.nvme, explicit.nvme);
        // A dead replica contributing no capacity raises the fraction:
        // same busy time over the survivor's span only.
        let survivor_only = ResourceUtil::from_capacity(&busy, 4.0);
        assert!((survivor_only.gpu - 0.5).abs() < 1e-12);
        // degenerate capacity is all-zero, never NaN
        assert_eq!(ResourceUtil::from_capacity(&busy, 0.0).gpu, 0.0);
        assert_eq!(ResourceUtil::from_capacity(&busy, -1.0).nvme, 0.0);
    }

    #[test]
    fn dedup_stats_ratios_and_deltas() {
        // empty run: every ratio stays defined
        let zero = DedupStats::default();
        assert_eq!(zero.mean_batch(), 0.0);
        assert_eq!(zero.expert_reuse_ratio(), 0.0);
        assert_eq!(zero.saved_fetches(), 0);

        let before = EngineStats {
            decode_batches: 2,
            decode_batch_tokens: 2,
            routed_pairs: 4,
            unique_expert_loads: 4,
            ..Default::default()
        };
        let after = EngineStats {
            decode_batches: 6,
            decode_batch_tokens: 18,
            routed_pairs: 36,
            unique_expert_loads: 12,
            ..Default::default()
        };
        let d = DedupStats::from_delta(&before, &after);
        assert_eq!(d.decode_batches, 4);
        assert_eq!(d.decode_batch_tokens, 16);
        assert!((d.mean_batch() - 4.0).abs() < 1e-12);
        assert!((d.expert_reuse_ratio() - 4.0).abs() < 1e-12);
        assert_eq!(d.saved_fetches(), 24);
    }
}

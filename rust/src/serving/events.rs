//! The cluster scheduler's event queue: a binary min-heap over the
//! three event kinds that drive a cluster run — scheduled churn,
//! request arrivals, and per-replica tick-completions — keyed by
//! virtual time with a fixed same-instant precedence.
//!
//! Ordering contract (pinned by the unit tests below and by the
//! engine-free property test in `tests/integration_cluster.rs`):
//!
//! 1. **Virtual time** first (`f64::total_cmp` on `at`).
//! 2. At the same instant, **churn before arrival before tick**.  This
//!    reproduces the retired min-clock loop's `<=` comparisons exactly:
//!    a failure at an arrival's time excludes the failed replica from
//!    that arrival's dispatch, and an arrival at a busy replica's clock
//!    is routed before the replica ticks past it.
//! 3. Within a kind, by `seq`: churn events carry their **schedule
//!    order** (the stable sort the config validation performs), arrivals
//!    their request id (the `(arrival, id)` order the pending queue used
//!    to be sorted by), ticks their replica index (the min-clock loop
//!    broke clock ties by lowest index).
//!
//! Tick entries are *cached clocks*, not promises: the queue never
//! removes an entry when a replica is evacuated, so consumers validate
//! on pop (a tick is stale unless the replica still has work and its
//! clock still equals the entry's `at`) — classic lazy deletion.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::config::ChurnEvent;

use super::arrival::TimedRequest;

/// What a popped event asks the scheduler to do.
#[derive(Debug, Clone)]
pub enum EventPayload {
    /// Fire a scheduled churn event (fail / drain).
    Churn(ChurnEvent),
    /// Route one arriving request through the dispatch policy.
    Arrival(TimedRequest),
    /// A replica's next scheduling step is due (`at` is the clock the
    /// replica held when the entry was pushed).
    Tick { replica: usize },
}

/// One scheduled event.
#[derive(Debug, Clone)]
pub struct Event {
    /// Virtual time the event is due at.
    pub at: f64,
    /// Same-kind tie-break (schedule order / request id / replica
    /// index — see the module docs).
    pub seq: u64,
    pub payload: EventPayload,
}

impl Event {
    pub fn churn(schedule_pos: u64, e: ChurnEvent) -> Event {
        Event { at: e.at, seq: schedule_pos, payload: EventPayload::Churn(e) }
    }

    pub fn arrival(r: TimedRequest) -> Event {
        Event { at: r.arrival, seq: r.id as u64, payload: EventPayload::Arrival(r) }
    }

    pub fn tick(clock: f64, replica: usize) -> Event {
        Event { at: clock, seq: replica as u64, payload: EventPayload::Tick { replica } }
    }

    /// Same-instant precedence class (lower fires first).
    fn class(&self) -> u8 {
        match self.payload {
            EventPayload::Churn(_) => 0,
            EventPayload::Arrival(_) => 1,
            EventPayload::Tick { .. } => 2,
        }
    }

    /// Total order over events: `(at, class, seq)` ascending.
    fn cmp_key(&self, other: &Event) -> Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.class().cmp(&other.class()))
            .then(self.seq.cmp(&other.seq))
    }
}

/// Heap slot with the comparison inverted: `BinaryHeap` is a max-heap
/// and we want the earliest event on top.
struct Slot(Event);

impl PartialEq for Slot {
    fn eq(&self, other: &Self) -> bool {
        self.0.cmp_key(&other.0) == Ordering::Equal
    }
}
impl Eq for Slot {}
impl PartialOrd for Slot {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Slot {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.cmp_key(&self.0)
    }
}

/// Binary-heap event queue: `pop` yields events in `(at, class, seq)`
/// order regardless of push order; pushing an event earlier than
/// everything already popped is allowed (a tick entry for a lagging
/// replica's clock is "in the past" relative to the arrival that woke
/// it — the replica's engine fast-forwards service internally).
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Slot>,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new() }
    }

    pub fn push(&mut self, e: Event) {
        self.heap.push(Slot(e));
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|s| s.0)
    }

    /// Virtual time of the earliest queued event, if any.
    pub fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.0.at)
    }

    /// Is the earliest queued event a tick-completion?  The scheduler
    /// uses this to claim every tick due before the next boundary
    /// (churn / arrival) event in one batch.
    pub fn peek_is_tick(&self) -> bool {
        matches!(self.heap.peek(), Some(Slot(e)) if matches!(e.payload, EventPayload::Tick { .. }))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChurnKind;
    use crate::workload::Request;

    fn arr(id: usize, at: f64) -> Event {
        Event::arrival(TimedRequest::new(id, at, Request { prompt: vec![1], max_new: 1 }))
    }

    fn churn(pos: u64, at: f64) -> Event {
        Event::churn(pos, ChurnEvent { at, replica: 0, kind: ChurnKind::Fail })
    }

    fn drain_order(q: &mut EventQueue) -> Vec<(f64, u8, u64)> {
        let mut out = Vec::new();
        while let Some(e) = q.pop() {
            out.push((e.at, e.class(), e.seq));
        }
        out
    }

    #[test]
    fn pops_in_virtual_time_order() {
        let mut q = EventQueue::new();
        for (id, at) in [(0, 3.0), (1, 1.0), (2, 2.5), (3, 0.25)] {
            q.push(arr(id, at));
        }
        q.push(Event::tick(1.75, 0));
        q.push(churn(0, 0.5));
        let times: Vec<f64> = drain_order(&mut q).iter().map(|x| x.0).collect();
        assert_eq!(times, vec![0.25, 0.5, 1.0, 1.75, 2.5, 3.0]);
    }

    #[test]
    fn same_instant_precedence_is_churn_arrival_tick() {
        let mut q = EventQueue::new();
        q.push(Event::tick(1.0, 2));
        q.push(arr(7, 1.0));
        q.push(churn(0, 1.0));
        let classes: Vec<u8> = drain_order(&mut q).iter().map(|x| x.1).collect();
        assert_eq!(classes, vec![0, 1, 2]);
    }

    #[test]
    fn churn_ties_break_by_schedule_order() {
        let mut q = EventQueue::new();
        // Push in reverse schedule order; pop must restore it.
        q.push(churn(2, 4.0));
        q.push(churn(0, 4.0));
        q.push(churn(1, 4.0));
        let seqs: Vec<u64> = drain_order(&mut q).iter().map(|x| x.2).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }

    #[test]
    fn arrival_ties_break_by_id_and_tick_ties_by_replica() {
        let mut q = EventQueue::new();
        q.push(arr(9, 2.0));
        q.push(arr(3, 2.0));
        q.push(Event::tick(2.0, 5));
        q.push(Event::tick(2.0, 1));
        let order = drain_order(&mut q);
        assert_eq!(order, vec![(2.0, 1, 3), (2.0, 1, 9), (2.0, 2, 1), (2.0, 2, 5)]);
    }

    #[test]
    fn past_time_pushes_pop_next() {
        let mut q = EventQueue::new();
        q.push(arr(0, 5.0));
        q.push(arr(1, 9.0));
        assert_eq!(q.pop().unwrap().at, 5.0);
        // A lagging replica's tick entry lands "in the past" relative
        // to the arrival that woke it; it must still pop first.
        q.push(Event::tick(0.5, 0));
        assert_eq!(q.pop().unwrap().at, 0.5);
        assert_eq!(q.pop().unwrap().at, 9.0);
        assert!(q.is_empty());
    }

    /// Property: for any interleaving of pushes, the pop sequence is
    /// sorted by `(at, class, seq)`.  Deterministic splitmix64 stream in
    /// place of a randomness crate (the build is offline/vendored).
    #[test]
    fn pop_order_is_sorted_for_random_interleavings() {
        let mut s: u64 = 0x9E37_79B9_7F4A_7C15;
        let mut next = move || {
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        for round in 0..50 {
            let mut q = EventQueue::new();
            let n = 3 + (next() % 40) as usize;
            for k in 0..n {
                let at = (next() % 16) as f64 * 0.25;
                match next() % 3 {
                    0 => q.push(churn(k as u64, at)),
                    1 => q.push(arr(k, at)),
                    _ => q.push(Event::tick(at, (next() % 8) as usize)),
                }
            }
            let order = drain_order(&mut q);
            let mut sorted = order.clone();
            sorted.sort_by(|a, b| {
                a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
            });
            assert_eq!(order, sorted, "round {round}: pops out of order");
        }
    }
}

//! Open-loop arrival traffic: seeded, deterministic request arrival
//! processes layered on the ShareGPT-like [`TraceGen`] content generator.
//!
//! Arrivals are *open-loop*: the schedule is fixed up front and does not
//! react to server backpressure, so overload actually builds queues (the
//! property closed-loop "send next after previous returns" drivers hide).
//! Three processes cover the classic serving-paper shapes:
//!
//! * [`ArrivalProcess::Poisson`] — memoryless steady traffic at `rate`;
//! * [`ArrivalProcess::Bursty`] — an on/off modulated Poisson process
//!   (rate alternates between a burst rate and a base rate each period),
//!   the diurnal-with-spikes shape;
//! * [`ArrivalProcess::Ramp`] — rate climbs linearly from `start_rate`
//!   to `end_rate` over `ramp_secs`, then holds (load-sweep / flash
//!   crowd onset).
//!
//! Non-homogeneous processes are sampled exactly by Lewis–Shedler
//! thinning: candidate gaps are drawn from a homogeneous process at the
//! peak rate and accepted with probability `rate(t) / peak`, which keeps
//! the draw deterministic under a fixed seed with no numeric integration.

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;
use crate::workload::{Request, TraceGen};

/// One request with its open-loop arrival time (virtual seconds).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Fleet-wide request id (index in the trace).
    pub id: usize,
    pub arrival: f64,
    pub request: Request,
}

/// The arrival process shape (rates in requests / virtual second).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    Poisson { rate: f64 },
    Bursty { base_rate: f64, burst_rate: f64, period: f64, burst_frac: f64 },
    Ramp { start_rate: f64, end_rate: f64, ramp_secs: f64 },
}

impl ArrivalProcess {
    /// Instantaneous rate at virtual time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, period, burst_frac } => {
                let phase = (t / period).fract();
                if phase < burst_frac {
                    burst_rate
                } else {
                    base_rate
                }
            }
            ArrivalProcess::Ramp { start_rate, end_rate, ramp_secs } => {
                if t >= ramp_secs {
                    end_rate
                } else {
                    start_rate + (end_rate - start_rate) * (t / ramp_secs)
                }
            }
        }
    }

    /// The peak rate (thinning envelope).
    fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, .. } => base_rate.max(burst_rate),
            ArrivalProcess::Ramp { start_rate, end_rate, .. } => start_rate.max(end_rate),
        }
    }

    fn validate(&self) -> Result<()> {
        ensure!(self.peak_rate() > 0.0, "arrival process needs a positive rate");
        match *self {
            ArrivalProcess::Poisson { rate } => {
                ensure!(rate > 0.0, "poisson rate must be > 0");
            }
            ArrivalProcess::Bursty { base_rate, burst_rate, period, burst_frac } => {
                ensure!(base_rate >= 0.0 && burst_rate >= 0.0, "bursty rates must be >= 0");
                ensure!(period > 0.0, "bursty period must be > 0");
                ensure!(
                    (0.0..=1.0).contains(&burst_frac),
                    "burst_frac must be in [0, 1]"
                );
                // The thinning sampler hangs if the rate is 0 over the
                // whole recurring cycle (accept probability stays 0).
                let mean = burst_frac * burst_rate + (1.0 - burst_frac) * base_rate;
                ensure!(mean > 0.0, "bursty process has zero average rate");
            }
            ArrivalProcess::Ramp { start_rate, end_rate, ramp_secs } => {
                ensure!(start_rate >= 0.0, "ramp rates must be >= 0");
                ensure!(ramp_secs > 0.0, "ramp_secs must be > 0");
                // rate_at(t) == end_rate forever after the ramp, so a zero
                // end rate would hang the sampler once the ramp completes.
                ensure!(end_rate > 0.0, "ramp end_rate must be > 0");
            }
        }
        Ok(())
    }

    /// CLI shorthand: a process named `poisson` / `bursty` / `ramp`
    /// parameterized by one mean rate (bursty splits it 4:1 around the
    /// mean over a 30 s period; ramp climbs from 0.2x to 2x over 60 s —
    /// both keep the long-run average near `rate`).
    pub fn from_cli(kind: &str, rate: f64) -> Result<ArrivalProcess> {
        ensure!(rate > 0.0, "--rate must be > 0");
        let p = match kind {
            "poisson" => ArrivalProcess::Poisson { rate },
            "bursty" => ArrivalProcess::Bursty {
                base_rate: rate * 0.25,
                burst_rate: rate * 4.0,
                period: 30.0,
                burst_frac: 0.2,
            },
            "ramp" => ArrivalProcess::Ramp {
                start_rate: rate * 0.2,
                end_rate: rate * 2.0,
                ramp_secs: 60.0,
            },
            _ => bail!("unknown arrival process {kind:?}; try poisson, bursty, ramp"),
        };
        p.validate()?;
        Ok(p)
    }
}

/// Seeded arrival-time generator (thinning sampler).
pub struct ArrivalGen {
    rng: Rng,
    process: ArrivalProcess,
    t: f64,
}

impl ArrivalGen {
    pub fn new(seed: u64, process: ArrivalProcess) -> Result<ArrivalGen> {
        process.validate()?;
        Ok(ArrivalGen { rng: Rng::new(seed), process, t: 0.0 })
    }

    /// Next arrival time (strictly increasing).
    pub fn next_arrival(&mut self) -> f64 {
        let peak = self.process.peak_rate();
        loop {
            self.t += self.rng.exponential(peak);
            let accept = self.process.rate_at(self.t) / peak;
            if self.rng.f64() < accept {
                return self.t;
            }
        }
    }

    /// A full deterministic trace: `n` arrivals paired with `TraceGen`
    /// content.  Arrival times and request content come from independent
    /// seeded streams, so changing the process never perturbs the
    /// prompts (and vice versa).
    pub fn generate(
        seed: u64,
        process: ArrivalProcess,
        content: &mut TraceGen,
        n: usize,
    ) -> Result<Vec<TimedRequest>> {
        let mut gen = ArrivalGen::new(seed, process)?;
        Ok((0..n)
            .map(|id| TimedRequest {
                id,
                arrival: gen.next_arrival(),
                request: content.next_request(),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrivals(seed: u64, p: ArrivalProcess, n: usize) -> Vec<f64> {
        let mut g = ArrivalGen::new(seed, p).unwrap();
        (0..n).map(|_| g.next_arrival()).collect()
    }

    #[test]
    fn poisson_is_deterministic_and_increasing() {
        let p = ArrivalProcess::Poisson { rate: 2.0 };
        let a = arrivals(9, p, 200);
        let b = arrivals(9, p, 200);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[1] > w[0], "non-increasing arrivals");
        }
        let c = arrivals(10, p, 200);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let rate = 4.0;
        let a = arrivals(3, ArrivalProcess::Poisson { rate }, 2000);
        let measured = a.len() as f64 / a.last().unwrap();
        assert!(
            (measured - rate).abs() / rate < 0.1,
            "poisson rate {measured} vs {rate}"
        );
    }

    #[test]
    fn bursty_clusters_in_the_on_window() {
        let p = ArrivalProcess::Bursty {
            base_rate: 0.2,
            burst_rate: 8.0,
            period: 10.0,
            burst_frac: 0.2,
        };
        let a = arrivals(7, p, 1000);
        let in_burst = a
            .iter()
            .filter(|&&t| (t / 10.0).fract() < 0.2)
            .count() as f64;
        // expected share: 8.0*0.2 / (8.0*0.2 + 0.2*0.8) ~ 0.91
        assert!(in_burst / a.len() as f64 > 0.7, "bursts not bursty");
    }

    #[test]
    fn ramp_rate_grows() {
        let p = ArrivalProcess::Ramp { start_rate: 0.5, end_rate: 5.0, ramp_secs: 100.0 };
        assert!(p.rate_at(0.0) < p.rate_at(50.0));
        assert!(p.rate_at(50.0) < p.rate_at(100.0));
        assert_eq!(p.rate_at(100.0), p.rate_at(500.0));
        let a = arrivals(5, p, 800);
        // gaps shrink as the rate climbs: compare first vs last quartile
        let q = a.len() / 4;
        let head = a[q] - a[0];
        let tail = a[a.len() - 1] - a[a.len() - 1 - q];
        assert!(tail < head, "ramp did not accelerate: head {head} tail {tail}");
    }

    #[test]
    fn content_and_timing_streams_are_independent() {
        let mut tg1 = TraceGen::new(11, 80, 16);
        let mut tg2 = TraceGen::new(11, 80, 16);
        let t1 = ArrivalGen::generate(1, ArrivalProcess::Poisson { rate: 1.0 }, &mut tg1, 20)
            .unwrap();
        let t2 = ArrivalGen::generate(2, ArrivalProcess::Poisson { rate: 1.0 }, &mut tg2, 20)
            .unwrap();
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.request.prompt, b.request.prompt, "content must not depend on timing seed");
        }
        assert_ne!(
            t1.iter().map(|r| r.arrival).collect::<Vec<_>>(),
            t2.iter().map(|r| r.arrival).collect::<Vec<_>>(),
        );
        assert!(ArrivalProcess::from_cli("nope", 1.0).is_err());
    }

    #[test]
    fn degenerate_zero_rate_processes_are_rejected() {
        // would hang the thinning sampler: rate 0 over the whole cycle
        let off_only = ArrivalProcess::Bursty {
            base_rate: 0.0,
            burst_rate: 1.0,
            period: 10.0,
            burst_frac: 0.0,
        };
        assert!(ArrivalGen::new(1, off_only).is_err());
        let burst_only_zero = ArrivalProcess::Bursty {
            base_rate: 1.0,
            burst_rate: 0.0,
            period: 10.0,
            burst_frac: 1.0,
        };
        assert!(ArrivalGen::new(1, burst_only_zero).is_err());
        // rate 0 forever after the ramp completes
        let dies_out = ArrivalProcess::Ramp { start_rate: 1.0, end_rate: 0.0, ramp_secs: 5.0 };
        assert!(ArrivalGen::new(1, dies_out).is_err());
    }
}

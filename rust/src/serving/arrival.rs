//! Open-loop arrival traffic: seeded, deterministic request arrival
//! processes layered on the ShareGPT-like [`TraceGen`] content generator.
//!
//! Arrivals are *open-loop*: the schedule is fixed up front and does not
//! react to server backpressure, so overload actually builds queues (the
//! property closed-loop "send next after previous returns" drivers hide).
//! Three processes cover the classic serving-paper shapes:
//!
//! * [`ArrivalProcess::Poisson`] — memoryless steady traffic at `rate`;
//! * [`ArrivalProcess::Bursty`] — an on/off modulated Poisson process
//!   (rate alternates between a burst rate and a base rate each period),
//!   the diurnal-with-spikes shape;
//! * [`ArrivalProcess::Ramp`] — rate climbs linearly from `start_rate`
//!   to `end_rate` over `ramp_secs`, then holds (load-sweep / flash
//!   crowd onset).
//!
//! A process may additionally be modulated by an [`Envelope`] — a
//! deterministic multiplicative rate curve layered on top (a diurnal
//! day-scale sinusoid, or a flash-crowd window that multiplies the rate
//! for a bounded interval).  The scenario library
//! ([`crate::serving::scenario`]) composes per-tenant-class processes
//! with envelopes into full mixed-tenant traces.
//!
//! Non-homogeneous processes are sampled exactly by Lewis–Shedler
//! thinning: candidate gaps are drawn from a homogeneous process at the
//! peak (envelope-inflated) rate and accepted with probability
//! `rate(t) / peak`, which keeps the draw deterministic under a fixed
//! seed with no numeric integration.

use anyhow::{bail, ensure, Result};

use super::metrics::SloTargets;
use crate::util::rng::Rng;
use crate::workload::{Request, TraceGen};

/// Tenant class of a request: which latency contract it is served
/// under and how the class-aware scheduler ranks it.  Adding a class
/// means adding a variant here (plus its [`TenantClass::parse`] name) —
/// every other layer keys off [`TenantClass::priority`] and
/// [`TenantClass::name`], so this enum is the single extension point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TenantClass {
    /// Human-in-the-loop chat: tight TTFT/TPOT targets, may preempt
    /// batch work under class-aware scheduling.
    Interactive,
    /// Bulk offline jobs: relaxed targets, preemptible, must still
    /// complete (no starvation).
    Batch,
}

impl TenantClass {
    pub const ALL: [TenantClass; 2] = [TenantClass::Interactive, TenantClass::Batch];

    pub fn name(self) -> &'static str {
        match self {
            TenantClass::Interactive => "interactive",
            TenantClass::Batch => "batch",
        }
    }

    pub fn parse(s: &str) -> Result<TenantClass> {
        Ok(match s {
            "interactive" | "chat" => TenantClass::Interactive,
            "batch" | "bulk" => TenantClass::Batch,
            _ => bail!("unknown tenant class {s:?}; try interactive, batch"),
        })
    }

    /// Scheduling priority: lower is more urgent.  Class-aware policies
    /// admit (and preempt) by this key before any other ordering.
    pub fn priority(self) -> u8 {
        match self {
            TenantClass::Interactive => 0,
            TenantClass::Batch => 1,
        }
    }
}

/// One request with its open-loop arrival time (virtual seconds).
#[derive(Debug, Clone)]
pub struct TimedRequest {
    /// Fleet-wide request id (index in the trace).
    pub id: usize,
    pub arrival: f64,
    /// Tenant class the request is served under.  Legacy single-class
    /// paths stamp [`TenantClass::Interactive`].
    pub class: TenantClass,
    /// Per-request SLO override; `None` (every legacy path) uses the
    /// fleet-level targets, keeping those paths digest-neutral.
    pub slo: Option<SloTargets>,
    pub request: Request,
}

impl TimedRequest {
    /// A single-class request on the fleet-default SLO — the legacy
    /// shape every pre-scenario call site produced.
    pub fn new(id: usize, arrival: f64, request: Request) -> TimedRequest {
        TimedRequest { id, arrival, class: TenantClass::Interactive, slo: None, request }
    }
}

/// The arrival process shape (rates in requests / virtual second).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    Poisson { rate: f64 },
    Bursty { base_rate: f64, burst_rate: f64, period: f64, burst_frac: f64 },
    Ramp { start_rate: f64, end_rate: f64, ramp_secs: f64 },
}

impl ArrivalProcess {
    /// Instantaneous rate at virtual time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, period, burst_frac } => {
                let phase = (t / period).fract();
                if phase < burst_frac {
                    burst_rate
                } else {
                    base_rate
                }
            }
            ArrivalProcess::Ramp { start_rate, end_rate, ramp_secs } => {
                if t >= ramp_secs {
                    end_rate
                } else {
                    start_rate + (end_rate - start_rate) * (t / ramp_secs)
                }
            }
        }
    }

    /// The peak rate (thinning envelope).
    fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Bursty { base_rate, burst_rate, .. } => base_rate.max(burst_rate),
            ArrivalProcess::Ramp { start_rate, end_rate, .. } => start_rate.max(end_rate),
        }
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.peak_rate() > 0.0, "arrival process needs a positive rate");
        match *self {
            ArrivalProcess::Poisson { rate } => {
                ensure!(rate > 0.0, "poisson rate must be > 0");
            }
            ArrivalProcess::Bursty { base_rate, burst_rate, period, burst_frac } => {
                ensure!(base_rate >= 0.0 && burst_rate >= 0.0, "bursty rates must be >= 0");
                ensure!(period > 0.0, "bursty period must be > 0");
                ensure!(
                    (0.0..=1.0).contains(&burst_frac),
                    "burst_frac must be in [0, 1]"
                );
                // The thinning sampler hangs if the rate is 0 over the
                // whole recurring cycle (accept probability stays 0).
                let mean = burst_frac * burst_rate + (1.0 - burst_frac) * base_rate;
                ensure!(mean > 0.0, "bursty process has zero average rate");
            }
            ArrivalProcess::Ramp { start_rate, end_rate, ramp_secs } => {
                ensure!(start_rate >= 0.0, "ramp rates must be >= 0");
                ensure!(ramp_secs > 0.0, "ramp_secs must be > 0");
                // rate_at(t) == end_rate forever after the ramp, so a zero
                // end rate would hang the sampler once the ramp completes.
                ensure!(end_rate > 0.0, "ramp end_rate must be > 0");
            }
        }
        Ok(())
    }

    /// CLI arrival spec.  Two grammars per process:
    ///
    /// * one-rate shorthands — `poisson`, `bursty`, `ramp` derive their
    ///   parameters from the mean `--rate` (bursty splits it 4:1 around
    ///   the mean over a 30 s period; ramp climbs from 0.2x to 2x over
    ///   60 s — both keep the long-run average near `rate`);
    /// * fully parameterized specs — `bursty:BASE:BURST:PERIOD:FRAC`
    ///   (rates in req/s, period in seconds, burst fraction in [0, 1])
    ///   and `ramp:START:END:SECS`, which ignore `--rate`.
    pub fn from_cli(spec: &str, rate: f64) -> Result<ArrivalProcess> {
        let mut parts = spec.split(':');
        let kind = parts.next().unwrap_or("");
        let params: Vec<f64> = parts
            .map(|p| {
                p.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--arrival {spec:?}: {p:?} is not a number"))
            })
            .collect::<Result<_>>()?;
        if params.is_empty() {
            ensure!(rate > 0.0, "--rate must be > 0");
        }
        let p = match (kind, params.as_slice()) {
            ("poisson", []) => ArrivalProcess::Poisson { rate },
            ("poisson", [r]) => ArrivalProcess::Poisson { rate: *r },
            ("bursty", []) => ArrivalProcess::Bursty {
                base_rate: rate * 0.25,
                burst_rate: rate * 4.0,
                period: 30.0,
                burst_frac: 0.2,
            },
            ("bursty", [base, burst, period, frac]) => ArrivalProcess::Bursty {
                base_rate: *base,
                burst_rate: *burst,
                period: *period,
                burst_frac: *frac,
            },
            ("ramp", []) => ArrivalProcess::Ramp {
                start_rate: rate * 0.2,
                end_rate: rate * 2.0,
                ramp_secs: 60.0,
            },
            ("ramp", [start, end, secs]) => ArrivalProcess::Ramp {
                start_rate: *start,
                end_rate: *end,
                ramp_secs: *secs,
            },
            ("poisson", _) => bail!("--arrival {spec:?}: expected poisson or poisson:RATE"),
            ("bursty", _) => {
                bail!("--arrival {spec:?}: expected bursty or bursty:BASE:BURST:PERIOD:FRAC")
            }
            ("ramp", _) => bail!("--arrival {spec:?}: expected ramp or ramp:START:END:SECS"),
            _ => bail!("unknown arrival process {kind:?}; try poisson, bursty, ramp"),
        };
        p.validate()?;
        Ok(p)
    }
}

/// Deterministic multiplicative rate modulation layered on an
/// [`ArrivalProcess`]: the effective rate at `t` is
/// `process.rate_at(t) * envelope.factor_at(t)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Envelope {
    /// No modulation (factor 1 everywhere) — bit-identical sampling to
    /// the unmodulated process.
    Flat,
    /// Day-scale sinusoid: factor `1 + amplitude * sin(2π t / period_s)`
    /// (starts at mean load, rising).  `amplitude` in [0, 1] keeps the
    /// factor non-negative; the long-run mean factor over whole periods
    /// is 1, so the process mean rate is preserved.
    Diurnal { period_s: f64, amplitude: f64 },
    /// Flash crowd: factor `1 + magnitude` inside
    /// `[at_s, at_s + duration_s)`, 1 elsewhere.
    Flash { at_s: f64, magnitude: f64, duration_s: f64 },
}

impl Envelope {
    /// Multiplicative rate factor at virtual time `t`.
    pub fn factor_at(&self, t: f64) -> f64 {
        match *self {
            Envelope::Flat => 1.0,
            Envelope::Diurnal { period_s, amplitude } => {
                1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period_s).sin()
            }
            Envelope::Flash { at_s, magnitude, duration_s } => {
                if t >= at_s && t < at_s + duration_s {
                    1.0 + magnitude
                } else {
                    1.0
                }
            }
        }
    }

    /// Upper bound of [`Envelope::factor_at`] (thinning envelope).
    pub fn peak_factor(&self) -> f64 {
        match *self {
            Envelope::Flat => 1.0,
            Envelope::Diurnal { amplitude, .. } => 1.0 + amplitude,
            Envelope::Flash { magnitude, .. } => 1.0 + magnitude,
        }
    }

    pub fn validate(&self) -> Result<()> {
        match *self {
            Envelope::Flat => {}
            Envelope::Diurnal { period_s, amplitude } => {
                ensure!(
                    period_s.is_finite() && period_s > 0.0,
                    "diurnal period must be > 0"
                );
                // amplitude > 1 would make the factor negative for part
                // of the cycle; == 1 touches zero only instantaneously,
                // which thinning handles (candidates keep arriving at
                // the peak rate).
                ensure!(
                    (0.0..=1.0).contains(&amplitude),
                    "diurnal amplitude must be in [0, 1]"
                );
            }
            Envelope::Flash { at_s, magnitude, duration_s } => {
                ensure!(at_s.is_finite() && at_s >= 0.0, "flash at must be >= 0");
                ensure!(
                    magnitude.is_finite() && magnitude >= 0.0,
                    "flash magnitude must be >= 0"
                );
                ensure!(
                    duration_s.is_finite() && duration_s > 0.0,
                    "flash duration must be > 0"
                );
            }
        }
        Ok(())
    }
}

/// Seeded arrival-time generator (thinning sampler).
pub struct ArrivalGen {
    rng: Rng,
    process: ArrivalProcess,
    envelope: Envelope,
    t: f64,
}

impl ArrivalGen {
    pub fn new(seed: u64, process: ArrivalProcess) -> Result<ArrivalGen> {
        ArrivalGen::with_envelope(seed, process, Envelope::Flat)
    }

    /// A generator whose process rate is modulated by `envelope`.
    /// [`Envelope::Flat`] multiplies every rate by exactly 1.0, so it is
    /// bit-identical to the unmodulated sampler draw for draw.
    pub fn with_envelope(
        seed: u64,
        process: ArrivalProcess,
        envelope: Envelope,
    ) -> Result<ArrivalGen> {
        process.validate()?;
        envelope.validate()?;
        Ok(ArrivalGen { rng: Rng::new(seed), process, envelope, t: 0.0 })
    }

    /// Next arrival time (strictly increasing).
    pub fn next_arrival(&mut self) -> f64 {
        let peak = self.process.peak_rate() * self.envelope.peak_factor();
        loop {
            self.t += self.rng.exponential(peak);
            let rate = self.process.rate_at(self.t) * self.envelope.factor_at(self.t);
            if self.rng.f64() < rate / peak {
                return self.t;
            }
        }
    }

    /// A full deterministic trace: `n` arrivals paired with `TraceGen`
    /// content, every request stamped [`TenantClass::Interactive`] on
    /// the fleet-default SLO (the legacy single-class shape; the
    /// scenario library builds mixed-class traces on the same streams).
    /// Arrival times and request content come from independent seeded
    /// streams, so changing the process never perturbs the prompts (and
    /// vice versa).
    pub fn generate(
        seed: u64,
        process: ArrivalProcess,
        content: &mut TraceGen,
        n: usize,
    ) -> Result<Vec<TimedRequest>> {
        let mut gen = ArrivalGen::new(seed, process)?;
        Ok((0..n)
            .map(|id| TimedRequest::new(id, gen.next_arrival(), content.next_request()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrivals(seed: u64, p: ArrivalProcess, n: usize) -> Vec<f64> {
        let mut g = ArrivalGen::new(seed, p).unwrap();
        (0..n).map(|_| g.next_arrival()).collect()
    }

    #[test]
    fn poisson_is_deterministic_and_increasing() {
        let p = ArrivalProcess::Poisson { rate: 2.0 };
        let a = arrivals(9, p, 200);
        let b = arrivals(9, p, 200);
        assert_eq!(a, b);
        for w in a.windows(2) {
            assert!(w[1] > w[0], "non-increasing arrivals");
        }
        let c = arrivals(10, p, 200);
        assert_ne!(a, c, "seed must matter");
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let rate = 4.0;
        let a = arrivals(3, ArrivalProcess::Poisson { rate }, 2000);
        let measured = a.len() as f64 / a.last().unwrap();
        assert!(
            (measured - rate).abs() / rate < 0.1,
            "poisson rate {measured} vs {rate}"
        );
    }

    #[test]
    fn bursty_clusters_in_the_on_window() {
        let p = ArrivalProcess::Bursty {
            base_rate: 0.2,
            burst_rate: 8.0,
            period: 10.0,
            burst_frac: 0.2,
        };
        let a = arrivals(7, p, 1000);
        let in_burst = a
            .iter()
            .filter(|&&t| (t / 10.0).fract() < 0.2)
            .count() as f64;
        // expected share: 8.0*0.2 / (8.0*0.2 + 0.2*0.8) ~ 0.91
        assert!(in_burst / a.len() as f64 > 0.7, "bursts not bursty");
    }

    #[test]
    fn ramp_rate_grows() {
        let p = ArrivalProcess::Ramp { start_rate: 0.5, end_rate: 5.0, ramp_secs: 100.0 };
        assert!(p.rate_at(0.0) < p.rate_at(50.0));
        assert!(p.rate_at(50.0) < p.rate_at(100.0));
        assert_eq!(p.rate_at(100.0), p.rate_at(500.0));
        let a = arrivals(5, p, 800);
        // gaps shrink as the rate climbs: compare first vs last quartile
        let q = a.len() / 4;
        let head = a[q] - a[0];
        let tail = a[a.len() - 1] - a[a.len() - 1 - q];
        assert!(tail < head, "ramp did not accelerate: head {head} tail {tail}");
    }

    #[test]
    fn content_and_timing_streams_are_independent() {
        let mut tg1 = TraceGen::new(11, 80, 16);
        let mut tg2 = TraceGen::new(11, 80, 16);
        let t1 = ArrivalGen::generate(1, ArrivalProcess::Poisson { rate: 1.0 }, &mut tg1, 20)
            .unwrap();
        let t2 = ArrivalGen::generate(2, ArrivalProcess::Poisson { rate: 1.0 }, &mut tg2, 20)
            .unwrap();
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.request.prompt, b.request.prompt, "content must not depend on timing seed");
        }
        assert_ne!(
            t1.iter().map(|r| r.arrival).collect::<Vec<_>>(),
            t2.iter().map(|r| r.arrival).collect::<Vec<_>>(),
        );
        assert!(ArrivalProcess::from_cli("nope", 1.0).is_err());
    }

    #[test]
    fn legacy_trace_is_single_class_fleet_slo() {
        let mut tg = TraceGen::new(11, 80, 16);
        let t = ArrivalGen::generate(1, ArrivalProcess::Poisson { rate: 1.0 }, &mut tg, 8)
            .unwrap();
        for r in &t {
            assert_eq!(r.class, TenantClass::Interactive);
            assert!(r.slo.is_none(), "legacy trace must use the fleet SLO");
        }
        assert_eq!(TenantClass::parse("batch").unwrap(), TenantClass::Batch);
        assert_eq!(TenantClass::parse("chat").unwrap(), TenantClass::Interactive);
        assert!(TenantClass::parse("gold").is_err());
        assert!(TenantClass::Interactive.priority() < TenantClass::Batch.priority());
    }

    #[test]
    fn degenerate_zero_rate_processes_are_rejected() {
        // would hang the thinning sampler: rate 0 over the whole cycle
        let off_only = ArrivalProcess::Bursty {
            base_rate: 0.0,
            burst_rate: 1.0,
            period: 10.0,
            burst_frac: 0.0,
        };
        assert!(ArrivalGen::new(1, off_only).is_err());
        let burst_only_zero = ArrivalProcess::Bursty {
            base_rate: 1.0,
            burst_rate: 0.0,
            period: 10.0,
            burst_frac: 1.0,
        };
        assert!(ArrivalGen::new(1, burst_only_zero).is_err());
        // rate 0 forever after the ramp completes
        let dies_out = ArrivalProcess::Ramp { start_rate: 1.0, end_rate: 0.0, ramp_secs: 5.0 };
        assert!(ArrivalGen::new(1, dies_out).is_err());
    }

    #[test]
    fn from_cli_parameterized_specs() {
        let p = ArrivalProcess::from_cli("bursty:0.5:4:20:0.25", 9.9).unwrap();
        assert_eq!(
            p,
            ArrivalProcess::Bursty {
                base_rate: 0.5,
                burst_rate: 4.0,
                period: 20.0,
                burst_frac: 0.25
            }
        );
        let p = ArrivalProcess::from_cli("ramp:0.1:2:45", 9.9).unwrap();
        assert_eq!(
            p,
            ArrivalProcess::Ramp { start_rate: 0.1, end_rate: 2.0, ramp_secs: 45.0 }
        );
        let p = ArrivalProcess::from_cli("poisson:3", 9.9).unwrap();
        assert_eq!(p, ArrivalProcess::Poisson { rate: 3.0 });
        // shorthands keep deriving from --rate
        assert_eq!(
            ArrivalProcess::from_cli("poisson", 2.0).unwrap(),
            ArrivalProcess::Poisson { rate: 2.0 }
        );
        for bad in [
            "bursty:1:2:30",      // wrong arity
            "bursty:1:2:30:0.2:9",
            "ramp:1:2",
            "ramp:1:2:x",
            "poisson:0",          // validated
            "bursty:0:0:30:0.2",  // zero mean rate
            "ramp:1:0:30",
            "nope:1",
        ] {
            assert!(ArrivalProcess::from_cli(bad, 1.0).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn flat_envelope_is_bit_identical() {
        let p = ArrivalProcess::Bursty {
            base_rate: 0.5,
            burst_rate: 4.0,
            period: 10.0,
            burst_frac: 0.3,
        };
        let mut plain = ArrivalGen::new(42, p).unwrap();
        let mut flat = ArrivalGen::with_envelope(42, p, Envelope::Flat).unwrap();
        for _ in 0..500 {
            assert_eq!(plain.next_arrival().to_bits(), flat.next_arrival().to_bits());
        }
    }

    #[test]
    fn envelopes_modulate_and_validate() {
        let diurnal = Envelope::Diurnal { period_s: 100.0, amplitude: 0.8 };
        assert!((diurnal.factor_at(0.0) - 1.0).abs() < 1e-12);
        assert!((diurnal.factor_at(25.0) - 1.8).abs() < 1e-12);
        assert!((diurnal.factor_at(75.0) - 0.2).abs() < 1e-12);
        assert_eq!(diurnal.peak_factor(), 1.8);
        let flash = Envelope::Flash { at_s: 10.0, magnitude: 3.0, duration_s: 5.0 };
        assert_eq!(flash.factor_at(9.9), 1.0);
        assert_eq!(flash.factor_at(10.0), 4.0);
        assert_eq!(flash.factor_at(14.9), 4.0);
        assert_eq!(flash.factor_at(15.0), 1.0);
        for bad in [
            Envelope::Diurnal { period_s: 0.0, amplitude: 0.5 },
            Envelope::Diurnal { period_s: 10.0, amplitude: 1.5 },
            Envelope::Diurnal { period_s: 10.0, amplitude: -0.1 },
            Envelope::Flash { at_s: -1.0, magnitude: 1.0, duration_s: 5.0 },
            Envelope::Flash { at_s: 0.0, magnitude: -1.0, duration_s: 5.0 },
            Envelope::Flash { at_s: 0.0, magnitude: 1.0, duration_s: 0.0 },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} accepted");
        }
        // a flash envelope concentrates arrivals inside its window
        let p = ArrivalProcess::Poisson { rate: 0.5 };
        let mut g = ArrivalGen::with_envelope(
            3,
            p,
            Envelope::Flash { at_s: 20.0, magnitude: 9.0, duration_s: 20.0 },
        )
        .unwrap();
        let a: Vec<f64> = (0..400).map(|_| g.next_arrival()).collect();
        for w in a.windows(2) {
            assert!(w[1] > w[0], "non-monotone arrivals under envelope");
        }
        let in_window = a.iter().filter(|&&t| (20.0..40.0).contains(&t)).count();
        let before = a.iter().filter(|&&t| t < 20.0).count();
        assert!(
            in_window > before * 3,
            "flash window not crowded: {in_window} in vs {before} before"
        );
    }
}

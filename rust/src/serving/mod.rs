//! Multi-session serving: open-loop arrival traffic, an admission queue
//! with a continuous session scheduler, and fleet-level SLO metrics.
//!
//! The seed engine served requests back-to-back (batch size 1); this
//! layer turns it into a *server*.  Requests arrive on an open-loop
//! schedule ([`arrival`]), wait in an admission queue, and once admitted
//! become in-flight sessions whose prefill and decode steps a
//! [`policy::SchedPolicy`] interleaves on the shared engine — one
//! device, one mixed-precision expert cache, one PCIe channel, many
//! sessions contending for all three.  Cross-session dynamics the
//! single-stream path could never show fall out naturally: one session's
//! demand fetches and prefetches warm (or thrash) the expert cache for
//! everyone else, and queue delay becomes part of user-visible TTFT.
//!
//! Decode steps batch across sessions: each virtual tick the scheduler
//! asks the policy for a decode batch of up to
//! [`crate::config::ServingConfig::max_decode_batch`] ready sessions and
//! runs them through [`Engine::decode_batch`] as one fused step — the
//! union of routed experts is materialized once per layer, so concurrent
//! sessions that route to the same expert share its fetch instead of
//! each paying it (`max_decode_batch = 1` is the serial interleaved
//! path, step-for-step).  [`metrics::DedupStats`] reports the resulting
//! expert-reuse / dedup savings per run.
//!
//! # Chunked prefill (token-budget continuous batching)
//!
//! With [`crate::config::ServingConfig::chunk_tokens`] `> 0` the loop
//! switches to **chunked prefill with mixed prefill/decode ticks**:
//! admission only allocates a session slot (no engine work), and every
//! virtual tick the policy plans a token budget
//! ([`policy::SchedPolicy::mixed_tick`]) of up to `chunk_tokens` prompt
//! tokens for *one* prefilling session plus up to `max_decode_batch`
//! decode tokens, executed by [`Engine::mixed_step`] as a single fused
//! per-layer pass (shared expert unions, cross-phase aggregated gate
//! mass, one batched roofline).  A long prompt therefore stalls
//! concurrent decoders for at most one chunk's service time instead of
//! its whole prefill — the head-of-line-blocking fix the regression
//! suite in `tests/integration_chunked_prefill.rs` pins down
//! (strictly lower p99 TPOT and bounded per-request `max_stall`).
//!
//! **Equivalence guarantees:** `chunk_tokens = 0` dispatches to the
//! untouched monolithic loop, reproducing the pre-chunking fleet path
//! *tick for tick*; chunked prefill reproduces
//! [`Engine::prefill_session`]'s numerics for any chunk size under
//! precision-invariant strategies (DyMoE's dynamic quantization plans
//! each chunk's importance over that chunk's tokens — chunk-local by
//! design); and a tick with no prefill chunk is exactly the classic
//! batched decode step.  [`metrics::PhaseStats`] reports chunk counts,
//! mean chunk size, and mixed-tick counts per run.
//!
//! Everything runs on the engine's virtual timeline, so a fleet run is
//! deterministic under a fixed seed and directly comparable across
//! scheduling policies ([`policy::PolicyKind`]).  [`metrics`] aggregates
//! per-session TTFT/TPOT (arrival-relative), queue delay with the
//! TTFT breakdown (queue vs prefill service), per-request worst
//! inter-token stall, goodput, and SLO attainment.  The `serve-fleet`
//! CLI subcommand and `benches/bench_serving.rs` drive this module.

pub mod arrival;
pub mod metrics;
pub mod policy;

use anyhow::{bail, ensure, Context, Result};

use crate::config::ServingConfig;
use crate::coordinator::engine::{Engine, EngineSession};
use crate::workload::Request;

use self::arrival::TimedRequest;
use self::metrics::{CompletedRequest, DedupStats, FleetMetrics, PhaseStats, SloTargets};
use self::policy::{Action, ActiveInfo, PolicyKind, QueuedInfo, SchedView};

/// Configuration of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub serving: ServingConfig,
    pub policy: PolicyKind,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { serving: ServingConfig::default(), policy: PolicyKind::SloAware }
    }
}

impl FleetConfig {
    fn slo(&self) -> SloTargets {
        SloTargets { ttft_s: self.serving.ttft_slo_s, tpot_s: self.serving.tpot_slo_s }
    }
}

/// Result of one fleet run.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub metrics: FleetMetrics,
    /// Completed requests in completion order.
    pub per_request: Vec<CompletedRequest>,
    /// High-water mark of concurrently in-flight sessions.
    pub peak_concurrency: usize,
    /// High-water mark of KV-cache bytes held by in-flight sessions
    /// (memory pressure of concurrency).
    pub peak_kv_bytes: u64,
    /// Total scheduler steps taken (prefills + decode steps; a fused
    /// mixed tick counts once however many sessions it advances).
    pub steps: usize,
    /// Cross-session decode-batch dedup telemetry for this run.
    pub dedup: DedupStats,
    /// Chunked-prefill telemetry (all zero on the monolithic path).
    pub phase: PhaseStats,
}

struct Queued {
    id: usize,
    arrival: f64,
    deadline: f64,
    request: Request,
}

struct Active {
    id: usize,
    arrival: f64,
    sess: EngineSession,
    last_token_at: f64,
}

/// Serve an open-loop trace on `engine` to completion.
///
/// The loop is a virtual-time co-simulation: each iteration admits every
/// request that has arrived by the engine clock, asks the policy for the
/// next step, and executes it on the engine — which advances the clock.
/// When the system goes idle it fast-forwards to the next arrival.  With
/// one session in flight this reduces exactly to the classic
/// back-to-back `serve` path.
///
/// `chunk_tokens == 0` (the default) dispatches to the monolithic loop
/// — admission runs the whole prefill as one step — and is tick-for-tick
/// identical to the pre-chunking scheduler; a positive budget runs
/// token-budget continuous batching over [`Engine::mixed_step`].
pub fn run_fleet(
    engine: &mut Engine,
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<FleetOutcome> {
    if cfg.serving.chunk_tokens == 0 {
        run_fleet_monolithic(engine, trace, cfg)
    } else {
        run_fleet_chunked(engine, trace, cfg)
    }
}

/// The pre-chunking fleet loop: admission runs the session's whole
/// prefill as one scheduling step (`Action::Admit`), decode steps batch
/// across sessions.  Kept verbatim so `--chunk-tokens 0` reproduces the
/// legacy path step for step.
fn run_fleet_monolithic(
    engine: &mut Engine,
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<FleetOutcome> {
    let slo = cfg.slo();
    let max_sessions = cfg.serving.max_sessions.max(1);
    let mut pending: std::collections::VecDeque<TimedRequest> = {
        let mut t = trace;
        t.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        t.into()
    };
    let mut queued: Vec<Queued> = Vec::new();
    let mut active: Vec<Active> = Vec::new();
    let enqueue = |r: TimedRequest| Queued {
        id: r.id,
        arrival: r.arrival,
        deadline: r.arrival + slo.ttft_s,
        request: r.request,
    };
    // Clamp the batch width to the model's largest expert token bucket:
    // the engine cannot fuse more decode tokens than one expert call can
    // carry, and `--sessions` above that limit should still serve (the
    // surplus sessions just decode in the next tick's batch).
    let max_decode_batch = cfg.serving.max_decode_batch.clamp(1, engine.model().max_seq);
    let stats_before = engine.stats;
    let mut policy = cfg.policy.build();
    let mut out = FleetOutcome {
        metrics: FleetMetrics::default(),
        per_request: Vec::new(),
        peak_concurrency: 0,
        peak_kv_bytes: 0,
        steps: 0,
        dedup: DedupStats::default(),
        phase: PhaseStats::default(),
    };

    loop {
        let now = engine.clock();
        // Open-loop admission: everything that has arrived joins the queue.
        while pending.front().is_some_and(|r| r.arrival <= now) {
            queued.push(enqueue(pending.pop_front().unwrap()));
        }
        if queued.is_empty() && active.is_empty() {
            // Idle: fast-forward to the next arrival (or finish).
            match pending.pop_front() {
                Some(r) => {
                    queued.push(enqueue(r));
                    continue;
                }
                None => break,
            }
        }

        let queued_info: Vec<QueuedInfo> = queued
            .iter()
            .map(|q| QueuedInfo { id: q.id, arrival: q.arrival, deadline: q.deadline })
            .collect();
        let active_info: Vec<ActiveInfo> = active
            .iter()
            .map(|a| ActiveInfo {
                id: a.id,
                arrival: a.arrival,
                emitted: a.sess.emitted(),
                target: a.sess.target_tokens(),
                last_token_at: a.last_token_at,
                prefill_remaining: a.sess.prefill_remaining(),
            })
            .collect();
        let free_slots = max_sessions.saturating_sub(active.len());
        let view = SchedView {
            now,
            queued: &queued_info,
            active: &active_info,
            free_slots,
        };
        let mut action = policy.next_action(&view);
        if action == Action::Idle {
            // Work-conserving fallback so a policy bug can never wedge
            // the loop: admit if possible, else decode something.
            action = if free_slots > 0 && !queued.is_empty() {
                Action::Admit(queued[0].id)
            } else if let Some(a) = active.first() {
                Action::Decode(a.id)
            } else {
                // queue non-empty but no slots and nothing active cannot
                // happen (max_sessions >= 1); guard anyway
                bail!("scheduler idle with {} queued sessions", queued.len());
            };
        }

        match action {
            Action::Admit(id) => {
                let Some(pos) = queued.iter().position(|q| q.id == id) else {
                    bail!("policy admitted unknown session {id}");
                };
                if active.len() >= max_sessions {
                    bail!("policy admitted session {id} with no free slot");
                }
                let q = queued.swap_remove(pos);
                let mut sess = engine
                    .begin_session(&q.request.prompt, q.request.max_new, None, q.arrival)
                    .with_context(|| format!("admitting session {id}"))?;
                engine
                    .prefill_session(&mut sess)
                    .with_context(|| format!("prefill session {id}"))?;
                out.steps += 1;
                out.peak_concurrency = out.peak_concurrency.max(active.len() + 1);
                let kv_in_flight: u64 =
                    active.iter().map(|a| a.sess.kv_bytes()).sum::<u64>() + sess.kv_bytes();
                out.peak_kv_bytes = out.peak_kv_bytes.max(kv_in_flight);
                let last_token_at = sess.out.start + sess.out.ttft;
                if sess.done() {
                    let done = out.metrics.record(q.id, q.arrival, &sess.out, slo);
                    out.per_request.push(done);
                } else {
                    active.push(Active { id: q.id, arrival: q.arrival, sess, last_token_at });
                }
            }
            Action::Decode(id) => {
                // Batch formation: the policy extends its pick into a
                // decode batch of ready sessions (knob: max_decode_batch;
                // 1 keeps the serial interleaved path, step for step).
                let batch_ids = if max_decode_batch > 1 && active.len() > 1 {
                    policy.decode_batch(&view, id, max_decode_batch)
                } else {
                    vec![id]
                };
                if batch_ids.len() <= 1 {
                    let lone = batch_ids.first().copied().unwrap_or(id);
                    let Some(pos) = active.iter().position(|a| a.id == lone) else {
                        bail!("policy decoded unknown session {lone}");
                    };
                    let a = &mut active[pos];
                    let done = engine
                        .decode_session(&mut a.sess)
                        .with_context(|| format!("decode session {lone}"))?;
                    out.steps += 1;
                    a.last_token_at = a.sess.out.start
                        + a.sess.out.token_times.last().copied().unwrap_or(0.0);
                    if done {
                        let a = active.swap_remove(pos);
                        let rec = out.metrics.record(a.id, a.arrival, &a.sess.out, slo);
                        out.per_request.push(rec);
                    }
                } else {
                    if !batch_ids.contains(&id) {
                        bail!("policy dropped its own pick {id} from the decode batch");
                    }
                    let mut batch: Vec<Active> = Vec::with_capacity(batch_ids.len());
                    for bid in &batch_ids {
                        let Some(pos) = active.iter().position(|a| a.id == *bid) else {
                            bail!("policy batched unknown or duplicate session {bid}");
                        };
                        batch.push(active.swap_remove(pos));
                    }
                    let dones = {
                        let mut refs: Vec<&mut EngineSession> =
                            batch.iter_mut().map(|a| &mut a.sess).collect();
                        engine
                            .decode_batch(&mut refs)
                            .with_context(|| format!("decode batch {batch_ids:?}"))?
                    };
                    out.steps += 1;
                    for (mut a, done) in batch.into_iter().zip(dones) {
                        a.last_token_at = a.sess.out.start
                            + a.sess.out.token_times.last().copied().unwrap_or(0.0);
                        if done {
                            let rec = out.metrics.record(a.id, a.arrival, &a.sess.out, slo);
                            out.per_request.push(rec);
                        } else {
                            active.push(a);
                        }
                    }
                }
            }
            Action::Idle => unreachable!("idle resolved above"),
        }
    }
    out.dedup = DedupStats::from_delta(&stats_before, &engine.stats);
    out.phase = PhaseStats::from_delta(&stats_before, &engine.stats);
    Ok(out)
}

/// The token-budget continuous loop (`chunk_tokens > 0`): admission
/// only allocates a session slot, and every tick the policy plans a
/// fused mixed step — up to `chunk_tokens` prompt tokens of one
/// prefilling session plus up to `max_decode_batch` decode tokens —
/// executed by [`Engine::mixed_step`] as one per-layer pass.
fn run_fleet_chunked(
    engine: &mut Engine,
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<FleetOutcome> {
    let slo = cfg.slo();
    let max_sessions = cfg.serving.max_sessions.max(1);
    let chunk_tokens = cfg.serving.chunk_tokens;
    let mut pending: std::collections::VecDeque<TimedRequest> = {
        let mut t = trace;
        t.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        t.into()
    };
    let mut queued: Vec<Queued> = Vec::new();
    let mut active: Vec<Active> = Vec::new();
    let enqueue = |r: TimedRequest| Queued {
        id: r.id,
        arrival: r.arrival,
        deadline: r.arrival + slo.ttft_s,
        request: r.request,
    };
    // The engine cannot fuse more tokens per tick than one expert call
    // can carry: the chunk is granted first, decode fills the rest.
    let max_seq = engine.model().max_seq;
    let max_decode_batch = cfg.serving.max_decode_batch.clamp(1, max_seq);
    let stats_before = engine.stats;
    let mut policy = cfg.policy.build();
    let mut out = FleetOutcome {
        metrics: FleetMetrics::default(),
        per_request: Vec::new(),
        peak_concurrency: 0,
        peak_kv_bytes: 0,
        steps: 0,
        dedup: DedupStats::default(),
        phase: PhaseStats::default(),
    };

    loop {
        let now = engine.clock();
        while pending.front().is_some_and(|r| r.arrival <= now) {
            queued.push(enqueue(pending.pop_front().unwrap()));
        }
        if queued.is_empty() && active.is_empty() {
            match pending.pop_front() {
                Some(r) => {
                    queued.push(enqueue(r));
                    continue;
                }
                None => break,
            }
        }

        let view_of = |queued: &[Queued], active: &[Active]| {
            let queued_info: Vec<QueuedInfo> = queued
                .iter()
                .map(|q| QueuedInfo { id: q.id, arrival: q.arrival, deadline: q.deadline })
                .collect();
            let active_info: Vec<ActiveInfo> = active
                .iter()
                .map(|a| ActiveInfo {
                    id: a.id,
                    arrival: a.arrival,
                    emitted: a.sess.emitted(),
                    target: a.sess.target_tokens(),
                    last_token_at: a.last_token_at,
                    prefill_remaining: a.sess.prefill_remaining(),
                })
                .collect();
            (queued_info, active_info)
        };

        // Admission allocates slots only (prefill happens chunk by
        // chunk), so free slots fill every tick in policy order.
        while active.len() < max_sessions && !queued.is_empty() {
            let (queued_info, active_info) = view_of(&queued, &active);
            let free_slots = max_sessions - active.len();
            let view = SchedView { now, queued: &queued_info, active: &active_info, free_slots };
            let Some(id) = policy.admit_pick(&view) else { break };
            let Some(pos) = queued.iter().position(|q| q.id == id) else {
                bail!("policy admitted unknown session {id}");
            };
            let q = queued.swap_remove(pos);
            let sess = engine
                .begin_session(&q.request.prompt, q.request.max_new, None, q.arrival)
                .with_context(|| format!("admitting session {id}"))?;
            active.push(Active { id: q.id, arrival: q.arrival, sess, last_token_at: q.arrival });
            out.peak_concurrency = out.peak_concurrency.max(active.len());
            let kv_in_flight: u64 = active.iter().map(|a| a.sess.kv_bytes()).sum();
            out.peak_kv_bytes = out.peak_kv_bytes.max(kv_in_flight);
        }
        if active.is_empty() {
            // queue non-empty but zero slots cannot happen (max_sessions
            // >= 1 and the admit loop always places someone); guard.
            bail!("chunked scheduler wedged with {} queued sessions", queued.len());
        }

        // Token-budget tick plan: one prefill chunk + a decode batch.
        let (queued_info, active_info) = view_of(&queued, &active);
        let free_slots = max_sessions - active.len();
        let view = SchedView { now, queued: &queued_info, active: &active_info, free_slots };
        // Hand the policy the decode budget that will actually fit next
        // to the worst-case chunk grant, so a stateful policy (round-
        // robin's rotation cursor) never advances past sessions a later
        // truncation would drop from the batch.
        let chunk_cap = active_info
            .iter()
            .map(|a| a.prefill_remaining.min(chunk_tokens))
            .max()
            .unwrap_or(0);
        let decode_budget = max_decode_batch.min(max_seq - chunk_cap);
        let mut plan = policy.mixed_tick(&view, decode_budget);
        if plan.is_empty() {
            // Work-conserving fallback so a policy bug can never wedge
            // the loop: chunk the oldest prefilling session, else decode
            // the first ready one.
            let pre = active_info.iter().find(|a| a.prefill_remaining > 0).map(|a| a.id);
            let dec: Vec<usize> = active_info
                .iter()
                .filter(|a| a.decode_ready())
                .take(1)
                .map(|a| a.id)
                .collect();
            ensure!(
                pre.is_some() || !dec.is_empty(),
                "chunked scheduler idle with {} active sessions",
                active.len()
            );
            plan = policy::TickPlan { prefill: pre, decode: dec };
        }

        // Validate the plan and split the borrow: the prefill session
        // and every decode session come out of `active` by value.
        let prefill_pos = match plan.prefill {
            Some(id) => {
                let Some(pos) = active.iter().position(|a| a.id == id) else {
                    bail!("policy chunked unknown session {id}");
                };
                ensure!(
                    active[pos].sess.prefill_remaining() > 0,
                    "policy chunked a prefilled session {id}"
                );
                Some(pos)
            }
            None => None,
        };
        let mut prefill_active = prefill_pos.map(|pos| active.swap_remove(pos));
        ensure!(
            plan.decode.len() <= decode_budget,
            "decode batch {} exceeds the per-tick budget {decode_budget}",
            plan.decode.len()
        );
        // The chunk is granted first; decode fills what the expert token
        // bucket has left.  With the budget handed to the policy above
        // this truncation is a no-op (granted <= chunk_cap), kept as a
        // belt-and-braces bound for misbehaving policies.
        let granted = prefill_active
            .as_ref()
            .map(|a| chunk_tokens.min(a.sess.prefill_remaining()))
            .unwrap_or(0);
        plan.decode.truncate(max_seq - granted);
        let mut batch: Vec<Active> = Vec::with_capacity(plan.decode.len());
        for bid in &plan.decode {
            let Some(pos) = active.iter().position(|a| a.id == *bid) else {
                bail!("policy batched unknown or duplicate session {bid}");
            };
            ensure!(
                active[pos].sess.prefilled() && !active[pos].sess.done(),
                "policy batched session {bid} that is not ready to decode"
            );
            batch.push(active.swap_remove(pos));
        }

        let report = {
            let pre_ref = prefill_active.as_mut().map(|a| (&mut a.sess, chunk_tokens));
            let mut refs: Vec<&mut EngineSession> =
                batch.iter_mut().map(|a| &mut a.sess).collect();
            engine
                .mixed_step(pre_ref, &mut refs)
                .with_context(|| {
                    format!(
                        "mixed tick (chunk session {:?}, decode {:?})",
                        plan.prefill, plan.decode
                    )
                })?
        };
        out.steps += 1;

        if let Some(mut a) = prefill_active {
            if report.prefill_done {
                a.last_token_at =
                    a.sess.out.start + a.sess.out.token_times.last().copied().unwrap_or(0.0);
                if a.sess.done() {
                    let rec = out.metrics.record(a.id, a.arrival, &a.sess.out, slo);
                    out.per_request.push(rec);
                } else {
                    active.push(a);
                }
            } else {
                active.push(a);
            }
        }
        for (mut a, done) in batch.into_iter().zip(report.dones) {
            a.last_token_at =
                a.sess.out.start + a.sess.out.token_times.last().copied().unwrap_or(0.0);
            if done {
                let rec = out.metrics.record(a.id, a.arrival, &a.sess.out, slo);
                out.per_request.push(rec);
            } else {
                active.push(a);
            }
        }
    }
    out.dedup = DedupStats::from_delta(&stats_before, &engine.stats);
    out.phase = PhaseStats::from_delta(&stats_before, &engine.stats);
    Ok(out)
}

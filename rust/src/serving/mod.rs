//! Multi-session serving: open-loop arrival traffic, an admission queue
//! with a continuous session scheduler, fleet-level SLO metrics — and,
//! on top, multi-replica edge **cluster** serving with a dispatcher in
//! front.
//!
//! The seed engine served requests back-to-back (batch size 1); this
//! layer turns it into a *server*.  Requests arrive on an open-loop
//! schedule ([`arrival`]), wait in an admission queue, and once admitted
//! become in-flight sessions whose prefill and decode steps a
//! [`policy::SchedPolicy`] interleaves on the shared engine — one
//! device, one mixed-precision expert cache, one PCIe channel, many
//! sessions contending for all three.  Cross-session dynamics the
//! single-stream path could never show fall out naturally: one session's
//! demand fetches and prefetches warm (or thrash) the expert cache for
//! everyone else, and queue delay becomes part of user-visible TTFT.
//!
//! Decode steps batch across sessions: each virtual tick the scheduler
//! asks the policy for a decode batch of up to
//! [`crate::config::ServingConfig::max_decode_batch`] ready sessions and
//! runs them through [`Engine::decode_batch`] as one fused step — the
//! union of routed experts is materialized once per layer, so concurrent
//! sessions that route to the same expert share its fetch instead of
//! each paying it (`max_decode_batch = 1` is the serial interleaved
//! path, step-for-step).  [`metrics::DedupStats`] reports the resulting
//! expert-reuse / dedup savings per run.
//!
//! # Chunked prefill (token-budget continuous batching)
//!
//! With [`crate::config::ServingConfig::chunk_tokens`] `> 0` the loop
//! switches to **chunked prefill with mixed prefill/decode ticks**:
//! admission only allocates a session slot (no engine work), and every
//! virtual tick the policy plans a token budget
//! ([`policy::SchedPolicy::mixed_tick`]) of up to `chunk_tokens` prompt
//! tokens for *one* prefilling session plus up to `max_decode_batch`
//! decode tokens, executed by [`Engine::mixed_step`] as a single fused
//! per-layer pass (shared expert unions, cross-phase aggregated gate
//! mass, one batched roofline).  A long prompt therefore stalls
//! concurrent decoders for at most one chunk's service time instead of
//! its whole prefill — the head-of-line-blocking fix the regression
//! suite in `tests/integration_chunked_prefill.rs` pins down
//! (strictly lower p99 TPOT and bounded per-request `max_stall`).
//!
//! # Replicas and the cluster (multi-device serving)
//!
//! Everything between admission and completion lives in a
//! [`replica::Replica`]: the engine, the queued/active session sets,
//! the scheduling-policy state, and the per-run telemetry snapshots,
//! behind a `tick` API covering both the monolithic and chunked paths.
//! [`run_fleet`] drives one replica (the classic single-engine entry
//! point, unchanged signature); [`run_cluster`] drives `Vec<Replica>`
//! behind a [`policy::DispatchPolicy`] (`rr` round-robin, `jsq`
//! join-shortest-queue by outstanding tokens, `affinity` hashing the
//! prompt's predicted hot experts onto warm caches) with a true
//! **next-event scheduler**: a binary-heap [`events::EventQueue`] of
//! arrivals, churn events, and per-replica tick-completions (idle
//! replicas cost nothing), with independent inter-boundary replica
//! work optionally advanced on [`std::thread::scope`] workers
//! ([`crate::config::ServingConfig::parallel`], bit-identical to
//! serial).  Per-replica [`metrics::FleetMetrics`] /
//! [`metrics::DedupStats`] / [`metrics::PhaseStats`] merge into a
//! cluster-level outcome with per-replica breakdowns and a
//! load-imbalance statistic; the retired min-clock lockstep loop
//! survives as [`run_cluster_minclock`], the reference the equivalence
//! suites pin the scheduler against.  Replicas may run heterogeneous
//! [`crate::config::HardwareConfig`]s (a big.LITTLE edge cluster).
//!
//! # Replica failure and drain (churn)
//!
//! Edge replicas die and get recalled mid-trace.  A cluster run may
//! carry a schedule of [`crate::config::ChurnEvent`]s (CLI: repeatable
//! `--fail T@R` / `--drain T@R`), fired by [`run_cluster`] in
//! virtual-time order between ticks.  **Drain** cordons a replica — no
//! new dispatches, its admitted work runs down; **fail** kills it — its
//! queued and in-flight sessions are evacuated
//! ([`replica::Replica::evacuate`]) and re-routed by the dispatch
//! policy (offered only the live replicas), restarting from scratch
//! with their *original* arrival times so the SLO cost of churn lands
//! in TTFT and queue delay.  [`metrics::ChurnStats`] reports what the
//! schedule cost (requeued sessions, discarded work tokens, worst
//! per-request retry count); request conservation holds for any
//! schedule that leaves a live replica, and a churn-free run is
//! tick-for-tick the plain cluster (pinned in
//! `tests/integration_churn.rs`).
//!
//! **Equivalence guarantees:** `chunk_tokens = 0` runs the monolithic
//! tick, reproducing the pre-chunking fleet path *tick for tick*; a
//! cluster of one replica with round-robin dispatch reproduces
//! [`run_fleet`] tick for tick (same steps, same metrics) on both the
//! monolithic and chunked paths; chunked prefill reproduces
//! [`Engine::prefill_session`]'s numerics for any chunk size under
//! precision-invariant strategies; and a tick with no prefill chunk is
//! exactly the classic batched decode step.  [`metrics::PhaseStats`]
//! reports chunk counts, mean chunk size, and mixed-tick counts per
//! run.
//!
//! Everything runs on the engines' virtual timelines, so fleet and
//! cluster runs are deterministic under a fixed seed and directly
//! comparable across scheduling policies ([`policy::PolicyKind`]) and
//! dispatch policies ([`policy::DispatchKind`]).  [`metrics`]
//! aggregates per-session TTFT/TPOT (arrival-relative), queue delay
//! with the TTFT breakdown, per-request worst inter-token stall,
//! goodput, SLO attainment, and per-channel resource utilization
//! ([`metrics::ResourceUtil`]).  The `serve-fleet` CLI subcommand and
//! `benches/bench_serving.rs` drive this module.

pub mod arrival;
pub mod cluster;
pub mod events;
pub mod metrics;
pub mod policy;
pub mod replica;
pub mod scenario;

use anyhow::Result;

use crate::config::ServingConfig;
use crate::coordinator::engine::Engine;

use self::arrival::TimedRequest;
use self::metrics::{
    CompletedRequest, DedupStats, FleetMetrics, PhaseStats, ResourceUtil, SloTargets,
};
use self::policy::{DispatchKind, PolicyKind};

pub use self::cluster::{
    run_cluster, run_cluster_minclock, ClusterOutcome, ReplicaBreakdown,
};
pub use self::replica::{Evacuation, Replica, ReplicaRun, ReplicaState};
pub use self::scenario::{ClassLoad, Scenario};

/// Configuration of one fleet (or cluster) run.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub serving: ServingConfig,
    /// Per-replica continuous-scheduling policy.
    pub policy: PolicyKind,
    /// Cluster-level request routing (ignored by single-replica
    /// [`run_fleet`]; `rr` with one replica is the equivalence baseline).
    pub dispatch: DispatchKind,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            serving: ServingConfig::default(),
            policy: PolicyKind::SloAware,
            dispatch: DispatchKind::RoundRobin,
        }
    }
}

impl FleetConfig {
    fn slo(&self) -> SloTargets {
        SloTargets { ttft_s: self.serving.ttft_slo_s, tpot_s: self.serving.tpot_slo_s }
    }
}

/// Result of one fleet run (one replica's view; [`ClusterOutcome`]
/// carries the merged cluster view plus one of these per replica).
#[derive(Debug, Clone, Default)]
pub struct FleetOutcome {
    pub metrics: FleetMetrics,
    /// Completed requests in completion order.
    pub per_request: Vec<CompletedRequest>,
    /// High-water mark of concurrently in-flight sessions (cluster
    /// view: sum of per-replica marks, an upper bound on simultaneous
    /// cluster concurrency).
    pub peak_concurrency: usize,
    /// High-water mark of KV-cache bytes held by in-flight sessions
    /// (memory pressure of concurrency; summed across replicas in the
    /// cluster view).
    pub peak_kv_bytes: u64,
    /// Total scheduler steps taken (prefills + decode steps; a fused
    /// mixed tick counts once however many sessions it advances).
    pub steps: usize,
    /// Cross-session decode-batch dedup telemetry for this run.
    pub dedup: DedupStats,
    /// Chunked-prefill telemetry (all zero on the monolithic path).
    pub phase: PhaseStats,
    /// Per-channel busy fractions over the run's makespan (GPU / CPU /
    /// PCIe / NVMe), computed from busy-time deltas so engine reuse
    /// across runs never double-counts.
    pub utilization: ResourceUtil,
}

/// Serve an open-loop trace on `engine` to completion.
///
/// The loop is a virtual-time co-simulation: each iteration delivers
/// every request that has arrived by the engine clock into the
/// replica's admission queue and advances the replica one scheduling
/// step ([`Replica::tick`]) — which advances the clock.  When the
/// system goes idle it fast-forwards to the next arrival.  With one
/// session in flight this reduces exactly to the classic back-to-back
/// `serve` path.
///
/// `chunk_tokens == 0` (the default) runs the monolithic tick —
/// admission runs the whole prefill as one step — and is tick-for-tick
/// identical to the pre-chunking scheduler; a positive budget runs
/// token-budget continuous batching over [`Engine::mixed_step`].  This
/// is the single-replica degeneration of [`run_cluster`], kept as the
/// direct entry point (same signature as before the cluster refactor).
pub fn run_fleet(
    engine: &mut Engine,
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<FleetOutcome> {
    // Churn needs a dispatcher to re-route evacuated sessions; silently
    // serving a churn schedule churn-free would corrupt an experiment.
    anyhow::ensure!(
        cfg.serving.churn.is_empty(),
        "run_fleet cannot serve a churn schedule ({} event(s)); use run_cluster",
        cfg.serving.churn.len()
    );
    let mut pending: std::collections::VecDeque<TimedRequest> = {
        let mut t = trace;
        t.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        t.into()
    };
    let mut replica = Replica::new(engine, cfg);
    loop {
        let now = replica.clock();
        // Open-loop admission: everything that has arrived joins the queue.
        while pending.front().is_some_and(|r| r.arrival <= now) {
            replica.enqueue(pending.pop_front().unwrap());
        }
        if !replica.has_work() {
            // Idle: fast-forward to the next arrival (or finish).
            match pending.pop_front() {
                Some(r) => {
                    replica.enqueue(r);
                    continue;
                }
                None => break,
            }
        }
        replica.tick()?;
    }
    Ok(replica.finish().outcome)
}

//! Multi-replica edge cluster serving: a dispatcher in front of
//! `Vec<Replica>`, advanced by min-clock next-event stepping (as in
//! event-driven co-simulation).
//!
//! The event loop maintains one invariant: **no replica ticks past an
//! undelivered arrival.**  Each iteration either (a) routes the oldest
//! pending request to a replica via the [`DispatchPolicy`] — whenever
//! its arrival time is at or before the minimum clock among busy
//! replicas (the cluster's virtual "now"), or the whole cluster is idle
//! (the fast-forward case) — or (b) ticks the busy replica with the
//! smallest virtual clock (ties by index).  When a replica is picked to
//! tick, every arrival up to its clock has therefore already been
//! dispatched, which is exactly the admission discipline of the
//! pre-refactor single-engine loop; with one replica the trace of
//! enqueue/tick operations is identical, making `--replicas 1
//! --dispatch rr` tick-for-tick equivalent to [`super::run_fleet`]
//! (pinned in `tests/integration_cluster.rs`).
//!
//! Replicas may be heterogeneous (different [`HardwareConfig`]s — a
//! big.LITTLE edge cluster): each owns its engine, expert cache, and
//! virtual timeline, so a slow replica simply surfaces as a high clock
//! the stepper visits less often.
//!
//! [`HardwareConfig`]: crate::config::HardwareConfig

use std::collections::VecDeque;

use anyhow::{ensure, Context, Result};

use crate::coordinator::engine::Engine;
use crate::memory::BusyTotals;

use super::arrival::TimedRequest;
use super::metrics::{load_imbalance, FleetMetrics, ResourceUtil};
use super::replica::Replica;
use super::{FleetConfig, FleetOutcome};

/// One replica's share of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaBreakdown {
    /// The replica's own fleet outcome (per-replica metrics, dedup and
    /// phase telemetry, utilization over *its* makespan).
    pub outcome: FleetOutcome,
    /// Requests the dispatcher routed here.
    pub dispatched: usize,
    /// Busy-seconds delta this run accrued on the replica's channels.
    pub busy: BusyTotals,
}

/// Result of one cluster run: the merged fleet view plus per-replica
/// breakdowns and the dispatch balance statistic.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    /// Cluster-merged outcome: union of per-request records (completion
    /// order), summed counters, utilization over `replicas x makespan`.
    pub fleet: FleetOutcome,
    /// Per-replica breakdowns, indexed by replica id.
    pub replicas: Vec<ReplicaBreakdown>,
    /// `max / mean` of per-replica emitted-token loads (1.0 = perfectly
    /// balanced, `replicas` = one replica served everything).
    pub load_imbalance: f64,
}

/// Serve an open-loop trace on a cluster of replicas to completion.
///
/// Each engine becomes one [`Replica`] (they may carry different
/// [`crate::config::HardwareConfig`]s); `cfg.dispatch` routes every
/// arriving request to a replica, and replicas advance in virtual-time
/// order.  With a single engine this reduces exactly to
/// [`super::run_fleet`].
pub fn run_cluster(
    engines: &mut [Engine],
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<ClusterOutcome> {
    ensure!(!engines.is_empty(), "cluster needs at least one replica engine");
    let n = engines.len();
    // The engine slice is authoritative for cluster size; an explicitly
    // configured replica count that disagrees with it is a caller bug
    // (the default of 1 means "unset" so single-replica configs can be
    // reused across any cluster).
    ensure!(
        cfg.serving.replicas <= 1 || cfg.serving.replicas == n,
        "config says {} replicas but {n} engines were provided",
        cfg.serving.replicas
    );
    let total_requests = trace.len();
    let mut pending: VecDeque<TimedRequest> = {
        let mut t = trace;
        t.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        t.into()
    };
    let mut replicas: Vec<Replica> =
        engines.iter_mut().map(|e| Replica::new(e, cfg)).collect();
    let mut dispatch = cfg.dispatch.build();
    let mut dispatched = vec![0usize; n];

    loop {
        // The cluster's virtual "now": the smallest clock among replicas
        // that still have work (ties by index).
        let next_tick: Option<usize> = {
            let mut best: Option<(f64, usize)> = None;
            for (i, r) in replicas.iter().enumerate() {
                if !r.has_work() {
                    continue;
                }
                let c = r.clock();
                let better = match best {
                    None => true,
                    Some((bc, _)) => c < bc,
                };
                if better {
                    best = Some((c, i));
                }
            }
            best.map(|(_, i)| i)
        };

        let deliver = match (next_tick, pending.front()) {
            (None, None) => break,
            // Whole cluster idle: fast-forward by dispatching the next
            // future arrival (its service start waits for its arrival
            // time inside the engine, exactly as the single-engine loop
            // fast-forwarded).
            (None, Some(_)) => true,
            // An arrival at or before the cluster's virtual now must be
            // routed before anyone ticks past it.
            (Some(i), Some(r)) => r.arrival <= replicas[i].clock(),
            (Some(_), None) => false,
        };

        if deliver {
            let req = pending.pop_front().unwrap();
            let views: Vec<_> =
                replicas.iter().enumerate().map(|(i, r)| r.dispatch_view(i)).collect();
            let idx = dispatch.route(&req, &views);
            ensure!(
                idx < n,
                "dispatch policy {} routed request {} to replica {idx} of {n}",
                dispatch.name(),
                req.id
            );
            dispatched[idx] += 1;
            replicas[idx].enqueue(req);
        } else {
            let i = next_tick.expect("no tick target with no arrival to deliver");
            replicas[i]
                .tick()
                .with_context(|| format!("replica {i} tick"))?;
        }
    }

    // Fold the per-replica runs into the cluster view.
    let runs: Vec<_> = replicas.into_iter().map(|r| r.finish()).collect();
    let mut metrics = FleetMetrics::default();
    let mut fleet = FleetOutcome::default();
    let mut busy_total = BusyTotals::default();
    let mut breakdowns = Vec::with_capacity(n);
    for (run, count) in runs.into_iter().zip(&dispatched) {
        metrics.merge(&run.outcome.metrics);
        fleet.per_request.extend(run.outcome.per_request.iter().cloned());
        // Cluster-wide concurrency / KV peaks are summed per-replica
        // high-water marks: an upper bound on simultaneous load (the
        // marks need not coincide in virtual time), exact for one
        // replica.
        fleet.peak_concurrency += run.outcome.peak_concurrency;
        fleet.peak_kv_bytes += run.outcome.peak_kv_bytes;
        fleet.steps += run.outcome.steps;
        fleet.dedup.merge(&run.outcome.dedup);
        fleet.phase.merge(&run.outcome.phase);
        busy_total = busy_total.plus(&run.busy);
        breakdowns.push(ReplicaBreakdown {
            outcome: run.outcome,
            dispatched: *count,
            busy: run.busy,
        });
    }
    // Completion order across the cluster: a stable merge by completion
    // time (per-replica records are already completion-ordered).  A
    // single replica's list is returned untouched — not even a stable
    // sort — so the one-replica cluster is bit-identical to `run_fleet`
    // (same-tick completions can differ by a float ulp in
    // `finished_at`, which a sort could otherwise reorder).
    if n > 1 {
        fleet
            .per_request
            .sort_by(|a, b| a.finished_at.total_cmp(&b.finished_at));
    }
    ensure!(
        metrics.completed == total_requests,
        "cluster lost requests: {} of {total_requests} completed",
        metrics.completed
    );
    fleet.utilization = ResourceUtil::from_busy(&busy_total, metrics.makespan(), n);
    fleet.metrics = metrics;
    let loads: Vec<f64> = breakdowns
        .iter()
        .map(|b| b.outcome.metrics.tokens_total as f64)
        .collect();
    Ok(ClusterOutcome {
        fleet,
        replicas: breakdowns,
        load_imbalance: load_imbalance(&loads),
    })
}

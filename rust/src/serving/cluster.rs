//! Multi-replica edge cluster serving: a dispatcher in front of
//! `Vec<Replica>`, advanced by a **next-event scheduler** over a
//! binary-heap [`EventQueue`] of arrivals, churn events, and
//! per-replica tick-completions.
//!
//! The scheduler maintains one invariant: **no replica ticks past an
//! undelivered arrival or an unfired churn event.**  Every piece of
//! cluster work is an event keyed `(virtual time, kind, seq)` — see
//! [`super::events`] for the exact order — and the loop simply pops:
//!
//! * a **churn** event fires a scheduled [`ChurnEvent`] (same-instant
//!   ties: before any arrival, so a failure at exactly an arrival's
//!   time excludes that replica from its dispatch);
//! * an **arrival** routes one request to a live replica via the
//!   [`DispatchPolicy`] (before any replica at that clock ticks past
//!   it); a replica woken from idle gets a tick-completion entry at its
//!   current clock — which may lag the arrival; the engine
//!   fast-forwards service internally, exactly as the single-engine
//!   loop did;
//! * a **tick-completion** advances replicas.  Between two boundary
//!   events (the next churn or arrival) replicas do not interact —
//!   dispatch and evacuation happen only at boundaries — so the
//!   scheduler claims *every* tick entry due before the boundary at
//!   once and advances each owner until its clock reaches the boundary
//!   or it runs dry ([`Replica::advance_until`]).  Per-replica tick
//!   sequences are identical to stepping one event at a time, which is
//!   how the retired min-clock loop behaved.
//!
//! Idle replicas hold no tick entry and cost nothing — the min-clock
//! loop's O(replicas) scan per tick is gone, which is what makes
//! 16–64-replica sweeps tractable.  The retired loop is kept verbatim
//! as [`run_cluster_minclock`]; `tests/integration_cluster.rs` and
//! `tests/integration_churn.rs` pin the two bit-identical across
//! dispatch × sched × chunk × churn, the same way PR 4 pinned
//! `run_fleet`.
//!
//! # Parallel replica execution
//!
//! Because inter-boundary replica work is independent, the advance
//! phase can run on [`std::thread::scope`] workers:
//! [`crate::config::ServingConfig::parallel`] (CLI `--parallel N`)
//! distributes the due replicas over up to `N` threads.  The partition
//! affects wall-clock only — each replica's tick sequence, and
//! therefore every outcome bit, is the same as serial; the determinism
//! suite pins `--parallel 4` bit-identical to serial.  Engines must
//! not share an [`Executor`] when `parallel > 1` (its staged-buffer
//! and compiled-program caches are single-thread confined); the run
//! rejects shared executors loudly.
//!
//! # Replica failure and drain
//!
//! Replicas are commodity edge devices that die or get recalled
//! mid-trace.  A [`ChurnEvent`] schedules that: on **drain** the
//! replica stops receiving dispatches and runs down everything already
//! dispatched to it; on **fail** the replica's queued *and* active
//! (mid-prefill / mid-decode) sessions are extracted via
//! [`Replica::evacuate`] and pushed back into the event queue as
//! arrival events at their **original** arrival times (in the past, so
//! they re-dispatch ahead of later traffic), where the
//! [`DispatchPolicy`] — offered only the still-live replicas —
//! re-routes them.  Restarted sessions keep their original arrival
//! times, so the SLO impact of churn (queue delay, TTFT) is reported
//! honestly — and service is gated at the failure time, so a restart
//! can never begin "before" the failure on a receiving replica whose
//! virtual clock lags the event; the work the dead replica had already
//! done on them is discarded and counted as
//! [`ChurnStats::lost_work_tokens`].  Request conservation (every
//! trace id completes exactly once) holds across any churn schedule
//! that leaves a live replica to serve it; a schedule that fails or
//! drains *every* replica while requests are still outstanding is
//! rejected with an error at the moment a request has nowhere to go.
//!
//! A failed replica also stops accruing **capacity**: cluster
//! utilization divides busy time by the sum of per-replica live
//! intervals (birth → failure, or the whole span for replicas that
//! never failed — draining replicas keep serving admitted work and
//! count in full), and the load-imbalance statistic weighs each
//! replica's token load by its live time, so a cluster whose survivors
//! are balanced after an early failure reads as balanced.  On a
//! churn-free (or failure-free) run both reduce bit-exactly to the
//! classic `replicas × makespan` forms.
//!
//! Replicas may be heterogeneous (different [`HardwareConfig`]s — a
//! big.LITTLE edge cluster): each owns its engine, expert cache, and
//! virtual timeline, so a slow replica simply surfaces as a high clock
//! the event queue visits less often.
//!
//! [`HardwareConfig`]: crate::config::HardwareConfig
//! [`ChurnEvent`]: crate::config::ChurnEvent
//! [`Executor`]: crate::model::executor::Executor
//! [`DispatchPolicy`]: super::policy::DispatchPolicy

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::{Arc, RwLock};

use anyhow::{ensure, Context, Result};

use crate::config::{ChurnEvent, ChurnKind};
use crate::coordinator::engine::Engine;
use crate::coordinator::prefetcher::predict_prefill;
use crate::costmodel::CostModel;
use crate::memory::{BusyTotals, HostExpertPool, HostPoolHandle, PoolStats};
use crate::model::assets::ExpertKey;
use crate::model::executor::Executor;
use crate::quant::Precision;
use crate::trace::TraceCapture;

use super::arrival::TimedRequest;
use super::events::{Event, EventPayload, EventQueue};
use super::metrics::{
    load_imbalance, load_imbalance_weighted, ChurnStats, FleetMetrics, ResourceUtil,
};
use super::policy::{DispatchKind, DispatchPolicy};
use super::replica::{Replica, ReplicaState};
use super::{FleetConfig, FleetOutcome};

/// One replica's share of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaBreakdown {
    /// The replica's own fleet outcome (per-replica metrics, dedup and
    /// phase telemetry, utilization over *its* makespan).
    pub outcome: FleetOutcome,
    /// Requests the dispatcher routed here, re-dispatches after a
    /// failure included (so across the cluster the counts sum to
    /// `trace.len() + churn.requeued`).
    pub dispatched: usize,
    /// Busy-seconds delta this run accrued on the replica's channels.
    pub busy: BusyTotals,
    /// Lifecycle state the replica ended the run in (Live unless a
    /// churn event touched it).
    pub state: ReplicaState,
    /// This run's trace streams (engine events + per-tick counter
    /// samples); empty unless the engine's timeline is recording.
    /// [`crate::trace::chrome::chrome_trace`] renders these as one
    /// Perfetto process per replica.
    pub trace: TraceCapture,
}

/// Result of one cluster run: the merged fleet view plus per-replica
/// breakdowns, the dispatch balance statistic, and churn telemetry.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    /// Cluster-merged outcome: union of per-request records (completion
    /// order), summed counters, utilization over the replicas' summed
    /// live capacity (`replicas x makespan` when none failed).
    pub fleet: FleetOutcome,
    /// Per-replica breakdowns, indexed by replica id.
    pub replicas: Vec<ReplicaBreakdown>,
    /// `max / mean` of per-replica emitted-token loads (1.0 = perfectly
    /// balanced, `replicas` = one replica served everything).  When a
    /// replica failed mid-run, loads are weighted by live time — tokens
    /// per live second — so balanced survivors read as balanced; see
    /// [`load_imbalance_weighted`].
    pub load_imbalance: f64,
    /// What the run's churn schedule cost (all zero on a churn-free
    /// run).
    pub churn: ChurnStats,
    /// Cluster-merged host-pool traffic (per-replica hits / fills /
    /// stall plus shared-side evictions and inserted bytes); all zero
    /// unless `--host-pool` attached a pool.  Deliberately **not**
    /// hashed by [`ClusterOutcome::digest`]: the off-path neutrality
    /// pin compares pool-less runs, and with a pool attached the
    /// timing impact is already visible through every per-request
    /// record.
    pub pool: PoolStats,
}

impl ClusterOutcome {
    /// Order-sensitive FNV-1a digest over the outcome's observable
    /// payload: every per-request record field, the merged counters,
    /// utilization, imbalance, churn stats, and per-replica breakdown
    /// shape.  Digest equality across two runs is the bit-identity
    /// check the parallel-determinism suite and `bench_serving`'s
    /// `event_driven_sweep` record.
    pub fn digest(&self) -> u64 {
        fn mix(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for r in &self.fleet.per_request {
            mix(&mut h, &(r.id as u64).to_le_bytes());
            for v in [r.arrival, r.queue_delay, r.ttft, r.tpot, r.finished_at, r.max_stall] {
                mix(&mut h, &v.to_bits().to_le_bytes());
            }
            mix(&mut h, &(r.tokens as u64).to_le_bytes());
            mix(&mut h, &(r.retries as u64).to_le_bytes());
            mix(&mut h, &(r.preemptions as u64).to_le_bytes());
            mix(&mut h, &[u8::from(r.ttft_ok), u8::from(r.tpot_ok)]);
        }
        for c in [
            self.fleet.metrics.completed,
            self.fleet.metrics.tokens_total,
            self.fleet.steps,
            self.fleet.peak_concurrency,
            self.churn.failed,
            self.churn.drained,
            self.churn.requeued,
            self.churn.max_retries,
        ] {
            mix(&mut h, &(c as u64).to_le_bytes());
        }
        mix(&mut h, &self.fleet.peak_kv_bytes.to_le_bytes());
        mix(&mut h, &self.churn.lost_work_tokens.to_le_bytes());
        for v in [
            self.load_imbalance,
            self.fleet.utilization.gpu,
            self.fleet.utilization.cpu,
            self.fleet.utilization.pcie,
            self.fleet.utilization.nvme,
            self.fleet.metrics.first_arrival,
            self.fleet.metrics.last_completion,
        ] {
            mix(&mut h, &v.to_bits().to_le_bytes());
        }
        for b in &self.replicas {
            mix(&mut h, &(b.dispatched as u64).to_le_bytes());
            let state = match b.state {
                ReplicaState::Live => 0u8,
                ReplicaState::Draining => 1,
                ReplicaState::Dead => 2,
            };
            mix(&mut h, &[state]);
            mix(&mut h, &(b.outcome.per_request.len() as u64).to_le_bytes());
            mix(&mut h, &(b.trace.events.len() as u64).to_le_bytes());
            mix(&mut h, &(b.trace.samples.len() as u64).to_le_bytes());
        }
        h
    }
}

/// Validate a cluster run's inputs and return the churn schedule sorted
/// by virtual time (ties by schedule order — `sort_by` is stable) and
/// the trace sorted by `(arrival, id)`.
fn prepare(
    engines: &[Engine],
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<(Vec<ChurnEvent>, Vec<TimedRequest>)> {
    ensure!(!engines.is_empty(), "cluster needs at least one replica engine");
    let n = engines.len();
    // The engine slice is authoritative for cluster size; an explicitly
    // configured replica count that disagrees with it is a caller bug
    // (the default of 1 means "unset" so single-replica configs can be
    // reused across any cluster).
    ensure!(
        cfg.serving.replicas <= 1 || cfg.serving.replicas == n,
        "config says {} replicas but {n} engines were provided",
        cfg.serving.replicas
    );
    let mut events = cfg.serving.churn.clone();
    for ev in &events {
        ensure!(
            ev.replica < n,
            "churn event {} {}@{} targets a replica outside the cluster of {n}",
            ev.kind.name(),
            ev.at,
            ev.replica
        );
        ensure!(
            ev.at.is_finite() && ev.at >= 0.0,
            "churn event {} at {} must have a finite non-negative time",
            ev.kind.name(),
            ev.at
        );
    }
    events.sort_by(|a, b| a.at.total_cmp(&b.at));
    let mut sorted = trace;
    sorted.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
    Ok((events, sorted))
}

/// Dispatcher-side gate-probe context for `--dispatch predictive`: a
/// clone of one replica's compiled [`Executor`] plus the model / policy
/// facts needed to turn a prompt into the session's predicted expert
/// set before admission.  Mirrors the paper's orchestrator running the
/// cheap layer-0 gate matmul on the dispatch node: the probe executes
/// real numerics on the shared compiled program but charges no virtual
/// time (its cost is negligible next to a prefill and it overlaps
/// queueing).  Probes run only on the scheduling thread at arrival
/// boundaries — scoped workers are always joined there — so holding an
/// `Rc<Executor>` clone never crosses a thread.
struct GateProbe {
    exec: Rc<Executor>,
    cost: CostModel,
    max_seq: usize,
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
    /// Experts to predict per probe (resolved from
    /// `ServingConfig::probe_depth`; 0 meant "model top_k").
    depth: usize,
    /// Precision pre-staged experts are fetched at — the policy's high
    /// tier, matching what a demand fill would bring in.
    prec: Precision,
    /// Pre-staging only makes sense when experts actually stream from
    /// SSD; VRAM-resident configs probe for routing only.
    ssd_resident: bool,
    /// Memoized predictions by request id: a re-dispatch after a
    /// failure reuses the original answer (same prompt, same gate)
    /// instead of re-running the probe.
    predicted: HashMap<usize, Vec<usize>>,
}

impl GateProbe {
    fn new(engine: &Engine, probe_depth: usize) -> GateProbe {
        let m = engine.model();
        let depth = if probe_depth == 0 { m.top_k } else { probe_depth }.min(m.n_experts);
        GateProbe {
            exec: engine.exec.clone(),
            cost: engine.cost.clone(),
            max_seq: m.max_seq,
            n_layers: m.n_layers,
            n_experts: m.n_experts,
            top_k: m.top_k,
            depth,
            prec: engine.sys.policy.high,
            ssd_resident: engine.sys.policy.ssd_resident,
            predicted: HashMap::new(),
        }
    }

    /// Run (or recall) the layer-0 gate on the request's prompt and
    /// return the predicted expert ids, most-frequently-routed first.
    fn predict(&mut self, req: &TimedRequest) -> Result<Vec<usize>> {
        if let Some(p) = self.predicted.get(&req.id) {
            return Ok(p.clone());
        }
        let seq_len = req.request.prompt.len().min(self.max_seq);
        let set = if seq_len == 0 {
            Vec::new()
        } else {
            let mut padded = req.request.prompt.clone();
            padded.resize(self.max_seq, 0);
            let h = self.exec.embed_seq(&padded)?;
            let po = self.exec.attn_prefill(0, &h, seq_len)?;
            predict_prefill(&po.gate_probs, seq_len, self.n_experts, self.top_k, self.depth)
        };
        self.predicted.insert(req.id, set.clone());
        Ok(set)
    }
}

/// Mutable cluster-run state shared by the event-driven scheduler and
/// the retired min-clock reference loop, so the two can only differ in
/// *when* they invoke the same churn / dispatch / fold actions — the
/// equivalence the pinning tests then verify is purely about event
/// order.
struct ClusterSim<'e> {
    replicas: Vec<Replica<'e>>,
    dispatch: Box<dyn DispatchPolicy>,
    dispatched: Vec<usize>,
    churn: ChurnStats,
    /// Per-request re-dispatch counts (patched into the completed
    /// records at the end).
    retries: HashMap<usize, usize>,
    /// Service gates for requeued requests: a restart cannot begin
    /// before the failure that caused it, even on a receiving replica
    /// whose virtual clock lags the event (metrics stay keyed to the
    /// original arrival).  Later failures overwrite with their (later)
    /// event times.
    not_before: HashMap<usize, f64>,
    /// Failure instants, indexed by replica — the end of each failed
    /// replica's live interval for capacity accounting.
    died_at: Vec<Option<f64>>,
    /// The shared host expert tier (`--host-pool`); `None` leaves every
    /// engine exactly on its pool-less code path.
    pool: Option<Arc<RwLock<HostExpertPool>>>,
    /// Gate-probe context; `Some` only under `--dispatch predictive`,
    /// so every other policy keeps its bit-identical dispatch path.
    probe: Option<GateProbe>,
}

impl<'e> ClusterSim<'e> {
    fn new(engines: &'e mut [Engine], cfg: &FleetConfig) -> ClusterSim<'e> {
        let n = engines.len();
        let pool = cfg
            .serving
            .host_pool
            .map(|pc| Arc::new(RwLock::new(HostExpertPool::new(&pc, n))));
        // Per-replica host-link weights (`--replica-hw ...:HOST_GBPS`):
        // fed to the shared pool so its contended-link split follows
        // the cluster's actual link asymmetry.  All-default weights
        // leave the split bitwise-identical to the even lane model.
        if let Some(p) = &pool {
            let weights: Vec<f64> =
                engines.iter().map(|e| e.sys.hardware.host_lane_weight).collect();
            p.write().expect("host pool lock poisoned").set_lane_weights(&weights);
        }
        // The predictive dispatcher probes the layer-0 gate on replica
        // 0's executor (every replica compiles the same model, so any
        // one works); the Rc clone happens before the replicas take
        // their mutable engine borrows.
        let probe = (cfg.dispatch == DispatchKind::Predictive)
            .then(|| GateProbe::new(&engines[0], cfg.serving.probe_depth));
        ClusterSim {
            replicas: engines
                .iter_mut()
                .enumerate()
                .map(|(i, e)| {
                    // Attach this run's pool handle — and defensively
                    // clear any stale one a reused engine might carry,
                    // so pool-less runs stay bitwise-identical.
                    e.host_pool =
                        pool.as_ref().map(|p| HostPoolHandle::new(p.clone(), i));
                    Replica::new(e, cfg)
                })
                .collect(),
            dispatch: cfg.dispatch.build(),
            dispatched: vec![0usize; n],
            churn: ChurnStats::default(),
            retries: HashMap::new(),
            not_before: HashMap::new(),
            died_at: vec![None; n],
            pool,
            probe,
        }
    }

    /// Fire one scheduled churn event.  A failure returns the evacuated
    /// requests (original arrival times, oldest first) for the caller
    /// to merge back into its pending structure.
    fn fire_churn(&mut self, e: ChurnEvent) -> Vec<TimedRequest> {
        match e.kind {
            ChurnKind::Drain => {
                if self.replicas[e.replica].begin_drain() {
                    self.churn.drained += 1;
                    self.replicas[e.replica].mark(e.at, "drain");
                }
                Vec::new()
            }
            ChurnKind::Fail => {
                if self.replicas[e.replica].state() == ReplicaState::Dead {
                    return Vec::new();
                }
                self.replicas[e.replica].mark(e.at, "fail");
                let evac = self.replicas[e.replica].evacuate();
                // The dead replica's staged fills still help survivors:
                // apply its journal, then return its host-link lane so
                // the remaining lanes contend less.  (Draining replicas
                // keep their lane — they still run down their work.)
                self.replicas[e.replica].flush_host_pool();
                if let Some(p) = &self.pool {
                    p.write().expect("host pool lock poisoned").fail_lane(e.replica);
                }
                self.died_at[e.replica] = Some(e.at);
                self.churn.failed += 1;
                self.churn.requeued += evac.requests.len();
                self.churn.lost_work_tokens += evac.lost_tokens;
                for r in &evac.requests {
                    *self.retries.entry(r.id).or_default() += 1;
                    self.not_before.insert(r.id, e.at);
                }
                evac.requests
            }
        }
    }

    /// Route one arrival through the dispatch policy (offered only the
    /// live replicas) and deliver it.  Returns the chosen replica index
    /// and whether it was idle before delivery (an idle replica needs a
    /// fresh tick-completion entry to wake it).
    fn dispatch(&mut self, req: TimedRequest) -> Result<(usize, bool)> {
        // The policy returns a *position* into the liveness-filtered
        // view slice, mapped back to the replica id through `index`.
        let views: Vec<_> = self
            .replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.accepts_dispatch())
            .map(|(i, r)| r.dispatch_view(i))
            .collect();
        ensure!(
            !views.is_empty(),
            "request {} has no live replica to dispatch to: the churn schedule \
             failed/drained the whole cluster with work outstanding",
            req.id
        );
        // Predictive dispatch: probe the layer-0 gate for the session's
        // expected expert set and route on byte-weighted overlap with
        // each replica's resident summary; every other policy routes
        // exactly as before.
        let predicted = match self.probe.as_mut() {
            Some(p) => Some(
                p.predict(&req)
                    .with_context(|| format!("gate probe for request {}", req.id))?,
            ),
            None => None,
        };
        let pos = match &predicted {
            Some(p) => self.dispatch.route_predicted(&req, &views, p),
            None => self.dispatch.route(&req, &views),
        };
        ensure!(
            pos < views.len(),
            "dispatch policy {} routed request {} to position {pos} of {}",
            self.dispatch.name(),
            req.id,
            views.len()
        );
        let idx = views[pos].index;
        // Look-ahead pre-staging: pull the predicted experts for every
        // layer into the shared pool ahead of the session's demand
        // misses, credited to the chosen replica's recency shard.
        // Arrivals are single-threaded boundary events with all window
        // journals flushed, so this direct write is deterministic under
        // `--parallel`; each transfer is modelled as one background
        // NVMe fetch finishing at `ready_at` and is charged to the
        // `prestaged` counters, never to demand `ssd_fills`.
        if let (Some(predicted), Some(probe), Some(pool)) =
            (&predicted, &self.probe, &self.pool)
        {
            if probe.ssd_resident && !predicted.is_empty() {
                let bytes = probe.cost.expert_weight_bytes(probe.prec) as u64;
                let ready = req.arrival + probe.cost.nvme_transfer(bytes as f64);
                let mut g = pool.write().expect("host pool lock poisoned");
                for layer in 0..probe.n_layers {
                    for &e in predicted {
                        g.prestage(
                            idx,
                            ExpertKey::new(layer, e),
                            probe.prec,
                            bytes,
                            ready,
                            req.arrival,
                        );
                    }
                }
            }
        }
        self.dispatched[idx] += 1;
        let was_idle = !self.replicas[idx].has_work();
        match self.not_before.get(&req.id).copied() {
            Some(gate) => self.replicas[idx].enqueue_not_before(req, gate),
            None => self.replicas[idx].enqueue(req),
        }
        Ok((idx, was_idle))
    }

    /// Is a popped tick-completion entry still current?  Stale entries
    /// (lazy deletion) belong to replicas that were evacuated or have
    /// already ticked past the cached clock.
    fn tick_entry_valid(&self, replica: usize, at: f64) -> bool {
        let r = &self.replicas[replica];
        r.has_work() && r.clock() == at
    }

    /// Fold the per-replica runs into the cluster view.
    fn finalize(self, total_requests: usize) -> Result<ClusterOutcome> {
        let ClusterSim { mut replicas, dispatched, mut churn, retries, died_at, pool, .. } =
            self;
        let n = replicas.len();
        churn.max_retries = retries.values().copied().max().unwrap_or(0);
        // Detach the host pool before finishing the replicas: final
        // journal flush, per-replica lifetime stats merged with the
        // shared-side accounting, and every engine handed back exactly
        // as pool-less as it arrived (engine reuse must not leak pool
        // state into a later run).
        let mut pool_stats = PoolStats::default();
        for r in replicas.iter_mut() {
            pool_stats.merge(&r.detach_host_pool());
        }
        if let Some(p) = &pool {
            pool_stats.merge(&p.read().expect("host pool lock poisoned").stats);
        }
        let runs: Vec<_> = replicas.into_iter().map(|r| r.finish()).collect();
        let mut metrics = FleetMetrics::default();
        let mut fleet = FleetOutcome::default();
        let mut busy_total = BusyTotals::default();
        let mut breakdowns = Vec::with_capacity(n);
        for (run, count) in runs.into_iter().zip(&dispatched) {
            metrics.merge(&run.outcome.metrics);
            fleet.per_request.extend(run.outcome.per_request.iter().cloned());
            // Cluster-wide concurrency / KV peaks are summed per-replica
            // high-water marks: an upper bound on simultaneous load (the
            // marks need not coincide in virtual time), exact for one
            // replica.
            fleet.peak_concurrency += run.outcome.peak_concurrency;
            fleet.peak_kv_bytes += run.outcome.peak_kv_bytes;
            fleet.steps += run.outcome.steps;
            fleet.dedup.merge(&run.outcome.dedup);
            fleet.phase.merge(&run.outcome.phase);
            busy_total = busy_total.plus(&run.busy);
            breakdowns.push(ReplicaBreakdown {
                outcome: run.outcome,
                dispatched: *count,
                busy: run.busy,
                state: run.state,
                trace: run.trace,
            });
        }
        // Completion order across the cluster: a stable merge by completion
        // time (per-replica records are already completion-ordered).  A
        // single replica's list is returned untouched — not even a stable
        // sort — so the one-replica cluster is bit-identical to `run_fleet`
        // (same-tick completions can differ by a float ulp in
        // `finished_at`, which a sort could otherwise reorder).
        if n > 1 {
            fleet
                .per_request
                .sort_by(|a, b| a.finished_at.total_cmp(&b.finished_at));
        }
        // Attribute re-dispatches to the requests that suffered them (both
        // in the merged view and the per-replica breakdowns).
        if !retries.is_empty() {
            for r in &mut fleet.per_request {
                r.retries = retries.get(&r.id).copied().unwrap_or(0);
            }
            for b in &mut breakdowns {
                for r in &mut b.outcome.per_request {
                    r.retries = retries.get(&r.id).copied().unwrap_or(0);
                }
            }
        }
        ensure!(
            metrics.completed == total_requests,
            "cluster lost requests: {} of {total_requests} completed",
            metrics.completed
        );
        // Capacity accounting: a failed replica stops existing at its
        // failure instant, so it contributes capacity (and is weighed in
        // the balance statistic) only over `[span start, failure)`.
        // Draining replicas keep serving admitted work and count in full.
        // Without failures both forms reduce bit-exactly to the classic
        // `replicas × makespan` denominator and raw `max/mean` loads.
        let span = metrics.makespan();
        let start = metrics.first_arrival;
        let live: Vec<f64> = died_at
            .iter()
            .map(|d| (d.unwrap_or(metrics.last_completion) - start).clamp(0.0, span))
            .collect();
        let any_failure = died_at.iter().any(|d| d.is_some());
        fleet.utilization = if any_failure {
            ResourceUtil::from_capacity(&busy_total, live.iter().sum())
        } else {
            ResourceUtil::from_busy(&busy_total, span, n)
        };
        fleet.metrics = metrics;
        let loads: Vec<f64> = breakdowns
            .iter()
            .map(|b| b.outcome.metrics.tokens_total as f64)
            .collect();
        let imbalance = if any_failure {
            load_imbalance_weighted(&loads, &live)
        } else {
            load_imbalance(&loads)
        };
        Ok(ClusterOutcome {
            fleet,
            replicas: breakdowns,
            load_imbalance: imbalance,
            churn,
            pool: pool_stats,
        })
    }
}

/// Moves one replica's `&mut` across a scoped-thread boundary.
///
/// `Replica` is `!Send` because its engine's object graph uses `Rc` /
/// `RefCell` (the executor's staged-buffer cache, the runtime's
/// compiled-program cache, the metrics `Series` percentile cache).  The
/// parallel advance phase is still sound because the graphs are
/// **disjoint and single-thread confined**: [`run_cluster`] rejects
/// engines sharing an executor when `parallel > 1`, every other piece
/// of replica state is owned, the only cross-replica sharing left is
/// the immutable `Arc<ModelAssets>` (atomically refcounted plain data,
/// no interior mutability) and — on `--host-pool` runs — the
/// `Arc<RwLock<HostExpertPool>>`, which engines only ever *read*-lock
/// during an advance window (writes are journaled replica-locally and
/// applied at the boundary flush on the spawning thread, after the
/// scope has joined), and each wrapper moves to exactly one worker for
/// the duration of one phase — the spawning thread touches no replica
/// until `std::thread::scope` has joined every worker.
struct SendMut<'a, 'e>(&'a mut Replica<'e>);

// SAFETY: see the type docs — per-replica object graphs are disjoint
// (distinct executors enforced at entry), the shared host pool is
// behind an RwLock and only read-locked during a window, exactly one
// thread accesses a given replica during an advance phase, and the
// scope joins before the spawner resumes.
unsafe impl Send for SendMut<'_, '_> {}

/// Advance every replica in `due` until its clock reaches `horizon` or
/// it runs out of work.  Between two boundary events replicas do not
/// interact — dispatch and evacuation happen only at boundaries — so
/// the per-replica tick sequences are independent and the advance
/// order (serial, or parallel over up to `parallel` workers) cannot
/// affect any outcome bit.  `due` must be sorted ascending.
fn advance(
    replicas: &mut [Replica<'_>],
    due: &[usize],
    horizon: f64,
    parallel: usize,
) -> Result<()> {
    if parallel <= 1 || due.len() <= 1 {
        for &i in due {
            replicas[i]
                .advance_until(horizon)
                .with_context(|| format!("replica {i} tick"))?;
        }
        return Ok(());
    }
    let workers = parallel.min(due.len());
    // Round-robin the due replicas over the workers; the partition only
    // affects wall-clock, never outcomes.
    let mut parts: Vec<Vec<(usize, SendMut<'_, '_>)>> =
        (0..workers).map(|_| Vec::new()).collect();
    for (k, (i, r)) in replicas
        .iter_mut()
        .enumerate()
        .filter(|(i, _)| due.binary_search(i).is_ok())
        .enumerate()
    {
        parts[k % workers].push((i, SendMut(r)));
    }
    let mut results: Vec<(usize, Result<()>)> = std::thread::scope(|s| {
        let handles: Vec<_> = parts
            .into_iter()
            .map(|part| {
                s.spawn(move || {
                    part.into_iter()
                        .map(|(i, slot)| {
                            let res = slot.0.advance_until(horizon);
                            (i, res)
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| match h.join() {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect()
    });
    // Deterministic error reporting: lowest replica index first, as the
    // serial order would have surfaced it.
    results.sort_by_key(|(i, _)| *i);
    for (i, res) in results {
        res.with_context(|| format!("replica {i} tick"))?;
    }
    Ok(())
}

/// Serve an open-loop trace on a cluster of replicas to completion.
///
/// Each engine becomes one [`Replica`] (they may carry different
/// [`crate::config::HardwareConfig`]s); `cfg.dispatch` routes every
/// arriving request to a live replica, replicas advance in virtual-time
/// order driven by the event queue, and `cfg.serving.churn` events fire
/// at their scheduled instants.  With a single engine and no churn this
/// reduces exactly to [`super::run_fleet`].
///
/// `cfg.serving.parallel > 1` runs the inter-boundary advance phases on
/// scoped worker threads — bit-identical outcomes, engines must not
/// share an executor.
pub fn run_cluster(
    engines: &mut [Engine],
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<ClusterOutcome> {
    let parallel = cfg.serving.parallel.max(1);
    let total_requests = trace.len();
    let (churn_events, arrivals) = prepare(engines, trace, cfg)?;
    if parallel > 1 {
        // Executor state (staged-buffer / compiled-program caches) is
        // single-thread confined; replicas advancing concurrently must
        // each own their executor.
        for i in 0..engines.len() {
            for j in (i + 1)..engines.len() {
                ensure!(
                    !engines[i].shares_executor(&engines[j]),
                    "parallel cluster execution ({parallel} workers) needs per-replica \
                     executors, but engines {i} and {j} share one; construct each engine \
                     with its own Executor (serve-fleet --parallel does this) or run serial"
                );
            }
        }
    }
    let mut q = EventQueue::new();
    for (pos, e) in churn_events.into_iter().enumerate() {
        q.push(Event::churn(pos as u64, e));
    }
    for r in arrivals {
        q.push(Event::arrival(r));
    }
    let mut sim = ClusterSim::new(engines, cfg);
    while let Some(ev) = q.pop() {
        match ev.payload {
            EventPayload::Churn(e) => {
                // Evacuees re-enter as arrival events at their original
                // (past) arrival times: the heap pops them ahead of
                // later traffic, exactly as a re-queued request should.
                for r in sim.fire_churn(e) {
                    q.push(Event::arrival(r));
                }
            }
            EventPayload::Arrival(req) => {
                let (idx, was_idle) = sim.dispatch(req)?;
                if was_idle {
                    // Wake the replica: one tick entry at its current
                    // clock (which may lag the arrival — the engine
                    // fast-forwards service internally).  Busy replicas
                    // already hold their entry; enqueue moves no clock.
                    q.push(Event::tick(sim.replicas[idx].clock(), idx));
                }
            }
            EventPayload::Tick { replica } => {
                // Claim every tick-completion due before the next
                // boundary (churn / arrival) event: heap order pops
                // them consecutively, and a tick at exactly the
                // boundary instant sorts *after* the boundary, so the
                // claimed set is exactly the replicas that must advance
                // to the boundary.
                let mut due: Vec<usize> = Vec::new();
                if sim.tick_entry_valid(replica, ev.at) {
                    due.push(replica);
                }
                while q.peek_is_tick() {
                    let t = q.pop().expect("peeked tick entry");
                    let EventPayload::Tick { replica: j } = t.payload else {
                        unreachable!("peek_is_tick returned a non-tick event");
                    };
                    if sim.tick_entry_valid(j, t.at) && !due.contains(&j) {
                        due.push(j);
                    }
                }
                let horizon = q.peek_at().unwrap_or(f64::INFINITY);
                due.sort_unstable();
                advance(&mut sim.replicas, &due, horizon, parallel)?;
                // Host-pool barrier: apply the window's journals in
                // ascending replica order — single-threaded, the same
                // order serial and parallel, so the shared tier every
                // replica sees next window is deterministic.  No-op
                // without `--host-pool`.
                for &i in &due {
                    sim.replicas[i].flush_host_pool();
                }
                for &i in &due {
                    if sim.replicas[i].has_work() {
                        q.push(Event::tick(sim.replicas[i].clock(), i));
                    }
                }
            }
        }
    }
    sim.finalize(total_requests)
}

/// The retired min-clock lockstep loop, kept verbatim as the reference
/// implementation [`run_cluster`] is pinned against (the same way PR 4
/// kept `run_fleet` as the single-replica reference) and as the
/// wall-clock baseline of `bench_serving`'s `event_driven_sweep`.
///
/// Each iteration rescans every replica for the minimum busy clock
/// (ties by index), fires any churn event due at or before both that
/// clock and the next pending arrival, else delivers the next arrival
/// due at or before that clock, else ticks the min-clock replica once.
/// O(replicas) per tick even when most replicas are idle — the cost
/// the event-driven scheduler removes.  Outcomes are bit-identical to
/// [`run_cluster`]; prefer that entry point everywhere else.
pub fn run_cluster_minclock(
    engines: &mut [Engine],
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<ClusterOutcome> {
    let total_requests = trace.len();
    let (churn_events, arrivals) = prepare(engines, trace, cfg)?;
    let mut events: VecDeque<ChurnEvent> = churn_events.into();
    let mut pending: VecDeque<TimedRequest> = arrivals.into();
    let mut sim = ClusterSim::new(engines, cfg);

    loop {
        // The cluster's virtual "now": the smallest clock among replicas
        // that still have work (ties by index).  Dead replicas hold no
        // work (evacuated) and draining replicas keep ticking theirs.
        let next_tick: Option<usize> = {
            let mut best: Option<(f64, usize)> = None;
            for (i, r) in sim.replicas.iter().enumerate() {
                if !r.has_work() {
                    continue;
                }
                let c = r.clock();
                let better = match best {
                    None => true,
                    Some((bc, _)) => c < bc,
                };
                if better {
                    best = Some((c, i));
                }
            }
            best.map(|(_, i)| i)
        };
        let tick_clock = next_tick.map(|i| sim.replicas[i].clock());

        // Churn events fire in virtual-time order between ticks: before
        // any replica ticks past them and before any later arrival is
        // routed (an event tied with an arrival fires first, so a
        // failure at exactly an arrival's time excludes that replica
        // from its dispatch).  On an idle cluster events fire
        // immediately up to the next arrival.
        let fire_event = match events.front() {
            None => false,
            Some(e) => {
                let before_tick = match tick_clock {
                    None => true,
                    Some(c) => e.at <= c,
                };
                let before_arrival = match pending.front() {
                    None => true,
                    Some(r) => e.at <= r.arrival,
                };
                before_tick && before_arrival
            }
        };
        if fire_event {
            let e = events.pop_front().unwrap();
            let evac = sim.fire_churn(e);
            if !evac.is_empty() {
                // Merge the evacuees back into the pending queue in
                // arrival order: their arrivals are in the past, so
                // they re-dispatch ahead of later traffic, exactly as
                // a re-queued request should.
                let mut all: Vec<TimedRequest> =
                    std::mem::take(&mut pending).into_iter().collect();
                all.extend(evac);
                all.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
                pending = all.into();
            }
            continue;
        }

        let deliver = match (next_tick, pending.front()) {
            (None, None) => break,
            // Whole cluster idle: fast-forward by dispatching the next
            // future arrival (its service start waits for its arrival
            // time inside the engine, exactly as the single-engine loop
            // fast-forwarded).
            (None, Some(_)) => true,
            // An arrival at or before the cluster's virtual now must be
            // routed before anyone ticks past it.
            (Some(i), Some(r)) => r.arrival <= sim.replicas[i].clock(),
            (Some(_), None) => false,
        };

        if deliver {
            let req = pending.pop_front().unwrap();
            sim.dispatch(req)?;
        } else {
            let i = next_tick.expect("no tick target with no arrival to deliver");
            sim.replicas[i]
                .tick()
                .with_context(|| format!("replica {i} tick"))?;
            // Host-pool barrier at the finest granularity: every tick is
            // its own window here.  Note the two loops are pinned
            // bit-identical only on pool-less configs — with a pool
            // attached their visibility windows legitimately differ
            // (the event-driven loop batches a whole inter-boundary
            // window before flushing).
            sim.replicas[i].flush_host_pool();
        }
    }
    sim.finalize(total_requests)
}

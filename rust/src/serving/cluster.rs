//! Multi-replica edge cluster serving: a dispatcher in front of
//! `Vec<Replica>`, advanced by min-clock next-event stepping (as in
//! event-driven co-simulation).
//!
//! The event loop maintains one invariant: **no replica ticks past an
//! undelivered arrival or an unfired churn event.**  Each iteration
//! either (a) fires the next scheduled [`ChurnEvent`] — whenever its
//! virtual time is at or before both the minimum clock among busy
//! replicas and the next pending arrival — or (b) routes the oldest
//! pending request to a replica via the [`DispatchPolicy`] — whenever
//! its arrival time is at or before the minimum clock among busy
//! replicas (the cluster's virtual "now"), or the whole cluster is idle
//! (the fast-forward case) — or (c) ticks the busy replica with the
//! smallest virtual clock (ties by index).  When a replica is picked to
//! tick, every arrival up to its clock has therefore already been
//! dispatched, which is exactly the admission discipline of the
//! pre-refactor single-engine loop; with one replica and no churn the
//! trace of enqueue/tick operations is identical, making `--replicas 1
//! --dispatch rr` tick-for-tick equivalent to [`super::run_fleet`]
//! (pinned in `tests/integration_cluster.rs`; the churn-free
//! equivalence of the churn-capable loop is pinned in
//! `tests/integration_churn.rs`).
//!
//! # Replica failure and drain
//!
//! Replicas are commodity edge devices that die or get recalled
//! mid-trace.  A [`ChurnEvent`] schedules that: on **drain** the
//! replica stops receiving dispatches and runs down everything already
//! dispatched to it; on **fail** the replica's queued *and* active
//! (mid-prefill / mid-decode) sessions are extracted via
//! [`Replica::evacuate`] and merged back into the pending queue, where
//! the [`DispatchPolicy`] — offered only the still-live replicas —
//! re-routes them.  Restarted sessions keep their **original** arrival
//! times, so the SLO impact of churn (queue delay, TTFT) is reported
//! honestly — and service is gated at the failure time, so a restart
//! can never begin "before" the failure on a receiving replica whose
//! virtual clock lags the event; the work the dead replica had already
//! done on them is discarded and counted as
//! [`ChurnStats::lost_work_tokens`].  Request
//! conservation (every trace id completes exactly once) holds across
//! any churn schedule that leaves a live replica to serve it; a
//! schedule that fails or drains *every* replica while requests are
//! still outstanding is rejected with an error at the moment a request
//! has nowhere to go.
//!
//! Replicas may be heterogeneous (different [`HardwareConfig`]s — a
//! big.LITTLE edge cluster): each owns its engine, expert cache, and
//! virtual timeline, so a slow replica simply surfaces as a high clock
//! the stepper visits less often.
//!
//! [`HardwareConfig`]: crate::config::HardwareConfig
//! [`ChurnEvent`]: crate::config::ChurnEvent

use std::collections::{HashMap, VecDeque};

use anyhow::{ensure, Context, Result};

use crate::config::{ChurnEvent, ChurnKind};
use crate::coordinator::engine::Engine;
use crate::memory::BusyTotals;
use crate::trace::TraceCapture;

use super::arrival::TimedRequest;
use super::metrics::{load_imbalance, ChurnStats, FleetMetrics, ResourceUtil};
use super::replica::{Replica, ReplicaState};
use super::{FleetConfig, FleetOutcome};

/// One replica's share of a cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaBreakdown {
    /// The replica's own fleet outcome (per-replica metrics, dedup and
    /// phase telemetry, utilization over *its* makespan).
    pub outcome: FleetOutcome,
    /// Requests the dispatcher routed here, re-dispatches after a
    /// failure included (so across the cluster the counts sum to
    /// `trace.len() + churn.requeued`).
    pub dispatched: usize,
    /// Busy-seconds delta this run accrued on the replica's channels.
    pub busy: BusyTotals,
    /// Lifecycle state the replica ended the run in (Live unless a
    /// churn event touched it).
    pub state: ReplicaState,
    /// This run's trace streams (engine events + per-tick counter
    /// samples); empty unless the engine's timeline is recording.
    /// [`crate::trace::chrome::chrome_trace`] renders these as one
    /// Perfetto process per replica.
    pub trace: TraceCapture,
}

/// Result of one cluster run: the merged fleet view plus per-replica
/// breakdowns, the dispatch balance statistic, and churn telemetry.
#[derive(Debug, Clone)]
pub struct ClusterOutcome {
    /// Cluster-merged outcome: union of per-request records (completion
    /// order), summed counters, utilization over `replicas x makespan`.
    pub fleet: FleetOutcome,
    /// Per-replica breakdowns, indexed by replica id.
    pub replicas: Vec<ReplicaBreakdown>,
    /// `max / mean` of per-replica emitted-token loads (1.0 = perfectly
    /// balanced, `replicas` = one replica served everything).
    pub load_imbalance: f64,
    /// What the run's churn schedule cost (all zero on a churn-free
    /// run).
    pub churn: ChurnStats,
}

/// Serve an open-loop trace on a cluster of replicas to completion.
///
/// Each engine becomes one [`Replica`] (they may carry different
/// [`crate::config::HardwareConfig`]s); `cfg.dispatch` routes every
/// arriving request to a live replica, replicas advance in virtual-time
/// order, and `cfg.serving.churn` events fire between ticks.  With a
/// single engine and no churn this reduces exactly to
/// [`super::run_fleet`].
pub fn run_cluster(
    engines: &mut [Engine],
    trace: Vec<TimedRequest>,
    cfg: &FleetConfig,
) -> Result<ClusterOutcome> {
    ensure!(!engines.is_empty(), "cluster needs at least one replica engine");
    let n = engines.len();
    // The engine slice is authoritative for cluster size; an explicitly
    // configured replica count that disagrees with it is a caller bug
    // (the default of 1 means "unset" so single-replica configs can be
    // reused across any cluster).
    ensure!(
        cfg.serving.replicas <= 1 || cfg.serving.replicas == n,
        "config says {} replicas but {n} engines were provided",
        cfg.serving.replicas
    );
    // Churn schedule: validated up front, fired in virtual-time order
    // (ties by schedule order — `sort_by` is stable).
    let mut events: VecDeque<ChurnEvent> = {
        let mut e = cfg.serving.churn.clone();
        for ev in &e {
            ensure!(
                ev.replica < n,
                "churn event {} {}@{} targets a replica outside the cluster of {n}",
                ev.kind.name(),
                ev.at,
                ev.replica
            );
            ensure!(
                ev.at.is_finite() && ev.at >= 0.0,
                "churn event {} at {} must have a finite non-negative time",
                ev.kind.name(),
                ev.at
            );
        }
        e.sort_by(|a, b| a.at.total_cmp(&b.at));
        e.into()
    };
    let total_requests = trace.len();
    let mut pending: VecDeque<TimedRequest> = {
        let mut t = trace;
        t.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        t.into()
    };
    let mut replicas: Vec<Replica> =
        engines.iter_mut().map(|e| Replica::new(e, cfg)).collect();
    let mut dispatch = cfg.dispatch.build();
    let mut dispatched = vec![0usize; n];
    let mut churn = ChurnStats::default();
    // Per-request re-dispatch counts (patched into the completed
    // records at the end).
    let mut retries: HashMap<usize, usize> = HashMap::new();
    // Service gates for requeued requests: a restart cannot begin
    // before the failure that caused it, even on a receiving replica
    // whose virtual clock lags the event (metrics stay keyed to the
    // original arrival).  Later failures overwrite with their (later)
    // event times.
    let mut not_before: HashMap<usize, f64> = HashMap::new();

    loop {
        // The cluster's virtual "now": the smallest clock among replicas
        // that still have work (ties by index).  Dead replicas hold no
        // work (evacuated) and draining replicas keep ticking theirs.
        let next_tick: Option<usize> = {
            let mut best: Option<(f64, usize)> = None;
            for (i, r) in replicas.iter().enumerate() {
                if !r.has_work() {
                    continue;
                }
                let c = r.clock();
                let better = match best {
                    None => true,
                    Some((bc, _)) => c < bc,
                };
                if better {
                    best = Some((c, i));
                }
            }
            best.map(|(_, i)| i)
        };
        let tick_clock = next_tick.map(|i| replicas[i].clock());

        // Churn events fire in virtual-time order between ticks: before
        // any replica ticks past them and before any later arrival is
        // routed (an event tied with an arrival fires first, so a
        // failure at exactly an arrival's time excludes that replica
        // from its dispatch).  On an idle cluster events fire
        // immediately up to the next arrival.
        let fire_event = match events.front() {
            None => false,
            Some(e) => {
                let before_tick = match tick_clock {
                    None => true,
                    Some(c) => e.at <= c,
                };
                let before_arrival = match pending.front() {
                    None => true,
                    Some(r) => e.at <= r.arrival,
                };
                before_tick && before_arrival
            }
        };
        if fire_event {
            let e = events.pop_front().unwrap();
            match e.kind {
                ChurnKind::Drain => {
                    if replicas[e.replica].begin_drain() {
                        churn.drained += 1;
                        replicas[e.replica].mark(e.at, "drain");
                    }
                }
                ChurnKind::Fail => {
                    if replicas[e.replica].state() != ReplicaState::Dead {
                        replicas[e.replica].mark(e.at, "fail");
                        let evac = replicas[e.replica].evacuate();
                        churn.failed += 1;
                        churn.requeued += evac.requests.len();
                        churn.lost_work_tokens += evac.lost_tokens;
                        for r in &evac.requests {
                            *retries.entry(r.id).or_default() += 1;
                            not_before.insert(r.id, e.at);
                        }
                        if !evac.requests.is_empty() {
                            // Merge the evacuees back into the pending
                            // queue in arrival order: their arrivals are
                            // in the past, so they re-dispatch ahead of
                            // later traffic, exactly as a re-queued
                            // request should.
                            let mut all: Vec<TimedRequest> =
                                std::mem::take(&mut pending).into_iter().collect();
                            all.extend(evac.requests);
                            all.sort_by(|a, b| {
                                a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id))
                            });
                            pending = all.into();
                        }
                    }
                }
            }
            continue;
        }

        let deliver = match (next_tick, pending.front()) {
            (None, None) => break,
            // Whole cluster idle: fast-forward by dispatching the next
            // future arrival (its service start waits for its arrival
            // time inside the engine, exactly as the single-engine loop
            // fast-forwarded).
            (None, Some(_)) => true,
            // An arrival at or before the cluster's virtual now must be
            // routed before anyone ticks past it.
            (Some(i), Some(r)) => r.arrival <= replicas[i].clock(),
            (Some(_), None) => false,
        };

        if deliver {
            let req = pending.pop_front().unwrap();
            // Offer the dispatcher only the live replicas; the policy
            // returns a *position* into this slice, mapped back to the
            // replica id through the view's `index`.
            let views: Vec<_> = replicas
                .iter()
                .enumerate()
                .filter(|(_, r)| r.accepts_dispatch())
                .map(|(i, r)| r.dispatch_view(i))
                .collect();
            ensure!(
                !views.is_empty(),
                "request {} has no live replica to dispatch to: the churn schedule \
                 failed/drained the whole cluster with work outstanding",
                req.id
            );
            let pos = dispatch.route(&req, &views);
            ensure!(
                pos < views.len(),
                "dispatch policy {} routed request {} to position {pos} of {}",
                dispatch.name(),
                req.id,
                views.len()
            );
            let idx = views[pos].index;
            dispatched[idx] += 1;
            match not_before.get(&req.id).copied() {
                Some(gate) => replicas[idx].enqueue_not_before(req, gate),
                None => replicas[idx].enqueue(req),
            }
        } else {
            let i = next_tick.expect("no tick target with no arrival to deliver");
            replicas[i]
                .tick()
                .with_context(|| format!("replica {i} tick"))?;
        }
    }
    churn.max_retries = retries.values().copied().max().unwrap_or(0);

    // Fold the per-replica runs into the cluster view.
    let runs: Vec<_> = replicas.into_iter().map(|r| r.finish()).collect();
    let mut metrics = FleetMetrics::default();
    let mut fleet = FleetOutcome::default();
    let mut busy_total = BusyTotals::default();
    let mut breakdowns = Vec::with_capacity(n);
    for (run, count) in runs.into_iter().zip(&dispatched) {
        metrics.merge(&run.outcome.metrics);
        fleet.per_request.extend(run.outcome.per_request.iter().cloned());
        // Cluster-wide concurrency / KV peaks are summed per-replica
        // high-water marks: an upper bound on simultaneous load (the
        // marks need not coincide in virtual time), exact for one
        // replica.
        fleet.peak_concurrency += run.outcome.peak_concurrency;
        fleet.peak_kv_bytes += run.outcome.peak_kv_bytes;
        fleet.steps += run.outcome.steps;
        fleet.dedup.merge(&run.outcome.dedup);
        fleet.phase.merge(&run.outcome.phase);
        busy_total = busy_total.plus(&run.busy);
        breakdowns.push(ReplicaBreakdown {
            outcome: run.outcome,
            dispatched: *count,
            busy: run.busy,
            state: run.state,
            trace: run.trace,
        });
    }
    // Completion order across the cluster: a stable merge by completion
    // time (per-replica records are already completion-ordered).  A
    // single replica's list is returned untouched — not even a stable
    // sort — so the one-replica cluster is bit-identical to `run_fleet`
    // (same-tick completions can differ by a float ulp in
    // `finished_at`, which a sort could otherwise reorder).
    if n > 1 {
        fleet
            .per_request
            .sort_by(|a, b| a.finished_at.total_cmp(&b.finished_at));
    }
    // Attribute re-dispatches to the requests that suffered them (both
    // in the merged view and the per-replica breakdowns).
    if !retries.is_empty() {
        for r in &mut fleet.per_request {
            r.retries = retries.get(&r.id).copied().unwrap_or(0);
        }
        for b in &mut breakdowns {
            for r in &mut b.outcome.per_request {
                r.retries = retries.get(&r.id).copied().unwrap_or(0);
            }
        }
    }
    ensure!(
        metrics.completed == total_requests,
        "cluster lost requests: {} of {total_requests} completed",
        metrics.completed
    );
    fleet.utilization = ResourceUtil::from_busy(&busy_total, metrics.makespan(), n);
    fleet.metrics = metrics;
    let loads: Vec<f64> = breakdowns
        .iter()
        .map(|b| b.outcome.metrics.tokens_total as f64)
        .collect();
    Ok(ClusterOutcome {
        fleet,
        replicas: breakdowns,
        load_imbalance: load_imbalance(&loads),
        churn,
    })
}

//! One serving replica: an [`Engine`] plus everything the fleet loop
//! used to own inline — the admission queue, the in-flight session set,
//! the scheduling-policy state, and the per-run telemetry — behind a
//! `tick`-style API the cluster layer can advance in virtual-time order.
//!
//! The two tick bodies are the pre-refactor fleet loops extracted
//! verbatim: [`Replica::tick`] dispatches to the monolithic step
//! (`chunk_tokens == 0`: admission runs the whole prefill as one
//! scheduling step, decode steps batch across sessions) or the
//! token-budget chunked step (admission only allocates a slot; each tick
//! fuses one prefill chunk with a decode batch through
//! [`Engine::mixed_step`]).  Driving one replica to completion — deliver
//! every arrival at its time, tick while there is work, fast-forward
//! when idle — therefore reproduces the pre-refactor single-engine
//! `run_fleet` tick for tick; `tests/integration_cluster.rs` pins that
//! equivalence for both paths.
//!
//! Telemetry discipline: engine counters ([`EngineStats`]) and channel
//! busy time ([`crate::memory::BusyTotals`]) are cumulative over the
//! engine's lifetime, so the replica snapshots both at construction and
//! reports **deltas** at [`Replica::finish`] — reusing an engine across
//! runs can never double-count an earlier run's work.

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::engine::{Engine, EngineSession, EngineStats};
use crate::memory::BusyTotals;
use crate::trace::{TickSample, TraceCapture};
use crate::workload::Request;

use super::arrival::{TenantClass, TimedRequest};
use super::metrics::{DedupStats, PhaseStats, ResourceUtil, SloTargets};
use super::policy::{
    Action, ActiveInfo, DispatchKind, QueuedInfo, ReplicaDispatchView, SchedPolicy, SchedView,
    TickPlan,
};
use super::{FleetConfig, FleetOutcome};

/// A replica's position in its lifecycle.  The cluster's churn events
/// ([`crate::config::ChurnEvent`]) move a replica Live -> Draining
/// (graceful recall: no new dispatches, admitted work runs down) or
/// Live/Draining -> Dead (failure: everything in flight is evacuated
/// via [`Replica::evacuate`] and re-dispatched elsewhere).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    Live,
    Draining,
    Dead,
}

impl ReplicaState {
    pub fn name(self) -> &'static str {
        match self {
            ReplicaState::Live => "live",
            ReplicaState::Draining => "draining",
            ReplicaState::Dead => "dead",
        }
    }
}

/// What a failed replica gives back: the sessions it can no longer
/// serve (rebuilt as dispatchable requests with their **original**
/// arrival times, so the re-run's queue delay and TTFT honestly include
/// the failure) plus the work it discards.
#[derive(Debug, Clone)]
pub struct Evacuation {
    /// Queued and in-flight sessions as fresh requests, oldest arrival
    /// first (the order the dispatcher will re-route them in).
    pub requests: Vec<TimedRequest>,
    /// Tokens of processing discarded by the failure: prompt tokens
    /// already prefilled plus output tokens already emitted by the
    /// evacuated in-flight sessions (each restarts from scratch).
    pub lost_tokens: u64,
}

/// A request that has been dispatched to this replica but not admitted.
struct Queued {
    id: usize,
    arrival: f64,
    deadline: f64,
    /// Earliest virtual time service may start: the arrival itself for
    /// a fresh dispatch, the failure time for a session restarted after
    /// its replica died — the restart cannot begin before the failure,
    /// even on a receiving replica whose clock lags behind it.
    earliest: f64,
    class: TenantClass,
    /// Resolved SLO: the request's own targets if it carried any, else
    /// the fleet-level targets (bit-identical deadline math on legacy
    /// single-class paths).
    slo: SloTargets,
    request: Request,
}

/// An admitted, still-running session.
struct Active {
    id: usize,
    arrival: f64,
    class: TenantClass,
    slo: SloTargets,
    /// Times this session has been preempted (parked) so far.
    preemptions: usize,
    sess: EngineSession,
    last_token_at: f64,
}

/// A preempted in-flight session: its slot was handed to a strictly
/// more urgent class, but the **live engine session survives** — prefix
/// KV and emitted tokens intact (work conserved, unlike a churn
/// re-dispatch which restarts from scratch).  Parked sessions appear in
/// the policy's queued view and re-enter service through the normal
/// admission pick; resuming is pure bookkeeping (no engine work).
struct Parked {
    id: usize,
    arrival: f64,
    class: TenantClass,
    slo: SloTargets,
    preemptions: usize,
    sess: EngineSession,
    last_token_at: f64,
}

/// One replica's completed run: its fleet outcome plus the busy-seconds
/// delta its engine accrued (the cluster merges busy time across
/// replicas to report cluster-level utilization).
#[derive(Debug, Clone)]
pub struct ReplicaRun {
    pub outcome: FleetOutcome,
    pub busy: BusyTotals,
    /// Lifecycle state the replica ended the run in (Live unless a
    /// churn event touched it).
    pub state: ReplicaState,
    /// This run's trace streams (engine events + per-tick counter
    /// samples); empty unless the engine's timeline is recording.
    pub trace: TraceCapture,
}

/// One serving replica (engine + queues + policy + telemetry).
pub struct Replica<'e> {
    engine: &'e mut Engine,
    policy: Box<dyn SchedPolicy>,
    slo: SloTargets,
    max_sessions: usize,
    /// Decode-batch width, clamped to the model's expert token bucket.
    max_decode_batch: usize,
    chunk_tokens: usize,
    max_seq: usize,
    queued: Vec<Queued>,
    /// Preempted sessions waiting to resume (never populated on
    /// single-class runs — preemption requires a strictly more urgent
    /// queued class).
    parked: Vec<Parked>,
    active: Vec<Active>,
    state: ReplicaState,
    stats_before: EngineStats,
    busy_before: BusyTotals,
    /// Trace scoping: `engine.timeline.events` is cumulative over the
    /// engine's lifetime (like `BusyTotals`), so the replica snapshots
    /// the log length at construction and [`Replica::finish`] captures
    /// only this run's suffix — engine reuse across runs never leaks
    /// earlier runs' events into a later trace.
    events_before: usize,
    /// One counter sample per tick (empty when not recording).
    samples: Vec<TickSample>,
    /// Whether the cluster dispatches predictively: only then does
    /// [`Replica::dispatch_view`] pay for the per-expert residency
    /// summary (every other policy gets the O(1) snapshot, so the new
    /// field cannot perturb their outcomes).
    predictive: bool,
    out: FleetOutcome,
}

/// Policy view of the replica's sets.  Parked (preempted) sessions
/// appear in the **queued** view — deadline keyed to their original
/// arrival — so the policy's normal admission ordering decides when
/// they re-enter service; empty on every single-class path.
fn infos(
    queued: &[Queued],
    parked: &[Parked],
    active: &[Active],
) -> (Vec<QueuedInfo>, Vec<ActiveInfo>) {
    let mut queued_info: Vec<QueuedInfo> = queued
        .iter()
        .map(|q| QueuedInfo { id: q.id, arrival: q.arrival, deadline: q.deadline, class: q.class })
        .collect();
    queued_info.extend(parked.iter().map(|p| QueuedInfo {
        id: p.id,
        arrival: p.arrival,
        deadline: p.arrival + p.slo.ttft_s,
        class: p.class,
    }));
    let active_info: Vec<ActiveInfo> = active
        .iter()
        .map(|a| ActiveInfo {
            id: a.id,
            arrival: a.arrival,
            class: a.class,
            emitted: a.sess.emitted(),
            target: a.sess.target_tokens(),
            last_token_at: a.last_token_at,
            prefill_remaining: a.sess.prefill_remaining(),
        })
        .collect();
    (queued_info, active_info)
}

impl<'e> Replica<'e> {
    /// Wrap an engine for one fleet run, snapshotting its cumulative
    /// counters so [`Replica::finish`] reports this run's deltas only.
    pub fn new(engine: &'e mut Engine, cfg: &FleetConfig) -> Replica<'e> {
        let policy = cfg.policy.build();
        Replica::with_policy(engine, cfg, policy)
    }

    /// Like [`Replica::new`] but with an explicit scheduling-policy
    /// instance — the entry point for custom [`SchedPolicy`]
    /// implementations outside [`super::policy::PolicyKind`] (tests use
    /// it to exercise the work-conserving fallbacks a policy bug would
    /// otherwise hit in production).
    pub fn with_policy(
        engine: &'e mut Engine,
        cfg: &FleetConfig,
        policy: Box<dyn SchedPolicy>,
    ) -> Replica<'e> {
        let max_seq = engine.model().max_seq;
        Replica {
            slo: cfg.slo(),
            max_sessions: cfg.serving.max_sessions.max(1),
            // Clamp the batch width to the model's largest expert token
            // bucket: the engine cannot fuse more decode tokens than one
            // expert call can carry, and `--sessions` above that limit
            // should still serve (the surplus sessions just decode in
            // the next tick's batch).
            max_decode_batch: cfg.serving.max_decode_batch.clamp(1, max_seq),
            chunk_tokens: cfg.serving.chunk_tokens,
            max_seq,
            queued: Vec::new(),
            parked: Vec::new(),
            active: Vec::new(),
            state: ReplicaState::Live,
            stats_before: engine.stats,
            busy_before: engine.busy_totals(),
            events_before: engine.timeline.events.len(),
            samples: Vec::new(),
            predictive: cfg.dispatch == DispatchKind::Predictive,
            out: FleetOutcome::default(),
            policy,
            engine,
        }
    }

    /// The replica's virtual clock (its engine's compute horizon).
    pub fn clock(&self) -> f64 {
        self.engine.clock()
    }

    /// Anything queued, parked, or in flight?
    pub fn has_work(&self) -> bool {
        !self.queued.is_empty() || !self.parked.is_empty() || !self.active.is_empty()
    }

    /// Lifecycle state (Live unless a churn event touched the replica).
    pub fn state(&self) -> ReplicaState {
        self.state
    }

    /// May the dispatcher route new requests here?  Only Live replicas
    /// accept dispatches; Draining replicas run down what they already
    /// hold and Dead replicas hold nothing.
    pub fn accepts_dispatch(&self) -> bool {
        self.state == ReplicaState::Live
    }

    /// Cordon the replica (churn `Drain`): it stops receiving
    /// dispatches and runs down everything already dispatched to it.
    /// Returns whether the state actually changed (a drain of an
    /// already-draining or dead replica is a no-op).
    pub fn begin_drain(&mut self) -> bool {
        if self.state == ReplicaState::Live {
            self.state = ReplicaState::Draining;
            true
        } else {
            false
        }
    }

    /// Kill the replica (churn `Fail`): mark it Dead and hand back
    /// every queued and in-flight session as re-dispatchable requests
    /// carrying their **original** arrival times, plus the token count
    /// of the work discarded.  After this the replica has no work and
    /// never ticks again; its telemetry (including the busy time spent
    /// on the lost work) still reports through [`Replica::finish`].
    pub fn evacuate(&mut self) -> Evacuation {
        self.state = ReplicaState::Dead;
        let mut requests: Vec<TimedRequest> =
            Vec::with_capacity(self.queued.len() + self.parked.len() + self.active.len());
        for q in self.queued.drain(..) {
            requests.push(TimedRequest {
                id: q.id,
                arrival: q.arrival,
                class: q.class,
                slo: Some(q.slo),
                request: q.request,
            });
        }
        let mut lost_tokens = 0u64;
        // Parked sessions restart from scratch like active ones: the
        // work a park conserved is lost when the replica dies (parked
        // sessions are always fully prefilled).
        for p in self.parked.drain(..) {
            lost_tokens += (p.sess.prompt_len() + p.sess.emitted()) as u64;
            requests.push(TimedRequest {
                id: p.id,
                arrival: p.arrival,
                class: p.class,
                slo: Some(p.slo),
                request: Request {
                    prompt: p.sess.prompt().to_vec(),
                    max_new: p.sess.target_tokens(),
                },
            });
        }
        for a in self.active.drain(..) {
            // Work discarded: prompt tokens whose layer sweep already
            // ran (the whole prompt once prefilled, the chunk cursor
            // mid-prefill) plus every emitted output token.
            let prefilled = if a.sess.prefilled() {
                a.sess.prompt_len()
            } else {
                a.sess.prefill_cursor()
            };
            lost_tokens += (prefilled + a.sess.emitted()) as u64;
            requests.push(TimedRequest {
                id: a.id,
                arrival: a.arrival,
                class: a.class,
                slo: Some(a.slo),
                request: Request {
                    prompt: a.sess.prompt().to_vec(),
                    max_new: a.sess.target_tokens(),
                },
            });
        }
        // Oldest arrival first: the order the dispatcher re-routes in
        // (matching the pending queue's arrival ordering).
        requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        Evacuation { requests, lost_tokens }
    }

    /// Deliver one dispatched request into the admission queue.
    pub fn enqueue(&mut self, r: TimedRequest) {
        let at = r.arrival;
        self.enqueue_not_before(r, at);
    }

    /// Deliver a request whose service may not start before
    /// `not_before` (a session restarted after a replica failure: its
    /// metrics stay keyed to the original arrival, but the restart
    /// cannot begin before the failure — even on a receiving replica
    /// whose virtual clock lags the event).  `enqueue` is the
    /// `not_before == arrival` case.
    pub fn enqueue_not_before(&mut self, r: TimedRequest, not_before: f64) {
        // Resolve the SLO once at the door: the request's own targets
        // if it carries any, else the fleet-level targets (exactly the
        // legacy deadline arithmetic when `r.slo` is `None`).
        let slo = r.slo.unwrap_or(self.slo);
        self.queued.push(Queued {
            id: r.id,
            arrival: r.arrival,
            deadline: r.arrival + slo.ttft_s,
            earliest: r.arrival.max(not_before),
            class: r.class,
            slo,
            request: r.request,
        });
    }

    /// Dispatcher-visible load snapshot.
    pub fn dispatch_view(&self, index: usize) -> ReplicaDispatchView {
        // Parked sessions count as queued load: they hold no slot but
        // still owe their remaining tokens to this replica.
        let queued_tokens = self
            .queued
            .iter()
            .map(|q| q.request.prompt.len() + q.request.max_new)
            .sum::<usize>()
            + self
                .parked
                .iter()
                .map(|p| p.sess.target_tokens().saturating_sub(p.sess.emitted()))
                .sum::<usize>();
        let active_tokens = self
            .active
            .iter()
            .map(|a| {
                a.sess.prefill_remaining()
                    + a.sess.target_tokens().saturating_sub(a.sess.emitted())
            })
            .sum();
        ReplicaDispatchView {
            index,
            clock: self.clock(),
            queued_requests: self.queued.len() + self.parked.len(),
            queued_tokens,
            active_sessions: self.active.len(),
            active_tokens,
            resident_expert_bytes: if self.predictive {
                self.resident_expert_bytes()
            } else {
                Vec::new()
            },
        }
    }

    /// Per-expert staged bytes across this replica's memory tiers
    /// (VRAM cache + its view of the shared host pool), summed over
    /// layers — the predictive dispatcher's overlap signal.  Cache key
    /// iteration order is nondeterministic (HashMap), but per-expert
    /// byte sums commute, so the summary is deterministic.
    fn resident_expert_bytes(&self) -> Vec<u64> {
        let n_experts = self.engine.model().n_experts;
        let mut out = vec![0u64; n_experts];
        for key in self.engine.cache.keys() {
            if let Some(prec) = self.engine.cache.contains(key) {
                if let Some(slot) = out.get_mut(key.expert as usize) {
                    *slot += self.engine.cost.expert_weight_bytes(prec) as u64;
                }
            }
        }
        if let Some(pool) = self.engine.host_pool.as_ref() {
            pool.add_resident_expert_bytes(&mut out);
        }
        out
    }

    /// Advance this replica by one scheduling step.  Every arrival with
    /// `arrival <= clock()` must already be enqueued (the cluster's
    /// min-clock stepping guarantees it), and the replica must have
    /// work.
    pub fn tick(&mut self) -> Result<()> {
        ensure!(self.has_work(), "ticked an idle replica");
        let recording = self.engine.timeline.record;
        let t0 = if recording { self.engine.clock() } else { 0.0 };
        if self.chunk_tokens == 0 {
            self.tick_monolithic()?;
        } else {
            self.tick_chunked()?;
        }
        if recording {
            // Tick span under the step context the engine just ran,
            // plus one counter sample at the post-tick clock.
            let t1 = self.engine.clock();
            self.engine.timeline.tick_span(t0, t1);
            let pool = self.engine.host_pool_stats();
            self.samples.push(TickSample {
                t: t1,
                queue_depth: self.queued.len() + self.parked.len(),
                active_sessions: self.active.len(),
                kv_bytes: self.active.iter().map(|a| a.sess.kv_bytes()).sum::<u64>()
                    + self.parked.iter().map(|p| p.sess.kv_bytes()).sum::<u64>(),
                cache_bytes: self.engine.cache.used_bytes(),
                host_pool_hits: pool.host_hits,
                host_pool_fills: pool.ssd_fills,
                host_pool_stall_s: pool.stall_s,
            });
        }
        Ok(())
    }

    /// Advance until the clock reaches `horizon` or the replica runs
    /// out of work.  The event-driven cluster scheduler calls this
    /// between two boundary events (the next arrival or churn instant):
    /// replicas do not interact through dispatch or churn in that
    /// window, so this exact tick sequence is what min-clock stepping
    /// would have performed one event at a time — and it is independent
    /// per replica, which is what lets the cluster advance replicas on
    /// parallel workers without changing a single outcome bit.
    pub fn advance_until(&mut self, horizon: f64) -> Result<()> {
        while self.has_work() && self.clock() < horizon {
            self.tick()?;
        }
        Ok(())
    }

    /// Stamp an instant marker on the replica's timeline (the cluster
    /// layer marks churn events with this so a trace shows *when* a
    /// replica failed or began draining).  No-op unless recording.
    pub fn mark(&mut self, t: f64, label: &str) {
        self.engine.timeline.marker(t, label);
    }

    /// Apply the engine's host-pool journal to the shared pool (the
    /// cluster's event-boundary barrier).  No-op without `--host-pool`.
    pub fn flush_host_pool(&mut self) {
        self.engine.flush_host_pool();
    }

    /// Detach the engine's host-pool handle (final flush included) and
    /// return its lifetime stats; zeros without `--host-pool`.
    pub fn detach_host_pool(&mut self) -> crate::memory::PoolStats {
        self.engine.detach_host_pool()
    }

    /// Consume the replica, yielding this run's outcome (engine-counter
    /// and busy-time deltas, utilization over the run's makespan).
    pub fn finish(self) -> ReplicaRun {
        let mut out = self.out;
        out.dedup = DedupStats::from_delta(&self.stats_before, &self.engine.stats);
        out.phase = PhaseStats::from_delta(&self.stats_before, &self.engine.stats);
        let busy = self.engine.busy_totals().minus(&self.busy_before);
        out.utilization = ResourceUtil::from_busy(&busy, out.metrics.makespan(), 1);
        // This run's event suffix only (see `events_before`).
        let events = self
            .engine
            .timeline
            .events
            .get(self.events_before..)
            .unwrap_or(&[])
            .to_vec();
        let trace = TraceCapture { events, samples: self.samples };
        ReplicaRun { outcome: out, busy, state: self.state, trace }
    }

    /// Record a finished session into the run outcome under its own
    /// class and resolved SLO.
    fn record_done(
        &mut self,
        id: usize,
        arrival: f64,
        class: TenantClass,
        slo: SloTargets,
        preemptions: usize,
        sess: &EngineSession,
    ) {
        let rec = self.out.metrics.record_class(id, arrival, class, &sess.out, slo, preemptions);
        self.out.per_request.push(rec);
    }

    /// Preemption check, run once per tick before planning: when every
    /// slot is taken and a strictly more urgent class waits, ask the
    /// policy for a victim and park it (live session kept — resuming
    /// costs no engine work).  The cheap guards in front mean the
    /// policy is **never consulted** on a single-class run (or with a
    /// free slot), so stateful policies stay bit-identical on every
    /// legacy path.
    fn maybe_preempt(&mut self, now: f64) -> Result<()> {
        if self.active.len() < self.max_sessions {
            return Ok(());
        }
        let Some(urgent) = self
            .queued
            .iter()
            .map(|q| q.class.priority())
            .chain(self.parked.iter().map(|p| p.class.priority()))
            .min()
        else {
            return Ok(());
        };
        if !self.active.iter().any(|a| a.class.priority() > urgent) {
            return Ok(());
        }
        let (queued_info, active_info) = infos(&self.queued, &self.parked, &self.active);
        let view = SchedView { now, queued: &queued_info, active: &active_info, free_slots: 0 };
        let Some(vid) = self.policy.preempt_victim(&view) else {
            return Ok(());
        };
        let Some(pos) = self.active.iter().position(|a| a.id == vid) else {
            bail!("policy preempted unknown session {vid}");
        };
        ensure!(
            self.active[pos].sess.prefilled() && !self.active[pos].sess.done(),
            "policy preempted session {vid} that is not mid-decode"
        );
        let a = self.active.swap_remove(pos);
        self.parked.push(Parked {
            id: a.id,
            arrival: a.arrival,
            class: a.class,
            slo: a.slo,
            preemptions: a.preemptions + 1,
            sess: a.sess,
            last_token_at: a.last_token_at,
        });
        Ok(())
    }

    /// Resume a parked session into the freed slot the policy just
    /// granted it (pure bookkeeping — its engine session never
    /// stopped existing).  Returns false if `id` is not parked.
    fn try_resume(&mut self, id: usize) -> Result<bool> {
        let Some(pos) = self.parked.iter().position(|p| p.id == id) else {
            return Ok(false);
        };
        ensure!(
            self.active.len() < self.max_sessions,
            "policy resumed session {id} with no free slot"
        );
        let p = self.parked.swap_remove(pos);
        self.active.push(Active {
            id: p.id,
            arrival: p.arrival,
            class: p.class,
            slo: p.slo,
            preemptions: p.preemptions,
            sess: p.sess,
            last_token_at: p.last_token_at,
        });
        self.out.peak_concurrency = self.out.peak_concurrency.max(self.active.len());
        Ok(true)
    }

    /// One step of the pre-chunking fleet loop: admission runs the
    /// session's whole prefill as one scheduling step (`Action::Admit`),
    /// decode steps batch across sessions.  Kept verbatim from the
    /// pre-refactor `run_fleet_monolithic` body so `--chunk-tokens 0`
    /// reproduces the legacy path step for step.
    fn tick_monolithic(&mut self) -> Result<()> {
        let now = self.engine.clock();
        // Preemption first: parking a victim frees the slot the urgent
        // request is then admitted into by the normal planning below,
        // so a preempting tick still runs engine work (the prefill).
        self.maybe_preempt(now)?;
        let (queued_info, active_info) = infos(&self.queued, &self.parked, &self.active);
        let free_slots = self.max_sessions.saturating_sub(self.active.len());
        let view = SchedView {
            now,
            queued: &queued_info,
            active: &active_info,
            free_slots,
        };
        let mut action = self.policy.next_action(&view);
        if action == Action::Idle {
            // Work-conserving fallback so a policy bug can never wedge
            // the loop: admit if possible, else decode something.
            action = if free_slots > 0 && !queued_info.is_empty() {
                // Oldest arrival (ties by id) over queued and parked,
                // like the chunked fallback: admission removes with
                // `swap_remove`, so after any prior admission index 0
                // holds whatever request was swapped into the hole, not
                // the oldest.
                let oldest = queued_info
                    .iter()
                    .min_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)))
                    .expect("non-empty queue");
                Action::Admit(oldest.id)
            } else if let Some(a) = self.active.first() {
                Action::Decode(a.id)
            } else {
                // queue non-empty but no slots and nothing active cannot
                // happen (max_sessions >= 1); guard anyway
                bail!("scheduler idle with {} queued sessions", self.queued.len());
            };
        }

        match action {
            Action::Admit(id) => {
                // A parked session re-enters by plain resume: its live
                // engine session (KV + emitted tokens) was conserved,
                // so no engine work happens until the next decode tick.
                if self.try_resume(id)? {
                    return Ok(());
                }
                let Some(pos) = self.queued.iter().position(|q| q.id == id) else {
                    bail!("policy admitted unknown session {id}");
                };
                if self.active.len() >= self.max_sessions {
                    bail!("policy admitted session {id} with no free slot");
                }
                let q = self.queued.swap_remove(pos);
                // Service is gated at `earliest` (== arrival except for
                // failure restarts); metrics stay keyed to the arrival.
                let mut sess = self
                    .engine
                    .begin_session(&q.request.prompt, q.request.max_new, None, q.earliest)
                    .with_context(|| format!("admitting session {id}"))?;
                sess.set_trace_tag(q.id as u64);
                self.engine
                    .prefill_session(&mut sess)
                    .with_context(|| format!("prefill session {id}"))?;
                self.out.steps += 1;
                self.out.peak_concurrency =
                    self.out.peak_concurrency.max(self.active.len() + 1);
                let kv_in_flight: u64 =
                    self.active.iter().map(|a| a.sess.kv_bytes()).sum::<u64>()
                        + self.parked.iter().map(|p| p.sess.kv_bytes()).sum::<u64>()
                        + sess.kv_bytes();
                self.out.peak_kv_bytes = self.out.peak_kv_bytes.max(kv_in_flight);
                let last_token_at = sess.out.start + sess.out.ttft;
                if sess.done() {
                    self.record_done(q.id, q.arrival, q.class, q.slo, 0, &sess);
                } else {
                    self.active.push(Active {
                        id: q.id,
                        arrival: q.arrival,
                        class: q.class,
                        slo: q.slo,
                        preemptions: 0,
                        sess,
                        last_token_at,
                    });
                }
            }
            Action::Decode(id) => {
                // Batch formation: the policy extends its pick into a
                // decode batch of ready sessions (knob: max_decode_batch;
                // 1 keeps the serial interleaved path, step for step).
                let batch_ids = if self.max_decode_batch > 1 && self.active.len() > 1 {
                    self.policy.decode_batch(&view, id, self.max_decode_batch)
                } else {
                    vec![id]
                };
                if batch_ids.len() <= 1 {
                    let lone = batch_ids.first().copied().unwrap_or(id);
                    let Some(pos) = self.active.iter().position(|a| a.id == lone) else {
                        bail!("policy decoded unknown session {lone}");
                    };
                    let a = &mut self.active[pos];
                    let done = self
                        .engine
                        .decode_session(&mut a.sess)
                        .with_context(|| format!("decode session {lone}"))?;
                    self.out.steps += 1;
                    a.last_token_at = a.sess.out.start
                        + a.sess.out.token_times.last().copied().unwrap_or(0.0);
                    if done {
                        let a = self.active.swap_remove(pos);
                        self.record_done(a.id, a.arrival, a.class, a.slo, a.preemptions, &a.sess);
                    }
                } else {
                    if !batch_ids.contains(&id) {
                        bail!("policy dropped its own pick {id} from the decode batch");
                    }
                    let mut batch: Vec<Active> = Vec::with_capacity(batch_ids.len());
                    for bid in &batch_ids {
                        let Some(pos) = self.active.iter().position(|a| a.id == *bid)
                        else {
                            bail!("policy batched unknown or duplicate session {bid}");
                        };
                        batch.push(self.active.swap_remove(pos));
                    }
                    let dones = {
                        let mut refs: Vec<&mut EngineSession> =
                            batch.iter_mut().map(|a| &mut a.sess).collect();
                        self.engine
                            .decode_batch(&mut refs)
                            .with_context(|| format!("decode batch {batch_ids:?}"))?
                    };
                    self.out.steps += 1;
                    for (mut a, done) in batch.into_iter().zip(dones) {
                        a.last_token_at = a.sess.out.start
                            + a.sess.out.token_times.last().copied().unwrap_or(0.0);
                        if done {
                            self.record_done(
                                a.id,
                                a.arrival,
                                a.class,
                                a.slo,
                                a.preemptions,
                                &a.sess,
                            );
                        } else {
                            self.active.push(a);
                        }
                    }
                }
            }
            Action::Idle => unreachable!("idle resolved above"),
        }
        Ok(())
    }

    /// One step of the token-budget continuous loop (`chunk_tokens >
    /// 0`): admission only allocates session slots, then the policy
    /// plans a fused mixed step — up to `chunk_tokens` prompt tokens of
    /// one prefilling session plus up to `max_decode_batch` decode
    /// tokens — executed by [`Engine::mixed_step`] as one per-layer
    /// pass.  Kept verbatim from the pre-refactor `run_fleet_chunked`
    /// body.
    fn tick_chunked(&mut self) -> Result<()> {
        let now = self.engine.clock();
        let chunk_tokens = self.chunk_tokens;
        let max_seq = self.max_seq;
        let max_decode_batch = self.max_decode_batch;

        // Preemption first, so the freed slot is filled by the normal
        // admission loop below in the same tick.
        self.maybe_preempt(now)?;

        // Admission allocates slots only (prefill happens chunk by
        // chunk), so free slots fill every tick in policy order.
        // Parked sessions compete through the same pick and resume in
        // place (no engine work).
        while self.active.len() < self.max_sessions
            && !(self.queued.is_empty() && self.parked.is_empty())
        {
            let (queued_info, active_info) = infos(&self.queued, &self.parked, &self.active);
            let free_slots = self.max_sessions - self.active.len();
            let view =
                SchedView { now, queued: &queued_info, active: &active_info, free_slots };
            let Some(id) = self.policy.admit_pick(&view) else { break };
            if self.try_resume(id)? {
                continue;
            }
            let Some(pos) = self.queued.iter().position(|q| q.id == id) else {
                bail!("policy admitted unknown session {id}");
            };
            let q = self.queued.swap_remove(pos);
            // Service gated at `earliest` (== arrival except for
            // failure restarts); metrics stay keyed to the arrival.
            let mut sess = self
                .engine
                .begin_session(&q.request.prompt, q.request.max_new, None, q.earliest)
                .with_context(|| format!("admitting session {id}"))?;
            sess.set_trace_tag(q.id as u64);
            self.active.push(Active {
                id: q.id,
                arrival: q.arrival,
                class: q.class,
                slo: q.slo,
                preemptions: 0,
                sess,
                last_token_at: q.arrival,
            });
            self.out.peak_concurrency = self.out.peak_concurrency.max(self.active.len());
            let kv_in_flight: u64 = self.active.iter().map(|a| a.sess.kv_bytes()).sum::<u64>()
                + self.parked.iter().map(|p| p.sess.kv_bytes()).sum::<u64>();
            self.out.peak_kv_bytes = self.out.peak_kv_bytes.max(kv_in_flight);
        }
        if self.active.is_empty() {
            // queue non-empty but zero slots cannot happen (max_sessions
            // >= 1 and the admit loop always places someone); guard.
            bail!("chunked scheduler wedged with {} queued sessions", self.queued.len());
        }

        // Token-budget tick plan: one prefill chunk + a decode batch.
        let (queued_info, active_info) = infos(&self.queued, &self.parked, &self.active);
        let free_slots = self.max_sessions - self.active.len();
        let view =
            SchedView { now, queued: &queued_info, active: &active_info, free_slots };
        // Hand the policy the decode budget that will actually fit next
        // to the worst-case chunk grant, so a stateful policy (round-
        // robin's rotation cursor) never advances past sessions a later
        // truncation would drop from the batch.
        let chunk_cap = active_info
            .iter()
            .map(|a| a.prefill_remaining.min(chunk_tokens))
            .max()
            .unwrap_or(0);
        let decode_budget = max_decode_batch.min(max_seq - chunk_cap);
        let mut plan = self.policy.mixed_tick(&view, decode_budget);
        if plan.is_empty() {
            // Work-conserving fallback so a policy bug can never wedge
            // the loop: chunk the oldest prefilling session, else decode
            // the first ready one.
            let pre = active_info.iter().find(|a| a.prefill_remaining > 0).map(|a| a.id);
            // Clamp the fallback to the tick's decode budget: with
            // `chunk_tokens >= max_seq` a full-length prompt grants the
            // whole expert token bucket to the chunk (`decode_budget ==
            // 0`), and an unclamped fallback decode would trip the
            // budget ensure below and abort a legitimate run.
            let dec: Vec<usize> = active_info
                .iter()
                .filter(|a| a.decode_ready())
                .take(decode_budget.min(1))
                .map(|a| a.id)
                .collect();
            ensure!(
                pre.is_some() || !dec.is_empty(),
                "chunked scheduler idle with {} active sessions",
                self.active.len()
            );
            plan = TickPlan { prefill: pre, decode: dec };
        }

        // Validate the plan and split the borrow: the prefill session
        // and every decode session come out of `active` by value.
        let prefill_pos = match plan.prefill {
            Some(id) => {
                let Some(pos) = self.active.iter().position(|a| a.id == id) else {
                    bail!("policy chunked unknown session {id}");
                };
                ensure!(
                    self.active[pos].sess.prefill_remaining() > 0,
                    "policy chunked a prefilled session {id}"
                );
                Some(pos)
            }
            None => None,
        };
        let mut prefill_active = prefill_pos.map(|pos| self.active.swap_remove(pos));
        ensure!(
            plan.decode.len() <= decode_budget,
            "decode batch {} exceeds the per-tick budget {decode_budget}",
            plan.decode.len()
        );
        // The chunk is granted first; decode fills what the expert token
        // bucket has left.  With the budget handed to the policy above
        // this truncation is a no-op (granted <= chunk_cap), kept as a
        // belt-and-braces bound for misbehaving policies.
        let granted = prefill_active
            .as_ref()
            .map(|a| chunk_tokens.min(a.sess.prefill_remaining()))
            .unwrap_or(0);
        plan.decode.truncate(max_seq - granted);
        let mut batch: Vec<Active> = Vec::with_capacity(plan.decode.len());
        for bid in &plan.decode {
            let Some(pos) = self.active.iter().position(|a| a.id == *bid) else {
                bail!("policy batched unknown or duplicate session {bid}");
            };
            ensure!(
                self.active[pos].sess.prefilled() && !self.active[pos].sess.done(),
                "policy batched session {bid} that is not ready to decode"
            );
            batch.push(self.active.swap_remove(pos));
        }

        let report = {
            let pre_ref = prefill_active.as_mut().map(|a| (&mut a.sess, chunk_tokens));
            let mut refs: Vec<&mut EngineSession> =
                batch.iter_mut().map(|a| &mut a.sess).collect();
            self.engine.mixed_step(pre_ref, &mut refs).with_context(|| {
                format!(
                    "mixed tick (chunk session {:?}, decode {:?})",
                    plan.prefill, plan.decode
                )
            })?
        };
        self.out.steps += 1;

        if let Some(mut a) = prefill_active {
            if report.prefill_done {
                a.last_token_at =
                    a.sess.out.start + a.sess.out.token_times.last().copied().unwrap_or(0.0);
                if a.sess.done() {
                    self.record_done(a.id, a.arrival, a.class, a.slo, a.preemptions, &a.sess);
                } else {
                    self.active.push(a);
                }
            } else {
                self.active.push(a);
            }
        }
        for (mut a, done) in batch.into_iter().zip(report.dones) {
            a.last_token_at =
                a.sess.out.start + a.sess.out.token_times.last().copied().unwrap_or(0.0);
            if done {
                self.record_done(a.id, a.arrival, a.class, a.slo, a.preemptions, &a.sess);
            } else {
                self.active.push(a);
            }
        }
        Ok(())
    }
}

//! Pluggable serving policies at both levels of the stack:
//!
//! * **Continuous-scheduling policies** ([`SchedPolicy`], selected by
//!   [`PolicyKind`]) run *inside one replica*: given a snapshot of the
//!   admission queue and the in-flight sessions, pick the engine's next
//!   step (admit-and-prefill one queued request, or decode one token of
//!   an active session).
//! * **Dispatch policies** ([`DispatchPolicy`], selected by
//!   [`DispatchKind`]) run *in front of the cluster*: route each
//!   arriving request to one of the replicas
//!   ([`crate::serving::run_cluster`]).
//!
//! With chunked prefill enabled (`--chunk-tokens > 0`) the fleet loop
//! instead asks the policy for a **token-budget tick plan**
//! ([`SchedPolicy::mixed_tick`]): at most one prefilling session gets
//! this tick's chunk budget and up to `--max-decode-batch` ready
//! sessions decode fused with it.  The policies decide the prefill /
//! decode mix with the same orderings they use for serial steps (fifo
//! arrival order, rr rotation, slo least-recently-served).
//!
//! All three policies are work-conserving; they differ in *ordering*:
//!
//! * [`PolicyKind::Fifo`] — strict arrival order, run-to-completion: the
//!   oldest unfinished session monopolizes the device.  This is the
//!   head-of-line-blocking baseline and degenerates to the classic
//!   back-to-back `serve` path.  Fifo is also the **class-blind**
//!   baseline: it ignores [`TenantClass`] everywhere and never preempts.
//! * [`PolicyKind::RoundRobin`] — continuous batching with decode
//!   fairness: free slots admit the most urgent class's oldest queued
//!   request first (prefill prioritized, which bounds TTFT), decode
//!   steps rotate round-robin so no session's TPOT starves.
//! * [`PolicyKind::SloAware`] — TTFT-SLO earliest-deadline-first within
//!   class priority: free slots admit the queued request whose TTFT
//!   deadline expires soonest (interactive before batch), and decode
//!   picks the session that has waited longest since its last token
//!   (least-recently-served), spreading TPOT jitter under load.
//!
//! **Tenant classes.** Every queued/active entry carries its
//! [`TenantClass`]; class-aware policies order by `class.priority()`
//! first (interactive before batch) and may name a **preemption
//! victim** ([`SchedPolicy::preempt_victim`]) when the slots are full
//! and a strictly more urgent request waits.  With a single class every
//! priority key ties, so all orderings reduce bit-exactly to the
//! pre-class behavior and no preemption ever fires.

use anyhow::{bail, Result};

use super::arrival::{TenantClass, TimedRequest};

/// A queued (arrived, not yet admitted) request.
#[derive(Debug, Clone, Copy)]
pub struct QueuedInfo {
    pub id: usize,
    pub arrival: f64,
    /// Absolute TTFT deadline: `arrival + ttft_slo`.
    pub deadline: f64,
    pub class: TenantClass,
}

/// An admitted, still-running session (prefilling or decoding).
#[derive(Debug, Clone, Copy)]
pub struct ActiveInfo {
    pub id: usize,
    pub arrival: f64,
    pub class: TenantClass,
    /// Tokens emitted so far (>= 1 once prefilled).
    pub emitted: usize,
    /// Total tokens the session will emit.
    pub target: usize,
    /// Absolute virtual time of the last emitted token.
    pub last_token_at: f64,
    /// Prompt tokens still to prefill; 0 once the first token exists.
    /// Only ever positive under chunked prefill, where admitted
    /// sessions prefill incrementally across ticks.
    pub prefill_remaining: usize,
}

impl ActiveInfo {
    /// Ready to decode: prefilled and not yet at its token target.
    pub fn decode_ready(&self) -> bool {
        self.prefill_remaining == 0 && self.emitted < self.target
    }
}

/// A policy's plan for one token-budget tick of the chunked continuous
/// scheduler: at most one prefilling session receives the tick's chunk
/// budget, and up to the decode-batch limit of ready sessions decode
/// fused with it in the same per-layer engine pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TickPlan {
    /// Active session to grant this tick's prefill chunk (must have
    /// `prefill_remaining > 0`).
    pub prefill: Option<usize>,
    /// Ready active sessions to decode this tick (distinct, each with
    /// `prefill_remaining == 0` and tokens left to emit).
    pub decode: Vec<usize>,
}

impl TickPlan {
    pub fn is_empty(&self) -> bool {
        self.prefill.is_none() && self.decode.is_empty()
    }
}

/// Scheduler snapshot handed to a policy.
#[derive(Debug)]
pub struct SchedView<'a> {
    pub now: f64,
    pub queued: &'a [QueuedInfo],
    pub active: &'a [ActiveInfo],
    /// Admission slots still free (`max_sessions - active.len()`).
    pub free_slots: usize,
}

/// The policy's pick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// Admit the queued request with this id and run its prefill.
    Admit(usize),
    /// Decode one token of the active session with this id.
    Decode(usize),
    /// Nothing runnable (queue empty or slots full, nothing active).
    Idle,
}

/// A continuous-scheduling policy (may keep state, e.g. a rotation
/// cursor).
pub trait SchedPolicy {
    fn name(&self) -> &'static str;
    fn next_action(&mut self, view: &SchedView) -> Action;

    /// Form this tick's cross-session decode batch around the session
    /// the policy just picked with [`SchedPolicy::next_action`].
    /// Returns distinct active-session ids, `lead` first, at most `max`
    /// of them; every id must be active.  The default fills the batch
    /// with the remaining active sessions most-urgent-class first, then
    /// least-recently-served (ties by id), which matches the SLO-aware
    /// decode order; policies with their own decode ordering (e.g.
    /// round-robin) override it.
    fn decode_batch(&mut self, view: &SchedView, lead: usize, max: usize) -> Vec<usize> {
        let mut ids = vec![lead];
        if max <= 1 {
            return ids;
        }
        let mut rest: Vec<&ActiveInfo> =
            view.active.iter().filter(|a| a.id != lead).collect();
        rest.sort_by(|a, b| class_lrs_order(a, b));
        for a in rest {
            if ids.len() >= max {
                break;
            }
            ids.push(a.id);
        }
        ids
    }

    /// Pick the queued request to admit next (chunked-prefill loop:
    /// admission allocates a session slot without doing prefill work, so
    /// free slots are filled every tick).  Default: most urgent class
    /// first, oldest arrival within it; the SLO-aware policy overrides
    /// with earliest deadline (also within class priority) and fifo —
    /// the class-blind baseline — with strict arrival order.
    fn admit_pick(&mut self, view: &SchedView) -> Option<usize> {
        if view.free_slots == 0 {
            return None;
        }
        oldest_queued(view.queued)
    }

    /// Plan one token-budget tick of the chunked continuous scheduler:
    /// at most one prefilling session to receive this tick's chunk
    /// budget plus up to `max_decode` ready sessions to decode fused
    /// with it.  Default: the most-urgent-class oldest-arrival
    /// prefilling session, and decode filled most-urgent-class
    /// least-recently-served first (ties by id) — the SLO-aware decode
    /// order.  Policies with their own decode ordering (fifo arrival
    /// order, round-robin rotation) override it.
    fn mixed_tick(&mut self, view: &SchedView, max_decode: usize) -> TickPlan {
        let prefill = oldest_prefilling(view.active);
        let mut ready: Vec<&ActiveInfo> =
            view.active.iter().filter(|a| a.decode_ready()).collect();
        ready.sort_by(|a, b| class_lrs_order(a, b));
        let decode = ready.iter().take(max_decode).map(|a| a.id).collect();
        TickPlan { prefill, decode }
    }

    /// Name an in-flight session to **preempt** so a strictly more
    /// urgent queued request can take its slot.  The replica parks the
    /// victim's live session (work conserved — prefix KV and emitted
    /// tokens survive) and re-admits it through the normal queue, so
    /// this only fires when it buys the urgent request a slot *now*:
    /// every slot is taken and at least one queued request outranks an
    /// in-flight session.
    ///
    /// Default: victim is the lowest-priority *prefilled* session —
    /// preempting mid-prefill would discard the only work done so far —
    /// with the most tokens still to emit (the cheapest slot to vacate
    /// per token of displaced progress), ties toward the highest id
    /// (youngest session).  Returns `None` when nothing queued strictly
    /// outranks every candidate.  Fifo — the class-blind baseline —
    /// overrides this to never preempt.  With a single tenant class no
    /// queued request can outrank an active one, so this is dead code
    /// on every legacy path.
    fn preempt_victim(&mut self, view: &SchedView) -> Option<usize> {
        if view.free_slots > 0 {
            return None;
        }
        let urgent = view.queued.iter().map(|q| q.class.priority()).min()?;
        view.active
            .iter()
            .filter(|a| a.decode_ready() && a.class.priority() > urgent)
            .max_by(|a, b| {
                a.class
                    .priority()
                    .cmp(&b.class.priority())
                    .then((a.target - a.emitted).cmp(&(b.target - b.emitted)))
                    .then(a.id.cmp(&b.id))
            })
            .map(|a| a.id)
    }
}

/// Policy selector (config / CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Fifo,
    RoundRobin,
    SloAware,
}

impl PolicyKind {
    pub fn parse(name: &str) -> Result<PolicyKind> {
        Ok(match name {
            "fifo" => PolicyKind::Fifo,
            "rr" | "round-robin" => PolicyKind::RoundRobin,
            "slo" | "slo-aware" => PolicyKind::SloAware,
            _ => bail!("unknown scheduling policy {name:?}; try fifo, rr, slo"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::RoundRobin => "rr",
            PolicyKind::SloAware => "slo",
        }
    }

    pub fn build(self) -> Box<dyn SchedPolicy> {
        match self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::RoundRobin => Box::new(RoundRobin { cursor: None }),
            PolicyKind::SloAware => Box::new(SloAware),
        }
    }

    pub const ALL: [PolicyKind; 3] =
        [PolicyKind::Fifo, PolicyKind::RoundRobin, PolicyKind::SloAware];
}

/// Class-aware queue order: most urgent class first, oldest arrival
/// within it, ties by id.  Single-class input reduces to strict arrival
/// order (the pre-class behavior, bit-exactly).
fn oldest_queued(queued: &[QueuedInfo]) -> Option<usize> {
    queued
        .iter()
        .min_by(|a, b| {
            a.class
                .priority()
                .cmp(&b.class.priority())
                .then(a.arrival.total_cmp(&b.arrival))
                .then(a.id.cmp(&b.id))
        })
        .map(|q| q.id)
}

/// Strict arrival order, class-blind (the fifo baseline's queue pick).
fn fifo_queued(queued: &[QueuedInfo]) -> Option<usize> {
    queued
        .iter()
        .min_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)))
        .map(|q| q.id)
}

/// Class-aware decode order: most urgent class first, then
/// least-recently-served, ties by id (the SLO-aware decode order;
/// single-class input reduces bit-exactly to plain LRS).
fn class_lrs_order(a: &ActiveInfo, b: &ActiveInfo) -> std::cmp::Ordering {
    a.class
        .priority()
        .cmp(&b.class.priority())
        .then(a.last_token_at.total_cmp(&b.last_token_at))
        .then(a.id.cmp(&b.id))
}

/// The prefilling session class-aware policies grant the chunk budget
/// to: most urgent class first, oldest arrival within it, ties by id
/// (shared by the default and round-robin `mixed_tick`s so their
/// prefill ordering cannot silently fork; fifo — the class-blind
/// baseline — keeps strict arrival order via [`fifo_prefilling`]).
fn oldest_prefilling(active: &[ActiveInfo]) -> Option<usize> {
    active
        .iter()
        .filter(|a| a.prefill_remaining > 0)
        .min_by(|a, b| {
            a.class
                .priority()
                .cmp(&b.class.priority())
                .then(a.arrival.total_cmp(&b.arrival))
                .then(a.id.cmp(&b.id))
        })
        .map(|a| a.id)
}

/// Oldest-arrival prefilling session, class-blind (fifo's chunk pick).
fn fifo_prefilling(active: &[ActiveInfo]) -> Option<usize> {
    active
        .iter()
        .filter(|a| a.prefill_remaining > 0)
        .min_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)))
        .map(|a| a.id)
}

/// Strict arrival order, one session at a time.  Also the class-blind
/// baseline: ignores [`TenantClass`] at every decision point and never
/// preempts, so mixed-tenant sweeps can measure what class-aware
/// scheduling buys against it.
struct Fifo;

impl SchedPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn next_action(&mut self, view: &SchedView) -> Action {
        // Finish the oldest active session before touching the queue.
        if let Some(a) = view
            .active
            .iter()
            .min_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)))
        {
            return Action::Decode(a.id);
        }
        match (view.free_slots > 0).then(|| fifo_queued(view.queued)).flatten() {
            Some(id) => Action::Admit(id),
            None => Action::Idle,
        }
    }

    /// Strict arrival order also for slot admission under chunked
    /// scheduling (the class-aware default would reorder by class).
    fn admit_pick(&mut self, view: &SchedView) -> Option<usize> {
        if view.free_slots == 0 {
            return None;
        }
        fifo_queued(view.queued)
    }

    /// Chunked ticks keep fifo's arrival ordering at every decision
    /// point: the oldest prefilling session gets the chunk budget and
    /// the oldest ready sessions fill the decode batch (only the decode
    /// sort key differs from the default tick plan).
    fn mixed_tick(&mut self, view: &SchedView, max_decode: usize) -> TickPlan {
        let prefill = fifo_prefilling(view.active);
        let mut ready: Vec<&ActiveInfo> =
            view.active.iter().filter(|a| a.decode_ready()).collect();
        ready.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        let decode = ready.iter().take(max_decode).map(|a| a.id).collect();
        TickPlan { prefill, decode }
    }

    /// The class-blind baseline never preempts.
    fn preempt_victim(&mut self, _view: &SchedView) -> Option<usize> {
        None
    }
}

/// FIFO admission (prefill prioritized), round-robin decode.
struct RoundRobin {
    /// Last session id decoded (`None` before the first decode).
    cursor: Option<usize>,
}

impl SchedPolicy for RoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn next_action(&mut self, view: &SchedView) -> Action {
        if view.free_slots > 0 {
            if let Some(id) = oldest_queued(view.queued) {
                return Action::Admit(id);
            }
        }
        if view.active.is_empty() {
            return Action::Idle;
        }
        // Rotate by id order so the cursor is stable as sessions retire.
        let mut ids: Vec<usize> = view.active.iter().map(|a| a.id).collect();
        ids.sort_unstable();
        let pick = ids
            .iter()
            .copied()
            .find(|&id| Some(id) > self.cursor)
            .unwrap_or(ids[0]);
        self.cursor = Some(pick);
        Action::Decode(pick)
    }

    /// Round-robin batches continue the rotation: the lead plus the next
    /// active ids in id order (wrapping), and the cursor advances to the
    /// last batched session so the next tick picks up after the batch.
    fn decode_batch(&mut self, view: &SchedView, lead: usize, max: usize) -> Vec<usize> {
        let mut ids: Vec<usize> = view.active.iter().map(|a| a.id).collect();
        ids.sort_unstable();
        let start = ids.iter().position(|&id| id == lead).unwrap_or(0);
        let picked: Vec<usize> = (0..ids.len())
            .map(|off| ids[(start + off) % ids.len()])
            .take(max.max(1))
            .collect();
        if let Some(&last) = picked.last() {
            self.cursor = Some(last);
        }
        picked
    }

    /// Chunked ticks rotate the decode batch over the *ready* sessions
    /// (id order, wrapping past the cursor) while the oldest prefilling
    /// session gets the chunk budget; the cursor advances past the
    /// batch so the next tick continues the rotation.
    fn mixed_tick(&mut self, view: &SchedView, max_decode: usize) -> TickPlan {
        let prefill = oldest_prefilling(view.active);
        let mut ids: Vec<usize> = view
            .active
            .iter()
            .filter(|a| a.decode_ready())
            .map(|a| a.id)
            .collect();
        ids.sort_unstable();
        let decode: Vec<usize> = if ids.is_empty() {
            Vec::new()
        } else {
            let start = ids
                .iter()
                .position(|&id| Some(id) > self.cursor)
                .unwrap_or(0);
            (0..ids.len())
                .map(|off| ids[(start + off) % ids.len()])
                .take(max_decode)
                .collect()
        };
        if let Some(&last) = decode.last() {
            self.cursor = Some(last);
        }
        TickPlan { prefill, decode }
    }
}

/// EDF admission on the TTFT deadline (within class priority),
/// least-recently-served decode (most urgent class first).
struct SloAware;

/// EDF within class priority: interactive deadlines always outrank
/// batch deadlines, however lax the interactive SLO (single-class input
/// reduces bit-exactly to plain EDF).
fn edf_queued(queued: &[QueuedInfo]) -> Option<usize> {
    queued
        .iter()
        .min_by(|a, b| {
            a.class
                .priority()
                .cmp(&b.class.priority())
                .then(a.deadline.total_cmp(&b.deadline))
                .then(a.id.cmp(&b.id))
        })
        .map(|q| q.id)
}

impl SchedPolicy for SloAware {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn next_action(&mut self, view: &SchedView) -> Action {
        if view.free_slots > 0 {
            if let Some(id) = edf_queued(view.queued) {
                return Action::Admit(id);
            }
        }
        match view.active.iter().min_by(|a, b| class_lrs_order(a, b)) {
            Some(a) => Action::Decode(a.id),
            None => Action::Idle,
        }
    }

    /// EDF admission also under chunked scheduling: the queued request
    /// whose TTFT deadline expires soonest (within class priority)
    /// claims the free slot.
    fn admit_pick(&mut self, view: &SchedView) -> Option<usize> {
        if view.free_slots == 0 {
            return None;
        }
        edf_queued(view.queued)
    }
}

// ---------------------------------------------------------------------
// Cluster-level dispatch policies
// ---------------------------------------------------------------------

/// Dispatcher-visible snapshot of one replica (what a cluster front-end
/// can observe without touching the replica's engine).
#[derive(Debug, Clone)]
pub struct ReplicaDispatchView {
    /// Replica index in the cluster (`0..replicas`).
    pub index: usize,
    /// The replica's virtual clock (its engine's compute horizon).
    pub clock: f64,
    /// Requests waiting in the replica's admission queue.
    pub queued_requests: usize,
    /// Prompt + generation tokens still owed by queued requests.
    pub queued_tokens: usize,
    /// Admitted, unfinished sessions.
    pub active_sessions: usize,
    /// Prompt + generation tokens still owed by active sessions.
    pub active_tokens: usize,
    /// Bytes of expert weights resident in the replica's tiers, per
    /// expert id (summed over layers: VRAM cache plus the replica's
    /// view of the shared host pool).  The predictive dispatcher's
    /// byte-weighted overlap signal.  Empty — and uncomputed, so the
    /// snapshot stays O(1) — for every non-predictive policy.
    pub resident_expert_bytes: Vec<u64>,
}

impl ReplicaDispatchView {
    /// Total tokens of outstanding work visible to the dispatcher (the
    /// join-shortest-queue load signal).
    pub fn backlog_tokens(&self) -> usize {
        self.queued_tokens + self.active_tokens
    }
}

/// A cluster dispatch policy: route each arriving request to one of the
/// **offered** replicas.  May keep state (e.g. a rotation cursor); must
/// return a *position* into the `replicas` slice (`< replicas.len()`
/// for a non-empty slice).  Under churn the cluster offers only live
/// replicas — dead and draining ones are excluded from the slice — so
/// positions are not replica ids; the caller maps the pick back through
/// [`ReplicaDispatchView::index`].  With every replica live (the
/// churn-free cluster) position and index coincide, so routing is
/// bit-identical to the pre-churn dispatcher.
///
/// The event-driven cluster calls `route` once per **arrival event**
/// (in virtual-time order, ties by request id), offering the liveness-
/// filtered view at that instant; because dispatch happens only at
/// event boundaries — never while replicas tick between boundaries —
/// the views a policy sees are identical under serial and parallel
/// execution, which is what makes `--parallel` bit-identical.
pub trait DispatchPolicy {
    fn name(&self) -> &'static str;
    fn route(&mut self, req: &TimedRequest, replicas: &[ReplicaDispatchView]) -> usize;

    /// Route with a gate-probe prediction of the request's expert set
    /// (expert ids, most-frequent first).  The cluster calls this —
    /// instead of [`DispatchPolicy::route`] — when a dispatcher-side
    /// probe ran; policies that don't exploit predictions just ignore
    /// them, so the default forwards to `route`.
    fn route_predicted(
        &mut self,
        req: &TimedRequest,
        replicas: &[ReplicaDispatchView],
        predicted: &[usize],
    ) -> usize {
        let _ = predicted;
        self.route(req, replicas)
    }
}

/// Dispatch policy selector (config / CLI surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchKind {
    /// Cycle through replicas in arrival order (oblivious baseline).
    RoundRobin,
    /// Route to the replica with the fewest outstanding tokens (queued
    /// prompt + generation tokens plus in-flight remaining work).
    JoinShortestQueue,
    /// Hash the prompt's predicted hot experts to a replica, so prompts
    /// that route to similar experts land on the same warm expert cache.
    ExpertAffinity,
    /// Probe the layer-0 gate on the prompt prefix at dispatch time and
    /// route to the replica whose resident experts (VRAM cache + host
    /// pool view) overlap the *actual* predicted expert set by the most
    /// bytes; ties go to the shorter backlog, degrading to jsq-like
    /// routing when nothing is resident.
    Predictive,
}

impl DispatchKind {
    pub fn parse(name: &str) -> Result<DispatchKind> {
        Ok(match name {
            "rr" | "round-robin" => DispatchKind::RoundRobin,
            "jsq" | "shortest-queue" => DispatchKind::JoinShortestQueue,
            "affinity" | "expert-affinity" => DispatchKind::ExpertAffinity,
            "predictive" | "probe" => DispatchKind::Predictive,
            _ => bail!("unknown dispatch policy {name:?}; try rr, jsq, affinity, predictive"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DispatchKind::RoundRobin => "rr",
            DispatchKind::JoinShortestQueue => "jsq",
            DispatchKind::ExpertAffinity => "affinity",
            DispatchKind::Predictive => "predictive",
        }
    }

    pub fn build(self) -> Box<dyn DispatchPolicy> {
        match self {
            DispatchKind::RoundRobin => Box::new(DispatchRoundRobin { next: 0 }),
            DispatchKind::JoinShortestQueue => Box::new(JoinShortestQueue),
            DispatchKind::ExpertAffinity => Box::new(ExpertAffinity),
            DispatchKind::Predictive => Box::new(PredictiveDispatch),
        }
    }

    pub const ALL: [DispatchKind; 4] = [
        DispatchKind::RoundRobin,
        DispatchKind::JoinShortestQueue,
        DispatchKind::ExpertAffinity,
        DispatchKind::Predictive,
    ];
}

struct DispatchRoundRobin {
    next: usize,
}

impl DispatchPolicy for DispatchRoundRobin {
    fn name(&self) -> &'static str {
        "rr"
    }

    fn route(&mut self, _req: &TimedRequest, replicas: &[ReplicaDispatchView]) -> usize {
        let pick = self.next % replicas.len().max(1);
        self.next = pick + 1;
        pick
    }
}

/// Join-shortest-queue by outstanding tokens (ties by replica index, so
/// routing is deterministic).
struct JoinShortestQueue;

impl DispatchPolicy for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(&mut self, _req: &TimedRequest, replicas: &[ReplicaDispatchView]) -> usize {
        // Returns the slice *position* of the least-loaded offered
        // replica (not its cluster index — the slice may exclude
        // churned replicas).
        replicas
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                a.backlog_tokens()
                    .cmp(&b.backlog_tokens())
                    .then(a.index.cmp(&b.index))
            })
            .map(|(pos, _)| pos)
            .unwrap_or(0)
    }
}

/// Expert-affinity dispatch: a cheap dispatcher-side prediction of the
/// prompt's hot experts.  Routing in this corpus is token-driven, so the
/// **multiset of prompt tokens** is a proxy for the expert set the
/// prompt will route to; an order-invariant hash of it sends prompts
/// with similar content to the same replica, whose mixed-precision
/// expert cache is already warm with exactly those experts.
struct ExpertAffinity;

/// SplitMix64 finalizer (deterministic, dependency-free avalanche).
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Order-invariant hash of the prompt's token multiset (summing the
/// per-token hashes commutes, so permuted prompts colocate).
pub fn prompt_affinity_hash(prompt: &[i32]) -> u64 {
    prompt
        .iter()
        .fold(0u64, |acc, &t| acc.wrapping_add(splitmix64(t as u64)))
}

/// Rendezvous (highest-random-weight) weight of one prompt on one
/// replica: a deterministic per-(prompt, replica) score.  The prompt
/// goes to the offered replica with the highest weight, so removing a
/// replica from the offered set only re-homes the prompts whose winner
/// vanished — every other prompt's argmax is untouched.
fn rendezvous_weight(prompt_hash: u64, replica_id: usize) -> u64 {
    splitmix64(prompt_hash ^ splitmix64(replica_id as u64 + 1))
}

impl DispatchPolicy for ExpertAffinity {
    fn name(&self) -> &'static str {
        "affinity"
    }

    fn route(&mut self, req: &TimedRequest, replicas: &[ReplicaDispatchView]) -> usize {
        // Rendezvous hashing over *stable* replica ids (`view.index`),
        // not positions in the liveness-filtered slice.  The previous
        // `hash % replicas.len()` re-mapped nearly every prompt's home
        // replica the moment churn shrank the offered set, destroying
        // exactly the cache affinity this policy exists to provide; the
        // argmax form is stable under membership changes by
        // construction.
        let h = prompt_affinity_hash(&req.request.prompt);
        let mut best = 0usize;
        let mut best_w = 0u64;
        for (pos, v) in replicas.iter().enumerate() {
            let w = rendezvous_weight(h, v.index);
            // Strict `>`: ties keep the earliest position, and offered
            // views arrive in ascending index order, so tie-breaking is
            // itself membership-stable.
            if pos == 0 || w > best_w {
                best = pos;
                best_w = w;
            }
        }
        best
    }
}

/// Predictive gate-probe dispatch (DyMoE's thesis applied to routing:
/// runtime knowledge of the routed expert set beats static placement).
/// The cluster probes the layer-0 gate on the prompt prefix and hands
/// the predicted expert set to [`DispatchPolicy::route_predicted`];
/// this policy scores every offered replica by **byte-weighted
/// overlap** — the staged bytes it already holds for the predicted
/// experts, VRAM cache plus its host-pool view
/// ([`ReplicaDispatchView::resident_expert_bytes`]) — and routes to
/// the argmax.  Ties (including the cold-start case where nothing is
/// resident anywhere, or an engine-free caller using plain `route`)
/// break toward the smaller backlog then the earlier offered position,
/// so the policy degrades to deterministic jsq-like load balancing
/// instead of hotspotting.
struct PredictiveDispatch;

impl DispatchPolicy for PredictiveDispatch {
    fn name(&self) -> &'static str {
        "predictive"
    }

    fn route(&mut self, req: &TimedRequest, replicas: &[ReplicaDispatchView]) -> usize {
        // No probe available (e.g. a dispatcher running without an
        // engine): an empty prediction scores every replica 0, which is
        // exactly the jsq-like fallback.
        self.route_predicted(req, replicas, &[])
    }

    fn route_predicted(
        &mut self,
        _req: &TimedRequest,
        replicas: &[ReplicaDispatchView],
        predicted: &[usize],
    ) -> usize {
        let mut best = 0usize;
        let mut best_score = 0u64;
        let mut best_backlog = usize::MAX;
        for (pos, v) in replicas.iter().enumerate() {
            let score: u64 = predicted
                .iter()
                .map(|&e| v.resident_expert_bytes.get(e).copied().unwrap_or(0))
                .sum();
            let backlog = v.backlog_tokens();
            // Offered views arrive in ascending index order, so the
            // strict comparisons keep tie-breaking membership-stable.
            if pos == 0 || score > best_score || (score == best_score && backlog < best_backlog)
            {
                best = pos;
                best_score = score;
                best_backlog = backlog;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, arrival: f64, deadline: f64) -> QueuedInfo {
        QueuedInfo { id, arrival, deadline, class: TenantClass::Interactive }
    }

    /// A queued batch-class request.
    fn qb(id: usize, arrival: f64, deadline: f64) -> QueuedInfo {
        QueuedInfo { class: TenantClass::Batch, ..q(id, arrival, deadline) }
    }

    fn a(id: usize, arrival: f64, last_token_at: f64) -> ActiveInfo {
        ActiveInfo {
            id,
            arrival,
            class: TenantClass::Interactive,
            emitted: 1,
            target: 8,
            last_token_at,
            prefill_remaining: 0,
        }
    }

    /// An active batch-class session.
    fn ab(id: usize, arrival: f64, last_token_at: f64) -> ActiveInfo {
        ActiveInfo { class: TenantClass::Batch, ..a(id, arrival, last_token_at) }
    }

    /// A session still mid-prefill (chunked mode).
    fn pre(id: usize, arrival: f64, remaining: usize) -> ActiveInfo {
        ActiveInfo {
            id,
            arrival,
            class: TenantClass::Interactive,
            emitted: 0,
            target: 8,
            last_token_at: arrival,
            prefill_remaining: remaining,
        }
    }

    #[test]
    fn fifo_runs_oldest_to_completion() {
        let mut p = PolicyKind::Fifo.build();
        let queued = [q(3, 0.5, 5.5), q(4, 0.1, 5.1)];
        let active = [a(1, 0.0, 2.0), a(2, 0.05, 1.0)];
        // active work first, oldest arrival wins
        let view = SchedView { now: 2.0, queued: &queued, active: &active, free_slots: 2 };
        assert_eq!(p.next_action(&view), Action::Decode(1));
        // queue drains in arrival order once nothing is active
        let view = SchedView { now: 2.0, queued: &queued, active: &[], free_slots: 4 };
        assert_eq!(p.next_action(&view), Action::Admit(4));
        // no slots -> idle
        let view = SchedView { now: 2.0, queued: &queued, active: &[], free_slots: 0 };
        assert_eq!(p.next_action(&view), Action::Idle);
    }

    #[test]
    fn round_robin_rotates_decodes_and_prefers_prefill() {
        let mut p = PolicyKind::RoundRobin.build();
        let active = [a(1, 0.0, 1.0), a(2, 0.1, 1.1), a(5, 0.2, 0.9)];
        let view = |queued: &'static [QueuedInfo], free| SchedView {
            now: 2.0,
            queued,
            active: &active,
            free_slots: free,
        };
        // with a free slot and a queued request, prefill wins
        static QUEUE: [QueuedInfo; 1] = [QueuedInfo {
            id: 9,
            arrival: 1.9,
            deadline: 6.9,
            class: TenantClass::Interactive,
        }];
        assert_eq!(p.next_action(&view(&QUEUE, 1)), Action::Admit(9));
        // decode rotation cycles 1 -> 2 -> 5 -> 1 ...
        assert_eq!(p.next_action(&view(&[], 0)), Action::Decode(1));
        assert_eq!(p.next_action(&view(&[], 0)), Action::Decode(2));
        assert_eq!(p.next_action(&view(&[], 0)), Action::Decode(5));
        assert_eq!(p.next_action(&view(&[], 0)), Action::Decode(1));
    }

    #[test]
    fn slo_aware_admits_earliest_deadline_and_serves_most_starved() {
        let mut p = PolicyKind::SloAware.build();
        let queued = [q(7, 1.0, 3.0), q(8, 0.5, 4.5)];
        let active = [a(1, 0.0, 2.5), a(2, 0.1, 1.5)];
        // id 7 arrived later but its deadline is tighter
        let view = SchedView { now: 2.0, queued: &queued, active: &active, free_slots: 1 };
        assert_eq!(p.next_action(&view), Action::Admit(7));
        // no slots: decode the session longest since last token
        let view = SchedView { now: 2.0, queued: &queued, active: &active, free_slots: 0 };
        assert_eq!(p.next_action(&view), Action::Decode(2));
    }

    #[test]
    fn default_batch_fills_least_recently_served() {
        let mut p = PolicyKind::SloAware.build();
        let active = [a(1, 0.0, 2.5), a(2, 0.1, 1.5), a(3, 0.2, 3.5), a(4, 0.3, 1.0)];
        let view = SchedView { now: 4.0, queued: &[], active: &active, free_slots: 0 };
        // lead stays first; the rest join oldest-token first
        assert_eq!(p.decode_batch(&view, 2, 3), vec![2, 4, 1]);
        // max 1 is the serial path
        assert_eq!(p.decode_batch(&view, 2, 1), vec![2]);
        // max beyond the active set batches everyone
        assert_eq!(p.decode_batch(&view, 2, 10), vec![2, 4, 1, 3]);
    }

    #[test]
    fn round_robin_batch_continues_rotation() {
        let mut p = PolicyKind::RoundRobin.build();
        let active = [a(1, 0.0, 1.0), a(2, 0.1, 1.1), a(5, 0.2, 0.9)];
        let view = SchedView { now: 2.0, queued: &[], active: &active, free_slots: 0 };
        // batch wraps in id order from the lead...
        assert_eq!(p.decode_batch(&view, 2, 2), vec![2, 5]);
        // ...and the cursor advanced past the whole batch: next pick
        // wraps to 1
        assert_eq!(p.next_action(&view), Action::Decode(1));
    }

    #[test]
    fn default_mixed_tick_prefills_oldest_and_decodes_least_recently_served() {
        let mut p = PolicyKind::SloAware.build();
        let active = [
            pre(1, 0.3, 5),          // prefilling, younger
            pre(2, 0.1, 9),          // prefilling, oldest -> gets the chunk
            a(3, 0.0, 2.5),
            a(4, 0.05, 1.0),         // least recently served -> leads decode
            a(5, 0.06, 1.5),
        ];
        let view = SchedView { now: 4.0, queued: &[], active: &active, free_slots: 0 };
        let plan = p.mixed_tick(&view, 2);
        assert_eq!(plan.prefill, Some(2));
        assert_eq!(plan.decode, vec![4, 5]);
        // finished sessions never decode
        let mut done = a(6, 0.0, 0.1);
        done.emitted = done.target;
        let active = [done, a(7, 0.1, 0.2)];
        let view = SchedView { now: 4.0, queued: &[], active: &active, free_slots: 0 };
        let plan = p.mixed_tick(&view, 4);
        assert_eq!(plan.prefill, None);
        assert_eq!(plan.decode, vec![7]);
    }

    #[test]
    fn fifo_mixed_tick_decodes_in_arrival_order() {
        let mut p = PolicyKind::Fifo.build();
        let active = [pre(9, 0.5, 3), a(1, 0.2, 9.0), a(2, 0.1, 0.5), a(3, 0.3, 1.0)];
        let view = SchedView { now: 4.0, queued: &[], active: &active, free_slots: 0 };
        let plan = p.mixed_tick(&view, 2);
        assert_eq!(plan.prefill, Some(9));
        // arrival order, not least-recently-served
        assert_eq!(plan.decode, vec![2, 1]);
    }

    #[test]
    fn round_robin_mixed_tick_rotates_ready_sessions() {
        let mut p = PolicyKind::RoundRobin.build();
        let active = [pre(9, 0.0, 4), a(1, 0.1, 1.0), a(2, 0.2, 1.1), a(5, 0.3, 0.9)];
        let view = SchedView { now: 2.0, queued: &[], active: &active, free_slots: 0 };
        // first tick rotates from the top of the ready id order ...
        let plan = p.mixed_tick(&view, 2);
        assert_eq!(plan.prefill, Some(9));
        assert_eq!(plan.decode, vec![1, 2]);
        // ... and the cursor advanced past the batch: next tick wraps
        let plan = p.mixed_tick(&view, 2);
        assert_eq!(plan.decode, vec![5, 1]);
    }

    #[test]
    fn admit_pick_orders_by_arrival_or_deadline() {
        let queued = [q(7, 1.0, 3.0), q(8, 0.5, 4.5)];
        let view = SchedView { now: 2.0, queued: &queued, active: &[], free_slots: 1 };
        // fifo / rr: oldest arrival
        assert_eq!(PolicyKind::Fifo.build().admit_pick(&view), Some(8));
        assert_eq!(PolicyKind::RoundRobin.build().admit_pick(&view), Some(8));
        // slo: tightest deadline
        assert_eq!(PolicyKind::SloAware.build().admit_pick(&view), Some(7));
        // no slots -> nothing admitted
        let full = SchedView { now: 2.0, queued: &queued, active: &[], free_slots: 0 };
        assert_eq!(PolicyKind::SloAware.build().admit_pick(&full), None);
    }

    #[test]
    fn class_priority_orders_admission_except_fifo() {
        // batch arrived first *and* has the tighter deadline;
        // interactive still outranks it everywhere except the
        // class-blind fifo baseline
        let queued = [qb(1, 0.1, 2.1), q(2, 0.9, 9.9)];
        let view = SchedView { now: 1.0, queued: &queued, active: &[], free_slots: 1 };
        assert_eq!(PolicyKind::RoundRobin.build().admit_pick(&view), Some(2));
        assert_eq!(PolicyKind::SloAware.build().admit_pick(&view), Some(2));
        assert_eq!(PolicyKind::SloAware.build().next_action(&view), Action::Admit(2));
        assert_eq!(PolicyKind::Fifo.build().admit_pick(&view), Some(1));
        assert_eq!(PolicyKind::Fifo.build().next_action(&view), Action::Admit(1));

        // slo decode: a more-starved batch session still yields to
        // interactive
        let active = [ab(3, 0.0, 0.5), a(4, 0.1, 1.5)];
        let view = SchedView { now: 2.0, queued: &[], active: &active, free_slots: 0 };
        assert_eq!(PolicyKind::SloAware.build().next_action(&view), Action::Decode(4));

        // chunked prefill budget: interactive prefill outranks an older
        // batch prefill (fifo keeps arrival order)
        let mut bp = pre(5, 0.0, 5);
        bp.class = TenantClass::Batch;
        let active = [bp, pre(6, 0.5, 5)];
        let view = SchedView { now: 1.0, queued: &[], active: &active, free_slots: 0 };
        assert_eq!(PolicyKind::SloAware.build().mixed_tick(&view, 1).prefill, Some(6));
        assert_eq!(PolicyKind::Fifo.build().mixed_tick(&view, 1).prefill, Some(5));
    }

    #[test]
    fn preempt_victim_picks_lowest_priority_most_remaining() {
        let mut p = PolicyKind::SloAware.build();
        let b1 = ab(1, 0.0, 1.0); // 7 tokens remaining
        let mut b2 = ab(2, 0.1, 1.1);
        b2.emitted = 5; // 3 remaining
        let active = [b1, b2, a(3, 0.2, 1.2)];
        let queued = [q(9, 2.0, 7.0)];
        let view = SchedView { now: 2.0, queued: &queued, active: &active, free_slots: 0 };
        assert_eq!(p.preempt_victim(&view), Some(1), "most remaining batch work vacates");

        // equal remaining work: the youngest (highest id) slot vacates
        let tied = [ab(5, 0.0, 1.0), ab(6, 0.1, 1.1), a(3, 0.2, 1.2)];
        let view = SchedView { now: 2.0, queued: &queued, active: &tied, free_slots: 0 };
        assert_eq!(p.preempt_victim(&view), Some(6));

        // a free slot means plain admission, never preemption
        let view = SchedView { now: 2.0, queued: &queued, active: &active, free_slots: 1 };
        assert_eq!(p.preempt_victim(&view), None);

        // nothing queued outranks the in-flight batch sessions
        let bq = [qb(9, 2.0, 7.0)];
        let view = SchedView { now: 2.0, queued: &bq, active: &active, free_slots: 0 };
        assert_eq!(p.preempt_victim(&view), None);

        // equal class never preempts (the single-class legacy paths)
        let inter = [a(1, 0.0, 1.0)];
        let view = SchedView { now: 2.0, queued: &queued, active: &inter, free_slots: 0 };
        assert_eq!(p.preempt_victim(&view), None);

        // mid-prefill sessions are never victims
        let mut bp = pre(4, 0.0, 6);
        bp.class = TenantClass::Batch;
        let prefilling = [bp, a(3, 0.2, 1.2)];
        let view = SchedView { now: 2.0, queued: &queued, active: &prefilling, free_slots: 0 };
        assert_eq!(p.preempt_victim(&view), None);

        // the class-blind baseline never preempts
        let view = SchedView { now: 2.0, queued: &queued, active: &active, free_slots: 0 };
        assert_eq!(PolicyKind::Fifo.build().preempt_victim(&view), None);
    }

    #[test]
    fn parse_round_trips() {
        for kind in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(PolicyKind::parse("lifo").is_err());
    }

    // -- dispatch policies ------------------------------------------------

    fn rv(index: usize, queued_tokens: usize, active_tokens: usize) -> ReplicaDispatchView {
        ReplicaDispatchView {
            index,
            clock: 0.0,
            queued_requests: queued_tokens.min(1),
            queued_tokens,
            active_sessions: active_tokens.min(1),
            active_tokens,
            resident_expert_bytes: Vec::new(),
        }
    }

    /// A view with a residency summary (predictive dispatch input).
    fn rv_res(index: usize, backlog: usize, resident: Vec<u64>) -> ReplicaDispatchView {
        let mut v = rv(index, backlog, 0);
        v.resident_expert_bytes = resident;
        v
    }

    fn treq(id: usize, prompt: Vec<i32>) -> TimedRequest {
        TimedRequest::new(id, 0.0, crate::workload::Request { prompt, max_new: 4 })
    }

    #[test]
    fn dispatch_round_robin_cycles() {
        let mut p = DispatchKind::RoundRobin.build();
        let views = [rv(0, 0, 0), rv(1, 0, 0), rv(2, 0, 0)];
        let r = treq(0, vec![1, 2]);
        let picks: Vec<usize> = (0..6).map(|_| p.route(&r, &views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn dispatch_jsq_picks_least_loaded_with_index_ties() {
        let mut p = DispatchKind::JoinShortestQueue.build();
        let r = treq(0, vec![1, 2]);
        // backlog = queued + active tokens
        let views = [rv(0, 5, 5), rv(1, 2, 3), rv(2, 0, 4)];
        assert_eq!(p.route(&r, &views), 2);
        // ties break toward the lower index
        let tied = [rv(0, 3, 0), rv(1, 0, 3), rv(2, 9, 9)];
        assert_eq!(p.route(&r, &tied), 0);
    }

    #[test]
    fn dispatch_affinity_is_deterministic_order_invariant_and_in_range() {
        let mut p = DispatchKind::ExpertAffinity.build();
        let views: Vec<ReplicaDispatchView> = (0..4).map(|i| rv(i, 0, 0)).collect();
        let a = p.route(&treq(0, vec![3, 7, 11]), &views);
        let b = p.route(&treq(9, vec![3, 7, 11]), &views);
        assert_eq!(a, b, "same prompt must colocate regardless of id");
        // permuted prompts land on the same replica (order-invariant hash)
        let c = p.route(&treq(1, vec![11, 3, 7]), &views);
        assert_eq!(a, c);
        assert!(a < 4);
        // the hash actually spreads: over many distinct prompts every
        // replica receives something
        let mut hit = [false; 4];
        for t in 0..64i32 {
            hit[p.route(&treq(t as usize, vec![1, t, t * 3 % 50]), &views)] = true;
        }
        assert!(hit.iter().all(|&h| h), "affinity hash never spread: {hit:?}");
    }

    #[test]
    fn dispatch_affinity_survives_membership_changes() {
        // Rendezvous hashing: removing one replica from the offered set
        // must re-home ONLY the prompts whose winner was removed.
        let mut p = DispatchKind::ExpertAffinity.build();
        let full: Vec<ReplicaDispatchView> = (0..4).map(|i| rv(i, 0, 0)).collect();
        let prompts: Vec<Vec<i32>> =
            (0..128i32).map(|t| vec![1, t, t * 7 % 61, t * 13 % 97]).collect();
        let home: Vec<usize> =
            prompts.iter().map(|pr| full[p.route(&treq(0, pr.clone()), &full)].index).collect();
        for dead in 0..4usize {
            let survivors: Vec<ReplicaDispatchView> =
                full.iter().cloned().filter(|v| v.index != dead).collect();
            for (pr, &h) in prompts.iter().zip(&home) {
                let now = survivors[p.route(&treq(0, pr.clone()), &survivors)].index;
                if h != dead {
                    assert_eq!(now, h, "prompt {pr:?} moved off surviving replica {h}");
                } else {
                    assert_ne!(now, dead, "prompt {pr:?} routed to the removed replica");
                }
            }
        }
    }

    #[test]
    fn dispatch_parse_round_trips() {
        for kind in DispatchKind::ALL {
            assert_eq!(DispatchKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(DispatchKind::parse("random").is_err());
        assert_eq!(
            DispatchKind::parse("shortest-queue").unwrap(),
            DispatchKind::JoinShortestQueue
        );
        assert_eq!(DispatchKind::parse("probe").unwrap(), DispatchKind::Predictive);
    }

    #[test]
    fn dispatch_predictive_routes_to_byte_weighted_overlap_argmax() {
        let mut p = DispatchKind::Predictive.build();
        let r = treq(0, vec![1, 2]);
        // replica 1 holds the most bytes of the predicted set {0, 2}
        let views = vec![
            rv_res(0, 0, vec![10, 500, 0]),
            rv_res(1, 9, vec![40, 0, 60]),
            rv_res(2, 0, vec![0, 0, 30]),
        ];
        assert_eq!(p.route_predicted(&r, &views, &[0, 2]), 1, "argmax must win over backlog");
        // prediction outside the summary bounds contributes nothing
        assert_eq!(p.route_predicted(&r, &views, &[7]), 0, "oob expert must tie to min backlog");
        // overlap ties break toward the smaller backlog
        let tied = vec![rv_res(0, 8, vec![50]), rv_res(1, 3, vec![50]), rv_res(2, 5, vec![50])];
        assert_eq!(p.route_predicted(&r, &tied, &[0]), 1);
    }

    #[test]
    fn dispatch_predictive_degrades_to_jsq_like_without_summaries() {
        let mut p = DispatchKind::Predictive.build();
        let mut jsq = DispatchKind::JoinShortestQueue.build();
        let r = treq(0, vec![1, 2]);
        // empty residency summaries (the non-predictive snapshot) and an
        // empty prediction: every pick must match join-shortest-queue
        let cases = [
            vec![rv(0, 5, 5), rv(1, 2, 3), rv(2, 0, 4)],
            vec![rv(0, 3, 0), rv(1, 0, 3), rv(2, 9, 9)],
            vec![rv(3, 0, 0)],
        ];
        for views in &cases {
            assert_eq!(p.route(&r, views), jsq.route(&r, views));
            assert_eq!(p.route_predicted(&r, views, &[]), jsq.route(&r, views));
        }
    }

    #[test]
    fn dispatch_predictive_is_deterministic_and_in_range_over_filtered_views() {
        let mut p = DispatchKind::Predictive.build();
        let r = treq(0, vec![1, 2]);
        // liveness-filtered slice: non-contiguous indices, positions
        // must still be in range and stable across repeated calls
        let views = vec![rv_res(1, 4, vec![0, 9]), rv_res(3, 2, vec![0, 9])];
        let first = p.route_predicted(&r, &views, &[1]);
        for _ in 0..8 {
            let pick = p.route_predicted(&r, &views, &[1]);
            assert_eq!(pick, first);
            assert!(pick < views.len());
        }
        // equal overlap: the smaller backlog (position 1, index 3) wins
        assert_eq!(views[first].index, 3);
    }
}

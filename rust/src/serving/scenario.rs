//! Production trace scenarios: named, parameterized compositions of
//! per-tenant-class arrival processes and modulation envelopes behind
//! `serve-fleet --scenario NAME[:ARGS]`.
//!
//! A [`Scenario`] is a list of [`ClassLoad`]s — one per tenant class in
//! the mix — each pairing an [`ArrivalProcess`] with an [`Envelope`]
//! (diurnal day-scale sinusoid, flash-crowd window) and an optional
//! per-class [`SloTargets`] override.  [`Scenario::generate`] samples
//! every class from an **independent seeded timing stream** and merges
//! the arrivals into one trace, so adding or re-weighting one class
//! never perturbs another class's arrival times.
//!
//! # Determinism and digest neutrality
//!
//! Class `k`'s timing seed is `seed ^ (k · GOLDEN)`, so class 0 samples
//! from exactly the seed the legacy single-stream
//! [`ArrivalGen::generate`] would use; with a single flat-envelope
//! class the merge is a no-op and the trace is **bit-identical** to the
//! `--arrival` path (same arrivals, same prompts, same ids) — pinned by
//! `steady_reduces_to_legacy_generate` here and end-to-end (through
//! `ClusterOutcome::digest()`) in `tests/integration_scenarios.rs`.
//! Request content is drawn from the caller's [`TraceGen`] in merged
//! generation order, id-stamped `0..n` in arrival order.
//!
//! # Scenario library
//!
//! | name | classes | shape |
//! |------|---------|-------|
//! | `steady` | interactive | Poisson at `--rate` (≡ `--arrival poisson`) |
//! | `diurnal[:PERIOD[:AMP]]` | interactive | Poisson × day-scale sinusoid |
//! | `flash-crowd[:AT[:MAG[:DUR]]]` | interactive | Poisson × flash window |
//! | `mixed[:SHARE]` | interactive + batch | two Poisson streams |
//! | `mixed-diurnal[:SHARE[:PERIOD[:AMP]]]` | interactive + batch | interactive rides the sinusoid, batch stays flat |
//! | `mixed-flash[:SHARE[:AT[:MAG[:DUR]]]]` | interactive + batch | interactive spikes, batch stays flat |
//!
//! `SHARE` is the interactive fraction of requests (and of `--rate`);
//! batch requests carry a relaxed SLO — the fleet targets scaled by
//! `--batch-slo-scale` — and are preemptible by interactive prefill
//! under class-aware scheduling.

use anyhow::{bail, ensure, Result};

use super::arrival::{ArrivalGen, ArrivalProcess, Envelope, TenantClass, TimedRequest};
use super::metrics::SloTargets;
use crate::workload::TraceGen;

/// Weyl/golden-ratio increment decorrelating per-class timing seeds
/// (class 0 keeps the base seed untouched — the digest-neutral case).
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One tenant class's contribution to a scenario trace.
#[derive(Debug, Clone)]
pub struct ClassLoad {
    pub class: TenantClass,
    pub process: ArrivalProcess,
    pub envelope: Envelope,
    /// Per-request SLO stamped on this class's requests; `None` (the
    /// interactive default) uses the fleet-level targets, which keeps
    /// single-class scenarios digest-neutral.
    pub slo: Option<SloTargets>,
    /// This class's fraction of the trace's requests (> 0; shares are
    /// normalized over the scenario).
    pub share: f64,
}

/// A named multi-tenant load scenario: per-class arrival processes and
/// envelopes, composed into one deterministic open-loop trace.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub classes: Vec<ClassLoad>,
}

/// Default diurnal period (virtual seconds; "day-scale" relative to the
/// second-scale request service times the engine models).
const DIURNAL_PERIOD_S: f64 = 600.0;
const DIURNAL_AMPLITUDE: f64 = 0.5;
/// Default flash-crowd window: a 5x spike (factor 1 + 4) 30 s in,
/// lasting 15 s.
const FLASH_AT_S: f64 = 30.0;
const FLASH_MAGNITUDE: f64 = 4.0;
const FLASH_DURATION_S: f64 = 15.0;
const MIXED_SHARE: f64 = 0.5;

impl Scenario {
    /// Parse a `--scenario NAME[:ARGS]` spec.  `rate` is the total mean
    /// request rate (split across classes by share); `fleet_slo` is the
    /// fleet-level target, which batch classes relax by
    /// `batch_slo_scale`.
    pub fn from_cli(
        spec: &str,
        rate: f64,
        fleet_slo: SloTargets,
        batch_slo_scale: f64,
    ) -> Result<Scenario> {
        ensure!(rate > 0.0, "--rate must be > 0");
        ensure!(
            batch_slo_scale.is_finite() && batch_slo_scale >= 1.0,
            "--batch-slo-scale must be >= 1 (batch SLOs are relaxations)"
        );
        let mut parts = spec.split(':');
        let name = parts.next().unwrap_or("");
        let params: Vec<f64> = parts
            .map(|p| {
                p.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--scenario {spec:?}: {p:?} is not a number"))
            })
            .collect::<Result<_>>()?;
        let arity = |max: usize, usage: &str| -> Result<()> {
            ensure!(params.len() <= max, "--scenario {spec:?}: expected {usage}");
            Ok(())
        };
        let p = |i: usize, default: f64| params.get(i).copied().unwrap_or(default);

        let interactive = |envelope: Envelope, r: f64, share: f64| ClassLoad {
            class: TenantClass::Interactive,
            process: ArrivalProcess::Poisson { rate: r },
            envelope,
            slo: None,
            share,
        };
        let batch_slo = SloTargets {
            ttft_s: fleet_slo.ttft_s * batch_slo_scale,
            tpot_s: fleet_slo.tpot_s * batch_slo_scale,
        };
        let batch = |r: f64, share: f64| ClassLoad {
            class: TenantClass::Batch,
            process: ArrivalProcess::Poisson { rate: r },
            envelope: Envelope::Flat,
            slo: Some(batch_slo),
            share,
        };
        let share_of = |s: f64| -> Result<f64> {
            ensure!(
                s > 0.0 && s < 1.0,
                "--scenario {spec:?}: interactive share must be in (0, 1)"
            );
            Ok(s)
        };

        let classes = match name {
            "steady" => {
                arity(0, "steady (no parameters)")?;
                vec![interactive(Envelope::Flat, rate, 1.0)]
            }
            "diurnal" => {
                arity(2, "diurnal[:PERIOD[:AMP]]")?;
                let env = Envelope::Diurnal {
                    period_s: p(0, DIURNAL_PERIOD_S),
                    amplitude: p(1, DIURNAL_AMPLITUDE),
                };
                vec![interactive(env, rate, 1.0)]
            }
            "flash-crowd" => {
                arity(3, "flash-crowd[:AT[:MAG[:DUR]]]")?;
                let env = Envelope::Flash {
                    at_s: p(0, FLASH_AT_S),
                    magnitude: p(1, FLASH_MAGNITUDE),
                    duration_s: p(2, FLASH_DURATION_S),
                };
                vec![interactive(env, rate, 1.0)]
            }
            "mixed" => {
                arity(1, "mixed[:SHARE]")?;
                let s = share_of(p(0, MIXED_SHARE))?;
                vec![
                    interactive(Envelope::Flat, rate * s, s),
                    batch(rate * (1.0 - s), 1.0 - s),
                ]
            }
            "mixed-diurnal" => {
                arity(3, "mixed-diurnal[:SHARE[:PERIOD[:AMP]]]")?;
                let s = share_of(p(0, MIXED_SHARE))?;
                let env = Envelope::Diurnal {
                    period_s: p(1, DIURNAL_PERIOD_S),
                    amplitude: p(2, DIURNAL_AMPLITUDE),
                };
                vec![interactive(env, rate * s, s), batch(rate * (1.0 - s), 1.0 - s)]
            }
            "mixed-flash" => {
                arity(4, "mixed-flash[:SHARE[:AT[:MAG[:DUR]]]]")?;
                let s = share_of(p(0, MIXED_SHARE))?;
                let env = Envelope::Flash {
                    at_s: p(1, FLASH_AT_S),
                    magnitude: p(2, FLASH_MAGNITUDE),
                    duration_s: p(3, FLASH_DURATION_S),
                };
                vec![interactive(env, rate * s, s), batch(rate * (1.0 - s), 1.0 - s)]
            }
            _ => bail!(
                "unknown scenario {name:?}; try steady, diurnal, flash-crowd, \
                 mixed, mixed-diurnal, mixed-flash"
            ),
        };
        let scenario = Scenario { name: name.to_string(), classes };
        scenario.validate()?;
        Ok(scenario)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(!self.classes.is_empty(), "scenario needs at least one class");
        for cl in &self.classes {
            ensure!(cl.share > 0.0, "class {} share must be > 0", cl.class.name());
            cl.process.validate()?;
            cl.envelope.validate()?;
            if let Some(slo) = &cl.slo {
                ensure!(
                    slo.ttft_s > 0.0 && slo.tpot_s > 0.0,
                    "class {} SLO targets must be > 0",
                    cl.class.name()
                );
            }
        }
        Ok(())
    }

    /// Timing seed for class `k`: class 0 keeps `seed` bit-for-bit (the
    /// legacy stream), later classes decorrelate via the golden-ratio
    /// increment.
    fn class_seed(seed: u64, k: usize) -> u64 {
        seed ^ (k as u64).wrapping_mul(GOLDEN)
    }

    /// Split `n` requests across classes proportionally to share
    /// (floor), handing the remainder out one request per class in
    /// declaration order — fully deterministic.
    fn apportion(&self, n: usize) -> Vec<usize> {
        let total: f64 = self.classes.iter().map(|c| c.share).sum();
        let mut counts: Vec<usize> = self
            .classes
            .iter()
            .map(|c| (c.share / total * n as f64).floor() as usize)
            .collect();
        // Floors sum to at most n, so the subtraction cannot underflow.
        let mut rem = n - counts.iter().sum::<usize>();
        let mut k = 0;
        while rem > 0 {
            counts[k] += 1;
            rem -= 1;
            k = (k + 1) % counts.len();
        }
        counts
    }

    /// Generate the scenario's deterministic open-loop trace: `n`
    /// requests apportioned across classes by share, each class sampled
    /// from its own timing stream, merged by arrival time (stable —
    /// ties keep class declaration order) and id-stamped `0..n`.
    /// Request content comes from `content` in merged generation order,
    /// so a single-class scenario consumes it exactly like the legacy
    /// generator.
    pub fn generate(
        &self,
        seed: u64,
        content: &mut TraceGen,
        n: usize,
    ) -> Result<Vec<TimedRequest>> {
        self.validate()?;
        let counts = self.apportion(n);
        let mut all: Vec<TimedRequest> = Vec::with_capacity(n);
        for (k, (cl, &count)) in self.classes.iter().zip(&counts).enumerate() {
            let mut gen =
                ArrivalGen::with_envelope(Self::class_seed(seed, k), cl.process, cl.envelope)?;
            for _ in 0..count {
                // Same evaluation order as the legacy generator: timing
                // draw first, then content — bit-compatibility of the
                // single-class case depends on this interleave.
                let arrival = gen.next_arrival();
                let request = content.next_request();
                all.push(TimedRequest { id: 0, arrival, class: cl.class, slo: cl.slo, request });
            }
        }
        all.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (id, r) in all.iter_mut().enumerate() {
            r.id = id;
        }
        Ok(all)
    }

    /// True when every request carries the same class on the fleet SLO
    /// (the digest-neutral shape).
    pub fn single_class(&self) -> bool {
        self.classes.len() == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FLEET_SLO: SloTargets = SloTargets { ttft_s: 5.0, tpot_s: 0.5 };

    fn scen(spec: &str) -> Scenario {
        Scenario::from_cli(spec, 2.0, FLEET_SLO, 8.0).unwrap()
    }

    #[test]
    fn steady_reduces_to_legacy_generate() {
        let mut legacy_content = TraceGen::new(11, 80, 16);
        let legacy = ArrivalGen::generate(
            42,
            ArrivalProcess::Poisson { rate: 2.0 },
            &mut legacy_content,
            64,
        )
        .unwrap();
        let s = scen("steady");
        assert!(s.single_class());
        let mut content = TraceGen::new(11, 80, 16);
        let trace = s.generate(42, &mut content, 64).unwrap();
        assert_eq!(trace.len(), legacy.len());
        for (a, b) in trace.iter().zip(&legacy) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "arrival stream diverged");
            assert_eq!(a.request.prompt, b.request.prompt);
            assert_eq!(a.request.max_new, b.request.max_new);
            assert_eq!(a.class, TenantClass::Interactive);
            assert!(a.slo.is_none());
        }
    }

    #[test]
    fn mixed_shares_split_counts_and_relax_batch_slo() {
        let s = scen("mixed:0.25");
        assert!(!s.single_class());
        let mut content = TraceGen::new(7, 80, 16);
        let trace = s.generate(9, &mut content, 16).unwrap();
        assert_eq!(trace.len(), 16);
        let inter = trace.iter().filter(|r| r.class == TenantClass::Interactive).count();
        let batch = trace.iter().filter(|r| r.class == TenantClass::Batch).count();
        assert_eq!((inter, batch), (4, 12));
        // ids are 0..n in arrival order
        for (i, w) in trace.windows(2).enumerate() {
            assert_eq!(w[0].id, i);
            assert!(w[0].arrival <= w[1].arrival, "trace not sorted by arrival");
        }
        assert_eq!(trace.last().unwrap().id, 15);
        for r in &trace {
            match r.class {
                TenantClass::Interactive => assert!(r.slo.is_none()),
                TenantClass::Batch => {
                    let slo = r.slo.expect("batch requests carry the relaxed SLO");
                    assert_eq!(slo.ttft_s, FLEET_SLO.ttft_s * 8.0);
                    assert_eq!(slo.tpot_s, FLEET_SLO.tpot_s * 8.0);
                }
            }
        }
    }

    #[test]
    fn class_timing_streams_are_independent() {
        // Re-weighting the mix must not perturb the other class's
        // arrival stream (each class samples its own seeded stream).
        let mut c1 = TraceGen::new(7, 80, 16);
        let mut c2 = TraceGen::new(7, 80, 16);
        let a = scen("mixed:0.5").generate(5, &mut c1, 32).unwrap();
        let b = scen("mixed-flash:0.5:1e9:4:1").generate(5, &mut c2, 32).unwrap();
        // the flash fires at t=1e9, far past the trace: batch arrivals
        // (flat in both) must be bitwise unchanged
        let batch_a: Vec<u64> = a
            .iter()
            .filter(|r| r.class == TenantClass::Batch)
            .map(|r| r.arrival.to_bits())
            .collect();
        let batch_b: Vec<u64> = b
            .iter()
            .filter(|r| r.class == TenantClass::Batch)
            .map(|r| r.arrival.to_bits())
            .collect();
        assert_eq!(batch_a, batch_b);
    }

    #[test]
    fn scenario_parse_accepts_params_and_rejects_bad_specs() {
        let s = scen("diurnal:300:0.8");
        assert_eq!(
            s.classes[0].envelope,
            Envelope::Diurnal { period_s: 300.0, amplitude: 0.8 }
        );
        let s = scen("flash-crowd:10:9:5");
        assert_eq!(
            s.classes[0].envelope,
            Envelope::Flash { at_s: 10.0, magnitude: 9.0, duration_s: 5.0 }
        );
        let s = scen("mixed-diurnal");
        assert_eq!(s.classes.len(), 2);
        assert_eq!(s.classes[0].class, TenantClass::Interactive);
        assert_eq!(s.classes[1].class, TenantClass::Batch);
        assert_eq!(s.classes[1].envelope, Envelope::Flat);
        // total rate splits by share
        let s = scen("mixed:0.25");
        assert_eq!(s.classes[0].process, ArrivalProcess::Poisson { rate: 2.0 * 0.25 });
        assert_eq!(s.classes[1].process, ArrivalProcess::Poisson { rate: 2.0 * 0.75 });
        for bad in [
            "nope",
            "steady:1",              // steady takes no params
            "diurnal:300:0.8:9",     // arity
            "diurnal:0",             // invalid period
            "diurnal:300:1.5",       // invalid amplitude
            "flash-crowd:10:9:5:1",  // arity
            "mixed:0",               // share out of (0, 1)
            "mixed:1",
            "mixed:x",               // not a number
            "mixed-flash:0.5:10:9:0", // zero duration
        ] {
            assert!(
                Scenario::from_cli(bad, 2.0, FLEET_SLO, 8.0).is_err(),
                "{bad:?} accepted"
            );
        }
        assert!(Scenario::from_cli("steady", 0.0, FLEET_SLO, 8.0).is_err());
        assert!(Scenario::from_cli("steady", 2.0, FLEET_SLO, 0.5).is_err());
    }

    #[test]
    fn apportion_is_exact_and_deterministic() {
        let s = scen("mixed:0.3");
        for n in [0usize, 1, 2, 7, 16, 101] {
            let counts = s.apportion(n);
            assert_eq!(counts.iter().sum::<usize>(), n, "n={n}");
        }
        // remainder goes to the earliest class
        assert_eq!(scen("mixed:0.5").apportion(3), vec![2, 1]);
    }
}

//! GPU/CPU roofline cost model: virtual durations for every operation the
//! engine schedules, computed at **paper scale** (DESIGN.md §6).
//!
//! Each op is `max(flops / throughput, bytes / bandwidth) + overhead` —
//! the standard roofline.  Quantized execution pays a dequant factor on
//! the compute term (shift/mask + rescale per weight), which is what makes
//! Fiddler-style CPU dequantization compute-bound in the paper.

use crate::config::{HardwareConfig, PaperModel};
use crate::quant::Precision;

/// Virtual durations (seconds) for engine-scheduled operations.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub hw: HardwareConfig,
    pub paper: PaperModel,
    /// Multiplier mapping one mini-model layer to paper layers.
    pub layer_scale: f64,
}

/// Extra compute cost per weight for in-kernel dequantization on the GPU.
fn gpu_dequant_factor(p: Precision) -> f64 {
    match p {
        Precision::Bf16 => 0.0,
        Precision::Int8 => 0.15,
        Precision::Int4 => 0.25,
        Precision::Int2 => 0.40,
        Precision::Skip => 0.0,
    }
}

/// CPU dequantization is much more expensive relative to CPU FLOPs — the
/// paper calls out exactly this as Fiddler's bottleneck.
fn cpu_dequant_factor(p: Precision) -> f64 {
    match p {
        Precision::Bf16 => 0.0,
        Precision::Int8 => 1.0,
        Precision::Int4 => 1.8,
        Precision::Int2 => 3.0,
        Precision::Skip => 0.0,
    }
}

impl CostModel {
    pub fn new(hw: HardwareConfig, paper: PaperModel, layer_scale: f64) -> Self {
        CostModel { hw, paper, layer_scale }
    }

    fn roofline(&self, flops: f64, bytes: f64) -> f64 {
        let compute = flops / self.hw.gpu_tflops;
        let memory = bytes / self.hw.hbm_gbps;
        compute.max(memory) + self.hw.kernel_overhead_s
    }

    /// Weight bytes of one expert at a precision (paper scale).
    pub fn expert_weight_bytes(&self, p: Precision) -> f64 {
        crate::quant::expert_bytes(self.paper.d_model, self.paper.d_ffn, 128, p) as f64
    }

    /// One layer's attention during prefill over `tokens` tokens.
    pub fn attn_prefill(&self, tokens: usize) -> f64 {
        let d = self.paper.d_model as f64;
        let t = tokens as f64;
        // qkvo projections + score/context matmuls
        let flops = 8.0 * d * d * t + 4.0 * d * t * t;
        let bytes = 4.0 * d * d * 2.0; // weight reads, bf16
        self.roofline(flops, bytes) * self.layer_scale
    }

    /// One layer's attention for a single decode token at position `pos`.
    pub fn attn_decode(&self, pos: usize) -> f64 {
        let d = self.paper.d_model as f64;
        let flops = 8.0 * d * d + 4.0 * d * pos as f64;
        let kv_bytes = 2.0 * pos as f64 * d * 2.0;
        let bytes = 4.0 * d * d * 2.0 + kv_bytes;
        self.roofline(flops, bytes) * self.layer_scale
    }

    /// One layer's attention for a cross-session decode batch, one token
    /// per session at its own KV position.  The batched roofline charges
    /// the attention weight read and the kernel overhead **once** for the
    /// whole batch (that is the batching win) while flops and per-session
    /// KV reads sum over the tokens.  For a single position this equals
    /// [`CostModel::attn_decode`] exactly.
    pub fn attn_decode_batch(&self, positions: &[usize]) -> f64 {
        let d = self.paper.d_model as f64;
        let mut flops = 0.0;
        let mut kv_bytes = 0.0;
        for &pos in positions {
            flops += 8.0 * d * d + 4.0 * d * pos as f64;
            kv_bytes += 2.0 * pos as f64 * d * 2.0;
        }
        let bytes = 4.0 * d * d * 2.0 + kv_bytes;
        self.roofline(flops, bytes) * self.layer_scale
    }

    /// One layer's attention for a fused **mixed** step: a prefill chunk
    /// of `chunk` tokens whose causal window ends at `prefix_end`
    /// (`prefix_end - chunk` earlier positions are read back from the
    /// KV cache) plus one decode token per entry of `positions`, each at
    /// its own KV position.  The batched roofline charges the attention
    /// weight read and the kernel overhead **once** for the whole fused
    /// step — that is the continuous-batching win — while flops and KV
    /// reads sum over chunk tokens and decode tokens.
    ///
    /// Degenerate cases reduce exactly (same float operations) to the
    /// phase-pure ops: `attn_mixed(t, t, &[]) == attn_prefill(t)` and
    /// `attn_mixed(0, 0, pos) == attn_decode_batch(pos)`, which is what
    /// makes `--chunk-tokens 0` and pure-decode ticks step-for-step
    /// identical to the monolithic paths.
    pub fn attn_mixed(&self, chunk: usize, prefix_end: usize, positions: &[usize]) -> f64 {
        debug_assert!(prefix_end >= chunk, "chunk window beyond its prefix");
        let d = self.paper.d_model as f64;
        let mut flops = 0.0;
        let mut kv_bytes = 0.0;
        if chunk > 0 {
            let c = chunk as f64;
            // qkvo projections for the chunk + score/context matmuls of
            // chunk queries against the full causal prefix.
            flops += 8.0 * d * d * c + 4.0 * d * c * prefix_end as f64;
            // earlier positions' K/V are read back from the cache
            kv_bytes += 2.0 * (prefix_end - chunk) as f64 * d * 2.0;
        }
        for &pos in positions {
            flops += 8.0 * d * d + 4.0 * d * pos as f64;
            kv_bytes += 2.0 * pos as f64 * d * 2.0;
        }
        let bytes = 4.0 * d * d * 2.0 + kv_bytes;
        self.roofline(flops, bytes) * self.layer_scale
    }

    /// One expert's FFN over `tokens` routed tokens at a precision, on GPU.
    pub fn expert_gpu(&self, tokens: usize, p: Precision) -> f64 {
        if p == Precision::Skip || tokens == 0 {
            return 0.0;
        }
        let d = self.paper.d_model as f64;
        let f = self.paper.d_ffn as f64;
        let t = tokens as f64;
        let weights = 3.0 * d * f;
        let flops = 2.0 * weights * t * (1.0 + gpu_dequant_factor(p));
        let bytes = self.expert_weight_bytes(p);
        self.roofline(flops, bytes) * self.layer_scale
    }

    /// One expert's FFN over `tokens` tokens executed on the host CPU
    /// (Fiddler-style co-execution).
    pub fn expert_cpu(&self, tokens: usize, p: Precision) -> f64 {
        if p == Precision::Skip || tokens == 0 {
            return 0.0;
        }
        let d = self.paper.d_model as f64;
        let f = self.paper.d_ffn as f64;
        let weights = 3.0 * d * f;
        let flops = 2.0 * weights * tokens as f64 * (1.0 + cpu_dequant_factor(p));
        (flops / self.hw.cpu_gflops) * self.layer_scale
    }

    /// Router + top-k (tiny): one matmul over the gate.
    pub fn gate(&self, tokens: usize) -> f64 {
        let d = self.paper.d_model as f64;
        let m = self.paper.n_experts as f64;
        let flops = 2.0 * d * m * tokens as f64;
        self.roofline(flops, d * m * 2.0) * self.layer_scale
    }

    /// Embedding + final norm + unembedding for `tokens` tokens.
    pub fn head(&self, tokens: usize, vocab_scale: f64) -> f64 {
        let d = self.paper.d_model as f64;
        let v = 32000.0 * vocab_scale;
        let flops = 2.0 * d * v * tokens as f64;
        self.roofline(flops, d * v * 2.0)
    }

    /// Host->device transfer duration for `bytes` over PCIe.
    pub fn pcie_transfer(&self, bytes: f64) -> f64 {
        self.hw.pcie_latency_s + bytes / self.hw.pcie_gbps
    }

    /// SSD->host staging duration for `bytes` (when experts live on SSD).
    pub fn nvme_transfer(&self, bytes: f64) -> f64 {
        self.hw.nvme_latency_s + bytes / self.hw.nvme_gbps
    }

    /// Host-pool->device transfer duration for `bytes` when `lanes`
    /// live replicas draw on the shared host-memory link
    /// ([`HardwareConfig::host_link_gbps`]): each lane's effective
    /// bandwidth is its own PCIe ceiling capped by an equal share of
    /// the host budget.  `lanes <= host_link_gbps / pcie_gbps` rides at
    /// full lane speed (the duration then equals
    /// [`CostModel::pcie_transfer`]); beyond that the shared link is
    /// the bottleneck and the surplus shows up as contention stall.
    pub fn host_pool_transfer(&self, bytes: f64, lanes: usize) -> f64 {
        let share = self.hw.host_link_gbps / lanes.max(1) as f64;
        self.hw.pcie_latency_s + bytes / self.hw.pcie_gbps.min(share)
    }

    /// Weighted variant of [`CostModel::host_pool_transfer`] for
    /// heterogeneous host attachments (`--replica-hw` `HOST_GBPS`
    /// field): this lane claims `own / total` of the shared host
    /// budget, where `total` sums the live lanes' weights
    /// ([`HardwareConfig::host_lane_weight`]).  With unit weights
    /// (`own = 1`, `total = live lanes`) the share — and the duration —
    /// is bitwise-identical to the unweighted form, which the lane
    /// asymmetry tests pin.
    pub fn host_pool_transfer_share(&self, bytes: f64, own: f64, total: f64) -> f64 {
        let share = self.hw.host_link_gbps * own / total.max(own).max(f64::MIN_POSITIVE);
        self.hw.pcie_latency_s + bytes / self.hw.pcie_gbps.min(share)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PaperModel;

    fn cm() -> CostModel {
        CostModel::new(HardwareConfig::default(), PaperModel::mixtral_8x7b(), 4.0)
    }

    #[test]
    fn transfer_times_match_bandwidth() {
        let c = cm();
        let b = c.expert_weight_bytes(Precision::Int4);
        // ~88 MB int4 expert over 12.8 GB/s ~ 6.9 ms
        let t = c.pcie_transfer(b);
        assert!(t > 5e-3 && t < 10e-3, "t={t}");
        // bf16 expert ~352 MB ~ 27 ms
        let tb = c.pcie_transfer(c.expert_weight_bytes(Precision::Bf16));
        assert!(tb > 20e-3 && tb < 35e-3, "tb={tb}");
        assert!(c.nvme_transfer(b) > t);
    }

    #[test]
    fn host_pool_transfer_contends_past_the_link_budget() {
        let c = cm();
        let b = c.expert_weight_bytes(Precision::Int4);
        // default host link = 2x pcie: 1 and 2 lanes ride at full lane
        // speed, the contended duration degenerates to pcie_transfer
        assert_eq!(c.host_pool_transfer(b, 0), c.pcie_transfer(b));
        assert_eq!(c.host_pool_transfer(b, 1), c.pcie_transfer(b));
        assert_eq!(c.host_pool_transfer(b, 2), c.pcie_transfer(b));
        // beyond the budget each lane's share shrinks monotonically
        let t4 = c.host_pool_transfer(b, 4);
        let t8 = c.host_pool_transfer(b, 8);
        assert!(t4 > c.pcie_transfer(b), "4 lanes must contend");
        assert!(t8 > t4, "more lanes, more stall");
        // 8 lanes over a 25.6 GB/s link = 3.2 GB/s per lane
        let expect = c.hw.pcie_latency_s + b / 3.2e9;
        assert!((t8 - expect).abs() < 1e-12, "t8={t8} expect={expect}");
    }

    #[test]
    fn weighted_host_pool_share_matches_even_split_at_unit_weights() {
        let c = cm();
        let b = c.expert_weight_bytes(Precision::Int4);
        // unit weights are the unweighted model, bit for bit
        for lanes in 1..=8usize {
            assert_eq!(
                c.host_pool_transfer_share(b, 1.0, lanes as f64),
                c.host_pool_transfer(b, lanes),
                "unit-weight share must be bitwise-identical at {lanes} lanes"
            );
        }
        // a heavier lane keeps more of the link: 7 of (7+1) on 25.6 GB/s
        // = 22.4 GB/s, above the 12.8 GB/s PCIe ceiling -> full lane speed
        let fat = c.host_pool_transfer_share(b, 7.0, 8.0);
        assert_eq!(fat, c.pcie_transfer(b));
        // ... while the light lane gets 1/8 = 3.2 GB/s
        let thin = c.host_pool_transfer_share(b, 1.0, 8.0);
        let expect = c.hw.pcie_latency_s + b / 3.2e9;
        assert!((thin - expect).abs() < 1e-12, "thin={thin} expect={expect}");
        assert!(thin > fat);
        // degenerate totals never divide by zero
        assert!(c.host_pool_transfer_share(b, 1.0, 0.0).is_finite());
    }

    #[test]
    fn decode_expert_is_memory_bound() {
        let c = cm();
        // one token: flops tiny, weight read dominates
        let t = c.expert_gpu(1, Precision::Bf16);
        let expect = c.expert_weight_bytes(Precision::Bf16) / c.hw.hbm_gbps * 4.0;
        assert!((t - expect - c.hw.kernel_overhead_s * 4.0).abs() / expect < 0.05);
        // quantized read is cheaper
        assert!(c.expert_gpu(1, Precision::Int2) < c.expert_gpu(1, Precision::Bf16));
    }

    #[test]
    fn prefill_expert_is_compute_bound() {
        let c = cm();
        let t_bf16 = c.expert_gpu(128, Precision::Bf16);
        let t_int4 = c.expert_gpu(128, Precision::Int4);
        // with many tokens the dequant factor makes int4 *compute* slower
        assert!(t_int4 > t_bf16);
    }

    #[test]
    fn cpu_much_slower_than_gpu_for_batches() {
        let c = cm();
        assert!(
            c.expert_cpu(128, Precision::Bf16) > 20.0 * c.expert_gpu(128, Precision::Bf16)
        );
    }

    #[test]
    fn skip_costs_nothing() {
        let c = cm();
        assert_eq!(c.expert_gpu(5, Precision::Skip), 0.0);
        assert_eq!(c.expert_cpu(5, Precision::Skip), 0.0);
    }

    #[test]
    fn batched_decode_attention_amortizes_weight_reads() {
        let c = cm();
        // a batch of one is exactly the serial op
        for pos in [1usize, 17, 300] {
            assert_eq!(c.attn_decode_batch(&[pos]), c.attn_decode(pos));
        }
        // batching never beats free: more tokens cost more...
        let batch = [10usize, 20, 30, 40];
        let t_batch = c.attn_decode_batch(&batch);
        assert!(t_batch > c.attn_decode(40));
        // ...but one fused step beats four serial steps (single weight
        // read + single kernel overhead)
        let t_serial: f64 = batch.iter().map(|&p| c.attn_decode(p)).sum();
        assert!(
            t_batch < t_serial,
            "batched {t_batch} not cheaper than serial {t_serial}"
        );
    }

    #[test]
    fn batched_expert_ffn_amortizes_weight_fetch() {
        let c = cm();
        // the expert roofline is already batched: n tokens through one
        // expert cost far less than n separate single-token executions
        let one = c.expert_gpu(1, Precision::Int4);
        let four = c.expert_gpu(4, Precision::Int4);
        assert!(four < 4.0 * one);
        assert!(four >= one);
    }

    #[test]
    fn mixed_attention_reduces_exactly_to_pure_phases() {
        let c = cm();
        // pure prefill chunk covering its whole window == monolithic op
        for t in [1usize, 8, 64, 300] {
            assert_eq!(c.attn_mixed(t, t, &[]), c.attn_prefill(t));
        }
        // pure decode == the batched decode op (and the serial op at b=1)
        for pos in [1usize, 17, 300] {
            assert_eq!(c.attn_mixed(0, 0, &[pos]), c.attn_decode(pos));
        }
        let batch = [10usize, 20, 30, 40];
        assert_eq!(c.attn_mixed(0, 0, &batch), c.attn_decode_batch(&batch));
    }

    #[test]
    fn mixed_attention_fuses_cheaper_than_separate_steps() {
        let c = cm();
        let batch = [10usize, 20, 30];
        // one fused chunk+decode layer beats a chunk layer plus a decode
        // layer (single weight read, single kernel overhead) ...
        let fused = c.attn_mixed(8, 24, &batch);
        let separate = c.attn_mixed(8, 24, &[]) + c.attn_decode_batch(&batch);
        assert!(fused < separate, "fused {fused} not below separate {separate}");
        // ... but fusion is not free: it costs more than either alone
        assert!(fused > c.attn_mixed(8, 24, &[]));
        assert!(fused > c.attn_decode_batch(&batch));
    }

    #[test]
    fn chunk_attention_pays_for_its_prefix_window() {
        let c = cm();
        // the same chunk deeper into the prompt attends to more history:
        // strictly more flops and KV read-back
        let early = c.attn_mixed(8, 8, &[]);
        let late = c.attn_mixed(8, 128, &[]);
        assert!(late > early);
        // chunks tile a prompt: the four chunk layers cost more than the
        // one monolithic layer (per-chunk weight reads + KV read-back) —
        // chunking buys interleaving, not raw prefill speed
        let whole = c.attn_prefill(32);
        let tiled: f64 = (1..=4).map(|i| c.attn_mixed(8, 8 * i, &[])).sum();
        assert!(tiled > whole);
    }

    #[test]
    fn durations_scale_with_layers() {
        let c4 = cm();
        let c1 = CostModel::new(HardwareConfig::default(), PaperModel::mixtral_8x7b(), 1.0);
        assert!(c4.attn_decode(10) > 3.0 * c1.attn_decode(10));
    }
}

//! The pluggable serving-policy interface consumed by the [`engine`], plus
//! the DyMoE policy itself.  The offloading baselines in
//! [`crate::baselines`] implement the same trait, so every system is
//! measured on the identical substrate (same model, same cache/transfer
//! machinery, same cost model) — only the *policy* differs.
//!
//! [`engine`]: super::engine

use crate::config::PolicyConfig;
use crate::model::assets::ExpertKey;
use crate::quant::Precision;
use crate::util::rng::Rng;

use super::importance::{decode_importance, prefill_importance};
use super::prefetcher::{predict_decode, predict_prefill};
use super::scheduler::{assign_precisions, layer_budget, Allocation, Selection};
use super::{Phase, Route};

/// Everything a policy may inspect when planning one layer's experts.
pub struct LayerCtx<'a> {
    pub layer: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub phase: Phase,
    /// Per valid token: routed experts with renormalized gate weights.
    pub routes: &'a [Route],
    /// Gate probabilities: `[M]` in decode, row-major `[T, M]` in
    /// prefill.  For a *batched* decode step (several sessions decoding
    /// together, `routes.len() > 1`) this is the batch-aggregated gate
    /// mass — the per-expert mean over the batch's gate rows, itself a
    /// distribution — so importance concentrates fidelity on the experts
    /// carrying the most gate mass across the whole batch.
    pub gate_probs: &'a [f32],
    /// Eq.-1 token-importance scores (prefill only).
    pub token_scores: Option<&'a [f32]>,
}

/// The policy's verdict for one layer.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    /// Execution precision per expert (`Skip` = drop the expert).
    pub precision: Vec<Precision>,
    /// If true and the expert is not VRAM-resident, execute it on the host
    /// CPU instead of transferring (Fiddler-style co-execution).
    pub cpu_fallback: Vec<bool>,
}

impl LayerPlan {
    pub fn uniform(n_experts: usize, p: Precision) -> Self {
        LayerPlan {
            precision: vec![p; n_experts],
            cpu_fallback: vec![false; n_experts],
        }
    }
}

/// Context for a look-ahead prefetch decision after layer `next_layer - 1`.
pub struct PrefetchCtx<'a> {
    pub next_layer: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub phase: Phase,
    pub seq_len: usize,
    /// Eq.-6 approximate gate probabilities for `next_layer`
    /// (`[M]` decode / `[T, M]` prefill).
    pub probe_probs: &'a [f32],
}

/// A serving policy: precision planning + prefetching + residency.
pub trait Strategy {
    fn name(&self) -> String;

    /// Plan the current layer's expert executions.
    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan;

    /// Whether the engine should run the Eq.-6 gate probe (costs one small
    /// matmul per layer; pointless for non-prefetching baselines).
    fn wants_probe(&self) -> bool {
        false
    }

    /// Experts to prefetch for `ctx.next_layer`, with target precisions.
    fn prefetch(&mut self, _ctx: &PrefetchCtx) -> Vec<(usize, Precision)> {
        Vec::new()
    }

    /// Whether this policy uses the VRAM expert cache at all.
    fn uses_cache(&self) -> bool {
        true
    }

    /// Whether demand misses populate the cache (static-placement
    /// baselines stream without caching).
    fn inserts_on_miss(&self) -> bool {
        true
    }

    /// Initial VRAM residency, highest priority first; the engine inserts
    /// entries until the budget is full (model-load time, not billed).
    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)>;

    /// Fraction of the warm residency that stays pinned (never evicted).
    /// 0.0 = plain LRU.  (DyMoE uses depth-aware eviction priorities
    /// instead — see [`Strategy::depth_priority`].)
    fn pinned_fraction(&self) -> f64 {
        0.0
    }

    /// Use the scan-resistant segmented LRU: fresh inserts are probation,
    /// re-referenced entries are protected, so the prefill layer sweep (a
    /// one-shot scan over every expert) cannot thrash the hot working set
    /// while decode's re-referenced experts stay protected.  Plain LRU
    /// when false (the baselines' published behaviour).
    fn scan_resistant_cache(&self) -> bool {
        false
    }

    /// Called at the start of every request (per-request policy state).
    fn begin_request(&mut self, _phase_hint: Phase) {}

    /// Update the retention ratio between requests (the §6.3 runtime
    /// knob; see [`super::adaptive::RetentionController`]).  No-op for
    /// policies without a retention concept.
    fn set_retention(&mut self, _r: f64) {}
}

/// Layer-major warm fill at a uniform precision (shared by baselines).
pub fn layer_major_residency(
    n_layers: usize,
    n_experts: usize,
    p: Precision,
) -> Vec<(ExpertKey, Precision)> {
    (0..n_layers)
        .flat_map(|l| (0..n_experts).map(move |e| (ExpertKey::new(l, e), p)))
        .collect()
}

// ---------------------------------------------------------------------------
// DyMoE
// ---------------------------------------------------------------------------

/// The paper's policy: phase-adaptive importance -> depth-aware cosine
/// budgets -> mixed-precision tiers, with Eq.-6/7/8 look-ahead prefetch.
pub struct DyMoEStrategy {
    pub policy: PolicyConfig,
    /// Fig.-3 knobs: how critical experts are picked and budgeted.
    pub selection: Selection,
    rng: Rng,
}

impl DyMoEStrategy {
    pub fn new(policy: PolicyConfig) -> Self {
        DyMoEStrategy { policy, selection: Selection::Importance, rng: Rng::new(0xD43) }
    }

    fn allocation(&self) -> Allocation {
        if self.policy.depth_aware {
            Allocation::DepthCosine
        } else {
            Allocation::Equal
        }
    }

    fn budget(&self, layer: usize, n_layers: usize, n_experts: usize) -> usize {
        layer_budget(
            self.allocation(),
            layer,
            n_layers,
            self.policy.retention,
            n_experts,
        )
    }
}

impl Default for DyMoEStrategy {
    fn default() -> Self {
        DyMoEStrategy::new(PolicyConfig::default())
    }
}

impl Strategy for DyMoEStrategy {
    fn name(&self) -> String {
        format!(
            "DyMoE({}, r={})",
            self.policy.low_mode.label(),
            self.policy.retention
        )
    }

    fn plan(&mut self, ctx: &LayerCtx) -> LayerPlan {
        if !self.policy.dyquant_enabled {
            return LayerPlan::uniform(ctx.n_experts, self.policy.high);
        }
        match ctx.phase {
            // Prefill (Fig. 8): Eq.-2 heavy-hitter importance over all M
            // experts, Eq.-5 budget t_l = ceil(r(l) * M).
            Phase::Prefill => {
                let importance = prefill_importance(
                    ctx.token_scores.unwrap_or(&[]),
                    ctx.routes,
                    ctx.n_experts,
                    self.policy.heavy_hitter_frac,
                );
                let budget = self.budget(ctx.layer, ctx.n_layers, ctx.n_experts);
                let precision = assign_precisions(
                    &importance,
                    budget,
                    self.selection,
                    self.policy.high,
                    self.policy.low_mode.precision(),
                    &mut self.rng,
                );
                LayerPlan { precision, cpu_fallback: vec![false; ctx.n_experts] }
            }
            // Decode (Fig. 9): gate-guided selection among the *routed*
            // experts — the retention ratio tiers the top-k set itself
            // (top ceil(r(l) * k) routed experts are Critical); this is
            // what makes 4/2 / 4/0 cut decode I/O and compute.
            Phase::Decode => {
                let importance = decode_importance(ctx.gate_probs);
                let budget = self.budget(ctx.layer, ctx.n_layers, ctx.top_k);
                let order = super::importance::rank_desc(&importance);
                let mut precision =
                    vec![self.policy.low_mode.precision(); ctx.n_experts];
                for (rank, e) in order.into_iter().enumerate() {
                    if rank < budget {
                        precision[e] = self.policy.high;
                    } else {
                        break;
                    }
                }
                if self.selection == Selection::Random {
                    // Fig.-3 "Random" arm: pick the critical routed
                    // experts uniformly instead of by gate score.
                    precision = assign_precisions(
                        &importance,
                        budget,
                        Selection::Random,
                        self.policy.high,
                        self.policy.low_mode.precision(),
                        &mut self.rng,
                    );
                }
                LayerPlan { precision, cpu_fallback: vec![false; ctx.n_experts] }
            }
        }
    }

    fn wants_probe(&self) -> bool {
        self.policy.prefetch_enabled
    }

    fn prefetch(&mut self, ctx: &PrefetchCtx) -> Vec<(usize, Precision)> {
        if !self.policy.prefetch_enabled {
            return Vec::new();
        }
        // Critical budget at the next layer: over all M experts in
        // prefill, over the routed top-k in decode (see `plan`).
        let budget = match ctx.phase {
            Phase::Prefill => self.budget(ctx.next_layer, ctx.n_layers, ctx.n_experts),
            Phase::Decode => self.budget(ctx.next_layer, ctx.n_layers, ctx.top_k),
        };
        let depth = if self.policy.prefetch_depth == 0 {
            ctx.top_k
        } else {
            self.policy.prefetch_depth
        };
        let predicted = match ctx.phase {
            // Eq. 8: direct prefetch of the top-t predicted experts.
            Phase::Decode => predict_decode(ctx.probe_probs, depth.min(ctx.n_experts)),
            // Eq. 7: token-frequency prefetch across the whole prompt; the
            // useful prefetch width is the next layer's critical budget.
            Phase::Prefill => predict_prefill(
                ctx.probe_probs,
                ctx.seq_len,
                ctx.n_experts,
                ctx.top_k,
                budget,
            ),
        };
        // Predicted rank within the critical budget -> high tier;
        // below it -> the low tier (never prefetch a Skip).
        let low = self.policy.low_mode.precision();
        predicted
            .into_iter()
            .enumerate()
            .filter_map(|(rank, e)| {
                let p = if !self.policy.dyquant_enabled || rank < budget {
                    self.policy.high
                } else {
                    low
                };
                (p != Precision::Skip).then_some((e, p))
            })
            .collect()
    }

    /// Depth-aware warm fill: shallow layers first (they hold the largest
    /// critical budgets under Eq. 4), experts at the high tier.
    fn warm_residency(&self, n_layers: usize, n_experts: usize) -> Vec<(ExpertKey, Precision)> {
        layer_major_residency(n_layers, n_experts, self.policy.high)
    }

    /// DyMoE's cache is scan-resistant (see trait docs): prefill's
    /// one-shot expert sweep must not evict the re-referenced residents.
    fn scan_resistant_cache(&self) -> bool {
        true
    }

    fn set_retention(&mut self, r: f64) {
        self.policy.retention = r.clamp(0.0, 1.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LowMode;

    fn decode_ctx<'a>(gate: &'a [f32], routes: &'a [Route]) -> LayerCtx<'a> {
        LayerCtx {
            layer: 4,
            n_layers: 8,
            n_experts: gate.len(),
            top_k: 2,
            phase: Phase::Decode,
            routes,
            gate_probs: gate,
            token_scores: None,
        }
    }

    #[test]
    fn dymoe_decode_plan_tiers_by_gate() {
        let mut s = DyMoEStrategy::new(PolicyConfig {
            retention: 0.5,
            low_mode: LowMode::Int2,
            ..Default::default()
        });
        let gate = [0.4f32, 0.3, 0.1, 0.05, 0.05, 0.04, 0.03, 0.03];
        let routes = vec![vec![(0usize, 0.57f32), (1, 0.43)]];
        let plan = s.plan(&decode_ctx(&gate, &routes));
        // decode budgets tier the routed top-k (Fig. 9): layer 4 of 8,
        // lambda 0 -> r(4) ~ 0.389 -> ceil(0.389 * k=2) = 1 critical
        let hi = plan
            .precision
            .iter()
            .filter(|&&p| p == Precision::Int4)
            .count();
        assert_eq!(hi, 1);
        assert_eq!(plan.precision[0], Precision::Int4); // top gate score
        assert_eq!(plan.precision[1], Precision::Int2); // 2nd routed -> low
        assert_eq!(plan.precision[7], Precision::Int2);
    }

    #[test]
    fn dymoe_shallow_layers_keep_everything() {
        let mut s = DyMoEStrategy::default(); // r = 0.75
        let gate = [0.2f32; 8];
        let routes = vec![vec![(0usize, 1.0f32)]];
        let mut ctx = decode_ctx(&gate, &routes);
        ctx.layer = 0;
        let plan = s.plan(&ctx);
        // layer 0 keeps the full routed set critical: budget = top_k = 2;
        // flat gate ties break by index.
        assert_eq!(plan.precision[0], Precision::Int4);
        assert_eq!(plan.precision[1], Precision::Int4);
        let hi = plan
            .precision
            .iter()
            .filter(|&&p| p == Precision::Int4)
            .count();
        assert_eq!(hi, ctx.top_k);
    }

    #[test]
    fn dyquant_disabled_is_uniform() {
        let mut s = DyMoEStrategy::new(PolicyConfig {
            dyquant_enabled: false,
            ..Default::default()
        });
        let gate = [0.9f32, 0.02, 0.02, 0.02, 0.01, 0.01, 0.01, 0.01];
        let routes = vec![vec![(0usize, 1.0f32)]];
        let plan = s.plan(&decode_ctx(&gate, &routes));
        assert!(plan.precision.iter().all(|&p| p == Precision::Int4));
    }

    #[test]
    fn prefetch_decode_tiers_by_rank() {
        let mut s = DyMoEStrategy::new(PolicyConfig {
            retention: 0.5,
            prefetch_depth: 4,
            low_mode: LowMode::Int2,
            ..Default::default()
        });
        let probe = [0.4f32, 0.3, 0.15, 0.1, 0.02, 0.01, 0.01, 0.01];
        let picks = s.prefetch(&PrefetchCtx {
            next_layer: 7,
            n_layers: 8,
            n_experts: 8,
            top_k: 2,
            phase: Phase::Decode,
            seq_len: 1,
            probe_probs: &probe,
        });
        assert_eq!(picks.len(), 4);
        // deepest layer budget at r=0.5 (lambda=0) -> 1 critical
        assert_eq!(picks[0], (0, Precision::Int4));
        assert_eq!(picks[1].1, Precision::Int2);
    }

    #[test]
    fn prefetch_skip_mode_prefetches_only_critical() {
        let mut s = DyMoEStrategy::new(PolicyConfig {
            retention: 0.5,
            prefetch_depth: 4,
            low_mode: LowMode::Skip,
            ..Default::default()
        });
        let probe = [0.4f32, 0.3, 0.15, 0.1, 0.02, 0.01, 0.01, 0.01];
        let picks = s.prefetch(&PrefetchCtx {
            next_layer: 7,
            n_layers: 8,
            n_experts: 8,
            top_k: 2,
            phase: Phase::Decode,
            seq_len: 1,
            probe_probs: &probe,
        });
        // sub-critical predictions would be Skip -> filtered out
        assert_eq!(picks, vec![(0, Precision::Int4)]);
    }
}

//! Look-ahead prefetching (paper §4.4.1, Eq. 6–8).
//!
//! The Eq.-6 gate approximation itself runs as the `gate_probe` HLO
//! artifact (layer-(l+1) router applied to the layer-l hidden state);
//! this module turns the predicted probabilities into prefetch decisions:
//!
//! * **Decode (Eq. 8)** — directly prefetch the top-t predicted experts.
//! * **Prefill (Eq. 7)** — aggregate each token's predicted top-k into
//!   per-expert activation frequencies and prefetch the top-t by count.
//!
//! Statistics track prediction usefulness (a prefetched expert that is
//! routed in the next layer counts as useful).

use super::importance::rank_desc;

#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchStats {
    pub issued: u64,
    pub useful: u64,
    pub wasted: u64,
}

impl PrefetchStats {
    /// Hit rate of the look-ahead predictor.  Well-defined (0.0, not NaN)
    /// when nothing was issued.
    pub fn accuracy(&self) -> f64 {
        if self.issued == 0 {
            0.0
        } else {
            self.useful as f64 / self.issued as f64
        }
    }

    /// Prefetches issued but not yet resolved into useful/wasted.  The
    /// engine invariant is `issued == useful + wasted + in_flight` at all
    /// times, with `in_flight == 0` at every step boundary (every
    /// prediction targets a layer that executes within the same step).
    /// Saturating so a broken accounting state reads as 0 rather than
    /// wrapping; use [`PrefetchStats::balanced`] to detect that state.
    pub fn in_flight(&self) -> u64 {
        self.issued.saturating_sub(self.useful + self.wasted)
    }

    /// The accounting invariant: resolved prefetches never exceed issued
    /// ones.
    pub fn balanced(&self) -> bool {
        self.useful + self.wasted <= self.issued
    }
}

/// Eq. 8: decode-phase prediction — top-t experts of the probe.
pub fn predict_decode(probe_probs: &[f32], t: usize) -> Vec<usize> {
    let imp: Vec<f64> = probe_probs.iter().map(|&p| p as f64).collect();
    rank_desc(&imp).into_iter().take(t).collect()
}

/// Batched Eq. 8: aggregate `batch` per-session decode probes (row-major
/// `[batch, n_experts]`) into one per-expert probe by mean gate mass, so
/// one prefetch decision serves the whole decode batch.  Identity for a
/// batch of one (see [`super::importance::batch_gate_mass`]).
pub fn aggregate_decode_probes(probe_probs: &[f32], batch: usize, n_experts: usize) -> Vec<f32> {
    super::importance::batch_gate_mass(probe_probs, batch, n_experts)
}

/// Eq.-6 probe rows for a prefill **chunk**: the `[start, end)` token
/// rows of a row-major `[seq, n_experts]` probe matrix, flattened
/// contiguously.  Chunked prefill issues its look-ahead from chunk
/// boundaries, so the Eq.-7 frequency prediction must run over exactly
/// the chunk's tokens — earlier positions already steered the prefetch
/// chain when their own chunk executed.  For a chunk covering the whole
/// prompt (`start == 0`) this is the full monolithic probe.
pub fn chunk_probe_rows(
    probe: &[f32],
    start: usize,
    end: usize,
    n_experts: usize,
) -> Vec<f32> {
    debug_assert!(start <= end && end * n_experts <= probe.len(), "chunk probe bounds");
    probe[start * n_experts..end * n_experts].to_vec()
}

/// Eq. 7: prefill-phase prediction — per-expert activation frequency
/// `c_e = sum_i 1[e in top-k of token i]`, then top-t by frequency.
///
/// `probe_probs` is row-major `[seq_len, n_experts]`.
pub fn predict_prefill(
    probe_probs: &[f32],
    seq_len: usize,
    n_experts: usize,
    top_k: usize,
    t: usize,
) -> Vec<usize> {
    let mut counts = vec![0f64; n_experts];
    for token in 0..seq_len {
        let row = &probe_probs[token * n_experts..(token + 1) * n_experts];
        let route = super::top_k_route(row, top_k);
        for (e, w) in route {
            counts[e] += 1.0 + (w as f64) * 1e-6; // tiny gate-mass tiebreak
        }
    }
    rank_desc(&counts)
        .into_iter()
        .take(t)
        .filter(|&e| counts[e] > 0.0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_prediction_ranks_probs() {
        assert_eq!(predict_decode(&[0.1, 0.6, 0.3], 2), vec![1, 2]);
        assert_eq!(predict_decode(&[0.1, 0.6, 0.3], 5), vec![1, 2, 0]);
    }

    #[test]
    fn prefill_prediction_counts_frequencies() {
        // 3 tokens, 4 experts, top-2 each
        #[rustfmt::skip]
        let probs = vec![
            0.5, 0.4, 0.1, 0.0,   // -> e0, e1
            0.6, 0.3, 0.1, 0.0,   // -> e0, e1
            0.0, 0.1, 0.5, 0.4,   // -> e2, e3
        ];
        let p = predict_prefill(&probs, 3, 4, 2, 2);
        assert_eq!(p, vec![0, 1]); // both hit twice; ties by index
        let p3 = predict_prefill(&probs, 3, 4, 2, 4);
        assert_eq!(p3.len(), 4);
        assert!(p3[2] == 2 || p3[2] == 3);
    }

    #[test]
    fn prefill_prediction_ignores_padding() {
        let probs = vec![
            1.0, 0.0, //
            0.0, 1.0, // padding row, must be ignored with seq_len = 1
        ];
        let p = predict_prefill(&probs, 1, 2, 1, 2);
        assert_eq!(p, vec![0]);
    }

    #[test]
    fn chunk_probe_rows_select_the_chunk_window() {
        #[rustfmt::skip]
        let probe = [
            0.9f32, 0.1,
            0.2,    0.8,
            0.5,    0.5,
        ];
        assert_eq!(chunk_probe_rows(&probe, 1, 3, 2), vec![0.2, 0.8, 0.5, 0.5]);
        // a chunk covering the whole prompt is the monolithic probe
        assert_eq!(chunk_probe_rows(&probe, 0, 3, 2), probe.to_vec());
        // chunk-local prediction sees only its own rows
        let rows = chunk_probe_rows(&probe, 1, 2, 2);
        assert_eq!(predict_prefill(&rows, 1, 2, 1, 1), vec![1]);
    }

    #[test]
    fn stats_accuracy() {
        let s = PrefetchStats { issued: 10, useful: 7, wasted: 3 };
        assert!((s.accuracy() - 0.7).abs() < 1e-12);
        assert_eq!(PrefetchStats::default().accuracy(), 0.0);
    }

    #[test]
    fn stats_balance_and_in_flight() {
        let settled = PrefetchStats { issued: 10, useful: 7, wasted: 3 };
        assert!(settled.balanced());
        assert_eq!(settled.in_flight(), 0);
        let pending = PrefetchStats { issued: 5, useful: 2, wasted: 1 };
        assert!(pending.balanced());
        assert_eq!(pending.in_flight(), 2);
        let broken = PrefetchStats { issued: 2, useful: 2, wasted: 1 };
        assert!(!broken.balanced());
        // zero issued: accuracy stays defined, nothing in flight
        let zero = PrefetchStats::default();
        assert!(zero.balanced());
        assert_eq!(zero.in_flight(), 0);
        assert!(zero.accuracy().is_finite());
    }

    #[test]
    fn decode_probe_aggregation_matches_mean() {
        #[rustfmt::skip]
        let probes = [
            0.7f32, 0.2, 0.1,
            0.1,    0.8, 0.1,
        ];
        let agg = aggregate_decode_probes(&probes, 2, 3);
        assert!((agg[0] - 0.4).abs() < 1e-7);
        assert!((agg[1] - 0.5).abs() < 1e-7);
        // a batch of one is the probe itself
        let one = aggregate_decode_probes(&probes[..3], 1, 3);
        assert_eq!(one, probes[..3].to_vec());
        // aggregated prediction ranks by combined mass
        assert_eq!(predict_decode(&agg, 2), vec![1, 0]);
    }
}

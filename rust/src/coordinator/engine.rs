//! The serving engine: drives the model artifact-by-artifact with real
//! numerics while co-simulating time on the virtual timeline
//! (DESIGN.md §6).  One engine = one model + one policy + one simulated
//! edge device.
//!
//! Requests are served through a **step-wise session API** —
//! [`Engine::begin_session`] / [`Engine::prefill_session`] /
//! [`Engine::decode_batch`] — so a scheduler can interleave prefill
//! steps and *batched* decode steps of many in-flight sessions on the
//! one device (the multi-session serving layer in [`crate::serving`]
//! does exactly that; sessions then contend for the shared
//! mixed-precision cache and PCIe channel).
//!
//! The unit of scheduling is the fused **mixed step**
//! ([`Engine::mixed_step`]): one tick may carry a resumable *prefill
//! chunk* of one session ([`Engine::prefill_chunk`]; cursor plus a
//! per-layer hidden-state carry live on [`EngineSession`]) **and** a
//! cross-session decode batch, executed as one pass per layer.
//! Routing is computed per token across both phases, the union of
//! routed experts is materialized **once** (cache hit, prefetch, or
//! load at the precision chosen by gate mass aggregated across chunk
//! *and* decode tokens — [`importance::mixed_gate_mass`]), the cost
//! model charges a single batched roofline per layer
//! ([`crate::costmodel::CostModel::attn_mixed`]: one attention weight
//! read plus per-token compute and KV reads), and Eq.-6 look-ahead
//! probes are issued from the chunk boundary and the decode batch.
//! The phase-pure paths are exact degenerations: a decode batch is a
//! mixed step with no chunk ([`Engine::decode_session`] is a decode
//! batch of one), a chunk spanning the whole prompt reproduces the
//! monolithic [`Engine::prefill_session`] numerics, and
//! [`Engine::run`] / [`Engine::run_forced`] are the classic
//! run-to-completion path implemented on top of the same steps, so
//! back-to-back serving (batch size 1, the paper's latency-sensitive
//! edge scenario) behaves exactly as before.
//!
//! Per layer the engine:
//! 1. runs the attention half (artifact) and charges its roofline cost;
//! 2. routes tokens top-k from the gate probabilities;
//! 3. asks the [`Strategy`] for a [`LayerPlan`] (precision per expert);
//! 4. resolves each routed expert's weights through the mixed-precision
//!    cache — hits use the cached copy (conservative reuse may upgrade
//!    fidelity), misses issue PCIe (and optionally NVMe) transfers;
//! 5. executes experts in weight-arrival order on the GPU channel (or the
//!    CPU channel for Fiddler-style fallback), accumulating the weighted,
//!    renormalized expert mixture onto the residual stream;
//! 6. runs the Eq.-6 gate probe and lets the strategy prefetch for the
//!    next layer, overlapping transfers with subsequent compute.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::config::SystemConfig;
use crate::costmodel::CostModel;
use crate::memory::{HostPoolHandle, PoolAccess, PoolStats, Timeline, TracePhase};
use crate::model::assets::{ExpertKey, ModelAssets};
use crate::model::executor::Executor;
use crate::model::kv::KvCache;
use crate::model::sampler;
use crate::quant::Precision;

use super::cache::{Lookup, MixedPrecisionCache, PinClass};
use super::prefetcher::{self, PrefetchStats};
use super::strategy::{LayerCtx, PrefetchCtx, Strategy};
use super::{importance, top_k_route, Phase, Route};

/// Engine construction options.
#[derive(Debug, Clone, Default)]
pub struct EngineOptions {
    /// Record timeline events (Fig.-1 pipeline visualisation).
    pub record_timeline: bool,
    /// Keep per-step logits in the output (needed by eval).
    pub collect_logits: bool,
    /// Keep per-layer prefill hidden states (Fig. 6).
    pub collect_hidden: bool,
    /// Execute experts at the *planned* precision even when the cache
    /// holds a higher-fidelity copy (disables the accuracy side of the
    /// conservative-reuse rule).  Accuracy experiments set this so that
    /// e.g. a 4/2 policy really executes Int2 for sub-critical experts —
    /// with ample VRAM the warm fill would otherwise serve everything
    /// from high-precision copies and the tables would be degenerate.
    pub strict_precision: bool,
}

/// Result of serving one request.
#[derive(Debug, Clone)]
pub struct RequestOutput {
    /// Generated (or teacher-forced) tokens.
    pub tokens: Vec<i32>,
    /// Time to first token (s, virtual).
    pub ttft: f64,
    /// Completion time of every emitted token, relative to request start.
    pub token_times: Vec<f64>,
    /// Logits at every emitted position (when `collect_logits`).
    pub logits_per_step: Vec<Vec<f32>>,
    /// Per-layer prefill hidden states (when `collect_hidden`).
    pub prefill_hidden: Vec<Vec<f32>>,
    /// Virtual request start time.
    pub start: f64,
}

impl RequestOutput {
    /// Mean time per output token after the first (s); falls back to TTFT
    /// when only one token was produced.
    pub fn tpot(&self) -> f64 {
        if self.token_times.len() <= 1 {
            return self.ttft;
        }
        let last = *self.token_times.last().unwrap();
        (last - self.token_times[0]) / (self.token_times.len() - 1) as f64
    }
}

/// Outcome of one fused [`Engine::mixed_step`].
#[derive(Debug, Clone)]
pub struct MixedReport {
    /// Prompt tokens the prefill chunk advanced this tick (0 when the
    /// step carried no prefill part).
    pub chunk: usize,
    /// The prefill session finished its prompt and emitted its first
    /// token this tick.
    pub prefill_done: bool,
    /// Per decode session (input order): has it emitted its last token?
    pub dones: Vec<bool>,
}

/// Aggregated engine counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub requests: u64,
    pub transferred_bytes: u64,
    pub expert_execs: u64,
    pub skipped_experts: u64,
    pub cpu_execs: u64,
    /// Decode steps taken through [`Engine::decode_batch`] (a serial
    /// decode is a batch of one).
    pub decode_batches: u64,
    /// Tokens emitted by those decode steps.
    pub decode_batch_tokens: u64,
    /// Routed `(token, expert)` pairs across all decode-batch layers.
    pub routed_pairs: u64,
    /// Distinct experts materialized for those pairs (one per layer per
    /// step, however many tokens share it) — the denominator of the
    /// cross-session dedup win.  Ratio/savings views over these counters
    /// live in [`crate::serving::metrics::DedupStats`].
    pub unique_expert_loads: u64,
    /// Prefill chunks executed through [`Engine::mixed_step`] (the
    /// monolithic [`Engine::prefill_session`] path does not count).
    pub prefill_chunks: u64,
    /// Prompt tokens those chunks advanced (sums to the prompt length
    /// per chunk-prefilled session — token conservation).
    pub prefill_chunk_tokens: u64,
    /// Mixed steps that fused a prefill chunk with a decode batch in
    /// one per-layer pass.
    pub mixed_steps: u64,
}

struct ExpertExec {
    key: ExpertKey,
    /// Precision actually executed (cache may upgrade it).
    exec_prec: Precision,
    ready_at: f64,
    on_cpu: bool,
    token_idx: Vec<usize>,
    weights: Vec<f32>,
}

/// The serving engine (one model, one policy, one simulated device).
pub struct Engine {
    pub exec: std::rc::Rc<Executor>,
    pub assets: Arc<ModelAssets>,
    pub sys: SystemConfig,
    pub cost: CostModel,
    pub timeline: Timeline,
    pub cache: MixedPrecisionCache,
    pub strategy: Box<dyn Strategy>,
    pub opts: EngineOptions,
    pub stats: EngineStats,
    pub prefetch_stats: PrefetchStats,
    /// Experts prefetched for the upcoming layer (usefulness accounting).
    prefetched_for: HashMap<usize, Vec<usize>>,
    /// Warm-residency keys pinned during prefill (phase-adaptive pinning:
    /// the scan-resistant prefix matters for the prefill layer sweep; the
    /// decode phase needs the slack for dynamic locality).
    warm_pinned: Vec<ExpertKey>,
    /// Which `(session, phase)` the strategy / pinning state is currently
    /// configured for.  Phase transitions (and session switches under
    /// interleaving) re-run the per-phase setup exactly once.
    phase_ctx: Option<(u64, Phase)>,
    next_session_id: u64,
    /// Cross-replica shared host expert tier.  Attached by the cluster
    /// for `--host-pool` runs and detached before the run finishes;
    /// `None` (the default, and the only state single-engine paths ever
    /// see) leaves every transfer path exactly as before.
    pub host_pool: Option<HostPoolHandle>,
}

/// One in-flight request's engine-side state: its private [`KvCache`],
/// sampling cursor, and timing.  This is the unit the multi-session
/// serving layer interleaves; everything else (mixed-precision cache,
/// PCIe/NVMe channels, GPU) is shared across sessions.
pub struct EngineSession {
    id: u64,
    /// Serving-layer trace tag (the fleet request id); `None` until the
    /// serving layer stamps one ([`EngineSession::set_trace_tag`]).
    tag: Option<u64>,
    prompt: Vec<i32>,
    forced: Option<Vec<i32>>,
    /// Total tokens to emit (first token included), >= 1.
    n_new: usize,
    kv: KvCache,
    /// Last emitted token (decode input).
    token: i32,
    emitted: usize,
    /// Chunked-prefill cursor: prompt tokens whose layer sweep has run.
    /// Stays 0 on the monolithic [`Engine::prefill_session`] path.
    cursor: usize,
    /// Per-layer hidden-state carry for resumable chunked prefill:
    /// `carry[l]` holds the layer-`l` *input* hidden states over the
    /// padded `[max_seq, d]` buffer (`carry[0]` = token embeddings,
    /// `carry[n_layers]` = final hidden states), valid for positions
    /// `0..cursor`.  Allocated on the first chunk and dropped the
    /// moment prefill completes.
    carry: Vec<Vec<f32>>,
    /// Virtual arrival time; service never starts earlier.
    pub arrival: f64,
    pub out: RequestOutput,
}

impl EngineSession {
    /// Engine-assigned session id (unique per engine).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// The id trace events are stamped with: the serving-layer request
    /// id when one was set, the engine session id otherwise.
    pub fn trace_tag(&self) -> u64 {
        self.tag.unwrap_or(self.id)
    }

    /// Stamp the serving-layer request id this session serves, so trace
    /// events correlate with the fleet's per-request records.
    pub fn set_trace_tag(&mut self, tag: u64) {
        self.tag = Some(tag);
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }

    /// The session's prompt tokens.  The serving layer uses this to
    /// rebuild the original request when a replica fails mid-session
    /// and its work must be re-dispatched elsewhere
    /// ([`crate::serving::Replica::evacuate`]).
    pub fn prompt(&self) -> &[i32] {
        &self.prompt
    }

    /// Tokens emitted so far.
    pub fn emitted(&self) -> usize {
        self.emitted
    }

    /// Total tokens this session will emit.
    pub fn target_tokens(&self) -> usize {
        self.n_new
    }

    /// Prefill has run (first token exists).
    pub fn prefilled(&self) -> bool {
        self.emitted > 0
    }

    /// Prompt tokens already processed by chunked prefill (0 before the
    /// first chunk and on the monolithic path).
    pub fn prefill_cursor(&self) -> usize {
        self.cursor
    }

    /// Prompt tokens still to prefill; 0 once the first token exists.
    /// Strictly decreases with every chunk the scheduler grants this
    /// session (the no-starvation property the token-budget scheduler
    /// tests pin down).
    pub fn prefill_remaining(&self) -> usize {
        if self.prefilled() {
            0
        } else {
            self.prompt.len() - self.cursor
        }
    }

    /// Bytes held by this session's private KV cache.
    pub fn kv_bytes(&self) -> u64 {
        self.kv.bytes()
    }

    /// Read-only view of the session's private KV cache (diagnostics;
    /// the chunked-prefill equivalence suite compares cache contents
    /// against the monolithic path through this).
    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    pub fn done(&self) -> bool {
        self.emitted >= self.n_new
    }

    /// Consume the session, yielding its request output.
    pub fn into_output(self) -> RequestOutput {
        self.out
    }
}

impl Engine {
    pub fn new(
        assets: &Arc<ModelAssets>,
        sys: SystemConfig,
        strategy: Box<dyn Strategy>,
    ) -> Result<Engine> {
        Engine::with_options(assets, sys, strategy, EngineOptions::default())
    }

    pub fn with_options(
        assets: &Arc<ModelAssets>,
        sys: SystemConfig,
        strategy: Box<dyn Strategy>,
        opts: EngineOptions,
    ) -> Result<Engine> {
        let exec = std::rc::Rc::new(Executor::new(assets.clone())?);
        Engine::with_executor(assets, sys, strategy, opts, exec)
    }

    /// Build an engine over a shared executor (experiment sweeps reuse the
    /// compiled artifacts and weight literals across configurations).
    pub fn with_executor(
        assets: &Arc<ModelAssets>,
        sys: SystemConfig,
        strategy: Box<dyn Strategy>,
        opts: EngineOptions,
        exec: std::rc::Rc<Executor>,
    ) -> Result<Engine> {
        let m = &assets.manifest.model;
        let cost = CostModel::new(
            sys.hardware.clone(),
            sys.paper.clone(),
            sys.layer_scale(m.n_layers),
        );
        let capacity = if strategy.uses_cache() {
            sys.expert_cache_bytes(m.n_layers, m.n_experts)
        } else {
            0
        };
        let mut cache = MixedPrecisionCache::new(capacity);
        cache.set_scan_resistant(strategy.scan_resistant_cache());
        // Warm residency: model load happens before serving; not billed.
        // An optional pinned fraction of the warm set survives eviction.
        let mut warm_pinned = Vec::new();
        if strategy.uses_cache() {
            let pin_budget =
                (capacity as f64 * strategy.pinned_fraction()) as u64;
            for (key, prec) in strategy.warm_residency(m.n_layers, m.n_experts) {
                let bytes = cost.expert_weight_bytes(prec) as u64;
                if cache.used_bytes() + bytes > cache.capacity() {
                    break;
                }
                let pin = cache.used_bytes() + bytes <= pin_budget;
                cache.insert(key, prec, bytes, 0.0);
                if pin {
                    cache.set_pinned(key, PinClass::Warm, true);
                    warm_pinned.push(key);
                }
            }
            // warm fill is not demand traffic
            cache.stats = Default::default();
        }
        Ok(Engine {
            exec,
            assets: assets.clone(),
            sys,
            cost,
            timeline: Timeline::new(opts.record_timeline),
            cache,
            strategy,
            opts,
            stats: EngineStats::default(),
            prefetch_stats: PrefetchStats::default(),
            prefetched_for: HashMap::new(),
            warm_pinned,
            phase_ctx: None,
            next_session_id: 0,
            host_pool: None,
        })
    }

    pub fn model(&self) -> &crate::model::manifest::MiniModel {
        &self.assets.manifest.model
    }

    /// Do two engines share one [`Executor`]?  Sharing is the cheap
    /// default for serial sweeps (compiled artifacts and weight
    /// literals reused), but executor state is single-thread confined,
    /// so the parallel cluster scheduler rejects shared executors.
    pub fn shares_executor(&self, other: &Engine) -> bool {
        std::rc::Rc::ptr_eq(&self.exec, &other.exec)
    }

    /// Current virtual time (the device's compute-availability horizon).
    pub fn clock(&self) -> f64 {
        self.timeline.gpu.free_at
    }

    /// Snapshot of the cumulative busy seconds on every device channel.
    /// Like [`Engine::stats`], these counters grow over the engine's
    /// whole lifetime and are **not** cleared by
    /// [`Engine::reset_stats`]; per-run consumers (the serving replica
    /// layer) must snapshot at run start and report the delta, or an
    /// engine reused across runs double-counts earlier runs' busy time.
    pub fn busy_totals(&self) -> crate::memory::BusyTotals {
        self.timeline.busy_totals()
    }

    /// Serve one request, sampling greedily.
    pub fn run(&mut self, prompt: &[i32], max_new: usize) -> Result<RequestOutput> {
        self.run_forced(prompt, max_new, None)
    }

    /// Serve one request to completion; when `forced` is given,
    /// teacher-force those tokens instead of sampling (eval:
    /// `logits_per_step[i]` then scores `forced[i]`).  Implemented on the
    /// step-wise session API, so it is numerically and temporally
    /// identical to a single-session fleet.
    pub fn run_forced(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        forced: Option<&[i32]>,
    ) -> Result<RequestOutput> {
        let arrival = self.timeline.gpu.free_at;
        let mut s = self.begin_session(prompt, max_new, forced, arrival)?;
        self.prefill_session(&mut s)?;
        while !s.done() {
            self.decode_session(&mut s)?;
        }
        Ok(s.into_output())
    }

    // -----------------------------------------------------------------
    // Step-wise session API (multi-session serving entry points)
    // -----------------------------------------------------------------

    /// Reconfigure the per-phase strategy / pinning state when the
    /// `(session, phase)` context changes.  For a single run-to-completion
    /// request this fires exactly twice (prefill, then decode), matching
    /// the classic path; under interleaving every session switch re-enters
    /// the phase so policies always see the phase they are planning for.
    fn enter_phase(&mut self, session: u64, phase: Phase) {
        if self.phase_ctx == Some((session, phase)) {
            return;
        }
        self.phase_ctx = Some((session, phase));
        self.strategy.begin_request(phase);
        // Look-ahead state never survives a context switch: a prefetch
        // issued for another session's next layer says nothing about this
        // one.  (Within one session the map is empty at phase boundaries —
        // predictions are consumed by the very next layer — so this only
        // bites, and only as `wasted`, under interleaving.)
        for (_, pref) in self.prefetched_for.drain() {
            self.prefetch_stats.wasted += pref.len() as u64;
        }
        match phase {
            // Phase-adaptive pinning: re-pin whatever of the warm resident
            // set survived earlier decode phases (evicted entries re-stream
            // on demand and re-enter the cache unpinned).  Warm pins are a
            // distinct [`PinClass`] so a fused layer's transient working-set
            // pin can come and go on the same entry without dropping them —
            // mixed ticks interleave both lifetimes on one cache.
            Phase::Prefill => {
                for key in self.warm_pinned.clone() {
                    self.cache.set_pinned(key, PinClass::Warm, true);
                }
            }
            // Release the prefill pins: decode's working set is small and
            // dynamic, so the whole cache becomes LRU slack.  Only the warm
            // class is released — in-flight layer pins are untouched.
            Phase::Decode => {
                for key in self.warm_pinned.clone() {
                    self.cache.set_pinned(key, PinClass::Warm, false);
                }
            }
        }
    }

    /// Open a session: validate the request and allocate its KV cache.
    /// Nothing is scheduled until [`Engine::prefill_session`]; `arrival`
    /// is the virtual time before which service may not start.
    pub fn begin_session(
        &mut self,
        prompt: &[i32],
        max_new: usize,
        forced: Option<&[i32]>,
        arrival: f64,
    ) -> Result<EngineSession> {
        let m = self.model().clone();
        ensure!(!prompt.is_empty(), "empty prompt");
        ensure!(
            prompt.len() <= m.max_seq,
            "prompt length {} exceeds bucket {}",
            prompt.len(),
            m.max_seq
        );
        let n_new = forced.map(|f| f.len()).unwrap_or(max_new);
        ensure!(
            prompt.len() + n_new <= m.max_cache,
            "prompt + generation exceeds KV capacity"
        );
        let id = self.next_session_id;
        self.next_session_id += 1;
        Ok(EngineSession {
            id,
            tag: None,
            prompt: prompt.to_vec(),
            forced: forced.map(|f| f.to_vec()),
            n_new: n_new.max(1),
            kv: KvCache::new(m.n_layers, m.max_cache, m.n_heads, m.head_dim),
            token: 0,
            emitted: 0,
            cursor: 0,
            carry: Vec::new(),
            arrival,
            out: RequestOutput {
                tokens: Vec::new(),
                ttft: 0.0,
                token_times: Vec::new(),
                logits_per_step: Vec::new(),
                prefill_hidden: Vec::new(),
                start: 0.0,
            },
        })
    }

    /// Run the session's whole prefill (all layers) and emit its first
    /// token as **one monolithic scheduling step** — the head-of-line
    /// path a long prompt makes every other session wait behind.  This
    /// is the `--chunk-tokens 0` behaviour and is kept verbatim so the
    /// monolithic fleet path stays step-for-step identical; the
    /// resumable alternative is [`Engine::prefill_chunk`], which
    /// reproduces these numerics for any chunk size under
    /// precision-invariant strategies (asserted with uniform Bf16 in
    /// `tests/integration_chunked_prefill.rs`; under DyMoE's dynamic
    /// quantization a partial chunk legitimately plans heavy hitters
    /// over its own tokens, a deliberate scheduling trade-off rather
    /// than an equivalence) while letting decode steps of other
    /// sessions fuse between chunks.
    pub fn prefill_session(&mut self, s: &mut EngineSession) -> Result<()> {
        ensure!(!s.prefilled(), "session {} already prefilled", s.id);
        ensure!(
            s.cursor == 0,
            "session {} has a chunked prefill in progress",
            s.id
        );
        let m = self.model().clone();
        self.enter_phase(s.id, Phase::Prefill);
        self.timeline.ctx_step(&[s.trace_tag()], TracePhase::Prefill);
        self.stats.requests += 1;

        let start = self.timeline.gpu.free_at.max(s.arrival);
        s.out.start = start;
        let seq_len = s.prompt.len();
        let mut padded = s.prompt.clone();
        padded.resize(m.max_seq, 0);
        let mut h = self.exec.embed_seq(&padded)?;
        let mut layer_ready = start;
        for layer in 0..m.n_layers {
            layer_ready = self
                .layer_prefill(layer, &mut h, seq_len, &mut s.kv, layer_ready)
                .with_context(|| format!("prefill layer {layer}"))?;
            if self.opts.collect_hidden {
                s.out.prefill_hidden.push(h.clone());
            }
        }
        // First-token logits from the last valid position.
        self.timeline.ctx_layer(None); // the head is not layer work
        let d = m.d_model;
        let h_last = &h[(seq_len - 1) * d..seq_len * d];
        let logits = self.exec.finalize_one(h_last)?;
        let t_first = self.timeline.gpu_compute(
            self.timeline.gpu.free_at,
            layer_ready,
            self.cost.head(1, 1.0),
            "finalize",
        );
        s.out.ttft = t_first - start;
        s.out.token_times.push(s.out.ttft);
        let first = s
            .forced
            .as_ref()
            .and_then(|f| f.first().copied())
            .unwrap_or_else(|| sampler::greedy(&logits) as i32);
        s.out.tokens.push(first);
        if self.opts.collect_logits {
            s.out.logits_per_step.push(logits);
        }
        s.token = first;
        s.emitted = 1;
        Ok(())
    }

    /// Decode one token for the session (all layers).  A decode batch of
    /// one — see [`Engine::decode_batch`].  Returns `true` when the
    /// session has emitted its last token.
    pub fn decode_session(&mut self, s: &mut EngineSession) -> Result<bool> {
        ensure!(s.prefilled(), "decode before prefill (session {})", s.id);
        if s.done() {
            return Ok(true);
        }
        let dones = self.decode_batch(&mut [s])?;
        Ok(dones[0])
    }

    /// Decode one token for **every** session in the batch as a single
    /// fused step.  Per layer, each session runs its own attention over
    /// its private KV cache (charged as one batched roofline: attention
    /// weight read and kernel overhead amortized across the batch),
    /// routing is computed per token, and the union of routed experts is
    /// materialized once — concurrent sessions that route to the same
    /// expert share its fetch/dequantization instead of each paying it,
    /// with precision and prefetch decisions driven by batch-aggregated
    /// gate mass.  A batch of one is step-for-step identical (numerics,
    /// virtual timing, stats) to the classic single-session decode.
    ///
    /// Implemented as a [`Engine::mixed_step`] with no prefill chunk —
    /// the phase-pure degeneration is exact (same float operations on
    /// the same virtual timeline).
    ///
    /// Returns, per session, whether it has now emitted its last token.
    pub fn decode_batch(&mut self, sessions: &mut [&mut EngineSession]) -> Result<Vec<bool>> {
        Ok(self.mixed_step(None, sessions)?.dones)
    }

    /// Advance one session's **resumable chunked prefill** by up to
    /// `max_tokens` prompt tokens (a [`Engine::mixed_step`] with no
    /// decode batch).  The cursor strictly advances on every call; when
    /// the chunk reaches the end of the prompt the first token is
    /// emitted, exactly as [`Engine::prefill_session`] would have.
    /// Returns `true` once prefill is complete.
    pub fn prefill_chunk(&mut self, s: &mut EngineSession, max_tokens: usize) -> Result<bool> {
        Ok(self.mixed_step(Some((s, max_tokens)), &mut [])?.prefill_done)
    }

    /// One fused **mixed step**: up to one prefill chunk plus a decode
    /// batch, executed as a single pass per layer (the unit the
    /// token-budget continuous scheduler in [`crate::serving`] issues
    /// every virtual tick).  Per layer:
    ///
    /// 1. the chunk's attention runs over its causal window (earlier
    ///    positions come from the per-layer hidden carry; new K/V rows
    ///    extend the session's cache) and each decode session attends
    ///    over its private KV cache — all charged as **one** batched
    ///    roofline ([`crate::costmodel::CostModel::attn_mixed`]);
    /// 2. Eq.-6 look-ahead probes are issued from the chunk boundary
    ///    (prefill prediction over the chunk's rows) and from the
    ///    aggregated decode probe;
    /// 3. routing is computed per token across both phases and the
    ///    union of routed experts is materialized **once**, at
    ///    precisions chosen from gate mass aggregated over chunk and
    ///    decode tokens ([`importance::mixed_gate_mass`]).
    ///
    /// Phase-pure steps degenerate exactly: no chunk reproduces the
    /// classic batched decode step for step, and a chunk covering the
    /// whole prompt with no decode batch reproduces the monolithic
    /// prefill numerics and virtual costs.  Partial chunks reproduce
    /// the monolithic numerics under precision-invariant strategies;
    /// DyMoE's dynamic quantization plans each chunk's heavy hitters
    /// over that chunk's tokens — a different (chunk-local) operating
    /// point by design.
    ///
    /// Host-side note: the co-simulated numerics re-run the fixed-shape
    /// prefill artifact over the whole `0..end` prefix each chunk (the
    /// AOT artifact set has no chunk-query attention kernel), so real
    /// wall-clock prefill work scales with the number of chunks even
    /// though [`crate::costmodel::CostModel::attn_mixed`] correctly
    /// charges chunk-only *virtual* cost.  A chunk-query attention
    /// artifact over the cached K/V rows would remove that recompute.
    pub fn mixed_step(
        &mut self,
        prefill: Option<(&mut EngineSession, usize)>,
        decode: &mut [&mut EngineSession],
    ) -> Result<MixedReport> {
        let m = self.model().clone();
        let d = m.d_model;
        let b = decode.len();

        let mut seen = std::collections::HashSet::with_capacity(b + 1);
        for s in decode.iter() {
            ensure!(s.prefilled(), "decode before prefill (session {})", s.id);
            ensure!(!s.done(), "session {} already finished", s.id);
            ensure!(seen.insert(s.id), "duplicate session {} in decode batch", s.id);
        }
        let mut pre = match prefill {
            Some((s, max_tokens)) => {
                ensure!(!s.prefilled(), "session {} already prefilled", s.id);
                ensure!(
                    seen.insert(s.id),
                    "prefill session {} also in the decode batch",
                    s.id
                );
                ensure!(max_tokens > 0, "empty prefill chunk budget");
                Some((s, max_tokens))
            }
            None => {
                ensure!(b > 0, "empty mixed step");
                None
            }
        };
        let chunk = pre
            .as_ref()
            .map(|(s, max_tokens)| (*max_tokens).min(s.prompt.len() - s.cursor))
            .unwrap_or(0);
        ensure!(
            chunk + b <= m.max_seq,
            "mixed step of {chunk} chunk + {b} decode tokens exceeds the \
             largest expert token bucket {}",
            m.max_seq
        );

        // Phase context: a tick carrying a chunk runs under the prefill
        // context (the warm scan-resistant prefix stays pinned while any
        // prompt sweep is in flight, even with decode tokens fused in);
        // a pure decode tick keys on the smallest session id so a stable
        // batch keeps its look-ahead chain as the scheduling lead
        // rotates, and a batch of one reduces to the classic path.
        match &pre {
            Some((s, _)) => self.enter_phase(s.id, Phase::Prefill),
            None => {
                let lead = decode.iter().map(|s| s.id).min().unwrap();
                self.enter_phase(lead, Phase::Decode);
            }
        }
        if self.timeline.record {
            let mut tags: Vec<u64> = decode.iter().map(|s| s.trace_tag()).collect();
            if let Some((s, _)) = pre.as_ref() {
                tags.push(s.trace_tag());
            }
            let phase = if chunk > 0 && b > 0 {
                TracePhase::Mixed
            } else if chunk > 0 {
                TracePhase::Prefill
            } else {
                TracePhase::Decode
            };
            self.timeline.ctx_step(&tags, phase);
        }
        if b > 0 {
            self.stats.decode_batches += 1;
            self.stats.decode_batch_tokens += b as u64;
        }
        if chunk > 0 {
            self.stats.prefill_chunks += 1;
            self.stats.prefill_chunk_tokens += chunk as u64;
            if b > 0 {
                self.stats.mixed_steps += 1;
            }
        }

        // First chunk: open the request, allocate the per-layer carry,
        // and embed the padded prompt once (`carry[0]` = layer-0 input).
        let mut deps = self.timeline.gpu.free_at;
        if let Some((s, _)) = pre.as_mut() {
            if s.cursor == 0 {
                self.stats.requests += 1;
                s.out.start = self.timeline.gpu.free_at.max(s.arrival);
                let mut padded = s.prompt.clone();
                padded.resize(m.max_seq, 0);
                let emb = self.exec.embed_seq(&padded)?;
                s.carry = vec![vec![0f32; m.max_seq * d]; m.n_layers + 1];
                s.carry[0].copy_from_slice(&emb);
            }
            deps = deps.max(s.arrival);
        }

        // Chunk hidden stream (layer-0 input rows of this chunk) and the
        // decode batch's embedded tokens.
        let mut h_chunk = pre
            .as_ref()
            .map(|(s, _)| s.carry[0][s.cursor * d..(s.cursor + chunk) * d].to_vec())
            .unwrap_or_default();
        let mut h_dec = vec![0f32; b * d];
        for (i, s) in decode.iter().enumerate() {
            let hd = self.exec.embed_one(s.token)?;
            h_dec[i * d..(i + 1) * d].copy_from_slice(&hd);
        }

        let mut ready = deps;
        for layer in 0..m.n_layers {
            // (explicit match, not Option::map: the chunk hidden buffer's
            // reborrow must not be captured by a closure)
            #[allow(clippy::manual_map)]
            let pf = match pre.as_mut() {
                Some((s, _)) => Some((&mut **s, &mut h_chunk)),
                None => None,
            };
            ready = self
                .layer_mixed(layer, pf, chunk, decode, &mut h_dec, ready)
                .with_context(|| {
                    format!("mixed layer {layer} (chunk {chunk} + batch {b})")
                })?;
        }

        // Advance the cursor; a chunk reaching the end of the prompt
        // emits the first token in this very tick.
        let mut completes = false;
        if let Some((s, _)) = pre.as_mut() {
            let end = s.cursor + chunk;
            s.carry[m.n_layers][s.cursor * d..end * d].copy_from_slice(&h_chunk);
            s.cursor = end;
            completes = end == s.prompt.len();
        }
        self.timeline.ctx_layer(None); // the head is not layer work
        let fin_tokens = b + completes as usize;
        let t_tok = if fin_tokens > 0 {
            self.timeline.gpu_compute(
                self.timeline.gpu.free_at,
                ready,
                self.cost.head(fin_tokens, 1.0),
                "finalize",
            )
        } else {
            ready
        };

        if completes {
            let (s, _) = pre.as_mut().unwrap();
            let seq_len = s.prompt.len();
            let h_last = &s.carry[m.n_layers][(seq_len - 1) * d..seq_len * d];
            let logits = self.exec.finalize_one(h_last)?;
            s.out.ttft = t_tok - s.out.start;
            s.out.token_times.push(s.out.ttft);
            let first = s
                .forced
                .as_ref()
                .and_then(|f| f.first().copied())
                .unwrap_or_else(|| sampler::greedy(&logits) as i32);
            s.out.tokens.push(first);
            if self.opts.collect_logits {
                s.out.logits_per_step.push(logits);
            }
            if self.opts.collect_hidden {
                // `prefill_hidden[l]` = output of layer `l` = input of
                // layer `l + 1` (valid for the prompt's positions).
                let outputs = s.carry[1..].iter().cloned();
                s.out.prefill_hidden.extend(outputs);
            }
            s.token = first;
            s.emitted = 1;
            s.carry = Vec::new(); // prefill is over; free the carry
        }

        let mut dones = Vec::with_capacity(b);
        for (i, s) in decode.iter_mut().enumerate() {
            let logits = self.exec.finalize_one(&h_dec[i * d..(i + 1) * d])?;
            let step = s.emitted;
            s.out.token_times.push(t_tok - s.out.start);
            let token = s
                .forced
                .as_ref()
                .map(|f| f[step])
                .unwrap_or_else(|| sampler::greedy(&logits) as i32);
            s.out.tokens.push(token);
            if self.opts.collect_logits {
                s.out.logits_per_step.push(logits);
            }
            s.token = token;
            s.emitted += 1;
            dones.push(s.done());
        }
        Ok(MixedReport { chunk, prefill_done: completes, dones })
    }

    // -----------------------------------------------------------------
    // Layer execution
    // -----------------------------------------------------------------

    fn layer_prefill(
        &mut self,
        layer: usize,
        h: &mut Vec<f32>,
        seq_len: usize,
        kv: &mut KvCache,
        deps: f64,
    ) -> Result<f64> {
        let m = self.model().clone();
        self.timeline.ctx_layer(Some(layer as u32));
        // Fused attention + Eq.-6 probe when the policy prefetches: one
        // PJRT execution, and the prefetch is issued *before* this layer's
        // expert compute so transfers overlap it (paper §4.4.1).
        let want_probe = self.strategy.wants_probe() && layer + 1 < m.n_layers;
        let (po, probe) = if want_probe {
            let (po, probe) = self.exec.attn_prefill_probe(layer, layer + 1, h, seq_len)?;
            (po, Some(probe))
        } else {
            (self.exec.attn_prefill(layer, h, seq_len)?, None)
        };
        let mut attn_cost = self.cost.attn_prefill(seq_len);
        if want_probe {
            attn_cost += self.cost.gate(seq_len);
        }
        let t_attn = self.timeline.gpu_compute(
            self.timeline.gpu.free_at,
            deps,
            attn_cost,
            &format!("attn_p L{layer}"),
        );
        kv.write_prefix(layer, seq_len, &po.k, &po.v)?;

        if let Some(probe) = &probe {
            self.issue_prefetch(layer + 1, probe, Phase::Prefill, seq_len);
        }

        // Route every valid token.
        let routes: Vec<Route> = (0..seq_len)
            .map(|t| {
                top_k_route(
                    &po.gate_probs[t * m.n_experts..(t + 1) * m.n_experts],
                    m.top_k,
                )
            })
            .collect();

        let plan = self.strategy.plan(&LayerCtx {
            layer,
            n_layers: m.n_layers,
            n_experts: m.n_experts,
            top_k: m.top_k,
            phase: Phase::Prefill,
            routes: &routes,
            gate_probs: &po.gate_probs,
            token_scores: Some(&po.token_scores),
        });

        self.execute_experts(
            layer,
            &routes,
            &plan,
            &po.moe_in,
            &po.h_resid,
            h,
            seq_len,
            t_attn,
        )
    }

    /// One layer of a fused mixed step: the prefill chunk's attention
    /// over its causal window (hidden carry supplies earlier positions),
    /// per-decode-session attention over private KV caches, **one**
    /// batched roofline charge, probe prefetch from the chunk boundary
    /// and the aggregated decode probe, per-token routing across both
    /// phases, and one shared expert-union execution.  With no chunk
    /// this is exactly the classic batched-decode layer; with no decode
    /// batch and a chunk covering the whole prompt it is exactly the
    /// monolithic prefill layer.
    #[allow(clippy::too_many_arguments)]
    fn layer_mixed(
        &mut self,
        layer: usize,
        mut prefill: Option<(&mut EngineSession, &mut Vec<f32>)>,
        chunk: usize,
        decode: &mut [&mut EngineSession],
        h_dec: &mut Vec<f32>,
        deps: f64,
    ) -> Result<f64> {
        let m = self.model().clone();
        self.timeline.ctx_layer(Some(layer as u32));
        let b = decode.len();
        let d = m.d_model;
        let want_probe = self.strategy.wants_probe() && layer + 1 < m.n_layers;

        // ---- prefill chunk: attention over the chunk's causal window --
        let mut chunk_moe = Vec::new();
        let mut chunk_resid = Vec::new();
        let mut chunk_gate = Vec::new();
        let mut chunk_scores = Vec::new();
        let mut chunk_probe = Vec::new();
        let mut prefix_end = 0;
        if let Some((s, h_chunk)) = prefill.as_mut() {
            let cursor = s.cursor;
            let end = cursor + chunk;
            prefix_end = end;
            // The chunk rows join the layer's input carry; rows before
            // `cursor` are already there from earlier chunks, rows past
            // `end` are zero (the artifact masks beyond `end`).
            s.carry[layer][cursor * d..end * d].copy_from_slice(&h_chunk[..]);
            let (po, probe) = if want_probe {
                let (po, probe) =
                    self.exec.attn_prefill_probe(layer, layer + 1, &s.carry[layer], end)?;
                (po, Some(probe))
            } else {
                (self.exec.attn_prefill(layer, &s.carry[layer], end)?, None)
            };
            s.kv.write_prefix(layer, end, &po.k, &po.v)?;
            chunk_moe = po.moe_in[cursor * d..end * d].to_vec();
            chunk_resid = po.h_resid[cursor * d..end * d].to_vec();
            chunk_gate = po.gate_probs[cursor * m.n_experts..end * m.n_experts].to_vec();
            chunk_scores = po.token_scores[cursor..end].to_vec();
            if let Some(pr) = &probe {
                chunk_probe = prefetcher::chunk_probe_rows(pr, cursor, end, m.n_experts);
            }
        }

        // ---- decode batch: per-session attention over private KV ------
        let mut moe_dec = vec![0f32; b * d];
        let mut resid_dec = vec![0f32; b * d];
        let mut gate_dec = vec![0f32; b * m.n_experts];
        let mut probe_dec =
            if want_probe { vec![0f32; b * m.n_experts] } else { Vec::new() };
        let mut positions = Vec::with_capacity(b);
        for (i, s) in decode.iter_mut().enumerate() {
            let pos = s.prompt.len() + s.emitted - 1;
            positions.push(pos);
            let hi = &h_dec[i * d..(i + 1) * d];
            let dout = if want_probe {
                let (dout, probe) =
                    self.exec.attn_decode_probe(layer, layer + 1, hi, &s.kv, pos)?;
                probe_dec[i * m.n_experts..(i + 1) * m.n_experts]
                    .copy_from_slice(&probe);
                dout
            } else {
                self.exec.attn_decode(layer, hi, &s.kv, pos)?
            };
            s.kv.write_row(layer, pos, &dout.k_new, &dout.v_new)?;
            moe_dec[i * d..(i + 1) * d].copy_from_slice(&dout.moe_in);
            resid_dec[i * d..(i + 1) * d].copy_from_slice(&dout.h_resid);
            gate_dec[i * m.n_experts..(i + 1) * m.n_experts]
                .copy_from_slice(&dout.gate_probs);
        }

        // One fused roofline for the whole step's attention; the gate
        // probes (one per phase present) ride on top.
        let mut attn_cost = self.cost.attn_mixed(chunk, prefix_end, &positions);
        if want_probe {
            if chunk > 0 {
                attn_cost += self.cost.gate(chunk);
            }
            if b > 0 {
                attn_cost += self.cost.gate(b);
            }
        }
        let label = if chunk > 0 && b > 0 {
            format!("attn_m L{layer}")
        } else if chunk > 0 {
            format!("attn_p L{layer}")
        } else {
            format!("attn_d L{layer}")
        };
        let t_attn =
            self.timeline.gpu_compute(self.timeline.gpu.free_at, deps, attn_cost, &label);

        // Prefetch before this layer's expert compute (maximum overlap):
        // Eq.-7 frequency prediction from the chunk boundary, Eq.-8 from
        // the batch-aggregated decode probe.
        if want_probe && chunk > 0 {
            self.issue_prefetch(layer + 1, &chunk_probe, Phase::Prefill, chunk);
        }
        if want_probe && b > 0 {
            let probe = prefetcher::aggregate_decode_probes(&probe_dec, b, m.n_experts);
            self.issue_prefetch(layer + 1, &probe, Phase::Decode, b);
        }

        // Per-token routing across both phases (chunk rows first).
        let mut routes: Vec<Route> = chunk_gate
            .chunks_exact(m.n_experts)
            .map(|row| top_k_route(row, m.top_k))
            .collect();
        let dec_routes: Vec<Route> = gate_dec
            .chunks_exact(m.n_experts)
            .map(|row| top_k_route(row, m.top_k))
            .collect();
        if b > 0 {
            // Dedup accounting keeps its decode-batch semantics: however
            // many sessions route to an expert, it is materialized once.
            let pairs: usize = dec_routes.iter().map(|r| r.len()).sum();
            let union: std::collections::HashSet<usize> = dec_routes
                .iter()
                .flat_map(|r| r.iter().map(|&(e, _)| e))
                .collect();
            self.stats.routed_pairs += pairs as u64;
            self.stats.unique_expert_loads += union.len() as u64;
        }
        routes.extend(dec_routes);

        // Precision planning: with decode tokens present the plan sees
        // the gate mass aggregated across both phases (bitwise the
        // batch-aggregated mass when there is no chunk); a pure chunk
        // plans with prefill heavy-hitter importance over its tokens.
        let plan = if b > 0 {
            let agg = importance::mixed_gate_mass(&chunk_gate, &gate_dec, m.n_experts);
            self.strategy.plan(&LayerCtx {
                layer,
                n_layers: m.n_layers,
                n_experts: m.n_experts,
                top_k: m.top_k,
                phase: Phase::Decode,
                routes: &routes,
                gate_probs: &agg,
                token_scores: None,
            })
        } else {
            self.strategy.plan(&LayerCtx {
                layer,
                n_layers: m.n_layers,
                n_experts: m.n_experts,
                top_k: m.top_k,
                phase: Phase::Prefill,
                routes: &routes,
                gate_probs: &chunk_gate,
                token_scores: Some(&chunk_scores),
            })
        };

        // One shared expert-union execution over chunk + decode rows.
        let rows = chunk + b;
        let mut moe_in = chunk_moe;
        moe_in.extend_from_slice(&moe_dec);
        let mut h_resid = chunk_resid;
        h_resid.extend_from_slice(&resid_dec);
        let mut h_all = vec![0f32; rows * d];
        let t_layer = self
            .execute_experts(layer, &routes, &plan, &moe_in, &h_resid, &mut h_all, rows, t_attn)?;
        if let Some((_, h_chunk)) = prefill.as_mut() {
            h_chunk.copy_from_slice(&h_all[..chunk * d]);
        }
        h_dec.copy_from_slice(&h_all[chunk * d..]);
        Ok(t_layer)
    }

    /// Resolve weights, schedule, and numerically execute all routed
    /// experts of one layer; writes `h = h_resid + mixture` for the valid
    /// tokens.  Returns the virtual completion time of the layer.
    #[allow(clippy::too_many_arguments)]
    fn execute_experts(
        &mut self,
        layer: usize,
        routes: &[Route],
        plan: &super::strategy::LayerPlan,
        moe_in: &[f32],
        h_resid: &[f32],
        h: &mut Vec<f32>,
        seq_len: usize,
        t_attn: f64,
    ) -> Result<f64> {
        let m = self.model().clone();
        let d = m.d_model;

        // Prefetch usefulness accounting for this layer.
        if let Some(pref) = self.prefetched_for.remove(&layer) {
            let routed: std::collections::HashSet<usize> =
                routes.iter().flat_map(|r| r.iter().map(|&(e, _)| e)).collect();
            for e in pref {
                if routed.contains(&e) {
                    self.prefetch_stats.useful += 1;
                } else {
                    self.prefetch_stats.wasted += 1;
                }
            }
        }

        // Group routed tokens per expert.
        let mut groups: HashMap<usize, (Vec<usize>, Vec<f32>)> = HashMap::new();
        for (t, route) in routes.iter().enumerate() {
            for &(e, w) in route {
                let g = groups.entry(e).or_default();
                g.0.push(t);
                g.1.push(w);
            }
        }

        let mut execs: Vec<ExpertExec> = Vec::with_capacity(groups.len());
        let mut pinned: Vec<ExpertKey> = Vec::new();
        for (e, (token_idx, weights)) in groups {
            let wanted = plan.precision[e];
            if wanted == Precision::Skip {
                self.stats.skipped_experts += 1;
                continue;
            }
            let key = ExpertKey::new(layer, e);
            // Stamp the expert before resolving: a demand transfer the
            // miss issues carries the expert that needed it.
            self.timeline.ctx_experts(&[e as u32]);
            let (exec_prec, ready_at, on_cpu) =
                self.resolve_weights(key, wanted, plan.cpu_fallback[e], t_attn);
            if self.strategy.uses_cache() && !self.cache.is_pinned_class(key, PinClass::Layer) {
                // layer-scoped pin for the duration of this fused layer;
                // the class is disjoint from warm-residency pins, so
                // releasing it below can never drop a warm pin the other
                // phase of a mixed tick still holds on the same expert
                self.cache.set_pinned(key, PinClass::Layer, true);
                pinned.push(key);
            }
            execs.push(ExpertExec { key, exec_prec, ready_at, on_cpu, token_idx, weights });
        }
        // Execute in weight-arrival order (hits first, streams as they land).
        execs.sort_by(|a, b| a.ready_at.partial_cmp(&b.ready_at).unwrap());

        let mut mix = vec![0f32; seq_len * d];
        let mut wsum = vec![0f32; seq_len];
        let mut layer_end = t_attn;
        for ex in &execs {
            let rows: Vec<&[f32]> = ex
                .token_idx
                .iter()
                .map(|&t| &moe_in[t * d..(t + 1) * d])
                .collect();
            let outs = self.exec.expert_ffn(ex.key, ex.exec_prec, &rows)?;
            self.timeline.ctx_experts(&[ex.key.expert as u32]);
            let t_end = if ex.on_cpu {
                self.stats.cpu_execs += 1;
                self.timeline.cpu_compute(
                    t_attn,
                    ex.ready_at,
                    self.cost.expert_cpu(ex.token_idx.len(), ex.exec_prec),
                    &format!("cpu {}", ex.key),
                )
            } else {
                self.timeline.gpu_compute(
                    self.timeline.gpu.free_at,
                    ex.ready_at.max(t_attn),
                    self.cost.expert_gpu(ex.token_idx.len(), ex.exec_prec),
                    &format!("ffn {}", ex.key),
                )
            };
            self.stats.expert_execs += 1;
            layer_end = layer_end.max(t_end);
            for ((&t, &w), y) in ex.token_idx.iter().zip(&ex.weights).zip(&outs) {
                let dst = &mut mix[t * d..(t + 1) * d];
                for (a, b) in dst.iter_mut().zip(y) {
                    *a += w * b;
                }
                wsum[t] += w;
            }
        }
        for key in pinned {
            self.cache.set_pinned(key, PinClass::Layer, false);
        }
        self.timeline.ctx_experts(&[]);

        // h = h_resid + renormalized mixture (paper 4/0 drops sub-critical
        // experts; renormalizing over the executed subset keeps the
        // residual scale stable).
        h.copy_from_slice(h_resid);
        for t in 0..seq_len {
            if wsum[t] > 1e-9 {
                let inv = 1.0 / wsum[t];
                let dst = &mut h[t * d..(t + 1) * d];
                for (a, b) in dst.iter_mut().zip(&mix[t * d..(t + 1) * d]) {
                    *a += inv * b;
                }
            }
        }
        Ok(layer_end)
    }

    /// Resolve one expert's weights through the cache / transfer path.
    /// Returns `(execution precision, ready time, on_cpu)`.
    fn resolve_weights(
        &mut self,
        key: ExpertKey,
        wanted: Precision,
        cpu_fallback: bool,
        now: f64,
    ) -> (Precision, f64, bool) {
        if !self.strategy.uses_cache() {
            let arrival = self.transfer(key, wanted, now, false);
            return (wanted, arrival, false);
        }
        match self.cache.lookup(key, wanted) {
            Lookup::Hit { prec, ready_at } => {
                let exec_prec = if self.opts.strict_precision { wanted } else { prec };
                // Late prefetch: if the in-flight background copy would
                // arrive later than a fresh demand fetch, upgrade it to
                // demand priority (re-issue on the demand lane).
                if ready_at > now {
                    let fresh = now + self.cost.pcie_transfer(self.cost.expert_weight_bytes(prec));
                    if ready_at > fresh {
                        let arrival = self.transfer(key, prec, now, false);
                        self.cache.update_ready(key, arrival);
                        return (exec_prec, arrival.min(ready_at), false);
                    }
                }
                (exec_prec, ready_at, false)
            }
            Lookup::Miss { .. } => {
                if cpu_fallback {
                    // Fiddler: compute on host from full-precision weights.
                    return (Precision::Bf16, now, true);
                }
                let arrival = self.transfer(key, wanted, now, false);
                if self.strategy.inserts_on_miss() {
                    let bytes = self.cost.expert_weight_bytes(wanted) as u64;
                    self.cache.insert(key, wanted, bytes, arrival);
                }
                (wanted, arrival, false)
            }
        }
    }

    /// Issue the (virtual) host->device transfer chain for one expert.
    /// Prefetch transfers ride the background (low-priority) PCIe lane so
    /// mispredictions never delay demand fetches.
    fn transfer(&mut self, key: ExpertKey, p: Precision, issue: f64, background: bool) -> f64 {
        let bytes = self.cost.expert_weight_bytes(p);
        self.stats.transferred_bytes += bytes as u64;
        let label = format!("xfer {key} {}", p.tag());
        if let Some(pool) = self.host_pool.as_mut() {
            // Hierarchical resolve: the VRAM cache already missed (that
            // is why we are here), so probe the shared host tier before
            // paying the SSD fill.  Pool fills are latency-only (no
            // NVMe channel queueing): mid-window the shared pool is a
            // frozen snapshot, so queueing state could not be shared
            // deterministically under `--parallel` anyway.
            let host_ready = if self.sys.policy.ssd_resident {
                match pool.acquire(key, p, issue) {
                    PoolAccess::Hit { ready_at } => issue.max(ready_at),
                    PoolAccess::Fill => {
                        let ready = issue + self.cost.nvme_transfer(bytes);
                        pool.fill(key, p, bytes as u64, ready, issue);
                        ready
                    }
                    // The pool holds this expert at a lower precision
                    // than requested: upgrade in place, paying SSD
                    // bandwidth only for the byte delta over what the
                    // resident copy already covers.
                    PoolAccess::Upgrade { ready_at, have_bytes } => {
                        let delta = (bytes - have_bytes as f64).max(0.0);
                        let ready = issue.max(ready_at) + self.cost.nvme_transfer(delta);
                        pool.fill_upgrade(key, p, bytes as u64, ready, issue);
                        ready
                    }
                }
            } else {
                issue
            };
            // Every live replica's PCIe lane draws on one host-link
            // budget, split by the replicas' configured link weights
            // (an even split at the default weight of 1.0); the widened
            // duration past pcie_transfer is the contention stall.
            let (own, total) = pool.lane_share();
            let dur = self.cost.host_pool_transfer_share(bytes, own, total);
            pool.note_stall(dur - self.cost.pcie_transfer(bytes));
            return if background {
                self.timeline.pcie_prefetch(host_ready, dur, &label)
            } else {
                self.timeline.pcie_transfer(host_ready, dur, &label)
            };
        }
        let host_ready = if self.sys.policy.ssd_resident {
            self.timeline
                .nvme_stage(issue, self.cost.nvme_transfer(bytes), &label)
        } else {
            issue
        };
        let dur = self.cost.pcie_transfer(bytes);
        if background {
            self.timeline.pcie_prefetch(host_ready, dur, &label)
        } else {
            self.timeline.pcie_transfer(host_ready, dur, &label)
        }
    }

    /// Apply the attached host-pool journal to the shared pool (the
    /// cluster's event-boundary barrier).  No-op when no pool is
    /// attached or the window recorded nothing.
    pub fn flush_host_pool(&mut self) {
        if let Some(pool) = self.host_pool.as_mut() {
            pool.flush();
        }
    }

    /// Lifetime host-pool traffic observed by this engine (hits, SSD
    /// fills, contention stall); zeros when no pool is attached.
    pub fn host_pool_stats(&self) -> PoolStats {
        self.host_pool.as_ref().map(|p| p.lifetime).unwrap_or_default()
    }

    /// Detach the host pool: apply any remaining journal and return the
    /// lifetime stats.  Leaves the engine exactly as an unattached one
    /// (engine reuse across runs must not leak pool state).
    pub fn detach_host_pool(&mut self) -> PoolStats {
        match self.host_pool.take() {
            Some(mut pool) => {
                pool.flush();
                pool.lifetime
            }
            None => PoolStats::default(),
        }
    }

    /// Let the strategy prefetch experts for `next_layer`.
    fn issue_prefetch(&mut self, next_layer: usize, probe: &[f32], phase: Phase, seq_len: usize) {
        let m = self.model().clone();
        // Prefetch transfers are *for* the next layer; stamp them so,
        // and restore the in-flight layer's stamp before returning
        // (callers always pass `next_layer == current layer + 1`).
        self.timeline.ctx_layer(Some(next_layer as u32));
        let picks = self.strategy.prefetch(&PrefetchCtx {
            next_layer,
            n_layers: m.n_layers,
            n_experts: m.n_experts,
            top_k: m.top_k,
            phase,
            seq_len,
            probe_probs: probe,
        });
        let now = self.timeline.gpu.free_at;
        let mut landed = Vec::new();
        for (e, prec) in picks {
            let key = ExpertKey::new(next_layer, e);
            if self.cache.peek(key, prec) {
                continue; // already resident at sufficient fidelity
            }
            // Bound the background backlog: a prefetch that could not even
            // start before one more transfer-time has passed will be too
            // late to help and only burns bandwidth.
            let dur = self.cost.pcie_transfer(self.cost.expert_weight_bytes(prec));
            let queue_head = self.timeline.pcie.bg_free_at.max(self.timeline.pcie.free_at);
            if queue_head > now + dur {
                break; // picks are priority-ordered; later ones are worse
            }
            self.timeline.ctx_experts(&[e as u32]);
            let arrival = self.transfer(key, prec, now, true);
            if self.strategy.inserts_on_miss() {
                let bytes = self.cost.expert_weight_bytes(prec) as u64;
                self.cache.insert(key, prec, bytes, arrival);
            }
            self.prefetch_stats.issued += 1;
            landed.push(e);
        }
        if !landed.is_empty() {
            self.prefetched_for.entry(next_layer).or_default().extend(landed);
        }
        self.timeline.ctx_layer(Some((next_layer - 1) as u32));
    }

    /// Prefetches issued but not yet resolved into useful/wasted
    /// (predictions for a layer that has not executed yet).  Zero at
    /// every step boundary; `prefetch_stats.issued == useful + wasted +
    /// prefetched_in_flight()` always.
    pub fn prefetched_in_flight(&self) -> u64 {
        self.prefetched_for.values().map(|v| v.len() as u64).sum()
    }

    /// Reset the cumulative run counters: [`Engine::stats`],
    /// [`Engine::prefetch_stats`], the in-flight look-ahead bookkeeping,
    /// and the cache's *hit/miss counters* (`cache.stats`).  Cache
    /// **contents**, the virtual clock, and the timeline's busy totals
    /// are kept — a reset engine keeps serving from a warm state.  Note
    /// the serving layer never calls this: `run_fleet` / `run_cluster`
    /// snapshot `stats` and `busy_totals()` at run start and report
    /// deltas, so reusing an engine across runs (with or without a
    /// reset in between) can never double-count
    /// (`tests/integration_cluster.rs` pins this).
    pub fn reset_stats(&mut self) {
        self.stats = EngineStats::default();
        self.prefetch_stats = PrefetchStats::default();
        // In-flight look-ahead state resets with the counters: a stale
        // entry consumed after the reset would credit useful/wasted with
        // no matching `issued`, breaking the PrefetchStats invariant.
        self.prefetched_for.clear();
        self.cache.stats = Default::default();
    }
}

//! Phase-adaptive expert importance estimation (paper §4.2).
//!
//! * Prefill (Eq. 1–2): token importance comes from attention mass
//!   (computed in-kernel, see `python/compile/kernels/attention.py`); an
//!   expert's importance is its **heavy-hitter token load** — how many of
//!   the top-k most-attended tokens route to it.
//! * Decode (Eq. 3): the gate score itself is the importance.

use super::Route;

/// Indices of the `k` highest-scoring tokens (stable: ties by index).
pub fn heavy_hitters(token_scores: &[f32], seq_len: usize, k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..seq_len.min(token_scores.len())).collect();
    idx.sort_by(|&a, &b| {
        token_scores[b]
            .partial_cmp(&token_scores[a])
            .unwrap()
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Eq. 2: importance of each expert as its heavy-hitter token load.
///
/// `routes[t]` is token `t`'s routed expert set; `token_scores` the Eq.-1
/// attention scores; `hh_frac` the fraction of tokens treated as
/// heavy-hitters.  A small total-load tiebreaker (and an even smaller gate
/// -mass one) keeps the ordering deterministic and sensible when several
/// experts serve the same number of critical tokens.
pub fn prefill_importance(
    token_scores: &[f32],
    routes: &[Route],
    n_experts: usize,
    hh_frac: f64,
) -> Vec<f64> {
    let seq_len = routes.len();
    let k = ((seq_len as f64 * hh_frac).ceil() as usize).clamp(1, seq_len.max(1));
    let heavy = heavy_hitters(token_scores, seq_len, k);
    let mut is_heavy = vec![false; seq_len];
    for &t in &heavy {
        is_heavy[t] = true;
    }
    let mut imp = vec![0f64; n_experts];
    let mut load = vec![0f64; n_experts];
    let mut gate_mass = vec![0f64; n_experts];
    for (t, route) in routes.iter().enumerate() {
        for &(e, w) in route {
            if is_heavy[t] {
                imp[e] += 1.0;
            }
            load[e] += 1.0;
            gate_mass[e] += w as f64;
        }
    }
    let max_load = seq_len.max(1) as f64;
    for e in 0..n_experts {
        imp[e] += load[e] / (max_load * 1e3) + gate_mass[e] / (max_load * 1e6);
    }
    imp
}

/// Eq. 3: decode importance is the gate probability vector itself.
pub fn decode_importance(gate_probs: &[f32]) -> Vec<f64> {
    gate_probs.iter().map(|&g| g as f64).collect()
}

/// Shared accumulation core of [`batch_gate_mass`] and
/// [`mixed_gate_mass`]: fold row-major `[rows, n_experts]` gate rows
/// into `mass`, sequentially in row order.  Sequential row order is
/// load-bearing — both public wrappers inherit the exact float
/// accumulation order of their original inline loops, so the bitwise
/// identities they promise (`batch == 1` is the identity; no prefill
/// rows degenerates `mixed` to `batch`) survive the deduplication.
fn accumulate_gate_rows(mass: &mut [f32], rows: &[f32]) {
    for row in rows.chunks_exact(mass.len()) {
        for (m, &g) in mass.iter_mut().zip(row) {
            *m += g;
        }
    }
}

/// Scale accumulated mass by `1 / rows` (the mean over gate rows).
fn normalize_gate_mass(mass: &mut [f32], rows: usize) {
    let inv = 1.0 / rows as f32;
    for m in mass {
        *m *= inv;
    }
}

/// Batch-aggregated gate mass for a cross-session decode step: the mean
/// of `batch` row-major `[batch, n_experts]` gate rows, one value per
/// expert.  The result is itself a probability distribution (rows sum to
/// one), so strategies consume it exactly like a single token's gate
/// vector — experts carrying the most gate mass *across the whole batch*
/// rank as most important.  For `batch == 1` this is bitwise identical
/// to the input row (`0.0 + x == x`, `x / 1.0 == x`), which is what
/// makes a decode batch of one indistinguishable from the classic
/// single-session decode path.
pub fn batch_gate_mass(gate_probs: &[f32], batch: usize, n_experts: usize) -> Vec<f32> {
    assert_eq!(gate_probs.len(), batch * n_experts, "gate batch shape");
    assert!(batch > 0, "empty gate batch");
    let mut mass = vec![0f32; n_experts];
    accumulate_gate_rows(&mut mass, gate_probs);
    normalize_gate_mass(&mut mass, batch);
    mass
}

/// Gate mass aggregated **across phases** for a fused mixed step: the
/// mean of the prefill chunk's gate rows and the decode batch's gate
/// rows together (both row-major `[*, n_experts]`), one value per
/// expert.  This extends [`batch_gate_mass`] to the chunked-prefill
/// tick, where precision must be chosen once for the union of experts
/// routed by chunk tokens *and* decode tokens: experts carrying the
/// most gate mass across every token in the step rank as most
/// important.  With no prefill rows this is bitwise identical to
/// `batch_gate_mass(decode_rows, ..)` (same accumulation order, and
/// `0.0 + x == x`), which keeps a pure-decode tick indistinguishable
/// from the classic batched decode path.
pub fn mixed_gate_mass(
    prefill_rows: &[f32],
    decode_rows: &[f32],
    n_experts: usize,
) -> Vec<f32> {
    assert!(n_experts > 0, "mixed gate mass without experts");
    assert_eq!(prefill_rows.len() % n_experts, 0, "prefill gate shape");
    assert_eq!(decode_rows.len() % n_experts, 0, "decode gate shape");
    let total = (prefill_rows.len() + decode_rows.len()) / n_experts;
    assert!(total > 0, "empty mixed gate batch");
    let mut mass = vec![0f32; n_experts];
    accumulate_gate_rows(&mut mass, prefill_rows);
    accumulate_gate_rows(&mut mass, decode_rows);
    normalize_gate_mass(&mut mass, total);
    mass
}

/// Rank expert indices by importance, descending (stable by index).
pub fn rank_desc(importance: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..importance.len()).collect();
    idx.sort_by(|&a, &b| {
        importance[b]
            .partial_cmp(&importance[a])
            .unwrap()
            .then(a.cmp(&b))
    });
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heavy_hitters_are_top_scored() {
        let scores = [0.1f32, 0.5, 0.2, 0.9, 0.0];
        assert_eq!(heavy_hitters(&scores, 5, 2), vec![3, 1]);
        // seq_len masks the tail
        assert_eq!(heavy_hitters(&scores, 3, 2), vec![1, 2]);
    }

    #[test]
    fn prefill_importance_counts_heavy_loads() {
        // 4 tokens, scores make tokens 0 and 1 heavy (hh_frac 0.5)
        let scores = [0.9f32, 0.8, 0.1, 0.1];
        let routes: Vec<Route> = vec![
            vec![(0, 1.0)],          // heavy -> e0
            vec![(0, 0.6), (1, 0.4)], // heavy -> e0, e1
            vec![(2, 1.0)],          // light -> e2
            vec![(2, 1.0)],          // light -> e2
        ];
        let imp = prefill_importance(&scores, &routes, 4, 0.5);
        // e0 has 2 heavy tokens, e1 has 1, e2 none (only load tiebreak), e3 zero
        assert!(imp[0] > imp[1] && imp[1] > imp[2] && imp[2] > imp[3]);
        assert!(imp[0] >= 2.0 && imp[1] >= 1.0 && imp[2] < 1.0);
    }

    #[test]
    fn tiebreak_prefers_higher_total_load() {
        let scores = [0.9f32, 0.1, 0.1];
        let routes: Vec<Route> = vec![
            vec![(0, 0.5), (1, 0.5)], // heavy hits both e0, e1
            vec![(0, 1.0)],           // extra light load on e0
            vec![(2, 1.0)],
        ];
        let imp = prefill_importance(&scores, &routes, 3, 0.34);
        assert!(imp[0] > imp[1]);
    }

    #[test]
    fn decode_importance_is_gate() {
        let imp = decode_importance(&[0.1, 0.7, 0.2]);
        assert_eq!(rank_desc(&imp), vec![1, 2, 0]);
    }

    #[test]
    fn rank_desc_stable() {
        assert_eq!(rank_desc(&[0.5, 0.5, 0.9]), vec![2, 0, 1]);
    }

    #[test]
    fn batch_gate_mass_of_one_row_is_identity() {
        let row = [0.125f32, 0.5, 0.25, 0.125];
        let agg = batch_gate_mass(&row, 1, 4);
        // bitwise identity: a decode batch of one must plan exactly like
        // the single-session path
        assert_eq!(agg, row.to_vec());
    }

    #[test]
    fn mixed_gate_mass_without_prefill_is_batch_gate_mass() {
        #[rustfmt::skip]
        let rows = [
            0.7f32, 0.2, 0.1,
            0.1,    0.8, 0.1,
        ];
        // bitwise identity: a pure-decode mixed tick must plan exactly
        // like the classic batched decode path
        assert_eq!(mixed_gate_mass(&[], &rows, 3), batch_gate_mass(&rows, 2, 3));
    }

    #[test]
    fn mixed_gate_mass_spans_both_phases() {
        // one prefill chunk row + one decode row: the mean weighs both
        let prefill = [1.0f32, 0.0, 0.0];
        let decode = [0.0f32, 0.5, 0.5];
        let agg = mixed_gate_mass(&prefill, &decode, 3);
        assert!((agg[0] - 0.5).abs() < 1e-7);
        assert!((agg[1] - 0.25).abs() < 1e-7);
        assert!((agg[2] - 0.25).abs() < 1e-7);
        // still a distribution over experts
        assert!((agg.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        // two chunk rows vs one decode row: prefill mass dominates 2:1
        let prefill2 = [1.0f32, 0.0, 0.0, 1.0, 0.0, 0.0];
        let agg2 = mixed_gate_mass(&prefill2, &decode, 3);
        assert!((agg2[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn batch_gate_mass_averages_rows() {
        #[rustfmt::skip]
        let rows = [
            1.0f32, 0.0, 0.0,
            0.0,    0.5, 0.5,
        ];
        let agg = batch_gate_mass(&rows, 2, 3);
        assert!((agg[0] - 0.5).abs() < 1e-7);
        assert!((agg[1] - 0.25).abs() < 1e-7);
        assert!((agg[2] - 0.25).abs() < 1e-7);
        // still a distribution
        assert!((agg.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }
}

//! Depth-aware precision scheduling (paper §4.3, Eq. 4–5) and the expert
//! selection / allocation strategies compared in Fig. 3.

use crate::quant::Precision;
use crate::util::rng::Rng;

use super::importance::rank_desc;

/// Eq. 4: cosine retention schedule.  Stays near 1 in shallow layers and
/// decays smoothly to `lambda` in the deepest layer.
pub fn retention(layer: usize, n_layers: usize, lambda: f64) -> f64 {
    if n_layers <= 1 {
        return 1.0;
    }
    let x = layer as f64 / (n_layers - 1) as f64;
    (1.0 - lambda) * ((std::f64::consts::PI * x).cos() + 1.0) / 2.0 + lambda
}

/// Eq. 5: number of critical experts at a layer.
pub fn critical_count(layer: usize, n_layers: usize, lambda: f64, n_experts: usize) -> usize {
    (retention(layer, n_layers, lambda) * n_experts as f64).ceil() as usize
}

/// How the per-layer retention budget is allocated (Fig. 3 comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Allocation {
    /// Eq. 4 cosine schedule ("Depth-based").
    DepthCosine,
    /// Uniform ratio across layers ("Equal").
    Equal,
}

/// How critical experts are selected within a layer (Fig. 3 comparison).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// By importance score ("Token-based" in prefill).
    Importance,
    /// Uniformly at random (the "Random" baseline).
    Random,
}

/// The per-layer critical-expert budget under an allocation scheme with a
/// target *average* retention `r`.
pub fn layer_budget(
    alloc: Allocation,
    layer: usize,
    n_layers: usize,
    r: f64,
    n_experts: usize,
) -> usize {
    let t = match alloc {
        Allocation::Equal => (r * n_experts as f64).ceil() as usize,
        Allocation::DepthCosine => {
            let lambda = (2.0 * r - 1.0).clamp(0.0, 1.0);
            critical_count(layer, n_layers, lambda, n_experts)
        }
    };
    t.clamp(1, n_experts)
}

/// Assign a precision to every expert of a layer: the top `budget` by
/// importance (or a random subset) become Critical at `high`, the rest
/// Sub-critical at `low` (Int2 for "4/2", Skip for "4/0").
pub fn assign_precisions(
    importance: &[f64],
    budget: usize,
    selection: Selection,
    high: Precision,
    low: Precision,
    rng: &mut Rng,
) -> Vec<Precision> {
    let m = importance.len();
    let chosen: Vec<usize> = match selection {
        Selection::Importance => rank_desc(importance).into_iter().take(budget).collect(),
        Selection::Random => rng.choose_k(m, budget),
    };
    let mut out = vec![low; m];
    for e in chosen {
        out[e] = high;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn retention_endpoints() {
        // slow start: layer 0 keeps everything
        assert!((retention(0, 8, 0.5) - 1.0).abs() < 1e-12);
        // deepest layer hits the floor lambda
        assert!((retention(7, 8, 0.5) - 0.5).abs() < 1e-12);
        assert_eq!(retention(0, 1, 0.3), 1.0);
    }

    #[test]
    fn retention_monotone_decreasing() {
        prop::check("retention-monotone", 20, |rng| {
            let n = rng.range(2, 40);
            let lambda = rng.f64();
            let mut prev = f64::INFINITY;
            for l in 0..n {
                let r = retention(l, n, lambda);
                assert!(r <= prev + 1e-12, "not monotone at {l}");
                assert!((lambda - 1e-12..=1.0 + 1e-12).contains(&r));
                prev = r;
            }
        });
    }

    #[test]
    fn mean_retention_matches_target() {
        // integrating the cosine over layers gives (1 + lambda) / 2
        let n = 64;
        let lambda = 0.5;
        let mean: f64 =
            (0..n).map(|l| retention(l, n, lambda)).sum::<f64>() / n as f64;
        assert!((mean - 0.75).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn critical_count_bounds() {
        prop::check("critical-count", 20, |rng| {
            let n = rng.range(2, 32);
            let m = rng.range(1, 128);
            let lambda = rng.f64();
            for l in 0..n {
                let t = critical_count(l, n, lambda, m);
                assert!(t >= 1 && t <= m, "t={t} m={m}");
            }
        });
        // layer 0 always retains all experts
        assert_eq!(critical_count(0, 8, 0.25, 8), 8);
    }

    #[test]
    fn equal_allocation_uniform() {
        for l in 0..8 {
            assert_eq!(layer_budget(Allocation::Equal, l, 8, 0.75, 8), 6);
        }
        // depth-based spends more at the top than the bottom
        let top = layer_budget(Allocation::DepthCosine, 0, 8, 0.75, 8);
        let bot = layer_budget(Allocation::DepthCosine, 7, 8, 0.75, 8);
        assert!(top > bot);
        assert_eq!(top, 8);
    }

    #[test]
    fn assignment_counts_and_selection() {
        let imp = vec![0.1, 0.9, 0.5, 0.2];
        let mut rng = Rng::new(0);
        let p = assign_precisions(
            &imp, 2, Selection::Importance, Precision::Int4, Precision::Int2, &mut rng,
        );
        assert_eq!(p[1], Precision::Int4);
        assert_eq!(p[2], Precision::Int4);
        assert_eq!(p[0], Precision::Int2);
        assert_eq!(
            p.iter().filter(|&&x| x == Precision::Int4).count(),
            2
        );
        // random selection still honors the budget
        let pr = assign_precisions(
            &imp, 3, Selection::Random, Precision::Int4, Precision::Skip, &mut rng,
        );
        assert_eq!(pr.iter().filter(|&&x| x == Precision::Int4).count(), 3);
    }
}

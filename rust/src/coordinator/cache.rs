//! Mixed-precision expert cache (paper §4.4.2): an LRU over expert slots
//! extended with three precision rules:
//!
//! 1. **No duplication** — one precision per expert, ever.
//! 2. **Precision promotion** — a high-precision request over a cached
//!    low-precision entry is a miss; the high copy replaces the low one.
//! 3. **Conservative reuse** — a low-precision request over a cached
//!    high-precision entry is served from the high copy (no I/O, no
//!    accuracy loss).
//!
//! Entries carry a `ready_at` virtual time (transfer completion) so the
//! engine can overlap prefetched loads with compute; an entry may be hit
//! before its bytes "arrive", in which case the dependent compute simply
//! waits until `ready_at` on the timeline.

use std::collections::HashMap;

use crate::memory::VramBudget;
use crate::model::assets::ExpertKey;
use crate::quant::Precision;

/// Who is holding a pin on a cache entry.  With chunked prefill the
/// engine fuses prefill chunks and decode tokens into one tick, so the
/// two pin lifetimes genuinely interleave: warm-residency pins span
/// whole phases while layer pins last exactly one fused layer.  Keeping
/// the classes separate means releasing one can never drop the other —
/// the bug a single boolean pin had under mixed ticks (a layer unpin at
/// the end of `execute_experts` would silently clear a warm-residency
/// pin taken by the prefill phase, and `unpin_all` nuked both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PinClass {
    /// Phase-scoped warm-residency pin (scan-resistant prefix held
    /// across the prefill layer sweep).
    Warm,
    /// Layer-scoped working-set pin (the experts executing right now).
    Layer,
}

impl PinClass {
    fn bit(self) -> u8 {
        match self {
            PinClass::Warm => 1,
            PinClass::Layer => 2,
        }
    }
}

#[derive(Debug, Clone)]
struct Entry {
    prec: Precision,
    bytes: u64,
    ready_at: f64,
    last_use: u64,
    /// Bitmask of [`PinClass`] holders; a non-zero mask blocks eviction
    /// (layer pins keep the executing working set resident, warm pins
    /// keep the scan-resistant prefix through prefill phases).
    pins: u8,
    /// Segment level for the scan-resistant (SLRU) mode: 0 = probation
    /// (fresh inserts), 1 = protected (re-referenced).  Victims are chosen
    /// by (segment asc, last_use asc), so a one-shot layer scan (prefill)
    /// churns probation while the re-referenced working set survives.
    /// Always 0 in plain-LRU mode.
    segment: u32,
}

/// Result of a cache lookup.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Lookup {
    /// Served from cache at `prec` (>= requested), usable at `ready_at`.
    Hit { prec: Precision, ready_at: f64 },
    /// Not cached (or cached below the requested precision).
    Miss {
        /// Promotion miss: a lower-precision copy exists and must be
        /// replaced (rule 2).
        promotes: bool,
    },
}

/// Cache statistics (reported by every experiment).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub promotions: u64,
    pub conservative_reuses: u64,
    /// Entries dropped to make room (genuine capacity evictions: the
    /// expert left the cache).  A rule-2 promotion swap is *not* an
    /// eviction — the expert stays cached at higher precision — and is
    /// counted under [`CacheStats::replacements`] instead.
    pub evictions: u64,
    /// Rule-1/2 in-place replacements (a cached copy's bytes swapped
    /// for a higher-precision copy of the *same* expert).
    pub replacements: u64,
    pub inserted_bytes: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The mixed-precision LRU expert cache.
pub struct MixedPrecisionCache {
    budget: VramBudget,
    map: HashMap<ExpertKey, Entry>,
    tick: u64,
    /// Scan-resistant (segmented-LRU) mode: hits promote entries into a
    /// protected segment capped at [`PROTECTED_FRACTION`] of capacity.
    scan_resistant: bool,
    protected_bytes: u64,
    pub stats: CacheStats,
}

/// Fraction of capacity the protected SLRU segment may occupy.
pub const PROTECTED_FRACTION: f64 = 0.8;

impl MixedPrecisionCache {
    pub fn new(capacity_bytes: u64) -> Self {
        MixedPrecisionCache {
            budget: VramBudget::new(capacity_bytes),
            map: HashMap::new(),
            tick: 0,
            scan_resistant: false,
            protected_bytes: 0,
            stats: CacheStats::default(),
        }
    }

    /// Enable/disable segmented-LRU scan resistance (DyMoE's cache mode;
    /// the baselines use the plain LRU of their published systems).
    pub fn set_scan_resistant(&mut self, on: bool) {
        self.scan_resistant = on;
    }

    pub fn capacity(&self) -> u64 {
        self.budget.capacity()
    }

    pub fn used_bytes(&self) -> u64 {
        self.budget.used()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: ExpertKey) -> Option<Precision> {
        self.map.get(&key).map(|e| e.prec)
    }

    fn bump(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Probe without counting stats or touching LRU order (prefetcher use).
    pub fn peek(&self, key: ExpertKey, wanted: Precision) -> bool {
        self.map
            .get(&key)
            .map(|e| e.prec.satisfies(wanted))
            .unwrap_or(false)
    }

    /// Look up `key` for a request at `wanted` precision, applying the
    /// three rules.  Hits refresh LRU order (and in scan-resistant mode
    /// promote the entry into the protected segment).
    pub fn lookup(&mut self, key: ExpertKey, wanted: Precision) -> Lookup {
        let tick = self.bump();
        match self.map.get_mut(&key) {
            Some(e) if e.prec.satisfies(wanted) => {
                e.last_use = tick;
                self.stats.hits += 1;
                if e.prec > wanted {
                    self.stats.conservative_reuses += 1; // rule 3
                }
                let result = Lookup::Hit { prec: e.prec, ready_at: e.ready_at };
                if self.scan_resistant {
                    self.promote(key);
                }
                result
            }
            Some(_) => {
                self.stats.misses += 1;
                self.stats.promotions += 1; // rule 2
                Lookup::Miss { promotes: true }
            }
            None => {
                self.stats.misses += 1;
                Lookup::Miss { promotes: false }
            }
        }
    }

    /// Promote a hit entry into the protected segment, demoting the
    /// protected LRU while the segment exceeds its budget.
    fn promote(&mut self, key: ExpertKey) {
        let cap = (self.budget.capacity() as f64 * PROTECTED_FRACTION) as u64;
        let Some(e) = self.map.get_mut(&key) else { return };
        if e.segment == 1 || e.bytes > cap {
            return;
        }
        e.segment = 1;
        self.protected_bytes += e.bytes;
        while self.protected_bytes > cap {
            let victim = self
                .map
                .iter()
                .filter(|(k, e)| e.segment == 1 && **k != key)
                .min_by_key(|(k, e)| (e.last_use, k.layer, k.expert))
                .map(|(k, _)| *k);
            match victim {
                Some(v) => {
                    let e = self.map.get_mut(&v).unwrap();
                    e.segment = 0;
                    self.protected_bytes -= e.bytes;
                }
                None => break,
            }
        }
    }

    /// Tighten an entry's availability time (late-prefetch upgraded to a
    /// demand fetch that completes earlier).
    pub fn update_ready(&mut self, key: ExpertKey, ready_at: f64) {
        if let Some(e) = self.map.get_mut(&key) {
            e.ready_at = e.ready_at.min(ready_at);
        }
    }

    /// Pin / unpin an expert for one [`PinClass`].  Classes are
    /// independent: releasing a layer pin never drops a warm pin on the
    /// same entry (and vice versa), which is what keeps pin lifetimes
    /// correct when prefill chunks and decode tokens share one tick.
    pub fn set_pinned(&mut self, key: ExpertKey, class: PinClass, pinned: bool) {
        if let Some(e) = self.map.get_mut(&key) {
            if pinned {
                e.pins |= class.bit();
            } else {
                e.pins &= !class.bit();
            }
        }
    }

    /// Pinned by *any* class (eviction-blocking view).
    pub fn is_pinned(&self, key: ExpertKey) -> bool {
        self.map.get(&key).map(|e| e.pins != 0).unwrap_or(false)
    }

    /// Pinned by this specific class.
    pub fn is_pinned_class(&self, key: ExpertKey, class: PinClass) -> bool {
        self.map
            .get(&key)
            .map(|e| e.pins & class.bit() != 0)
            .unwrap_or(false)
    }

    /// Release every pin of one class, leaving the other class's pins
    /// (and hence their eviction protection) untouched.
    pub fn unpin_all(&mut self, class: PinClass) {
        for e in self.map.values_mut() {
            e.pins &= !class.bit();
        }
    }

    /// Insert (or replace — rule 1/2) `key` at `prec`.  Evicts LRU entries
    /// until the new entry fits.  Returns the evicted keys; returns `None`
    /// if the entry cannot fit at all (it is then used transiently without
    /// caching, like a streaming buffer).
    pub fn insert(
        &mut self,
        key: ExpertKey,
        prec: Precision,
        bytes: u64,
        ready_at: f64,
    ) -> Option<Vec<ExpertKey>> {
        let tick = self.bump();
        // Rule 1: no duplication — at most one copy per expert; an
        // existing copy that already satisfies the new precision stays.
        if let Some(e) = self.map.get(&key) {
            if e.prec.satisfies(prec) {
                return Some(vec![]);
            }
        }
        // Feasibility first: `None` must leave the cache unchanged (the
        // caller streams transiently).  Reclaimable = the replaced copy +
        // every unpinned entry.  A rule-2 promotion replacement swaps
        // the *bytes* of an entry, not its identity: the pin mask an
        // in-flight phase holds on the expert and its SLRU protected
        // status carry over to the replacement (dropping them would let
        // a fused layer evict an expert the other phase still pins).
        let (replaced, carried_pins, was_protected) = match self.map.get(&key) {
            Some(e) => (e.bytes, e.pins, e.segment == 1),
            None => (0, 0, false),
        };
        let reclaimable: u64 = self
            .map
            .iter()
            .filter(|(k, e)| e.pins == 0 && **k != key)
            .map(|(_, e)| e.bytes)
            .sum();
        if bytes > self.budget.free() + replaced + reclaimable {
            return None;
        }
        if replaced > 0 {
            // Rule 1 / promotion replacement: the expert stays cached
            // (at higher precision), so this is a replacement, not an
            // eviction — counting it as one inflated eviction totals in
            // every report.
            self.remove_entry(key);
            self.stats.replacements += 1;
        }
        let mut evicted = Vec::new();
        while !self.budget.fits(bytes) {
            let victim = self.lru_victim().expect("feasible by construction");
            self.remove_entry(victim);
            self.stats.evictions += 1;
            evicted.push(victim);
        }
        self.budget.alloc(bytes).expect("fits by construction");
        self.stats.inserted_bytes += bytes;
        // Fresh inserts land in the probation segment (0) with no pins;
        // a promotion replacement inherits the replaced entry's pins.
        self.map.insert(
            key,
            Entry { prec, bytes, ready_at, last_use: tick, pins: carried_pins, segment: 0 },
        );
        // Re-promote a replaced protected entry (accounts the *new*
        // byte size against the protected budget, demoting others if
        // the segment overflows — exactly the hit-path promotion).
        if was_protected {
            self.promote(key);
        }
        Some(evicted)
    }

    fn remove_entry(&mut self, key: ExpertKey) {
        if let Some(e) = self.map.remove(&key) {
            self.budget.release(e.bytes);
            if e.segment == 1 {
                self.protected_bytes -= e.bytes;
            }
        }
    }

    fn lru_victim(&self) -> Option<ExpertKey> {
        self.map
            .iter()
            .filter(|(_, e)| e.pins == 0)
            .min_by_key(|(k, e)| (e.segment, e.last_use, k.layer, k.expert))
            .map(|(k, _)| *k)
    }

    /// All cached keys (diagnostics).
    pub fn keys(&self) -> Vec<ExpertKey> {
        self.map.keys().copied().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    #[test]
    fn basic_hit_miss() {
        let mut c = MixedPrecisionCache::new(100);
        assert_eq!(c.lookup(k(0, 0), Precision::Int4), Lookup::Miss { promotes: false });
        c.insert(k(0, 0), Precision::Int4, 40, 1.0).unwrap();
        match c.lookup(k(0, 0), Precision::Int4) {
            Lookup::Hit { prec, ready_at } => {
                assert_eq!(prec, Precision::Int4);
                assert_eq!(ready_at, 1.0);
            }
            _ => panic!("expected hit"),
        }
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn rule_conservative_reuse() {
        let mut c = MixedPrecisionCache::new(100);
        c.insert(k(0, 0), Precision::Int8, 40, 0.0).unwrap();
        match c.lookup(k(0, 0), Precision::Int2) {
            Lookup::Hit { prec, .. } => assert_eq!(prec, Precision::Int8),
            _ => panic!("high-prec entry must serve low-prec request"),
        }
        assert_eq!(c.stats.conservative_reuses, 1);
    }

    #[test]
    fn rule_promotion_is_miss_and_replaces() {
        let mut c = MixedPrecisionCache::new(100);
        c.insert(k(0, 0), Precision::Int2, 10, 0.0).unwrap();
        assert_eq!(
            c.lookup(k(0, 0), Precision::Int4),
            Lookup::Miss { promotes: true }
        );
        c.insert(k(0, 0), Precision::Int4, 40, 2.0).unwrap();
        assert_eq!(c.contains(k(0, 0)), Some(Precision::Int4));
        assert_eq!(c.len(), 1); // rule 1: no duplication
        assert_eq!(c.used_bytes(), 40);
    }

    #[test]
    fn insert_keeps_higher_existing() {
        let mut c = MixedPrecisionCache::new(100);
        c.insert(k(0, 0), Precision::Int8, 50, 0.0).unwrap();
        // inserting a lower precision must NOT downgrade the entry
        c.insert(k(0, 0), Precision::Int2, 10, 1.0).unwrap();
        assert_eq!(c.contains(k(0, 0)), Some(Precision::Int8));
        assert_eq!(c.used_bytes(), 50);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = MixedPrecisionCache::new(100);
        c.insert(k(0, 0), Precision::Int4, 40, 0.0).unwrap();
        c.insert(k(0, 1), Precision::Int4, 40, 0.0).unwrap();
        let _ = c.lookup(k(0, 0), Precision::Int4); // refresh 0
        let ev = c.insert(k(0, 2), Precision::Int4, 40, 0.0).unwrap();
        assert_eq!(ev, vec![k(0, 1)]); // least recently used
        assert!(c.contains(k(0, 0)).is_some());
    }

    #[test]
    fn pinned_entries_survive() {
        let mut c = MixedPrecisionCache::new(80);
        c.insert(k(0, 0), Precision::Int4, 40, 0.0).unwrap();
        c.insert(k(0, 1), Precision::Int4, 40, 0.0).unwrap();
        c.set_pinned(k(0, 0), PinClass::Layer, true);
        c.set_pinned(k(0, 1), PinClass::Layer, true);
        // nothing evictable -> transient use
        assert!(c.insert(k(0, 2), Precision::Int4, 40, 0.0).is_none());
        c.unpin_all(PinClass::Layer);
        assert!(c.insert(k(0, 2), Precision::Int4, 40, 0.0).is_some());
    }

    /// Pin lifetime across mixed (fused prefill-chunk + decode) ticks:
    /// the layer-scoped pin taken while an expert executes must not
    /// drop the warm-residency pin the prefill phase holds on the same
    /// entry, and releasing one class must leave the other's eviction
    /// protection intact.
    #[test]
    fn pin_classes_are_independent_across_mixed_ticks() {
        let mut c = MixedPrecisionCache::new(80);
        c.insert(k(0, 0), Precision::Int4, 40, 0.0).unwrap();
        c.insert(k(0, 1), Precision::Int4, 40, 0.0).unwrap();
        // prefill phase pins the warm resident ...
        c.set_pinned(k(0, 0), PinClass::Warm, true);
        // ... and a fused layer transiently pins the same expert while
        // decode tokens route to it.
        c.set_pinned(k(0, 0), PinClass::Layer, true);
        assert!(c.is_pinned_class(k(0, 0), PinClass::Warm));
        assert!(c.is_pinned_class(k(0, 0), PinClass::Layer));
        // layer release at the end of the fused layer: the warm pin from
        // the other phase survives and the entry still cannot be evicted.
        c.set_pinned(k(0, 0), PinClass::Layer, false);
        assert!(c.is_pinned_class(k(0, 0), PinClass::Warm));
        assert!(c.is_pinned(k(0, 0)));
        let ev = c.insert(k(1, 0), Precision::Int4, 40, 0.0).unwrap();
        assert_eq!(ev, vec![k(0, 1)], "warm pin must deflect eviction");
        // unpin_all of the layer class must not leak into warm pins ...
        c.unpin_all(PinClass::Layer);
        assert!(c.is_pinned_class(k(0, 0), PinClass::Warm));
        // ... and releasing the warm phase finally frees the entry.
        c.unpin_all(PinClass::Warm);
        assert!(!c.is_pinned(k(0, 0)));
        let ev = c.insert(k(1, 1), Precision::Int4, 40, 0.0).unwrap();
        assert!(!ev.is_empty());
    }

    /// Rule-2 promotion replacement must carry the replaced entry's pin
    /// mask and SLRU protected status: an in-flight phase (warm pin) or
    /// fused layer (layer pin) holds pins on the *expert*, and swapping
    /// its bytes for a higher-precision copy must not silently release
    /// them — the replacement regression twin of
    /// `pin_classes_are_independent_across_mixed_ticks`.
    #[test]
    fn promotion_replacement_carries_pins_and_protection() {
        let mut c = MixedPrecisionCache::new(100);
        c.set_scan_resistant(true);
        c.insert(k(0, 0), Precision::Int2, 10, 0.0).unwrap();
        // re-reference -> protected segment
        let _ = c.lookup(k(0, 0), Precision::Int2);
        assert_eq!(c.protected_bytes, 10);
        // both pin classes held across the replacement, as in a mixed
        // tick (warm pin from prefill, layer pin from the fused layer)
        c.set_pinned(k(0, 0), PinClass::Warm, true);
        c.set_pinned(k(0, 0), PinClass::Layer, true);
        // rule-2 promotion: higher-precision request misses and replaces
        assert_eq!(c.lookup(k(0, 0), Precision::Int4), Lookup::Miss { promotes: true });
        c.insert(k(0, 0), Precision::Int4, 40, 2.0).unwrap();
        assert_eq!(c.contains(k(0, 0)), Some(Precision::Int4));
        assert!(
            c.is_pinned_class(k(0, 0), PinClass::Warm),
            "promotion replacement dropped the warm pin"
        );
        assert!(
            c.is_pinned_class(k(0, 0), PinClass::Layer),
            "promotion replacement dropped the layer pin"
        );
        // protected status carried, re-accounted at the new byte size
        assert_eq!(c.protected_bytes, 40);
        // releasing one class leaves the other's protection intact ...
        c.set_pinned(k(0, 0), PinClass::Layer, false);
        assert!(c.is_pinned(k(0, 0)));
        // ... and once fully unpinned, the entry still rides the
        // protected segment: a one-shot probation scan (90 bytes into
        // the 60 left, forcing evictions) churns probation only
        c.set_pinned(k(0, 0), PinClass::Warm, false);
        for e in 1..10 {
            c.insert(k(1, e), Precision::Int4, 10, 0.0).unwrap();
        }
        assert!(
            c.contains(k(0, 0)).is_some(),
            "promotion replacement dropped SLRU protected status"
        );
    }

    #[test]
    fn oversized_entry_is_transient() {
        let mut c = MixedPrecisionCache::new(30);
        assert!(c.insert(k(0, 0), Precision::Bf16, 50, 0.0).is_none());
        assert_eq!(c.len(), 0);
    }

    /// A rule-2 promotion swap keeps the expert cached, so it must count
    /// as a replacement — never as an eviction (the old accounting
    /// inflated eviction totals in every report).
    #[test]
    fn promotion_replacement_counts_as_replacement_not_eviction() {
        let mut c = MixedPrecisionCache::new(100);
        c.insert(k(0, 0), Precision::Int2, 10, 0.0).unwrap();
        assert_eq!(c.lookup(k(0, 0), Precision::Int4), Lookup::Miss { promotes: true });
        c.insert(k(0, 0), Precision::Int4, 40, 1.0).unwrap();
        assert_eq!(c.stats.evictions, 0, "promotion swap miscounted as eviction");
        assert_eq!(c.stats.replacements, 1);
        assert_eq!(c.stats.promotions, 1);
        assert_eq!(c.contains(k(0, 0)), Some(Precision::Int4));
        // A genuine capacity eviction still counts exactly once, and
        // does not bleed into the replacement counter.
        c.insert(k(0, 1), Precision::Int4, 70, 0.0).unwrap();
        assert_eq!(c.stats.evictions, 1);
        assert_eq!(c.stats.replacements, 1);
    }

    /// SLRU ledger conservation: `protected_bytes` equals the sum of
    /// segment-1 entry bytes after arbitrary interleavings of lookups,
    /// inserts, and promotion replacements (the replacement path
    /// re-accounts protected bytes at the *new* size — the ledger is
    /// easy to drift silently).
    #[test]
    fn prop_protected_bytes_matches_segment_sum() {
        use crate::util::prop;
        prop::check("slru protected-bytes conservation", 60, |rng| {
            let cap = rng.range(50, 400) as u64;
            let mut c = MixedPrecisionCache::new(cap);
            c.set_scan_resistant(true);
            let precs = [Precision::Int2, Precision::Int4, Precision::Int8];
            for _ in 0..rng.range(20, 120) {
                let key = k(rng.range(0, 3), rng.range(0, 5));
                let prec = precs[rng.range(0, 2)];
                if rng.range(0, 2) == 0 {
                    let _ = c.lookup(key, prec);
                } else {
                    let bytes = rng.range(5, 60) as u64;
                    let _ = c.insert(key, prec, bytes, 0.0);
                }
                let truth: u64 = c
                    .map
                    .values()
                    .filter(|e| e.segment == 1)
                    .map(|e| e.bytes)
                    .sum();
                assert_eq!(c.protected_bytes, truth, "protected ledger drifted");
                assert!(c.used_bytes() <= c.capacity(), "budget exceeded");
            }
        });
    }
}

#[cfg(test)]
mod slru_tests {
    use super::*;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    #[test]
    fn scan_does_not_evict_protected_working_set() {
        let mut c = MixedPrecisionCache::new(100);
        c.set_scan_resistant(true);
        // hot set: 2 entries, re-referenced -> protected
        c.insert(k(0, 0), Precision::Int4, 40, 0.0).unwrap();
        c.insert(k(0, 1), Precision::Int4, 40, 0.0).unwrap();
        let _ = c.lookup(k(0, 0), Precision::Int4);
        let _ = c.lookup(k(0, 1), Precision::Int4);
        // one-shot scan of 10 other experts churns probation only
        for e in 2..12 {
            c.insert(k(1, e), Precision::Int4, 20, 0.0).unwrap();
        }
        assert!(c.contains(k(0, 0)).is_some(), "protected entry scanned out");
        assert!(c.contains(k(0, 1)).is_some(), "protected entry scanned out");
    }

    #[test]
    fn plain_lru_is_scanned_out() {
        let mut c = MixedPrecisionCache::new(100);
        c.insert(k(0, 0), Precision::Int4, 40, 0.0).unwrap();
        let _ = c.lookup(k(0, 0), Precision::Int4);
        for e in 2..12 {
            c.insert(k(1, e), Precision::Int4, 20, 0.0).unwrap();
        }
        assert!(c.contains(k(0, 0)).is_none(), "plain LRU must scan out");
    }

    #[test]
    fn protected_segment_bounded() {
        let mut c = MixedPrecisionCache::new(100);
        c.set_scan_resistant(true);
        // promote more than PROTECTED_FRACTION worth: oldest demote back
        for e in 0..5 {
            c.insert(k(0, e), Precision::Int4, 20, 0.0).unwrap();
            let _ = c.lookup(k(0, e), Precision::Int4);
        }
        assert!(c.protected_bytes <= 80);
        // a fresh scan can still evict the demoted entries
        let ev = c.insert(k(1, 0), Precision::Int4, 20, 0.0).unwrap();
        assert!(!ev.is_empty());
    }

    #[test]
    fn failed_insert_leaves_cache_unchanged() {
        let mut c = MixedPrecisionCache::new(60);
        c.insert(k(0, 0), Precision::Int2, 20, 0.0).unwrap();
        c.set_pinned(k(0, 0), PinClass::Warm, true);
        c.insert(k(0, 1), Precision::Int2, 20, 0.0).unwrap();
        c.set_pinned(k(0, 1), PinClass::Layer, true);
        // promotion replace that cannot fit: everything pinned
        assert!(c.insert(k(0, 0), Precision::Bf16, 55, 0.0).is_none());
        // the old copy must still be there
        assert_eq!(c.contains(k(0, 0)), Some(Precision::Int2));
        assert_eq!(c.len(), 2);
    }
}

//! The DyMoE coordinator — the paper's system contribution (§4).
//!
//! * [`importance`]  — phase-adaptive expert importance (Eq. 1–3)
//! * [`scheduler`]   — depth-aware precision scheduling (Eq. 4–5)
//! * [`cache`]       — mixed-precision LRU cache management (§4.4.2)
//! * [`prefetcher`]  — look-ahead prefetching (Eq. 6–8)
//! * [`strategy`]    — the pluggable serving-policy trait + DyMoE itself
//! * [`engine`]      — the serving engine: co-simulated numerics + time

pub mod adaptive;
pub mod cache;
pub mod engine;
pub mod importance;
pub mod prefetcher;
pub mod scheduler;
pub mod strategy;

/// Inference phase; DyMoE's estimator and prefetcher are phase-adaptive.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// One token's routed experts: `(expert index, renormalized gate weight)`.
pub type Route = Vec<(usize, f32)>;

/// Stable top-k routing from a row of gate probabilities: descending by
/// probability, ties broken by ascending expert index (matches
/// `python/compile/model.topk_mask`), renormalized over the selected set.
pub fn top_k_route(probs: &[f32], k: usize) -> Route {
    let mut idx: Vec<usize> = (0..probs.len()).collect();
    idx.sort_by(|&a, &b| {
        probs[b].partial_cmp(&probs[a]).unwrap().then(a.cmp(&b))
    });
    idx.truncate(k);
    let total: f32 = idx.iter().map(|&e| probs[e]).sum();
    let denom = total.max(1e-9);
    idx.into_iter().map(|e| (e, probs[e] / denom)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_route_selects_and_renormalizes() {
        let r = top_k_route(&[0.5, 0.3, 0.1, 0.1], 2);
        assert_eq!(r[0].0, 0);
        assert_eq!(r[1].0, 1);
        assert!((r[0].1 - 0.5 / 0.8).abs() < 1e-6);
        assert!((r.iter().map(|(_, w)| w).sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn top_k_route_tie_breaks_by_index() {
        let r = top_k_route(&[0.25, 0.25, 0.25, 0.25], 2);
        assert_eq!(r[0].0, 0);
        assert_eq!(r[1].0, 1);
    }
}

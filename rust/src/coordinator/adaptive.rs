//! Load-adaptive retention control — the paper's §6.3 deployment story
//! ("users can dynamically adjust the retention ratio to trade a marginal
//! amount of accuracy for significant latency reduction during peak
//! loads, or increase r to prioritize quality") made operational.
//!
//! A small proportional controller maps an observed load signal (queue
//! depth, or measured TPOT vs an SLO) to a retention ratio in
//! `[r_min, r_max]`; the serving loop applies it between requests.

use crate::config::PolicyConfig;

/// Proportional controller for the retention ratio.
#[derive(Debug, Clone)]
pub struct RetentionController {
    /// Quality-first retention under no load.
    pub r_max: f64,
    /// Latency-first floor under peak load.
    pub r_min: f64,
    /// Queue depth at which retention reaches the floor.
    pub saturation_depth: usize,
    /// Optional TPOT service-level objective (seconds); when measured
    /// TPOT exceeds it, retention backs off proportionally.
    pub tpot_slo: Option<f64>,
    /// Exponential smoothing for the measured TPOT signal.
    ema_tpot: f64,
    alpha: f64,
}

impl RetentionController {
    pub fn new(r_min: f64, r_max: f64, saturation_depth: usize) -> Self {
        assert!(r_min <= r_max && r_min >= 0.0 && r_max <= 1.0);
        RetentionController {
            r_max,
            r_min,
            saturation_depth: saturation_depth.max(1),
            tpot_slo: None,
            ema_tpot: 0.0,
            alpha: 0.3,
        }
    }

    pub fn with_tpot_slo(mut self, slo: f64) -> Self {
        self.tpot_slo = Some(slo);
        self
    }

    /// Record a completed request's TPOT.
    pub fn observe_tpot(&mut self, tpot: f64) {
        self.ema_tpot = if self.ema_tpot == 0.0 {
            tpot
        } else {
            self.alpha * tpot + (1.0 - self.alpha) * self.ema_tpot
        };
    }

    /// Retention ratio for the next request given the current queue depth.
    pub fn retention(&self, queue_depth: usize) -> f64 {
        // queue pressure: linear from r_max at empty to r_min at saturation
        let q = (queue_depth as f64 / self.saturation_depth as f64).min(1.0);
        let mut r = self.r_max - q * (self.r_max - self.r_min);
        // SLO pressure: if smoothed TPOT exceeds the objective, back off
        // proportionally to the violation (up to the floor).
        if let (Some(slo), true) = (self.tpot_slo, self.ema_tpot > 0.0) {
            if self.ema_tpot > slo {
                let viol = ((self.ema_tpot / slo) - 1.0).min(1.0);
                r -= viol * (r - self.r_min);
            }
        }
        r.clamp(self.r_min, self.r_max)
    }

    /// Apply the controller to a policy for the next request.
    pub fn apply(&self, policy: &mut PolicyConfig, queue_depth: usize) {
        policy.retention = self.retention(queue_depth);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_uses_quality_retention() {
        let c = RetentionController::new(0.5, 0.9, 8);
        assert!((c.retention(0) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn saturation_hits_floor() {
        let c = RetentionController::new(0.5, 0.9, 8);
        assert!((c.retention(8) - 0.5).abs() < 1e-12);
        assert!((c.retention(100) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn retention_monotone_in_queue_depth() {
        let c = RetentionController::new(0.6, 1.0, 10);
        let mut prev = f64::INFINITY;
        for q in 0..15 {
            let r = c.retention(q);
            assert!(r <= prev + 1e-12);
            assert!((0.6..=1.0).contains(&r));
            prev = r;
        }
    }

    #[test]
    fn slo_violation_backs_off() {
        let mut c = RetentionController::new(0.5, 0.9, 8).with_tpot_slo(0.05);
        c.observe_tpot(0.10); // 2x over SLO
        assert!(c.retention(0) < 0.9);
        assert!(c.retention(0) >= 0.5);
        // healthy TPOT restores quality-first retention
        let mut h = RetentionController::new(0.5, 0.9, 8).with_tpot_slo(0.05);
        h.observe_tpot(0.01);
        assert!((h.retention(0) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn apply_updates_policy() {
        let c = RetentionController::new(0.5, 1.0, 4);
        let mut p = PolicyConfig::default();
        c.apply(&mut p, 2);
        assert!((p.retention - 0.75).abs() < 1e-12);
    }
}

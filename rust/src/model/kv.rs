//! Per-layer KV cache owned by the coordinator (the decode artifact reads
//! the full fixed-capacity cache and returns the new row; L3 writes it).

use anyhow::{ensure, Result};

/// KV cache for every layer of one sequence.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub capacity: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    /// Per layer: `[capacity, n_heads, head_dim]` row-major.
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize, capacity: usize, n_heads: usize, head_dim: usize) -> Self {
        let sz = capacity * n_heads * head_dim;
        KvCache {
            capacity,
            n_heads,
            head_dim,
            k: vec![vec![0.0; sz]; n_layers],
            v: vec![vec![0.0; sz]; n_layers],
            len: 0,
        }
    }

    pub fn row_elems(&self) -> usize {
        self.n_heads * self.head_dim
    }

    /// Bytes this cache holds (K and V planes, f32).  The multi-session
    /// serving layer sums this over in-flight sessions to report KV
    /// memory pressure under concurrency.
    pub fn bytes(&self) -> u64 {
        (2 * self.k.len() * self.capacity * self.row_elems() * 4) as u64
    }

    /// Write the K/V for position `pos` of `layer`.
    pub fn write_row(&mut self, layer: usize, pos: usize, k_new: &[f32], v_new: &[f32]) -> Result<()> {
        let re = self.row_elems();
        ensure!(pos < self.capacity, "kv overflow: pos {pos} >= {}", self.capacity);
        ensure!(k_new.len() == re && v_new.len() == re, "kv row size");
        self.k[layer][pos * re..(pos + 1) * re].copy_from_slice(k_new);
        self.v[layer][pos * re..(pos + 1) * re].copy_from_slice(v_new);
        Ok(())
    }

    /// Bulk-write rows `0..t` of `layer` from prefill outputs `[t, H, hd]`.
    pub fn write_prefix(&mut self, layer: usize, t: usize, k: &[f32], v: &[f32]) -> Result<()> {
        let re = self.row_elems();
        ensure!(t <= self.capacity, "kv overflow");
        ensure!(k.len() >= t * re && v.len() >= t * re, "kv prefix size");
        self.k[layer][..t * re].copy_from_slice(&k[..t * re]);
        self.v[layer][..t * re].copy_from_slice(&v[..t * re]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_accounting() {
        let kv = KvCache::new(2, 4, 2, 3);
        // 2 planes * 2 layers * 4 rows * 6 elems * 4 bytes
        assert_eq!(kv.bytes(), 2 * 2 * 4 * 6 * 4);
    }

    #[test]
    fn write_and_capacity() {
        let mut kv = KvCache::new(2, 4, 2, 3);
        let row = vec![1.0f32; 6];
        kv.write_row(1, 2, &row, &row).unwrap();
        assert_eq!(kv.k[1][12..18], row[..]);
        assert!(kv.write_row(0, 4, &row, &row).is_err());
        assert!(kv.write_row(0, 0, &row[..5], &row).is_err());
    }

    #[test]
    fn write_prefix_roundtrip() {
        let mut kv = KvCache::new(1, 8, 2, 2);
        let data: Vec<f32> = (0..3 * 4).map(|i| i as f32).collect();
        kv.write_prefix(0, 3, &data, &data).unwrap();
        assert_eq!(kv.k[0][..12], data[..]);
        assert_eq!(kv.v[0][4..8], data[4..8]);
    }
}

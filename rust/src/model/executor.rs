//! Typed model executor: drives the per-piece HLO artifacts (embed,
//! attention halves, gate probe, expert FFNs, finalize) with weights from
//! the asset store.  This is the only place that touches XLA literals /
//! device buffers; the coordinator above it deals in plain `Vec<f32>`.
//!
//! Weights are staged to device buffers once and cached (per layer for
//! the non-MoE weights, per (expert, precision) for expert weights); only
//! dynamic inputs (hidden states, KV caches, token ids) are staged per
//! call.  Besides saving the conversion cost, this avoids the
//! literal-argument `execute` path whose C++ conversion leaks memory in
//! xla_extension 0.5.1 (see `runtime::Runtime::to_buffer`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use super::assets::{ExpertKey, ModelAssets};
use super::kv::KvCache;
use crate::quant::Precision;
use crate::runtime::{lit_f32, lit_i32, lit_u32, Runtime};

type Buf = crate::runtime::Staged;

/// Cached per-layer non-MoE weight buffers, artifact argument order.
struct LayerWeights {
    ln1: Buf,
    wq: Buf,
    wk: Buf,
    wv: Buf,
    wo: Buf,
    ln2: Buf,
    wg: Buf,
}

/// Outputs of the prefill attention artifact for one layer.
pub struct PrefillOut {
    /// `[S, d]` residual stream after attention.
    pub h_resid: Vec<f32>,
    /// `[S, d]` normed MoE input.
    pub moe_in: Vec<f32>,
    /// `[S, M]` gate probabilities.
    pub gate_probs: Vec<f32>,
    /// `[S]` Eq.-1 token-importance scores.
    pub token_scores: Vec<f32>,
    /// `[S, H, hd]` keys / values for the KV cache.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Outputs of the decode attention artifact for one layer.
pub struct DecodeOut {
    /// `[d]` residual stream after attention.
    pub h_resid: Vec<f32>,
    /// `[d]` normed MoE input.
    pub moe_in: Vec<f32>,
    /// `[M]` gate probabilities.
    pub gate_probs: Vec<f32>,
    /// `[H, hd]` new KV rows for position `pos`.
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// The executor: artifacts + staged weight buffers + an expert cache.
pub struct Executor {
    pub runtime: Runtime,
    pub assets: Arc<ModelAssets>,
    layers: Vec<LayerWeights>,
    emb: Buf,
    ln_f: Buf,
    expert_bufs: RefCell<HashMap<(ExpertKey, Precision), Rc<Vec<Buf>>>>,
}

impl Executor {
    pub fn new(assets: Arc<ModelAssets>) -> Result<Executor> {
        let runtime = Runtime::new(&assets.dir)?;
        let m = &assets.manifest.model;
        let mut layers = Vec::with_capacity(m.n_layers);
        for l in 0..m.n_layers {
            let buf = |suffix: &str| -> Result<Buf> {
                let (data, shape) = assets.f32_section(&format!("L{l}.{suffix}"))?;
                runtime.stage(lit_f32(&data, &shape)?)
            };
            layers.push(LayerWeights {
                ln1: buf("ln1")?,
                wq: buf("wq")?,
                wk: buf("wk")?,
                wv: buf("wv")?,
                wo: buf("wo")?,
                ln2: buf("ln2")?,
                wg: buf("wg")?,
            });
        }
        let (emb_d, emb_s) = assets.f32_section("emb")?;
        let (lnf_d, lnf_s) = assets.f32_section("ln_f")?;
        let emb = runtime.stage(lit_f32(&emb_d, &emb_s)?)?;
        let ln_f = runtime.stage(lit_f32(&lnf_d, &lnf_s)?)?;
        Ok(Executor {
            runtime,
            assets: assets.clone(),
            layers,
            emb,
            ln_f,
            expert_bufs: RefCell::new(HashMap::new()),
        })
    }

    fn m(&self) -> &super::manifest::MiniModel {
        &self.assets.manifest.model
    }

    fn stage_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buf> {
        self.runtime.stage(lit_f32(data, dims)?)
    }

    fn stage_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buf> {
        self.runtime.stage(lit_i32(data, dims)?)
    }

    /// Embed a full (padded) prompt: `tokens.len() == max_seq`.
    pub fn embed_seq(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let s = self.m().max_seq;
        ensure!(tokens.len() == s, "embed_seq wants padded length {s}");
        let t = self.stage_i32(tokens, &[s])?;
        let out = self
            .runtime
            .exec_bufs_f32(&format!("embed_t{s}"), &[&t.buf, &self.emb.buf])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Embed a single decode token.
    pub fn embed_one(&self, token: i32) -> Result<Vec<f32>> {
        let t = self.stage_i32(&[token], &[1])?;
        let out = self.runtime.exec_bufs_f32("embed_t1", &[&t.buf, &self.emb.buf])?;
        Ok(out.into_iter().next().unwrap())
    }

    fn unpack_prefill(mut out: Vec<Vec<f32>>) -> Result<PrefillOut> {
        ensure!(out.len() == 6, "prefill arity");
        let v = out.pop().unwrap();
        let k = out.pop().unwrap();
        let token_scores = out.pop().unwrap();
        let gate_probs = out.pop().unwrap();
        let moe_in = out.pop().unwrap();
        let h_resid = out.pop().unwrap();
        Ok(PrefillOut { h_resid, moe_in, gate_probs, token_scores, k, v })
    }

    fn unpack_decode(mut out: Vec<Vec<f32>>) -> Result<DecodeOut> {
        ensure!(out.len() == 5, "decode arity");
        let v_new = out.pop().unwrap();
        let k_new = out.pop().unwrap();
        let gate_probs = out.pop().unwrap();
        let moe_in = out.pop().unwrap();
        let h_resid = out.pop().unwrap();
        Ok(DecodeOut { h_resid, moe_in, gate_probs, k_new, v_new })
    }

    /// Prefill attention half for `layer` over padded hidden `h [S, d]`.
    pub fn attn_prefill(&self, layer: usize, h: &[f32], seq_len: usize) -> Result<PrefillOut> {
        let m = self.m();
        let lw = &self.layers[layer];
        let hb = self.stage_f32(h, &[m.max_seq, m.d_model])?;
        let sl = self.stage_i32(&[seq_len as i32], &[1])?;
        let out = self
            .runtime
            .exec_bufs_f32(
                "attn_prefill",
                &[&hb.buf, &sl.buf, &lw.ln1.buf, &lw.wq.buf, &lw.wk.buf, &lw.wv.buf, &lw.wo.buf, &lw.ln2.buf, &lw.wg.buf],
            )
            .with_context(|| format!("attn_prefill layer {layer}"))?;
        Self::unpack_prefill(out)
    }

    /// Fused prefill attention + Eq.-6 probe for `next_layer` (one PJRT
    /// execution instead of two — see EXPERIMENTS.md §Perf).
    pub fn attn_prefill_probe(
        &self,
        layer: usize,
        next_layer: usize,
        h: &[f32],
        seq_len: usize,
    ) -> Result<(PrefillOut, Vec<f32>)> {
        let m = self.m();
        let lw = &self.layers[layer];
        let nw = &self.layers[next_layer];
        let hb = self.stage_f32(h, &[m.max_seq, m.d_model])?;
        let sl = self.stage_i32(&[seq_len as i32], &[1])?;
        let mut out = self
            .runtime
            .exec_bufs_f32(
                "attn_prefill_probe",
                &[
                    &hb.buf, &sl.buf, &lw.ln1.buf, &lw.wq.buf, &lw.wk.buf, &lw.wv.buf, &lw.wo.buf, &lw.ln2.buf,
                    &lw.wg.buf, &nw.ln2.buf, &nw.wg.buf,
                ],
            )
            .with_context(|| format!("attn_prefill_probe layer {layer}"))?;
        ensure!(out.len() == 7, "attn_prefill_probe arity");
        let probe = out.pop().unwrap();
        Ok((Self::unpack_prefill(out)?, probe))
    }

    /// Decode attention half for `layer` at position `pos`.
    pub fn attn_decode(
        &self,
        layer: usize,
        h: &[f32],
        kv: &KvCache,
        pos: usize,
    ) -> Result<DecodeOut> {
        let m = self.m();
        let lw = &self.layers[layer];
        let cache_dims = [m.max_cache, m.n_heads, m.head_dim];
        let hb = self.stage_f32(h, &[1, m.d_model])?;
        let kb = self.stage_f32(&kv.k[layer], &cache_dims)?;
        let vb = self.stage_f32(&kv.v[layer], &cache_dims)?;
        let pb = self.stage_i32(&[pos as i32], &[1])?;
        let out = self
            .runtime
            .exec_bufs_f32(
                "attn_decode",
                &[&hb.buf, &kb.buf, &vb.buf, &pb.buf, &lw.ln1.buf, &lw.wq.buf, &lw.wk.buf, &lw.wv.buf, &lw.wo.buf, &lw.ln2.buf, &lw.wg.buf],
            )
            .with_context(|| format!("attn_decode layer {layer}"))?;
        Self::unpack_decode(out)
    }

    /// Fused decode attention + Eq.-6 probe for `next_layer`.
    pub fn attn_decode_probe(
        &self,
        layer: usize,
        next_layer: usize,
        h: &[f32],
        kv: &KvCache,
        pos: usize,
    ) -> Result<(DecodeOut, Vec<f32>)> {
        let m = self.m();
        let lw = &self.layers[layer];
        let nw = &self.layers[next_layer];
        let cache_dims = [m.max_cache, m.n_heads, m.head_dim];
        let hb = self.stage_f32(h, &[1, m.d_model])?;
        let kb = self.stage_f32(&kv.k[layer], &cache_dims)?;
        let vb = self.stage_f32(&kv.v[layer], &cache_dims)?;
        let pb = self.stage_i32(&[pos as i32], &[1])?;
        let mut out = self
            .runtime
            .exec_bufs_f32(
                "attn_decode_probe",
                &[
                    &hb.buf, &kb.buf, &vb.buf, &pb.buf, &lw.ln1.buf, &lw.wq.buf, &lw.wk.buf, &lw.wv.buf, &lw.wo.buf,
                    &lw.ln2.buf, &lw.wg.buf, &nw.ln2.buf, &nw.wg.buf,
                ],
            )
            .with_context(|| format!("attn_decode_probe layer {layer}"))?;
        ensure!(out.len() == 6, "attn_decode_probe arity");
        let probe = out.pop().unwrap();
        Ok((Self::unpack_decode(out)?, probe))
    }

    /// Eq.-6 look-ahead probe: layer-`next`'s gate over the current hidden.
    /// `h` is `[d]` (decode) or `[S, d]` (prefill).
    pub fn gate_probe(&self, next_layer: usize, h: &[f32]) -> Result<Vec<f32>> {
        let m = self.m();
        let t = h.len() / m.d_model;
        ensure!(t == 1 || t == m.max_seq, "gate_probe shape");
        let lw = &self.layers[next_layer];
        let hb = self.stage_f32(h, &[t, m.d_model])?;
        let out = self
            .runtime
            .exec_bufs_f32(&format!("gate_probe_t{t}"), &[&hb.buf, &lw.ln2.buf, &lw.wg.buf])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Expert-weight buffers at a precision, staged once and cached.
    fn expert_buffers(&self, key: ExpertKey, p: Precision) -> Result<Rc<Vec<Buf>>> {
        if let Some(l) = self.expert_bufs.borrow().get(&(key, p)) {
            return Ok(l.clone());
        }
        let names = self.assets.expert_section_names(key, p);
        ensure!(!names.is_empty(), "no weights for Skip");
        let mut bufs = Vec::with_capacity(names.len());
        for name in &names {
            let lit = if name.ends_with(".q") {
                let (data, shape) = self.assets.u32_section(name)?;
                lit_u32(&data, &shape)?
            } else {
                let (data, shape) = self.assets.f32_section(name)?;
                lit_f32(&data, &shape)?
            };
            bufs.push(self.runtime.stage(lit)?);
        }
        let rc = Rc::new(bufs);
        self.expert_bufs.borrow_mut().insert((key, p), rc.clone());
        Ok(rc)
    }

    /// Run one expert over `rows` token vectors (each `[d]`) at `p`,
    /// padding up to the smallest exported bucket.  Returns one `[d]`
    /// output per input row.
    pub fn expert_ffn(
        &self,
        key: ExpertKey,
        p: Precision,
        rows: &[&[f32]],
    ) -> Result<Vec<Vec<f32>>> {
        let m = self.m();
        ensure!(p != Precision::Skip, "cannot execute a skipped expert");
        ensure!(!rows.is_empty(), "expert_ffn with no tokens");
        let bucket = self
            .assets
            .manifest
            .bucket_for(rows.len())
            .ok_or_else(|| anyhow!("no bucket >= {}", rows.len()))?;
        let d = m.d_model;
        let mut x = vec![0f32; bucket * d];
        for (i, r) in rows.iter().enumerate() {
            ensure!(r.len() == d, "expert input row dim");
            x[i * d..(i + 1) * d].copy_from_slice(r);
        }
        let xb = self.stage_f32(&x, &[bucket, d])?;
        let weights = self.expert_buffers(key, p)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.len());
        inputs.push(&xb.buf);
        for w in weights.iter() {
            inputs.push(&w.buf);
        }
        let name = format!("expert_{}_t{bucket}", p.tag());
        let out = self
            .runtime
            .exec_bufs_f32(&name, &inputs)
            .with_context(|| format!("expert {key} {p:?} bucket {bucket}"))?;
        let y = out.into_iter().next().unwrap();
        Ok(rows
            .iter()
            .enumerate()
            .map(|(i, _)| y[i * d..(i + 1) * d].to_vec())
            .collect())
    }

    /// Final norm + unembedding for one `[d]` hidden -> `[vocab]` logits.
    pub fn finalize_one(&self, h: &[f32]) -> Result<Vec<f32>> {
        let m = self.m();
        let hb = self.stage_f32(h, &[1, m.d_model])?;
        let out = self
            .runtime
            .exec_bufs_f32("finalize_t1", &[&hb.buf, &self.ln_f.buf, &self.emb.buf])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Finalize the whole padded sequence: `[S, d] -> [S, vocab]`.
    pub fn finalize_seq(&self, h: &[f32]) -> Result<Vec<f32>> {
        let m = self.m();
        let hb = self.stage_f32(h, &[m.max_seq, m.d_model])?;
        let out = self.runtime.exec_bufs_f32(
            &format!("finalize_t{}", m.max_seq),
            &[&hb.buf, &self.ln_f.buf, &self.emb.buf],
        )?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Drop cached expert buffers (frees the simulated "GPU" copies).
    pub fn clear_expert_literals(&self) {
        self.expert_bufs.borrow_mut().clear();
    }
}

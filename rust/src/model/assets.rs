//! Model assets: manifest + the flat weight store (`weights.bin`).
//!
//! The weight store is the simulated host-RAM / SSD tier: the engine
//! "transfers" sections out of it into the (virtual) VRAM cache, and the
//! executor builds XLA literals from them on demand.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use super::manifest::{Manifest, Section};
use crate::quant::Precision;
use crate::runtime::DType;

/// Identifies one expert of one layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ExpertKey {
    pub layer: u16,
    pub expert: u16,
}

impl ExpertKey {
    pub fn new(layer: usize, expert: usize) -> Self {
        ExpertKey { layer: layer as u16, expert: expert as u16 }
    }
}

impl std::fmt::Display for ExpertKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "L{}E{}", self.layer, self.expert)
    }
}

/// Loaded model directory: manifest + weight blob.
pub struct ModelAssets {
    pub dir: PathBuf,
    pub manifest: Manifest,
    blob: Arc<Vec<u8>>,
}

impl ModelAssets {
    pub fn load(artifacts_dir: &str, model: &str) -> Result<ModelAssets> {
        let dir = Path::new(artifacts_dir).join(model);
        let manifest = Manifest::load(&dir)?;
        let wpath = dir.join(&manifest.weights_file);
        let blob = std::fs::read(&wpath)
            .with_context(|| format!("reading weight store {wpath:?}"))?;
        Ok(ModelAssets { dir, manifest, blob: Arc::new(blob) })
    }

    fn section(&self, name: &str) -> Result<&Section> {
        self.manifest
            .sections
            .get(name)
            .ok_or_else(|| anyhow!("missing weight section {name:?}"))
    }

    fn raw(&self, s: &Section) -> &[u8] {
        &self.blob[s.offset..s.offset + s.nbytes]
    }

    /// Read a section as f32 (copies; sections are little-endian on disk).
    pub fn f32_section(&self, name: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        let s = self.section(name)?;
        ensure!(s.dtype == DType::F32, "section {name} is not f32");
        let raw = self.raw(s);
        let mut out = vec![0f32; raw.len() / 4];
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            out[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok((out, s.shape.clone()))
    }

    /// Read a section as u32 (packed quantized words).
    pub fn u32_section(&self, name: &str) -> Result<(Vec<u32>, Vec<usize>)> {
        let s = self.section(name)?;
        ensure!(s.dtype == DType::U32, "section {name} is not u32");
        let raw = self.raw(s);
        let mut out = vec![0u32; raw.len() / 4];
        for (i, chunk) in raw.chunks_exact(4).enumerate() {
            out[i] = u32::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok((out, s.shape.clone()))
    }

    /// Weight-section names for one expert at one precision, in the order
    /// the expert artifacts expect them.
    pub fn expert_section_names(&self, key: ExpertKey, p: Precision) -> Vec<String> {
        let base = format!("L{}.E{}", key.layer, key.expert);
        match p {
            Precision::Bf16 => vec![
                format!("{base}.w1.bf16"),
                format!("{base}.w3.bf16"),
                format!("{base}.w2.bf16"),
            ],
            Precision::Skip => vec![],
            q => {
                let t = q.tag();
                vec![
                    format!("{base}.w1.{t}.q"),
                    format!("{base}.w1.{t}.s"),
                    format!("{base}.w3.{t}.q"),
                    format!("{base}.w3.{t}.s"),
                    format!("{base}.w2.{t}.q"),
                    format!("{base}.w2.{t}.s"),
                ]
            }
        }
    }

    /// All expert keys of the model, layer-major.
    pub fn expert_keys(&self) -> Vec<ExpertKey> {
        let m = &self.manifest.model;
        (0..m.n_layers)
            .flat_map(|l| (0..m.n_experts).map(move |e| ExpertKey::new(l, e)))
            .collect()
    }
}

//! Token sampling over the finalize artifact's logits.

use crate::util::rng::Rng;

/// Greedy argmax (ties -> lowest token id, matching jnp.argmax).
pub fn greedy(logits: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

/// Temperature sampling (temperature 0 degenerates to greedy).
pub fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> usize {
    if temperature <= 1e-6 {
        return greedy(logits);
    }
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f64> = logits
        .iter()
        .map(|&l| (((l - max) / temperature) as f64).exp())
        .collect();
    let total: f64 = exps.iter().sum();
    let mut u = rng.f64() * total;
    for (i, e) in exps.iter().enumerate() {
        u -= e;
        if u <= 0.0 {
            return i;
        }
    }
    logits.len() - 1
}

/// Log-softmax NLL of `target` under `logits` (eval metric).
pub fn nll(logits: &[f32], target: usize) -> f64 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = (logits.iter().map(|&l| ((l as f64) - max).exp()).sum::<f64>()).ln() + max;
    lse - logits[target] as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max_first_tie() {
        assert_eq!(greedy(&[0.1, 3.0, 3.0, -1.0]), 1);
        assert_eq!(greedy(&[-5.0, -2.0]), 1);
    }

    #[test]
    fn sample_zero_temp_is_greedy() {
        let mut rng = Rng::new(0);
        assert_eq!(sample(&[0.0, 9.0, 1.0], 0.0, &mut rng), 1);
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::new(1);
        let logits = [0.0f32, 5.0, 0.0];
        let hits = (0..200)
            .filter(|_| sample(&logits, 1.0, &mut rng) == 1)
            .count();
        assert!(hits > 150, "hits={hits}");
    }

    #[test]
    fn nll_matches_closed_form() {
        // uniform logits -> nll = ln(n)
        let l = [0.0f32; 8];
        assert!((nll(&l, 3) - (8f64).ln()).abs() < 1e-9);
        // confident correct -> near zero
        let mut c = [0.0f32; 4];
        c[2] = 50.0;
        assert!(nll(&c, 2) < 1e-6);
    }
}

//! Model-side substrates: manifest parsing, the weight store, the typed
//! artifact executor, the KV cache, and sampling.

pub mod assets;
pub mod executor;
pub mod kv;
pub mod manifest;
pub mod sampler;

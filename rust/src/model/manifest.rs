//! Artifact-manifest parsing (`artifacts/<model>/manifest.json`, written
//! by `python/compile/aot.py`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::quant::Precision;
use crate::runtime::DType;
use crate::util::json::Json;

/// Mini-model hyper-parameters (mirrors `python/compile/configs.py`).
#[derive(Debug, Clone)]
pub struct MiniModel {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub max_cache: usize,
    pub group_size: usize,
}

/// One tensor in an artifact signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

/// One HLO artifact's I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One named section of `weights.bin`.
#[derive(Debug, Clone)]
pub struct Section {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub nbytes: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: MiniModel,
    pub expert_buckets: Vec<usize>,
    pub weights_file: String,
    /// Logical transfer bytes per expert per precision tier (mini scale).
    pub expert_bytes: BTreeMap<String, u64>,
    pub sections: BTreeMap<String, Section>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn tensor_spec(v: &Json) -> Result<TensorSpec> {
    Ok(TensorSpec {
        name: v
            .opt("name")
            .map(|n| n.as_str().map(str::to_string))
            .transpose()?
            .unwrap_or_default(),
        dtype: DType::from_tag(v.get("dtype")?.as_str()?)?,
        shape: v.get("shape")?.as_usize_vec()?,
    })
}

impl Manifest {
    pub fn load(model_dir: &Path) -> Result<Manifest> {
        let path = model_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let m = v.get("model")?;
        let model = MiniModel {
            name: m.get("name")?.as_str()?.to_string(),
            n_layers: m.get("n_layers")?.as_usize()?,
            d_model: m.get("d_model")?.as_usize()?,
            n_heads: m.get("n_heads")?.as_usize()?,
            head_dim: m.get("head_dim")?.as_usize()?,
            d_ffn: m.get("d_ffn")?.as_usize()?,
            n_experts: m.get("n_experts")?.as_usize()?,
            top_k: m.get("top_k")?.as_usize()?,
            vocab: m.get("vocab")?.as_usize()?,
            max_seq: m.get("max_seq")?.as_usize()?,
            max_cache: m.get("max_cache")?.as_usize()?,
            group_size: m.get("group_size")?.as_usize()?,
        };

        let mut sections = BTreeMap::new();
        for (name, s) in v.get("sections")?.as_obj()? {
            sections.insert(
                name.clone(),
                Section {
                    dtype: DType::from_tag(s.get("dtype")?.as_str()?)?,
                    shape: s.get("shape")?.as_usize_vec()?,
                    offset: s.get("offset")?.as_usize()?,
                    nbytes: s.get("nbytes")?.as_usize()?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, a) in v.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: a.get("file")?.as_str()?.to_string(),
                    inputs: a
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(tensor_spec)
                        .collect::<Result<_>>()?,
                    outputs: a
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(tensor_spec)
                        .collect::<Result<_>>()?,
                },
            );
        }

        let mut expert_bytes = BTreeMap::new();
        for (k, val) in v.get("expert_bytes")?.as_obj()? {
            expert_bytes.insert(k.clone(), val.as_f64()? as u64);
        }

        Ok(Manifest {
            model,
            expert_buckets: v.get("expert_buckets")?.as_usize_vec()?,
            weights_file: v.get("weights_file")?.as_str()?.to_string(),
            expert_bytes,
            sections,
            artifacts,
        })
    }

    /// Logical (mini-scale) transfer bytes for one expert at a precision.
    pub fn expert_transfer_bytes(&self, p: Precision) -> u64 {
        if p == Precision::Skip {
            return 0;
        }
        *self.expert_bytes.get(p.tag()).unwrap_or(&0)
    }

    /// Smallest exported token bucket >= `count`.
    pub fn bucket_for(&self, count: usize) -> Option<usize> {
        self.expert_buckets.iter().copied().find(|&b| b >= count)
    }
}

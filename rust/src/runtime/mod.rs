//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client (adapting /opt/xla-example/load_hlo — HLO *text* is the
//! interchange format; see python/compile/aot.py).
//!
//! Executables are compiled lazily on first use and cached for the life of
//! the runtime; Python is never involved at this point.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Typed tensor views for artifact I/O.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn from_tag(tag: &str) -> Result<DType> {
        Ok(match tag {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            _ => anyhow::bail!("unknown dtype tag {tag:?}"),
        })
    }
}

/// Build an f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} != len {}", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} != len {}", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

pub fn lit_u32(data: &[u32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} != len {}", data.len());
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims_i64)?)
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// A device buffer plus the host literal it was staged from (the copy is
/// asynchronous; the literal must stay alive until the pipeline syncs).
pub struct Staged {
    pub buf: xla::PjRtBuffer,
    _keepalive: xla::Literal,
}

/// Executes HLO-text artifacts on a shared PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    compiled: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    exec_count: RefCell<u64>,
    exec_nanos: RefCell<u64>,
}

impl Runtime {
    /// `dir` is the per-model artifact directory (contains `*.hlo.txt`).
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            compiled: RefCell::new(HashMap::new()),
            exec_count: RefCell::new(0),
            exec_nanos: RefCell::new(0),
        })
    }

    /// Number of artifact executions so far (perf accounting).
    pub fn exec_count(&self) -> u64 {
        *self.exec_count.borrow()
    }

    /// Wall nanoseconds spent inside PJRT execute+fetch (perf accounting);
    /// the remainder of request wall time is L3 logic + literal building.
    pub fn exec_nanos(&self) -> u64 {
        *self.exec_nanos.borrow()
    }

    fn compile(&self, name: &str) -> Result<()> {
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e}"))?;
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Ensure an artifact is compiled (for warm-up, excluded from timings).
    pub fn warm(&self, name: &str) -> Result<()> {
        if !self.compiled.borrow().contains_key(name) {
            self.compile(name)?;
        }
        Ok(())
    }

    /// Stage a host literal as a device buffer.  Weight tensors are staged
    /// once and cached by the executor; dynamic inputs are staged per call.
    ///
    /// NOTES on xla_extension 0.5.1 behaviour (EXPERIMENTS.md §Perf):
    /// * the runtime deliberately avoids `PjRtLoadedExecutable::execute`
    ///   (literal arguments): its C++ literal->buffer conversion leaks
    ///   ~9 KB per call, which OOMs long experiment sweeps;
    /// * `buffer_from_host_literal` copies **asynchronously** on a worker
    ///   thread, so the source literal must outlive the copy — [`Staged`]
    ///   keeps it alive alongside the buffer; synchronisation happens at
    ///   the next output fetch (`to_literal_sync`), which transitively
    ///   waits on all input copies.
    pub fn stage(&self, lit: xla::Literal) -> Result<Staged> {
        let devices = self.client.addressable_devices();
        let buf = self
            .client
            .buffer_from_host_literal(Some(&devices[0]), &lit)
            .map_err(|e| anyhow!("staging buffer: {e}"))?;
        // Force the async host->device copy to complete while the source
        // literal is provably alive: a buffer dropped before its pending
        // copy runs (error paths, never-used weights on engine teardown)
        // otherwise segfaults a worker thread.  One synchronising
        // round-trip per staged tensor; weights pay it once at init.
        let _ = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("synchronising staged buffer: {e}"))?;
        Ok(Staged { buf, _keepalive: lit })
    }

    /// Execute artifact `name` over pre-staged device buffers; returns the
    /// tuple elements (aot.py lowers everything with `return_tuple=True`).
    pub fn exec_bufs(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        self.warm(name)?;
        *self.exec_count.borrow_mut() += 1;
        let t0 = std::time::Instant::now();
        let map = self.compiled.borrow();
        let exe = map.get(name).expect("warmed above");
        let result = exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e}"))?;
        let out = lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"));
        *self.exec_nanos.borrow_mut() += t0.elapsed().as_nanos() as u64;
        out
    }

    /// Execute with host literals (staged per call; literals are kept
    /// alive until the output fetch synchronises the pipeline).
    pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let staged = inputs
            .iter()
            .map(|l| self.stage(l.clone()))
            .collect::<Result<Vec<_>>>()?;
        let refs: Vec<&xla::PjRtBuffer> = staged.iter().map(|s| &s.buf).collect();
        self.exec_bufs(name, &refs)
    }

    /// Execute over buffers and convert every output to f32 (helper for
    /// the common all-f32 artifacts).
    pub fn exec_bufs_f32(
        &self,
        name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<Vec<f32>>> {
        self.exec_bufs(name, inputs)?
            .iter()
            .map(to_f32)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("outputs of {name}"))
    }

    /// Execute with host literals and convert every output to f32.
    pub fn exec_f32(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.exec(name, inputs)?
            .iter()
            .map(to_f32)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("outputs of {name}"))
    }
}

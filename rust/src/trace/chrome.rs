//! Chrome Trace Event JSON writer (and structural linter) for cluster
//! runs.  The emitted document loads in Perfetto (https://ui.perfetto.dev)
//! and `chrome://tracing`:
//!
//! - one **process** (`pid`) per replica, named with its final
//!   lifecycle state;
//! - one **thread** (`tid`) per engine channel — GPU, CPU, demand PCIe,
//!   prefetch PCIe, NVMe — plus scheduler-tick, marker, and session
//!   rows;
//! - every channel interval as a `ph:"X"` duration slice (µs
//!   timestamps) with structured args (sessions, phase, layer,
//!   experts);
//! - churn and marker instants as `ph:"i"`;
//! - session lifecycle as nestable async events (`ph:"b"/"n"/"e"`:
//!   arrival -> admitted -> first-token -> done), keyed by request id,
//!   with the tenant class (interactive/batch) and retry/preemption
//!   counts as args on the begin event for Perfetto-side filtering;
//! - per-tick counters (`ph:"C"`): queue depth, active sessions, KV
//!   bytes, expert-cache bytes, and the host-pool tracks (hits, SSD
//!   fills, contention stall; flat zero without `--host-pool`).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{bail, Result};

use crate::memory::{EventKind, TraceEvent};
use crate::serving::ClusterOutcome;
use crate::util::json::{arr, num, obj, s, Json};

/// Seconds (virtual time) to Chrome-trace microseconds.
const US: f64 = 1e6;

/// Stable thread id per event kind within a replica process.
fn tid(kind: EventKind) -> f64 {
    match kind {
        EventKind::GpuCompute => 1.0,
        EventKind::CpuCompute => 2.0,
        EventKind::PcieTransfer => 3.0,
        EventKind::PciePrefetch => 4.0,
        EventKind::NvmeStage => 5.0,
        EventKind::Tick => 6.0,
        EventKind::Marker => 7.0,
    }
}

/// Thread id of the session-lifecycle row.
const SESSION_TID: f64 = 8.0;

fn thread_name(kind: EventKind) -> &'static str {
    match kind {
        EventKind::GpuCompute => "gpu",
        EventKind::CpuCompute => "cpu",
        EventKind::PcieTransfer => "pcie demand",
        EventKind::PciePrefetch => "pcie prefetch",
        EventKind::NvmeStage => "nvme",
        EventKind::Tick => "scheduler ticks",
        EventKind::Marker => "markers",
    }
}

/// `ph:"M"` metadata event naming a process or thread.
fn meta_event(what: &str, pid: f64, tid: Option<f64>, name: &str) -> Json {
    let mut pairs = vec![
        ("ph", s("M")),
        ("name", s(what)),
        ("pid", num(pid)),
        ("args", obj(vec![("name", s(name))])),
    ];
    if let Some(t) = tid {
        pairs.push(("tid", num(t)));
    }
    obj(pairs)
}

/// Structured args for a duration slice, from the event's trace meta.
fn span_args(e: &TraceEvent) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    if !e.meta.sessions.is_empty() {
        pairs.push(("sessions", arr(e.meta.sessions.iter().map(|&v| num(v as f64)))));
    }
    if let Some(p) = e.meta.phase {
        pairs.push(("phase", s(p.tag())));
    }
    if let Some(l) = e.meta.layer {
        pairs.push(("layer", num(l as f64)));
    }
    if !e.meta.experts.is_empty() {
        pairs.push(("experts", arr(e.meta.experts.iter().map(|&v| num(v as f64)))));
    }
    obj(pairs)
}

/// Render a cluster run as a Chrome Trace Event JSON document.
///
/// Metadata events lead; every timed event follows in global timestamp
/// order (Perfetto does not require it, but sorted output makes the
/// per-track monotonicity the linter checks a structural property of
/// the file rather than a viewer-side repair).
pub fn chrome_trace(cluster: &ClusterOutcome) -> Json {
    let mut head: Vec<Json> = Vec::new();
    let mut timed: Vec<(f64, Json)> = Vec::new();
    for (i, b) in cluster.replicas.iter().enumerate() {
        let pid = (i + 1) as f64;
        head.push(meta_event(
            "process_name",
            pid,
            None,
            &format!("replica {i} [{}]", b.state.name()),
        ));
        for kind in EventKind::ALL {
            head.push(meta_event("thread_name", pid, Some(tid(kind)), thread_name(kind)));
        }
        head.push(meta_event("thread_name", pid, Some(SESSION_TID), "sessions"));

        for e in &b.trace.events {
            let ts = e.start * US;
            let j = if e.kind == EventKind::Marker {
                obj(vec![
                    ("ph", s("i")),
                    ("name", s(&e.label)),
                    ("cat", s(e.kind.tag())),
                    ("pid", num(pid)),
                    ("tid", num(tid(e.kind))),
                    ("ts", num(ts)),
                    ("s", s("p")),
                ])
            } else {
                obj(vec![
                    ("ph", s("X")),
                    ("name", s(&e.label)),
                    ("cat", s(e.kind.tag())),
                    ("pid", num(pid)),
                    ("tid", num(tid(e.kind))),
                    ("ts", num(ts)),
                    ("dur", num((e.end - e.start) * US)),
                    ("args", span_args(e)),
                ])
            };
            timed.push((ts, j));
        }

        for sample in &b.trace.samples {
            let ts = sample.t * US;
            for (name, v) in [
                ("queue depth", sample.queue_depth as f64),
                ("active sessions", sample.active_sessions as f64),
                ("kv bytes", sample.kv_bytes as f64),
                ("expert cache bytes", sample.cache_bytes as f64),
                // Host-pool tracks (flat zero without `--host-pool`;
                // always emitted so traces diff structurally).
                ("host pool hits", sample.host_pool_hits as f64),
                ("host pool fills", sample.host_pool_fills as f64),
                ("host pool stall s", sample.host_pool_stall_s),
            ] {
                timed.push((
                    ts,
                    obj(vec![
                        ("ph", s("C")),
                        ("name", s(name)),
                        ("pid", num(pid)),
                        ("ts", num(ts)),
                        ("args", obj(vec![("value", num(v))])),
                    ]),
                ));
            }
        }

        // Session lifecycle as nestable async events, from the replica's
        // completed-request records (a re-dispatched session appears on
        // the replica that completed it, with its original arrival).
        for r in &b.outcome.per_request {
            let span_name = format!("req {}", r.id);
            let lifecycle = |ph: &str, at: f64, name: &str| {
                obj(vec![
                    ("ph", s(ph)),
                    ("cat", s("session")),
                    ("name", s(name)),
                    ("id", num(r.id as f64)),
                    ("pid", num(pid)),
                    ("tid", num(SESSION_TID)),
                    ("ts", num(at * US)),
                ])
            };
            let admitted = r.arrival + r.queue_delay;
            let first_token = r.arrival + r.ttft;
            // The begin event carries the request's tenant class plus
            // its re-dispatch / preemption counts, so Perfetto queries
            // can filter interactive vs batch session flows.
            let begin = obj(vec![
                ("ph", s("b")),
                ("cat", s("session")),
                ("name", s(&span_name)),
                ("id", num(r.id as f64)),
                ("pid", num(pid)),
                ("tid", num(SESSION_TID)),
                ("ts", num(r.arrival * US)),
                (
                    "args",
                    obj(vec![
                        ("class", s(r.class.name())),
                        ("retries", num(r.retries as f64)),
                        ("preemptions", num(r.preemptions as f64)),
                    ]),
                ),
            ]);
            timed.push((r.arrival * US, begin));
            timed.push((admitted * US, lifecycle("n", admitted, "admitted")));
            timed.push((first_token * US, lifecycle("n", first_token, "first-token")));
            timed.push((r.finished_at * US, lifecycle("e", r.finished_at, &span_name)));
        }
    }
    // Stable sort keeps same-timestamp insertion order (b before e).
    timed.sort_by(|a, b| a.0.total_cmp(&b.0));
    head.extend(timed.into_iter().map(|(_, j)| j));
    obj(vec![("traceEvents", Json::Arr(head)), ("displayTimeUnit", s("ms"))])
}

/// Counts from a [`lint`] pass over a trace document.
#[derive(Debug, Clone, Copy, Default)]
pub struct LintReport {
    pub processes: usize,
    pub slices: usize,
    pub counters: usize,
    pub instants: usize,
    pub session_events: usize,
}

/// Structural validation of a Chrome Trace Event JSON document as this
/// writer emits it: `traceEvents` present and non-empty, only known
/// phase types, timestamps non-negative and monotone non-decreasing per
/// `(pid, tid)` track, `ph:"X"` slices with non-negative durations,
/// counters carrying a numeric value, and balanced session begin/end
/// pairs.  Used by the `trace-lint` CLI command and the CI smoke step.
pub fn lint(doc: &Json) -> Result<LintReport> {
    let events = doc.get("traceEvents")?.as_arr()?;
    if events.is_empty() {
        bail!("empty traceEvents");
    }
    let mut rep = LintReport::default();
    let mut pids: BTreeSet<i64> = BTreeSet::new();
    let mut last_ts: BTreeMap<(i64, i64), f64> = BTreeMap::new();
    let mut open_sessions: BTreeMap<(i64, i64), i64> = BTreeMap::new();
    for ev in events {
        let ph = ev.get("ph")?.as_str()?;
        let pid = ev.get("pid")?.as_f64()? as i64;
        pids.insert(pid);
        if ph == "M" {
            continue;
        }
        let ts = ev.get("ts")?.as_f64()?;
        if ts.is_nan() || ts < 0.0 {
            bail!("negative or NaN ts {ts}");
        }
        match ph {
            "X" => {
                let dur = ev.get("dur")?.as_f64()?;
                if dur.is_nan() || dur < 0.0 {
                    bail!("negative or NaN dur {dur}");
                }
                let tid = ev.get("tid")?.as_f64()? as i64;
                let prev = last_ts.entry((pid, tid)).or_insert(f64::NEG_INFINITY);
                if ts < *prev {
                    bail!("track (pid {pid}, tid {tid}) timestamps went backwards");
                }
                *prev = ts;
                rep.slices += 1;
            }
            "C" => {
                ev.get("args")?.get("value")?.as_f64()?;
                rep.counters += 1;
            }
            "i" => rep.instants += 1,
            "b" | "n" | "e" => {
                let id = ev.get("id")?.as_f64()? as i64;
                let depth = open_sessions.entry((pid, id)).or_insert(0);
                match ph {
                    "b" => *depth += 1,
                    "e" => {
                        *depth -= 1;
                        if *depth < 0 {
                            bail!("session {id} on pid {pid} ended before it began");
                        }
                    }
                    _ => {}
                }
                rep.session_events += 1;
            }
            other => bail!("unknown event phase {other:?}"),
        }
    }
    if let Some(((pid, id), _)) = open_sessions.iter().find(|(_, &d)| d != 0) {
        bail!("session {id} on pid {pid} never ended");
    }
    rep.processes = pids.len();
    Ok(rep)
}

//! Cluster-scale trace export: turns the per-engine
//! [`crate::memory::Timeline`] event log into a Perfetto-loadable
//! Chrome Trace Event JSON file (`serve-fleet --trace-out PATH`).
//!
//! The serving replica captures two per-run streams when its engine's
//! timeline is recording: the structured [`crate::memory::TraceEvent`]
//! suffix this run appended (snapshot-delta scoped exactly like
//! [`crate::memory::BusyTotals`], so engine reuse across runs never
//! leaks earlier runs' events) and one [`TickSample`] of serving
//! counters per scheduler tick.  The cluster layer carries both through
//! [`crate::serving::ReplicaBreakdown::trace`]; [`chrome::chrome_trace`]
//! renders the whole cluster as one trace — replica -> `pid`, channel
//! -> `tid`, duration slices, churn instants, session lifecycle flows,
//! and counter tracks.

pub mod chrome;

use crate::memory::TraceEvent;

/// One per-tick sample of a serving replica's counters (the source of
/// the Chrome-trace `ph:"C"` counter tracks).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TickSample {
    /// Virtual time of the sample (the replica clock after the tick).
    pub t: f64,
    /// Requests dispatched to the replica but not yet admitted.
    pub queue_depth: usize,
    /// Admitted, still-running sessions.
    pub active_sessions: usize,
    /// KV-cache bytes held by the active sessions (VRAM).
    pub kv_bytes: u64,
    /// Expert-cache bytes resident in VRAM.
    pub cache_bytes: u64,
    /// Cumulative host-pool hits observed by this replica (zero with no
    /// pool attached; `--host-pool` runs only).
    pub host_pool_hits: u64,
    /// Cumulative SSD fills this replica paid into the host pool.
    pub host_pool_fills: u64,
    /// Cumulative host-link contention stall seconds.
    pub host_pool_stall_s: f64,
}

/// One replica's run-scoped trace streams.  Empty when the engine's
/// timeline is not recording (the `--trace-out`-absent fast path).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceCapture {
    /// The engine events this run appended, in log order.
    pub events: Vec<TraceEvent>,
    /// One counter sample per scheduler tick, in tick order.
    pub samples: Vec<TickSample>,
}

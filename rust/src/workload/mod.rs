//! Workloads: the ShareGPT-like latency trace generator and the eval-suite
//! loader (`artifacts/eval/suites.json`, written by the build).
//!
//! For latency experiments only the *length distribution* matters at batch
//! size 1; we fit log-normals to published ShareGPT statistics (median
//! prompt ~50 tokens, long tail; outputs a bit longer), clipped to the
//! mini models' sequence budget.  Prompt *content* is sampled from the
//! same pattern corpus the models were trained on so that routing
//! behaviour (and hence cache/prefetch dynamics) is realistic rather than
//! uniform.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Token-space constants mirrored from `python/compile/corpus.py`.
pub mod tokens {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const DELIM: i32 = 10;
    pub const TAG_COPY: i32 = 2;
    pub const TAG_ARITH: i32 = 3;
    pub const TAG_SORT: i32 = 4;
    pub const TAG_REPEAT: i32 = 5;
    pub const TAG_MARKOV_A: i32 = 6;
    pub const TAG_MARKOV_B: i32 = 7;
    pub const TAG_SUCC: i32 = 8;
    pub const DIGIT0: i32 = 11;
    pub const LETTER0: i32 = 27;
    pub const LETTER1: i32 = 63;
    /// Ring used by the repeat/succ tasks (see python corpus.py).
    pub const RING_N: i32 = 16;
    pub const VOCAB: usize = 64;
}

/// One serving request of the latency trace.
#[derive(Debug, Clone)]
pub struct Request {
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// ShareGPT-like trace generator (seeded, deterministic).
pub struct TraceGen {
    rng: Rng,
    pub max_prompt: usize,
    pub max_new: usize,
}

impl TraceGen {
    pub fn new(seed: u64, max_prompt: usize, max_new: usize) -> Self {
        TraceGen { rng: Rng::new(seed), max_prompt, max_new }
    }

    fn pattern_body(&mut self, len: usize) -> Vec<i32> {
        use tokens::*;
        let dom = self.rng.below(6);
        let mut out = Vec::with_capacity(len);
        match dom {
            0 => {
                // copy: TAG seg | seg
                out.push(TAG_COPY);
                let seg: Vec<i32> = (0..len / 2)
                    .map(|_| self.rng.range(LETTER0 as usize, LETTER1 as usize) as i32)
                    .collect();
                out.extend(&seg);
                out.push(DELIM);
                out.extend(&seg);
            }
            1 => {
                // arith chain
                out.push(TAG_ARITH);
                let start = self.rng.below(10);
                let step = self.rng.range(1, 3);
                for i in 0..len {
                    out.push(((start + i * step) % 10) as i32 + DIGIT0);
                }
            }
            2 => {
                // sort: TAG seg | sorted(seg)
                out.push(TAG_SORT);
                let mut seg: Vec<i32> = (0..len / 2)
                    .map(|_| self.rng.range(LETTER0 as usize, LETTER1 as usize) as i32)
                    .collect();
                out.extend(&seg);
                out.push(DELIM);
                seg.sort_unstable();
                out.extend(&seg);
            }
            3 => {
                // periodic repeat over the small ring
                out.push(TAG_REPEAT);
                let period = self.rng.range(1, 4);
                let motif: Vec<i32> = (0..period)
                    .map(|_| LETTER0 + self.rng.below(RING_N as usize) as i32)
                    .collect();
                for i in 0..len {
                    out.push(motif[i % period]);
                }
            }
            4 => {
                // letter-successor chain
                out.push(TAG_SUCC);
                let start = self.rng.below(RING_N as usize) as i32;
                let step = self.rng.range(1, 3) as i32;
                for i in 0..len {
                    out.push(LETTER0 + (start + i as i32 * step).rem_euclid(RING_N));
                }
            }
            _ => {
                // markov-ish letters
                out.push(TAG_MARKOV_A);
                for _ in 0..len {
                    out.push(self.rng.range(LETTER0 as usize, LETTER1 as usize) as i32);
                }
            }
        }
        out.truncate(len);
        out
    }

    /// Next request: log-normal prompt/output lengths, pattern content.
    pub fn next_request(&mut self) -> Request {
        // ln-space fits: prompts median ~ 40 tokens, outputs ~ 16 (scaled
        // to the mini models' 96-token budget).
        let plen = (self.rng.lognormal(3.6, 0.5) as usize).clamp(8, self.max_prompt);
        let olen = (self.rng.lognormal(2.4, 0.6) as usize).clamp(4, self.max_new);
        let mut prompt = vec![tokens::BOS];
        prompt.extend(self.pattern_body(plen - 1));
        Request { prompt, max_new: olen }
    }

    /// A deterministic trace of `n` requests.
    pub fn trace(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// One eval item: teacher-forced answer with known ground truth.
#[derive(Debug, Clone)]
pub struct EvalItem {
    pub prompt: Vec<i32>,
    pub answer: Vec<i32>,
}

/// A named benchmark suite (stand-ins for MMLU / CMMLU / GSM8K).
#[derive(Debug, Clone)]
pub struct EvalSuite {
    pub name: String,
    pub items: Vec<EvalItem>,
}

/// Load `artifacts/eval/suites.json`.
pub fn load_suites(artifacts_dir: &str) -> Result<Vec<EvalSuite>> {
    let path = Path::new(artifacts_dir).join("eval/suites.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?}"))?;
    let v = Json::parse(&text)?;
    let mut suites = Vec::new();
    for (name, arr) in v.as_obj()? {
        let items = arr
            .as_arr()?
            .iter()
            .map(|it| {
                Ok(EvalItem {
                    prompt: it
                        .get("prompt")?
                        .as_usize_vec()?
                        .into_iter()
                        .map(|t| t as i32)
                        .collect(),
                    answer: it
                        .get("answer")?
                        .as_usize_vec()?
                        .into_iter()
                        .map(|t| t as i32)
                        .collect(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        suites.push(EvalSuite { name: name.clone(), items });
    }
    suites.sort_by(|a, b| a.name.cmp(&b.name));
    Ok(suites)
}

/// The paper's benchmark naming: map suites to their stand-in roles.
pub fn suite_role(name: &str) -> &'static str {
    match name {
        "suite_repeat" => "MMLU-proxy",
        "suite_succ" => "CMMLU-proxy",
        "suite_arith" => "GSM8K-proxy",
        _ => "unknown",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_deterministic_and_bounded() {
        let mut g1 = TraceGen::new(7, 96, 32);
        let mut g2 = TraceGen::new(7, 96, 32);
        let t1 = g1.trace(20);
        let t2 = g2.trace(20);
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new, b.max_new);
            assert!(a.prompt.len() <= 96 && a.prompt.len() >= 8);
            assert!(a.max_new <= 32 && a.max_new >= 4);
            assert_eq!(a.prompt[0], tokens::BOS);
            assert!(a.prompt.iter().all(|&t| (t as usize) < tokens::VOCAB));
        }
    }

    #[test]
    fn lengths_have_spread() {
        let mut g = TraceGen::new(3, 96, 32);
        let t = g.trace(100);
        let lens: Vec<usize> = t.iter().map(|r| r.prompt.len()).collect();
        let min = *lens.iter().min().unwrap();
        let max = *lens.iter().max().unwrap();
        assert!(max > min + 10, "degenerate length distribution");
    }
}

//! Accuracy evaluation harness: runs eval suites through a serving engine
//! with teacher forcing and scores exact-match accuracy, answer NLL, and
//! top-1 agreement with a BF16 reference (DESIGN.md §2: these fidelity
//! metrics stand in for the paper's MMLU/CMMLU/GSM8K numbers).

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::model::sampler;
use crate::workload::EvalSuite;

/// Accuracy metrics over one suite.
#[derive(Debug, Clone, Default)]
pub struct SuiteScore {
    pub name: String,
    /// Fraction of items whose entire answer is greedily exact.
    pub exact_match: f64,
    /// Fraction of answer tokens predicted correctly (greedy).
    pub token_acc: f64,
    /// Mean NLL of the ground-truth answer tokens.
    pub answer_nll: f64,
    /// Fraction of answer positions whose greedy prediction agrees with a
    /// reference run (only when a reference is supplied).
    pub ref_agreement: f64,
    pub items: usize,
}

/// Evaluate `engine` on a suite with teacher forcing.
///
/// `reference`: optional per-item greedy predictions from a BF16 reference
/// engine (`predictions` output of a previous [`evaluate_suite`] call).
pub fn evaluate_suite(
    engine: &mut Engine,
    suite: &EvalSuite,
    limit: usize,
    reference: Option<&[Vec<i32>]>,
) -> Result<(SuiteScore, Vec<Vec<i32>>)> {
    let mut exact = 0usize;
    let mut tok_hits = 0usize;
    let mut tok_total = 0usize;
    let mut nll_sum = 0f64;
    let mut agree_hits = 0usize;
    let mut agree_total = 0usize;
    let mut predictions: Vec<Vec<i32>> = Vec::new();

    let n = suite.items.len().min(limit);
    for (i, item) in suite.items.iter().take(n).enumerate() {
        let out = engine.run_forced(&item.prompt, item.answer.len(), Some(&item.answer))?;
        debug_assert_eq!(out.logits_per_step.len(), item.answer.len());
        let mut all_ok = true;
        let mut preds = Vec::with_capacity(item.answer.len());
        for (logits, &truth) in out.logits_per_step.iter().zip(&item.answer) {
            let pred = sampler::greedy(logits) as i32;
            preds.push(pred);
            if pred == truth {
                tok_hits += 1;
            } else {
                all_ok = false;
            }
            tok_total += 1;
            nll_sum += sampler::nll(logits, truth as usize);
        }
        if all_ok {
            exact += 1;
        }
        if let Some(refs) = reference {
            for (p, r) in preds.iter().zip(&refs[i]) {
                if p == r {
                    agree_hits += 1;
                }
                agree_total += 1;
            }
        }
        predictions.push(preds);
    }

    Ok((
        SuiteScore {
            name: suite.name.clone(),
            exact_match: exact as f64 / n.max(1) as f64,
            token_acc: tok_hits as f64 / tok_total.max(1) as f64,
            answer_nll: nll_sum / tok_total.max(1) as f64,
            ref_agreement: if agree_total > 0 {
                agree_hits as f64 / agree_total as f64
            } else {
                f64::NAN
            },
            items: n,
        },
        predictions,
    ))
}

/// Mean token accuracy across several suite scores (a single "benchmark
/// accuracy" number for sweep plots like Fig. 3 / Fig. 11).
pub fn mean_token_acc(scores: &[SuiteScore]) -> f64 {
    if scores.is_empty() {
        return 0.0;
    }
    scores.iter().map(|s| s.token_acc).sum::<f64>() / scores.len() as f64
}

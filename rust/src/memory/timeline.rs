//! Virtual timeline: FIFO resource channels + structured trace-event log.
//!
//! When `record` is on, every scheduled interval is logged as a
//! [`TraceEvent`] carrying the serving context active at log time
//! ([`TraceMeta`]: session ids, phase, layer, expert set), which the
//! [`crate::trace`] module turns into a Perfetto-loadable Chrome trace.
//! Like [`Channel::busy_total`], the event log is cumulative over the
//! engine's lifetime and is never cleared; per-run consumers (the
//! serving replica layer) snapshot `events.len()` at run start and
//! capture the suffix, so engine reuse never leaks earlier runs' events.

/// What an event occupied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    GpuCompute,
    CpuCompute,
    /// Demand host->device transfer (a session is waiting on it).
    PcieTransfer,
    /// Background look-ahead prefetch transfer.  A distinct kind from
    /// [`EventKind::PcieTransfer`] so the overlap wins of prefetching
    /// (paper contribution 3) are visible in renderings of the log.
    PciePrefetch,
    NvmeStage,
    /// One serving-layer scheduler tick, spanning the engine work that
    /// tick issued (logged by [`crate::serving::Replica::tick`]).
    Tick,
    Marker,
}

impl EventKind {
    pub fn tag(self) -> &'static str {
        match self {
            EventKind::GpuCompute => "gpu",
            EventKind::CpuCompute => "cpu",
            EventKind::PcieTransfer => "pcie",
            EventKind::PciePrefetch => "pfch",
            EventKind::NvmeStage => "nvme",
            EventKind::Tick => "tick",
            EventKind::Marker => "mark",
        }
    }

    /// Every kind, in the row order [`Timeline::render_ascii`] uses.
    pub const ALL: [EventKind; 7] = [
        EventKind::GpuCompute,
        EventKind::CpuCompute,
        EventKind::PcieTransfer,
        EventKind::PciePrefetch,
        EventKind::NvmeStage,
        EventKind::Tick,
        EventKind::Marker,
    ];
}

/// Which serving phase a scheduling step ran under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TracePhase {
    /// A prefill chunk (or a monolithic whole-prompt prefill).
    Prefill,
    /// A pure decode batch.
    Decode,
    /// A fused tick carrying a prefill chunk and a decode batch.
    Mixed,
}

impl TracePhase {
    pub fn tag(self) -> &'static str {
        match self {
            TracePhase::Prefill => "prefill-chunk",
            TracePhase::Decode => "decode-batch",
            TracePhase::Mixed => "mixed-tick",
        }
    }
}

/// Structured serving context stamped onto every logged event: which
/// sessions the current scheduling step serves, under which phase, and
/// (for engine-internal events) which layer / expert set.  The replica
/// id is *not* here — a timeline belongs to one engine, and the cluster
/// layer keys each captured stream by its replica.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceMeta {
    /// Serving-layer session tags of the step (request ids once the
    /// fleet stamps them, engine session ids otherwise).
    pub sessions: Vec<u64>,
    pub phase: Option<TracePhase>,
    pub layer: Option<u32>,
    /// Experts the event materializes or executes (empty when not
    /// expert work).
    pub experts: Vec<u32>,
}

/// One scheduled interval on a resource, with the serving context that
/// scheduled it (Fig.-1-style timelines; Chrome-trace export).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub kind: EventKind,
    pub label: String,
    pub start: f64,
    pub end: f64,
    pub meta: TraceMeta,
}

/// A serially-occupied resource: work issued at `t` starts at
/// `max(t, free_at)` and occupies the resource for its duration.
///
/// The channel has two service classes: **demand** work (the default) and
/// **background** work (prefetch).  Background work never delays demand
/// work — it is modelled as running in otherwise-idle bandwidth (real
/// systems chunk DMA transfers and preempt at chunk granularity; we
/// approximate by letting demand scheduling ignore the background queue,
/// while background transfers wait for both queues).
#[derive(Debug, Clone, Default)]
pub struct Channel {
    pub free_at: f64,
    /// Completion horizon of background (prefetch) work.
    pub bg_free_at: f64,
    pub busy_total: f64,
}

impl Channel {
    /// Schedule `dur` seconds of demand work issued at `issue`; returns
    /// (start, end).
    pub fn schedule(&mut self, issue: f64, dur: f64) -> (f64, f64) {
        debug_assert!(dur >= 0.0 && issue >= 0.0);
        let start = issue.max(self.free_at);
        let end = start + dur;
        self.free_at = end;
        self.busy_total += dur;
        (start, end)
    }

    /// Schedule `dur` seconds of low-priority background work: it yields
    /// to all demand work known at issue time and to earlier background
    /// work, and never pushes `free_at` (demand is never delayed by it).
    pub fn schedule_background(&mut self, issue: f64, dur: f64) -> (f64, f64) {
        debug_assert!(dur >= 0.0 && issue >= 0.0);
        let start = issue.max(self.free_at).max(self.bg_free_at);
        let end = start + dur;
        self.bg_free_at = end;
        self.busy_total += dur;
        (start, end)
    }

    /// Fraction of `span` seconds this channel spent busy (clamped to 1;
    /// 0 for an empty span).  Fleet serving reports per-resource
    /// utilization over a run's makespan with this.
    pub fn utilization(&self, span: f64) -> f64 {
        if span <= 0.0 {
            return 0.0;
        }
        (self.busy_total / span).min(1.0)
    }
}

/// Busy-seconds snapshot of the four pipeline channels.  Serving code
/// snapshots this at run boundaries and works with **deltas**
/// ([`BusyTotals::minus`]): `Channel::busy_total` is cumulative over the
/// engine's whole lifetime, so computing a run's utilization from the
/// raw totals double-counts earlier runs when an engine is reused.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BusyTotals {
    pub gpu: f64,
    pub cpu: f64,
    pub pcie: f64,
    pub nvme: f64,
}

impl BusyTotals {
    /// Component-wise `self - earlier`: the busy seconds accrued between
    /// two snapshots.
    pub fn minus(&self, earlier: &BusyTotals) -> BusyTotals {
        BusyTotals {
            gpu: self.gpu - earlier.gpu,
            cpu: self.cpu - earlier.cpu,
            pcie: self.pcie - earlier.pcie,
            nvme: self.nvme - earlier.nvme,
        }
    }

    /// Component-wise sum (cluster-level busy time across replicas).
    pub fn plus(&self, other: &BusyTotals) -> BusyTotals {
        BusyTotals {
            gpu: self.gpu + other.gpu,
            cpu: self.cpu + other.cpu,
            pcie: self.pcie + other.pcie,
            nvme: self.nvme + other.nvme,
        }
    }
}

/// The four resources of the edge pipeline plus an event log.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    pub gpu: Channel,
    pub cpu: Channel,
    pub pcie: Channel,
    pub nvme: Channel,
    pub events: Vec<TraceEvent>,
    /// Record events (off by default: latency experiments schedule many
    /// thousands of intervals).
    pub record: bool,
    /// Serving context stamped onto every logged event; maintained by
    /// the `ctx_*` methods (all no-ops when `record` is off).
    ctx: TraceMeta,
}

impl Timeline {
    pub fn new(record: bool) -> Self {
        Timeline { record, ..Default::default() }
    }

    fn log(&mut self, kind: EventKind, label: &str, start: f64, end: f64) {
        if self.record {
            self.events.push(TraceEvent {
                kind,
                label: label.to_string(),
                start,
                end,
                meta: self.ctx.clone(),
            });
        }
    }

    /// Enter a scheduling step's context: which sessions it serves and
    /// under which phase.  Clears the layer / expert stamps.
    pub fn ctx_step(&mut self, sessions: &[u64], phase: TracePhase) {
        if !self.record {
            return;
        }
        self.ctx.sessions.clear();
        self.ctx.sessions.extend_from_slice(sessions);
        self.ctx.phase = Some(phase);
        self.ctx.layer = None;
        self.ctx.experts.clear();
    }

    /// Stamp the layer subsequent events belong to (`None` for
    /// layer-independent work such as the finalize head).  Clears the
    /// expert stamp.
    pub fn ctx_layer(&mut self, layer: Option<u32>) {
        if !self.record {
            return;
        }
        self.ctx.layer = layer;
        self.ctx.experts.clear();
    }

    /// Stamp the expert set subsequent events materialize or execute.
    pub fn ctx_experts(&mut self, experts: &[u32]) {
        if !self.record {
            return;
        }
        self.ctx.experts.clear();
        self.ctx.experts.extend_from_slice(experts);
    }

    /// GPU compute that additionally depends on inputs ready at `deps`.
    pub fn gpu_compute(&mut self, issue: f64, deps: f64, dur: f64, label: &str) -> f64 {
        let (start, end) = self.gpu.schedule(issue.max(deps), dur);
        self.log(EventKind::GpuCompute, label, start, end);
        end
    }

    pub fn cpu_compute(&mut self, issue: f64, deps: f64, dur: f64, label: &str) -> f64 {
        let (start, end) = self.cpu.schedule(issue.max(deps), dur);
        self.log(EventKind::CpuCompute, label, start, end);
        end
    }

    /// Host->device transfer; returns arrival time.
    pub fn pcie_transfer(&mut self, issue: f64, dur: f64, label: &str) -> f64 {
        let (start, end) = self.pcie.schedule(issue, dur);
        self.log(EventKind::PcieTransfer, label, start, end);
        end
    }

    /// Low-priority host->device prefetch transfer; never delays demand
    /// transfers.  Returns arrival time.  Logged as its own
    /// [`EventKind::PciePrefetch`] so demand and prefetch traffic land
    /// on distinct tracks.
    pub fn pcie_prefetch(&mut self, issue: f64, dur: f64, label: &str) -> f64 {
        let (start, end) = self.pcie.schedule_background(issue, dur);
        self.log(EventKind::PciePrefetch, label, start, end);
        end
    }

    /// SSD->host staging; returns availability-in-host time.
    pub fn nvme_stage(&mut self, issue: f64, dur: f64, label: &str) -> f64 {
        let (start, end) = self.nvme.schedule(issue, dur);
        self.log(EventKind::NvmeStage, label, start, end);
        end
    }

    pub fn marker(&mut self, t: f64, label: &str) {
        self.log(EventKind::Marker, label, t, t);
    }

    /// Log one serving-layer scheduler tick spanning `[start, end]`,
    /// labelled and stamped with the step context the engine just ran
    /// under (the layer / expert stamps are cleared first — a tick is
    /// not layer work).
    pub fn tick_span(&mut self, start: f64, end: f64) {
        if !self.record {
            return;
        }
        self.ctx.layer = None;
        self.ctx.experts.clear();
        let label = self.ctx.phase.map(TracePhase::tag).unwrap_or("tick");
        self.log(EventKind::Tick, label, start, end);
    }

    /// Snapshot every channel's cumulative busy seconds (see
    /// [`BusyTotals`] for the delta discipline).
    pub fn busy_totals(&self) -> BusyTotals {
        BusyTotals {
            gpu: self.gpu.busy_total,
            cpu: self.cpu.busy_total,
            pcie: self.pcie.busy_total,
            nvme: self.nvme.busy_total,
        }
    }

    /// Render the recorded events as an ASCII timeline (Fig. 1).  The
    /// four channel rows always print; prefetch / tick / marker rows
    /// print only when they have events.  Every event paints at least
    /// one cell, so zero-width instants (markers) survive rasterization.
    pub fn render_ascii(&self, width: usize) -> String {
        if self.events.is_empty() {
            return "<no events recorded>".to_string();
        }
        let width = width.max(1);
        let t_max = self
            .events
            .iter()
            .map(|e| e.end)
            .fold(0.0_f64, f64::max)
            .max(1e-9);
        let mut out = String::new();
        for kind in EventKind::ALL {
            let mut row = vec![b'.'; width];
            let mut any = false;
            for e in self.events.iter().filter(|e| e.kind == kind) {
                any = true;
                let a = (((e.start / t_max) * width as f64) as usize).min(width - 1);
                // `a <= width - 1` guarantees `a + 1 <= width`, so the
                // clamp is well-formed and the event paints >= 1 cell.
                let b = (((e.end / t_max) * width as f64).ceil() as usize).clamp(a + 1, width);
                for c in row.iter_mut().take(b).skip(a) {
                    *c = b'#';
                }
            }
            let always = matches!(
                kind,
                EventKind::GpuCompute
                    | EventKind::CpuCompute
                    | EventKind::PcieTransfer
                    | EventKind::NvmeStage
            );
            if any || always {
                out.push_str(&format!(
                    "{:<5} |{}|\n",
                    kind.tag(),
                    String::from_utf8(row).unwrap()
                ));
            }
        }
        out.push_str(&format!("scale: 0 .. {:.4} s\n", t_max));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn channel_fifo_no_time_travel() {
        let mut c = Channel::default();
        let (s1, e1) = c.schedule(0.0, 1.0);
        let (s2, e2) = c.schedule(0.5, 1.0); // issued while busy -> queues
        assert_eq!((s1, e1), (0.0, 1.0));
        assert_eq!((s2, e2), (1.0, 2.0));
        let (s3, _) = c.schedule(5.0, 0.5); // idle gap -> starts at issue
        assert_eq!(s3, 5.0);
    }

    #[test]
    fn busy_total_conserved() {
        prop::check("channel-conservation", 30, |rng| {
            let mut c = Channel::default();
            let mut total = 0.0;
            let mut last_end = 0.0_f64;
            for _ in 0..50 {
                let issue = rng.f64() * 10.0;
                let dur = rng.f64();
                let (s, e) = c.schedule(issue, dur);
                assert!(s >= issue && (e - s - dur).abs() < 1e-12);
                assert!(s >= last_end.min(s)); // starts never precede queue head
                last_end = e;
                total += dur;
            }
            assert!((c.busy_total - total).abs() < 1e-9);
            assert!(c.free_at >= total - 1e-9); // can't finish faster than work
        });
    }

    #[test]
    fn utilization_is_clamped_fraction() {
        let mut c = Channel::default();
        c.schedule(0.0, 2.0);
        c.schedule(5.0, 1.0);
        assert!((c.utilization(6.0) - 0.5).abs() < 1e-12);
        assert_eq!(c.utilization(1.0), 1.0); // clamped
        assert_eq!(c.utilization(0.0), 0.0);
        assert_eq!(Channel::default().utilization(10.0), 0.0);
    }

    #[test]
    fn busy_totals_snapshot_and_delta() {
        let mut tl = Timeline::new(false);
        tl.gpu_compute(0.0, 0.0, 1.0, "a");
        tl.pcie_transfer(0.0, 2.0, "w");
        let first = tl.busy_totals();
        assert_eq!(first.gpu, 1.0);
        assert_eq!(first.pcie, 2.0);
        assert_eq!(first.cpu, 0.0);
        tl.gpu_compute(5.0, 5.0, 0.5, "b");
        tl.nvme_stage(5.0, 0.25, "s");
        let delta = tl.busy_totals().minus(&first);
        assert_eq!(delta.gpu, 0.5);
        assert_eq!(delta.pcie, 0.0);
        assert_eq!(delta.nvme, 0.25);
        let sum = delta.plus(&first);
        assert_eq!(sum.gpu, 1.5);
        assert_eq!(sum.pcie, 2.0);
    }

    #[test]
    fn compute_waits_for_deps() {
        let mut tl = Timeline::new(true);
        let arr = tl.pcie_transfer(0.0, 2.0, "w");
        let end = tl.gpu_compute(0.5, arr, 1.0, "e");
        assert_eq!(arr, 2.0);
        assert_eq!(end, 3.0);
        assert_eq!(tl.events.len(), 2);
    }

    #[test]
    fn overlap_across_channels() {
        // transfer and compute on different channels overlap
        let mut tl = Timeline::new(false);
        let t_end = tl.pcie_transfer(0.0, 1.0, "w1");
        let c_end = tl.gpu_compute(0.0, 0.0, 1.0, "attn");
        assert_eq!(t_end, 1.0);
        assert_eq!(c_end, 1.0); // simultaneous, not serialized
    }

    #[test]
    fn prefetch_logs_its_own_kind() {
        let mut tl = Timeline::new(true);
        tl.pcie_transfer(0.0, 1.0, "demand");
        tl.pcie_prefetch(0.0, 1.0, "bg");
        assert_eq!(tl.events[0].kind, EventKind::PcieTransfer);
        assert_eq!(tl.events[1].kind, EventKind::PciePrefetch);
        // Both classes still share the one physical channel's busy total.
        assert_eq!(tl.busy_totals().pcie, 2.0);
    }

    #[test]
    fn ctx_stamps_events_and_is_inert_when_not_recording() {
        let mut tl = Timeline::new(true);
        tl.ctx_step(&[7, 9], TracePhase::Mixed);
        tl.ctx_layer(Some(3));
        tl.ctx_experts(&[1, 4]);
        tl.gpu_compute(0.0, 0.0, 1.0, "ffn");
        let m = &tl.events[0].meta;
        assert_eq!(m.sessions, vec![7, 9]);
        assert_eq!(m.phase, Some(TracePhase::Mixed));
        assert_eq!(m.layer, Some(3));
        assert_eq!(m.experts, vec![1, 4]);
        // A new step clears the layer / expert stamps.
        tl.ctx_step(&[7], TracePhase::Decode);
        tl.tick_span(0.0, 1.0);
        let t = tl.events.last().unwrap();
        assert_eq!(t.kind, EventKind::Tick);
        assert_eq!(t.label, "decode-batch");
        assert_eq!(t.meta.layer, None);
        assert!(t.meta.experts.is_empty());

        let mut off = Timeline::new(false);
        off.ctx_step(&[1], TracePhase::Prefill);
        off.gpu_compute(0.0, 0.0, 1.0, "a");
        off.tick_span(0.0, 1.0);
        assert!(off.events.is_empty());
        assert_eq!(off.ctx, TraceMeta::default()); // fast path: untouched
    }

    #[test]
    fn ascii_render_has_rows() {
        let mut tl = Timeline::new(true);
        tl.pcie_transfer(0.0, 1.0, "w");
        tl.gpu_compute(1.0, 1.0, 1.0, "e");
        let art = tl.render_ascii(40);
        assert!(art.contains("gpu"));
        assert!(art.contains("pcie"));
        assert!(art.contains('#'));
        // Rows for kinds with no events do not print.
        assert!(!art.contains("mark"));
        assert!(!art.contains("pfch"));
    }

    #[test]
    fn ascii_render_keeps_markers_and_zero_width_events() {
        let mut tl = Timeline::new(true);
        tl.gpu_compute(0.0, 0.0, 10.0, "work");
        tl.marker(5.0, "fail");
        tl.marker(10.0, "end"); // at the right edge: must still paint
        let art = tl.render_ascii(40);
        let mark_row = art
            .lines()
            .find(|l| l.starts_with("mark"))
            .expect("marker row rendered");
        assert_eq!(mark_row.matches('#').count(), 2);
        // Prefetch events render on their own row, distinct from demand.
        tl.pcie_prefetch(0.0, 1.0, "bg");
        let art = tl.render_ascii(40);
        assert!(art.contains("pfch"));
    }
}

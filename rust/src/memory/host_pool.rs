//! Cross-replica shared host expert pool: the host-RAM tier between the
//! per-replica VRAM caches ([`crate::coordinator::cache`]) and SSD.
//!
//! The co-located edge deployment keeps ONE staged copy of each expert
//! in host memory and feeds every replica's PCIe lane from it (HOBBIT's
//! three-level VRAM/host/SSD caching, EdgeMoE's expert memory
//! hierarchy).  A VRAM-cache miss therefore resolves in two steps:
//! probe the host pool (cheap — the bytes are already staged), and only
//! on a pool miss pay the SSD fill before the PCIe hop.  The
//! host<->device link itself is shared: live replicas' lanes draw on
//! one host bandwidth budget ([`crate::costmodel::CostModel::host_pool_transfer`]),
//! so wide co-locations see contention stalls.
//!
//! ## Determinism under `--parallel`
//!
//! The cluster advances replicas concurrently between boundary events,
//! so the pool must never let one replica's mid-window writes influence
//! another replica's same-window behaviour (the interleaving is
//! nondeterministic).  The discipline is **journal + barrier flush**:
//!
//! * during an advance window an engine only *reads* the shared pool
//!   (a frozen snapshot) and records its own fills / touches in a
//!   replica-local journal ([`HostPoolHandle`]), consulting that
//!   journal as an overlay for its own staged copies;
//! * at every event boundary the cluster flushes journals into the
//!   shared pool in ascending replica order — single-threaded, same
//!   order serial and parallel — so the shared state every replica
//!   sees next window is identical bit for bit.
//!
//! Two replicas that fill the same expert in one window both pay the
//! SSD fill (honest: neither could see the other's in-flight copy);
//! the flush keeps one staged copy, folding in the earlier completion
//! time.  LRU touches merge as `max(last_use)`, which is commutative —
//! flush order cannot change the outcome.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use crate::config::{HostPoolConfig, PoolPolicyKind};
use crate::model::assets::ExpertKey;
use crate::quant::Precision;

use super::vram::VramBudget;

/// Host-pool traffic breakdown.  Hits / fills / stalls are observed by
/// each replica's engine ([`HostPoolHandle::lifetime`]); evictions and
/// inserted bytes are accounted shared-side at flush
/// ([`HostExpertPool::stats`]).  [`PoolStats::merge`] sums either kind,
/// so merging the per-replica lifetimes with the shared stats yields
/// the cluster totals.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    /// VRAM misses served from a staged host copy (no SSD traffic).
    pub host_hits: u64,
    /// VRAM misses that fell through to an SSD fill.
    pub ssd_fills: u64,
    /// Extra seconds of PCIe transfer time attributable to host-link
    /// contention (the contended duration minus the uncontended one).
    pub stall_s: f64,
    /// Staged copies dropped to make room (capacity evictions).
    pub evictions: u64,
    /// Bytes staged into the pool (fills and precision replacements).
    pub inserted_bytes: u64,
}

impl PoolStats {
    pub fn merge(&mut self, o: &PoolStats) {
        self.host_hits += o.host_hits;
        self.ssd_fills += o.ssd_fills;
        self.stall_s += o.stall_s;
        self.evictions += o.evictions;
        self.inserted_bytes += o.inserted_bytes;
    }

    /// Fraction of host-tier lookups served without SSD traffic.
    pub fn hit_rate(&self) -> f64 {
        let total = self.host_hits + self.ssd_fills;
        if total == 0 {
            0.0
        } else {
            self.host_hits as f64 / total as f64
        }
    }
}

/// One staged expert copy.  Mirrors the VRAM cache's precision rules:
/// at most one copy per expert per shard, a higher-precision fill
/// replaces a lower one in place, and a copy at `>=` the requested
/// precision serves the request (conservative reuse).
#[derive(Debug, Clone)]
struct PoolEntry {
    prec: Precision,
    bytes: u64,
    /// Virtual time the SSD fill completes; a replica hitting earlier
    /// waits until the staging is done.
    ready_at: f64,
    /// Virtual time of the last touch (LRU recency; merged as `max`).
    last_use: f64,
}

/// The shared host-RAM expert tier, capacity-budgeted via
/// [`VramBudget`].  Entries are keyed `(shard, expert)`: the Static
/// policy gives each replica a private shard (the independent-caches
/// baseline at equal total budget); Shared and Pinned use one shard, so
/// "one staged copy per expert across the pool" holds structurally.
#[derive(Debug)]
pub struct HostExpertPool {
    policy: PoolPolicyKind,
    /// One budget per shard: `replicas` under Static, one otherwise.
    budgets: Vec<VramBudget>,
    map: BTreeMap<(usize, ExpertKey), PoolEntry>,
    /// Live replicas drawing on the host link (failures give lanes
    /// back; drains keep theirs until the run ends).
    lanes: usize,
    /// Shared-side accounting (evictions, inserted bytes) — applied at
    /// flush, deterministically ordered by replica index.
    pub stats: PoolStats,
}

impl HostExpertPool {
    pub fn new(cfg: &HostPoolConfig, replicas: usize) -> HostExpertPool {
        let n = replicas.max(1);
        let budgets = match cfg.policy {
            PoolPolicyKind::Static => {
                vec![VramBudget::new(cfg.capacity_bytes / n as u64); n]
            }
            _ => vec![VramBudget::new(cfg.capacity_bytes)],
        };
        HostExpertPool {
            policy: cfg.policy,
            budgets,
            map: BTreeMap::new(),
            lanes: n,
            stats: PoolStats::default(),
        }
    }

    fn shard_of(&self, replica: usize) -> usize {
        match self.policy {
            PoolPolicyKind::Static => replica.min(self.budgets.len() - 1),
            _ => 0,
        }
    }

    pub fn policy(&self) -> PoolPolicyKind {
        self.policy
    }

    /// Live replicas currently contending for the host link.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// A replica failed: its lane stops drawing on the link.  (Drained
    /// replicas keep their lane — they still run down their work.)
    pub fn fail_lane(&mut self) {
        self.lanes = self.lanes.saturating_sub(1).max(1);
    }

    pub fn capacity(&self) -> u64 {
        self.budgets.iter().map(|b| b.capacity()).sum()
    }

    pub fn used_bytes(&self) -> u64 {
        self.budgets.iter().map(|b| b.used()).sum()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Probe `replica`'s view of the pool without mutating anything
    /// (the read path engines use mid-window; recency is journaled by
    /// the handle and applied at flush).
    pub fn probe(
        &self,
        replica: usize,
        key: ExpertKey,
        wanted: Precision,
    ) -> Option<(Precision, f64)> {
        self.map
            .get(&(self.shard_of(replica), key))
            .filter(|e| e.prec.satisfies(wanted))
            .map(|e| (e.prec, e.ready_at))
    }

    /// Apply one replica's window journal.  Called only from
    /// [`HostPoolHandle::flush`] at event boundaries, in ascending
    /// replica order — the single-threaded step that makes the shared
    /// state deterministic under parallel execution.
    fn apply(&mut self, replica: usize, journal: Journal) {
        let shard = self.shard_of(replica);
        for (key, t) in journal.touches {
            if let Some(e) = self.map.get_mut(&(shard, key)) {
                e.last_use = e.last_use.max(t);
            }
        }
        for (key, ins) in journal.inserts {
            self.insert(shard, key, ins);
        }
    }

    fn insert(&mut self, shard: usize, key: ExpertKey, ins: JournalInsert) {
        let slot = (shard, key);
        if let Some(e) = self.map.get_mut(&slot) {
            if e.prec.satisfies(ins.prec) {
                // Duplicate fill (another replica staged it this window,
                // or a lower-precision refill): keep the staged copy,
                // fold in recency and the earlier completion time.
                e.last_use = e.last_use.max(ins.last_use);
                if e.prec == ins.prec {
                    e.ready_at = e.ready_at.min(ins.ready_at);
                }
                return;
            }
        }
        let replaced = self.map.get(&slot).map(|e| e.bytes).unwrap_or(0);
        match self.policy {
            // First-touch pinning: never evict others to make room.  An
            // entry may still replace ITS OWN lower-precision copy if
            // the upgrade fits; otherwise the fill stays transient.
            PoolPolicyKind::Pinned => {
                if ins.bytes > self.budgets[shard].free() + replaced {
                    return;
                }
            }
            // LRU shards: feasible iff the entry fits an empty shard
            // (everything is evictable); oversized fills are transient.
            _ => {
                if ins.bytes > self.budgets[shard].capacity() {
                    return;
                }
            }
        }
        if replaced > 0 {
            let e = self.map.remove(&slot).expect("replaced entry exists");
            self.budgets[shard].release(e.bytes);
        }
        while !self.budgets[shard].fits(ins.bytes) {
            let victim = self.lru_victim(shard).expect("feasible by construction");
            let e = self.map.remove(&victim).expect("victim exists");
            self.budgets[shard].release(e.bytes);
            self.stats.evictions += 1;
        }
        self.budgets[shard].alloc(ins.bytes).expect("fits by construction");
        self.stats.inserted_bytes += ins.bytes;
        self.map.insert(
            slot,
            PoolEntry {
                prec: ins.prec,
                bytes: ins.bytes,
                ready_at: ins.ready_at,
                last_use: ins.last_use,
            },
        );
    }

    /// Least-recently-used entry of one shard; virtual-time recency,
    /// ties by expert key (total, deterministic order).
    fn lru_victim(&self, shard: usize) -> Option<(usize, ExpertKey)> {
        self.map
            .iter()
            .filter(|((s, _), _)| *s == shard)
            .min_by(|(ka, ea), (kb, eb)| {
                ea.last_use.total_cmp(&eb.last_use).then(ka.1.cmp(&kb.1))
            })
            .map(|(k, _)| *k)
    }
}

/// One staged fill recorded in a replica's window journal.
#[derive(Debug, Clone, Copy)]
struct JournalInsert {
    prec: Precision,
    bytes: u64,
    ready_at: f64,
    last_use: f64,
}

/// A replica's local overlay over the frozen shared pool: fills and
/// touches accumulated during an advance window, applied at the next
/// boundary flush.
#[derive(Debug, Default)]
struct Journal {
    inserts: BTreeMap<ExpertKey, JournalInsert>,
    touches: Vec<(ExpertKey, f64)>,
}

/// What [`HostPoolHandle::acquire`] resolved a VRAM miss to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PoolAccess {
    /// Staged in the host tier; the bytes are usable at `ready_at`.
    Hit { ready_at: f64 },
    /// Not staged: the caller pays the SSD fill and registers it with
    /// [`HostPoolHandle::fill`].
    Fill,
}

/// One replica's handle on the shared pool: the read path engines use
/// mid-window plus the journal that defers every write to the boundary
/// flush.  Holding only read locks between flushes is what lets
/// `--parallel` advance replicas concurrently without changing a bit.
#[derive(Debug)]
pub struct HostPoolHandle {
    shared: Arc<RwLock<HostExpertPool>>,
    replica: usize,
    journal: Journal,
    /// Cumulative per-replica stats over the handle's lifetime
    /// (hits / fills / stall; shared-side accounting lives on
    /// [`HostExpertPool::stats`]).
    pub lifetime: PoolStats,
}

impl HostPoolHandle {
    pub fn new(shared: Arc<RwLock<HostExpertPool>>, replica: usize) -> HostPoolHandle {
        HostPoolHandle {
            shared,
            replica,
            journal: Journal::default(),
            lifetime: PoolStats::default(),
        }
    }

    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Resolve a VRAM miss against the host tier at virtual time `now`:
    /// this replica's own window fills first (journal overlay), then
    /// the frozen shared snapshot.  A hit journals an LRU touch; a
    /// [`PoolAccess::Fill`] commits the caller to an SSD fill.
    pub fn acquire(&mut self, key: ExpertKey, wanted: Precision, now: f64) -> PoolAccess {
        if let Some(j) = self.journal.inserts.get_mut(&key) {
            if j.prec.satisfies(wanted) {
                j.last_use = j.last_use.max(now);
                self.lifetime.host_hits += 1;
                return PoolAccess::Hit { ready_at: j.ready_at };
            }
        }
        let hit = self
            .shared
            .read()
            .expect("host pool lock poisoned")
            .probe(self.replica, key, wanted);
        if let Some((_, ready_at)) = hit {
            self.journal.touches.push((key, now));
            self.lifetime.host_hits += 1;
            return PoolAccess::Hit { ready_at };
        }
        PoolAccess::Fill
    }

    /// Register the SSD fill an [`PoolAccess::Fill`] committed to: the
    /// staged copy becomes visible to this replica immediately (journal
    /// overlay) and to the cluster at the next boundary flush.
    pub fn fill(&mut self, key: ExpertKey, prec: Precision, bytes: u64, ready_at: f64, now: f64) {
        self.lifetime.ssd_fills += 1;
        let e = self
            .journal
            .inserts
            .entry(key)
            .or_insert(JournalInsert { prec, bytes, ready_at, last_use: now });
        if !e.prec.satisfies(prec) {
            // precision upgrade within the window replaces the copy
            *e = JournalInsert { prec, bytes, ready_at, last_use: now };
        } else {
            e.last_use = e.last_use.max(now);
            if e.prec == prec {
                e.ready_at = e.ready_at.min(ready_at);
            }
        }
    }

    /// Account host-link contention stall (the contended PCIe duration
    /// minus the uncontended one).
    pub fn note_stall(&mut self, stall_s: f64) {
        self.lifetime.stall_s += stall_s.max(0.0);
    }

    /// Live replicas currently sharing the host link.
    pub fn lanes(&self) -> usize {
        self.shared.read().expect("host pool lock poisoned").lanes()
    }

    /// Apply this replica's window journal to the shared pool.  The
    /// cluster calls this at event boundaries in ascending replica
    /// order (identical serial and parallel); cheap no-op when the
    /// window recorded nothing.
    pub fn flush(&mut self) {
        if self.journal.inserts.is_empty() && self.journal.touches.is_empty() {
            return;
        }
        let journal = std::mem::take(&mut self.journal);
        self.shared
            .write()
            .expect("host pool lock poisoned")
            .apply(self.replica, journal);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    fn pool(
        cap: u64,
        policy: PoolPolicyKind,
        replicas: usize,
    ) -> Arc<RwLock<HostExpertPool>> {
        Arc::new(RwLock::new(HostExpertPool::new(
            &HostPoolConfig { capacity_bytes: cap, policy },
            replicas,
        )))
    }

    #[test]
    fn shared_policy_shares_fills_across_replicas() {
        let p = pool(100, PoolPolicyKind::Shared, 2);
        let mut h0 = HostPoolHandle::new(p.clone(), 0);
        let mut h1 = HostPoolHandle::new(p.clone(), 1);
        assert_eq!(h0.acquire(k(0, 0), Precision::Int4, 1.0), PoolAccess::Fill);
        h0.fill(k(0, 0), Precision::Int4, 40, 1.5, 1.0);
        // same replica, same window: the journal overlay serves it
        assert_eq!(
            h0.acquire(k(0, 0), Precision::Int4, 1.6),
            PoolAccess::Hit { ready_at: 1.5 }
        );
        // other replica, same window: the fill is not visible yet
        assert_eq!(h1.acquire(k(0, 0), Precision::Int4, 1.6), PoolAccess::Fill);
        h0.flush();
        // after the boundary flush every replica sees the staged copy
        assert_eq!(
            h1.acquire(k(0, 0), Precision::Int4, 2.0),
            PoolAccess::Hit { ready_at: 1.5 }
        );
        // conservative reuse across precisions, like the VRAM cache
        assert_eq!(
            h1.acquire(k(0, 0), Precision::Int2, 2.1),
            PoolAccess::Hit { ready_at: 1.5 }
        );
        assert_eq!(h0.lifetime.host_hits, 1);
        assert_eq!(h0.lifetime.ssd_fills, 1);
        assert_eq!(h1.lifetime.host_hits, 2);
        assert_eq!(p.read().unwrap().used_bytes(), 40);
    }

    #[test]
    fn static_policy_keeps_shards_private() {
        let p = pool(100, PoolPolicyKind::Static, 2);
        let mut h0 = HostPoolHandle::new(p.clone(), 0);
        let mut h1 = HostPoolHandle::new(p.clone(), 1);
        h0.fill(k(0, 0), Precision::Int4, 40, 1.0, 0.5);
        h0.flush();
        // replica 1's shard never sees replica 0's fill
        assert_eq!(h1.acquire(k(0, 0), Precision::Int4, 2.0), PoolAccess::Fill);
        assert_eq!(h0.acquire(k(0, 0), Precision::Int4, 2.0), PoolAccess::Hit { ready_at: 1.0 });
        // each shard got half the capacity
        let shard_cap = 100 / 2;
        h1.fill(k(9, 9), Precision::Int4, shard_cap + 1, 1.0, 0.5);
        h1.flush();
        let g = p.read().unwrap();
        assert_eq!(g.len(), 1, "oversized static fill must stay transient");
        assert_eq!(g.used_bytes(), 40);
    }

    #[test]
    fn pinned_policy_never_evicts() {
        let p = pool(50, PoolPolicyKind::Pinned, 2);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int4, 40, 1.0, 0.5);
        h.flush();
        // no room: second fill is transient, the pin survives
        h.fill(k(0, 1), Precision::Int4, 40, 2.0, 1.5);
        h.flush();
        let g = p.read().unwrap();
        assert_eq!(g.probe(1, k(0, 0), Precision::Int4), Some((Precision::Int4, 1.0)));
        assert_eq!(g.probe(1, k(0, 1), Precision::Int4), None);
        assert_eq!(g.stats.evictions, 0, "pinned pool must never evict");
        assert_eq!(g.used_bytes(), 40);
    }

    #[test]
    fn shared_lru_evicts_least_recent() {
        let p = pool(80, PoolPolicyKind::Shared, 2);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int4, 40, 1.0, 1.0);
        h.fill(k(0, 1), Precision::Int4, 40, 2.0, 2.0);
        h.flush();
        // touch 0 so 1 becomes LRU
        assert!(matches!(h.acquire(k(0, 0), Precision::Int4, 3.0), PoolAccess::Hit { .. }));
        h.flush();
        h.fill(k(0, 2), Precision::Int4, 40, 4.0, 4.0);
        h.flush();
        let g = p.read().unwrap();
        assert!(g.probe(0, k(0, 0), Precision::Int4).is_some(), "touched entry evicted");
        assert!(g.probe(0, k(0, 1), Precision::Int4).is_none(), "LRU entry kept");
        assert!(g.probe(0, k(0, 2), Precision::Int4).is_some());
        assert_eq!(g.stats.evictions, 1);
    }

    #[test]
    fn precision_upgrade_replaces_in_place() {
        let p = pool(100, PoolPolicyKind::Shared, 1);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int2, 10, 1.0, 1.0);
        h.flush();
        // a higher-precision request misses the staged low copy ...
        assert_eq!(h.acquire(k(0, 0), Precision::Int4, 2.0), PoolAccess::Fill);
        h.fill(k(0, 0), Precision::Int4, 40, 2.5, 2.0);
        h.flush();
        let g = p.read().unwrap();
        // ... and the upgrade swapped bytes in place: one copy, no eviction
        assert_eq!(g.len(), 1);
        assert_eq!(g.used_bytes(), 40);
        assert_eq!(g.probe(0, k(0, 0), Precision::Int4), Some((Precision::Int4, 2.5)));
        assert_eq!(g.stats.evictions, 0);
    }

    #[test]
    fn duplicate_window_fills_keep_one_copy_and_min_ready() {
        let p = pool(100, PoolPolicyKind::Shared, 2);
        let mut h0 = HostPoolHandle::new(p.clone(), 0);
        let mut h1 = HostPoolHandle::new(p.clone(), 1);
        // both replicas fill the same expert in one window (neither can
        // see the other's in-flight copy — both honestly pay the SSD)
        h0.fill(k(0, 0), Precision::Int4, 40, 3.0, 1.0);
        h1.fill(k(0, 0), Precision::Int4, 40, 2.0, 1.0);
        h0.flush();
        h1.flush();
        let g = p.read().unwrap();
        assert_eq!(g.len(), 1, "flush must keep one staged copy");
        assert_eq!(g.used_bytes(), 40);
        // the earlier completion wins
        assert_eq!(g.probe(0, k(0, 0), Precision::Int4), Some((Precision::Int4, 2.0)));
    }

    #[test]
    fn failed_lanes_return_bandwidth() {
        let p = pool(100, PoolPolicyKind::Shared, 4);
        assert_eq!(p.read().unwrap().lanes(), 4);
        p.write().unwrap().fail_lane();
        assert_eq!(p.read().unwrap().lanes(), 3);
        for _ in 0..10 {
            p.write().unwrap().fail_lane();
        }
        assert_eq!(p.read().unwrap().lanes(), 1, "lanes must floor at 1");
    }

    /// Byte conservation under arbitrary acquire/fill/flush
    /// interleavings, for every policy: tier budgets are never
    /// exceeded, each shard's ledger equals the sum of its staged
    /// entries, shared policies keep one copy per expert, and the
    /// pinned pool never evicts.
    #[test]
    fn prop_pool_conserves_bytes() {
        prop::check("host-pool byte conservation", 40, |rng| {
            let replicas = rng.range(1, 4);
            let policy = PoolPolicyKind::ALL[rng.range(0, 2)];
            let cap = rng.range(50, 300) as u64;
            let p = pool(cap, policy, replicas);
            let mut handles: Vec<HostPoolHandle> =
                (0..replicas).map(|r| HostPoolHandle::new(p.clone(), r)).collect();
            let precs = [Precision::Int2, Precision::Int4, Precision::Int8];
            let mut t = 0.0;
            for _ in 0..rng.range(30, 120) {
                t += rng.f64();
                let r = rng.range(0, replicas - 1);
                let key = k(rng.range(0, 2), rng.range(0, 5));
                let prec = precs[rng.range(0, 2)];
                if handles[r].acquire(key, prec, t) == PoolAccess::Fill {
                    let bytes = rng.range(5, 60) as u64;
                    handles[r].fill(key, prec, bytes, t + 0.1, t);
                }
                if rng.f64() < 0.4 {
                    for h in handles.iter_mut() {
                        h.flush();
                    }
                    let g = p.read().unwrap();
                    assert!(g.used_bytes() <= g.capacity(), "pool budget exceeded");
                    for (shard, b) in g.budgets.iter().enumerate() {
                        let sum: u64 = g
                            .map
                            .iter()
                            .filter(|((s, _), _)| *s == shard)
                            .map(|(_, e)| e.bytes)
                            .sum();
                        assert_eq!(b.used(), sum, "shard {shard} ledger drifted");
                        assert!(b.used() <= b.capacity(), "shard {shard} over budget");
                    }
                    if policy != PoolPolicyKind::Static {
                        assert!(
                            g.map.keys().all(|(s, _)| *s == 0),
                            "shared pool grew a second shard"
                        );
                    }
                    if policy == PoolPolicyKind::Pinned {
                        assert_eq!(g.stats.evictions, 0, "pinned pool evicted");
                    }
                }
            }
        });
    }
}

//! Cross-replica shared host expert pool: the host-RAM tier between the
//! per-replica VRAM caches ([`crate::coordinator::cache`]) and SSD.
//!
//! The co-located edge deployment keeps ONE staged copy of each expert
//! in host memory and feeds every replica's PCIe lane from it (HOBBIT's
//! three-level VRAM/host/SSD caching, EdgeMoE's expert memory
//! hierarchy).  A VRAM-cache miss therefore resolves in two steps:
//! probe the host pool (cheap — the bytes are already staged), and only
//! on a pool miss pay the SSD fill before the PCIe hop.  The
//! host<->device link itself is shared: live replicas' lanes draw on
//! one host bandwidth budget ([`crate::costmodel::CostModel::host_pool_transfer`]),
//! so wide co-locations see contention stalls.
//!
//! ## Determinism under `--parallel`
//!
//! The cluster advances replicas concurrently between boundary events,
//! so the pool must never let one replica's mid-window writes influence
//! another replica's same-window behaviour (the interleaving is
//! nondeterministic).  The discipline is **journal + barrier flush**:
//!
//! * during an advance window an engine only *reads* the shared pool
//!   (a frozen snapshot) and records its own fills / touches in a
//!   replica-local journal ([`HostPoolHandle`]), consulting that
//!   journal as an overlay for its own staged copies;
//! * at every event boundary the cluster flushes journals into the
//!   shared pool in ascending replica order — single-threaded, same
//!   order serial and parallel — so the shared state every replica
//!   sees next window is identical bit for bit.
//!
//! Two replicas that fill the same expert in one window both pay the
//! SSD fill (honest: neither could see the other's in-flight copy);
//! the flush keeps one staged copy, folding in the earlier completion
//! time.  LRU touches merge as `max(last_use)`, which is commutative —
//! flush order cannot change the outcome.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use crate::config::{HostPoolConfig, PoolPolicyKind};
use crate::model::assets::ExpertKey;
use crate::quant::Precision;

use super::vram::VramBudget;

/// Host-pool traffic breakdown.  Hits / fills / stalls are observed by
/// each replica's engine ([`HostPoolHandle::lifetime`]); evictions and
/// inserted bytes are accounted shared-side at flush
/// ([`HostExpertPool::stats`]).  [`PoolStats::merge`] sums either kind,
/// so merging the per-replica lifetimes with the shared stats yields
/// the cluster totals.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PoolStats {
    /// VRAM misses served from a staged host copy (no SSD traffic).
    pub host_hits: u64,
    /// VRAM misses that fell through to an SSD fill.
    pub ssd_fills: u64,
    /// VRAM misses served by upgrading a lower-precision staged copy in
    /// place (precision-aware staging): SSD traffic for the byte
    /// *delta* only, never a full refill.
    pub replacements: u64,
    /// Extra seconds of PCIe transfer time attributable to host-link
    /// contention (the contended duration minus the uncontended one).
    pub stall_s: f64,
    /// Staged copies dropped to make room (capacity evictions).
    pub evictions: u64,
    /// Bytes staged into the pool (fills and precision replacements).
    pub inserted_bytes: u64,
    /// Copies staged speculatively by the predictive dispatcher's
    /// look-ahead (`--dispatch predictive`), before any replica
    /// demanded them.  Not SSD *demand* traffic: accounted apart from
    /// `ssd_fills` so mispredictions cannot inflate the demand story.
    pub prestaged: u64,
    /// Pre-staged copies a replica later actually used (demand touch,
    /// duplicate demand fill, or in-place upgrade).
    pub prestage_used: u64,
    /// Pre-staged copies evicted or replaced without ever serving a
    /// demand access (the misprediction count).
    pub prestage_evicted: u64,
}

impl PoolStats {
    pub fn merge(&mut self, o: &PoolStats) {
        self.host_hits += o.host_hits;
        self.ssd_fills += o.ssd_fills;
        self.replacements += o.replacements;
        self.stall_s += o.stall_s;
        self.evictions += o.evictions;
        self.inserted_bytes += o.inserted_bytes;
        self.prestaged += o.prestaged;
        self.prestage_used += o.prestage_used;
        self.prestage_evicted += o.prestage_evicted;
    }

    /// Fraction of host-tier lookups served without a *full* SSD fill
    /// (in-place upgrades pay only the byte delta, so they count
    /// against the denominator but not as hits).
    pub fn hit_rate(&self) -> f64 {
        let total = self.host_hits + self.ssd_fills + self.replacements;
        if total == 0 {
            0.0
        } else {
            self.host_hits as f64 / total as f64
        }
    }

    /// Fraction of pre-staged copies that served a demand access — the
    /// dispatcher-side analogue of
    /// [`crate::coordinator::prefetcher::PrefetchStats::accuracy`].
    /// Copies still staged and untouched at the end of a run are
    /// unresolved: counted in neither `prestage_used` nor
    /// `prestage_evicted`.
    pub fn prestage_accuracy(&self) -> f64 {
        if self.prestaged == 0 {
            0.0
        } else {
            self.prestage_used as f64 / self.prestaged as f64
        }
    }
}

/// One staged expert copy.  Mirrors the VRAM cache's precision rules:
/// at most one copy per expert per shard, a higher-precision fill
/// replaces a lower one in place, and a copy at `>=` the requested
/// precision serves the request (conservative reuse).
#[derive(Debug, Clone)]
struct PoolEntry {
    prec: Precision,
    bytes: u64,
    /// Virtual time the SSD fill completes; a replica hitting earlier
    /// waits until the staging is done.
    ready_at: f64,
    /// Virtual time of the last touch (LRU recency; merged as `max`).
    last_use: f64,
    /// Staged speculatively by the predictive dispatcher and not yet
    /// resolved: the first demand access clears the flag as
    /// `prestage_used`; eviction or replacement while still set counts
    /// `prestage_evicted`.
    prestaged: bool,
}

/// The shared host-RAM expert tier, capacity-budgeted via
/// [`VramBudget`].  Entries are keyed `(shard, expert)`: the Static
/// policy gives each replica a private shard (the independent-caches
/// baseline at equal total budget); Shared and Pinned use one shard, so
/// "one staged copy per expert across the pool" holds structurally.
#[derive(Debug)]
pub struct HostExpertPool {
    policy: PoolPolicyKind,
    /// One budget per shard: `replicas` under Static, one otherwise.
    budgets: Vec<VramBudget>,
    map: BTreeMap<(usize, ExpertKey), PoolEntry>,
    /// Per-replica relative claims on the shared host link
    /// ([`crate::config::HardwareConfig::host_lane_weight`]; unit
    /// weights = an even split).
    lane_weights: Vec<f64>,
    /// Which replicas' lanes still draw on the link (failures give
    /// lanes back; drains keep theirs until the run ends).
    lane_live: Vec<bool>,
    /// Shared-side accounting (evictions, inserted bytes) — applied at
    /// flush, deterministically ordered by replica index.
    pub stats: PoolStats,
}

impl HostExpertPool {
    pub fn new(cfg: &HostPoolConfig, replicas: usize) -> HostExpertPool {
        let n = replicas.max(1);
        let budgets = match cfg.policy {
            PoolPolicyKind::Static => {
                vec![VramBudget::new(cfg.capacity_bytes / n as u64); n]
            }
            _ => vec![VramBudget::new(cfg.capacity_bytes)],
        };
        HostExpertPool {
            policy: cfg.policy,
            budgets,
            map: BTreeMap::new(),
            lane_weights: vec![1.0; n],
            lane_live: vec![true; n],
            stats: PoolStats::default(),
        }
    }

    /// Install per-replica host-link weights (`--replica-hw`'s
    /// `HOST_GBPS` field); the cluster sets these once before the run.
    /// Non-finite or non-positive weights are clamped to the unit
    /// weight rather than poisoning every share computation.
    pub fn set_lane_weights(&mut self, weights: &[f64]) {
        self.lane_weights = (0..self.lane_weights.len())
            .map(|i| match weights.get(i) {
                Some(&w) if w.is_finite() && w > 0.0 => w,
                _ => 1.0,
            })
            .collect();
    }

    fn shard_of(&self, replica: usize) -> usize {
        match self.policy {
            PoolPolicyKind::Static => replica.min(self.budgets.len() - 1),
            _ => 0,
        }
    }

    pub fn policy(&self) -> PoolPolicyKind {
        self.policy
    }

    /// Live replicas currently contending for the host link.
    pub fn lanes(&self) -> usize {
        self.lane_live.iter().filter(|&&l| l).count().max(1)
    }

    /// `replica`'s `(own weight, total live weight)` share of the host
    /// link.  With unit weights this is `(1, live lanes)` — the even
    /// split, bit for bit
    /// ([`crate::costmodel::CostModel::host_pool_transfer_share`]).
    /// When every lane is dead (the run is tearing down) the lone
    /// caller keeps the whole link, matching the old `lanes >= 1`
    /// floor.
    pub fn lane_share(&self, replica: usize) -> (f64, f64) {
        let own = match self.lane_weights.get(replica) {
            Some(&w) => w,
            None => 1.0,
        };
        let total: f64 = self
            .lane_weights
            .iter()
            .zip(&self.lane_live)
            .filter(|(_, &live)| live)
            .map(|(&w, _)| w)
            .sum();
        if total > 0.0 {
            (own, total)
        } else {
            (own, own)
        }
    }

    /// Replica `replica` failed: its lane stops drawing on the link.
    /// (Drained replicas keep their lane — they still run down their
    /// work.)
    pub fn fail_lane(&mut self, replica: usize) {
        if let Some(l) = self.lane_live.get_mut(replica) {
            *l = false;
        }
    }

    pub fn capacity(&self) -> u64 {
        self.budgets.iter().map(|b| b.capacity()).sum()
    }

    pub fn used_bytes(&self) -> u64 {
        self.budgets.iter().map(|b| b.used()).sum()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Probe `replica`'s view of the pool without mutating anything
    /// (the read path engines use mid-window; recency is journaled by
    /// the handle and applied at flush).
    pub fn probe(
        &self,
        replica: usize,
        key: ExpertKey,
        wanted: Precision,
    ) -> Option<(Precision, f64)> {
        self.map
            .get(&(self.shard_of(replica), key))
            .filter(|e| e.prec.satisfies(wanted))
            .map(|e| (e.prec, e.ready_at))
    }

    /// Unfiltered probe of `replica`'s view: whatever copy is staged,
    /// at any precision.  The upgrade path uses this to find a
    /// lower-precision base whose bytes it can keep.
    pub fn probe_entry(&self, replica: usize, key: ExpertKey) -> Option<(Precision, u64, f64)> {
        self.map
            .get(&(self.shard_of(replica), key))
            .map(|e| (e.prec, e.bytes, e.ready_at))
    }

    /// Add `replica`'s visible staged bytes into a per-expert summary
    /// (`out[expert] += bytes`, summed over layers).  Feeds the
    /// predictive dispatcher's byte-weighted overlap score; experts
    /// beyond `out.len()` are ignored.
    pub fn add_resident_expert_bytes(&self, replica: usize, out: &mut [u64]) {
        let shard = self.shard_of(replica);
        for ((s, key), e) in self.map.iter() {
            if *s == shard {
                if let Some(slot) = out.get_mut(key.expert as usize) {
                    *slot += e.bytes;
                }
            }
        }
    }

    /// Apply one replica's window journal.  Called only from
    /// [`HostPoolHandle::flush`] at event boundaries, in ascending
    /// replica order — the single-threaded step that makes the shared
    /// state deterministic under parallel execution.
    fn apply(&mut self, replica: usize, journal: Journal) {
        let shard = self.shard_of(replica);
        for (key, t) in journal.touches {
            if let Some(e) = self.map.get_mut(&(shard, key)) {
                e.last_use = e.last_use.max(t);
                if e.prestaged {
                    // a journaled touch is a demand hit on the staged
                    // copy: the pre-stage prediction paid off
                    e.prestaged = false;
                    self.stats.prestage_used += 1;
                }
            }
        }
        for (key, ins) in journal.inserts {
            self.insert(shard, key, ins, false);
        }
    }

    fn insert(&mut self, shard: usize, key: ExpertKey, ins: JournalInsert, prestage: bool) {
        let slot = (shard, key);
        if let Some(e) = self.map.get_mut(&slot) {
            if e.prec.satisfies(ins.prec) {
                // Duplicate fill (another replica staged it this window,
                // or a lower-precision refill): keep the staged copy,
                // fold in recency and the earlier completion time.
                e.last_use = e.last_use.max(ins.last_use);
                if e.prec == ins.prec {
                    e.ready_at = e.ready_at.min(ins.ready_at);
                }
                if e.prestaged && !prestage {
                    // a demand fill landed on a pre-staged copy
                    e.prestaged = false;
                    self.stats.prestage_used += 1;
                }
                return;
            }
        }
        let replaced = self.map.get(&slot).map(|e| e.bytes).unwrap_or(0);
        match self.policy {
            // First-touch pinning: never evict others to make room.  An
            // entry may still replace ITS OWN lower-precision copy if
            // the upgrade fits; otherwise the fill stays transient.
            PoolPolicyKind::Pinned => {
                if ins.bytes > self.budgets[shard].free() + replaced {
                    return;
                }
            }
            // LRU shards: feasible iff the entry fits an empty shard
            // (everything is evictable); oversized fills are transient.
            _ => {
                if ins.bytes > self.budgets[shard].capacity() {
                    return;
                }
            }
        }
        if replaced > 0 {
            let e = self.map.remove(&slot).expect("replaced entry exists");
            self.budgets[shard].release(e.bytes);
            if e.prestaged && !prestage {
                // a demand upgrade consumed the speculative base copy
                self.stats.prestage_used += 1;
            }
        }
        while !self.budgets[shard].fits(ins.bytes) {
            let victim = self.lru_victim(shard).expect("feasible by construction");
            let e = self.map.remove(&victim).expect("victim exists");
            self.budgets[shard].release(e.bytes);
            self.stats.evictions += 1;
            if e.prestaged {
                self.stats.prestage_evicted += 1;
            }
        }
        self.budgets[shard].alloc(ins.bytes).expect("fits by construction");
        self.stats.inserted_bytes += ins.bytes;
        self.map.insert(
            slot,
            PoolEntry {
                prec: ins.prec,
                bytes: ins.bytes,
                ready_at: ins.ready_at,
                last_use: ins.last_use,
                prestaged: prestage,
            },
        );
    }

    /// Speculatively stage one predicted expert for `replica`'s shard
    /// (the predictive dispatcher's look-ahead, fired at an arrival
    /// event — a single-threaded boundary where every journal is
    /// already flushed, so a direct shared write is deterministic
    /// serial or `--parallel`).  A copy already staged at sufficient
    /// fidelity only gets a recency touch (no traffic, no counters);
    /// otherwise the copy is inserted flagged, counted under
    /// `prestaged` rather than `ssd_fills`.  Returns whether bytes
    /// were actually staged.
    pub fn prestage(
        &mut self,
        replica: usize,
        key: ExpertKey,
        prec: Precision,
        bytes: u64,
        ready_at: f64,
        now: f64,
    ) -> bool {
        let shard = self.shard_of(replica);
        if let Some(e) = self.map.get_mut(&(shard, key)) {
            if e.prec.satisfies(prec) {
                e.last_use = e.last_use.max(now);
                return false;
            }
        }
        self.stats.prestaged += 1;
        self.insert(shard, key, JournalInsert { prec, bytes, ready_at, last_use: now }, true);
        // a capacity-infeasible insert stays transient (e.g. Pinned
        // with no room): still a prediction that produced no staged
        // copy, so resolve it as evicted immediately
        if !self.map.get(&(shard, key)).map_or(false, |e| e.prestaged) {
            self.stats.prestage_evicted += 1;
        }
        true
    }

    /// Least-recently-used entry of one shard; virtual-time recency,
    /// ties by expert key (total, deterministic order).
    fn lru_victim(&self, shard: usize) -> Option<(usize, ExpertKey)> {
        self.map
            .iter()
            .filter(|((s, _), _)| *s == shard)
            .min_by(|(ka, ea), (kb, eb)| {
                ea.last_use.total_cmp(&eb.last_use).then(ka.1.cmp(&kb.1))
            })
            .map(|(k, _)| *k)
    }
}

/// One staged fill recorded in a replica's window journal.
#[derive(Debug, Clone, Copy)]
struct JournalInsert {
    prec: Precision,
    bytes: u64,
    ready_at: f64,
    last_use: f64,
}

/// A replica's local overlay over the frozen shared pool: fills and
/// touches accumulated during an advance window, applied at the next
/// boundary flush.
#[derive(Debug, Default)]
struct Journal {
    inserts: BTreeMap<ExpertKey, JournalInsert>,
    touches: Vec<(ExpertKey, f64)>,
}

/// What [`HostPoolHandle::acquire`] resolved a VRAM miss to.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PoolAccess {
    /// Staged in the host tier; the bytes are usable at `ready_at`.
    Hit { ready_at: f64 },
    /// Staged, but at a precision below the request: the caller
    /// upgrades the copy in place — SSD traffic for the byte *delta*
    /// over `have_bytes` only, gated on the base copy's `ready_at` —
    /// and registers it with [`HostPoolHandle::fill_upgrade`]
    /// (precision-aware staging).
    Upgrade { ready_at: f64, have_bytes: u64 },
    /// Not staged: the caller pays the full SSD fill and registers it
    /// with [`HostPoolHandle::fill`].
    Fill,
}

/// One replica's handle on the shared pool: the read path engines use
/// mid-window plus the journal that defers every write to the boundary
/// flush.  Holding only read locks between flushes is what lets
/// `--parallel` advance replicas concurrently without changing a bit.
#[derive(Debug)]
pub struct HostPoolHandle {
    shared: Arc<RwLock<HostExpertPool>>,
    replica: usize,
    journal: Journal,
    /// Cumulative per-replica stats over the handle's lifetime
    /// (hits / fills / stall; shared-side accounting lives on
    /// [`HostExpertPool::stats`]).
    pub lifetime: PoolStats,
}

impl HostPoolHandle {
    pub fn new(shared: Arc<RwLock<HostExpertPool>>, replica: usize) -> HostPoolHandle {
        HostPoolHandle {
            shared,
            replica,
            journal: Journal::default(),
            lifetime: PoolStats::default(),
        }
    }

    pub fn replica(&self) -> usize {
        self.replica
    }

    /// Resolve a VRAM miss against the host tier at virtual time `now`:
    /// this replica's own window fills first (journal overlay), then
    /// the frozen shared snapshot.  A hit journals an LRU touch; a
    /// [`PoolAccess::Upgrade`] hands the caller a lower-precision base
    /// copy to upgrade in place; a [`PoolAccess::Fill`] commits the
    /// caller to a full SSD fill.
    pub fn acquire(&mut self, key: ExpertKey, wanted: Precision, now: f64) -> PoolAccess {
        if let Some(j) = self.journal.inserts.get_mut(&key) {
            if j.prec.satisfies(wanted) {
                j.last_use = j.last_use.max(now);
                self.lifetime.host_hits += 1;
                return PoolAccess::Hit { ready_at: j.ready_at };
            }
        }
        let (hit, staged) = {
            let g = self.shared.read().expect("host pool lock poisoned");
            (
                g.probe(self.replica, key, wanted),
                g.probe_entry(self.replica, key),
            )
        };
        if let Some((_, ready_at)) = hit {
            self.journal.touches.push((key, now));
            self.lifetime.host_hits += 1;
            return PoolAccess::Hit { ready_at };
        }
        // Precision-aware staging: a lower-precision copy (own window
        // first, else the frozen shared snapshot) is a base the caller
        // can upgrade for the byte delta instead of a full refill.
        let base = self
            .journal
            .inserts
            .get(&key)
            .map(|j| (j.bytes, j.ready_at))
            .or_else(|| staged.map(|(_, bytes, ready_at)| (bytes, ready_at)));
        if let Some((have_bytes, ready_at)) = base {
            return PoolAccess::Upgrade { ready_at, have_bytes };
        }
        PoolAccess::Fill
    }

    /// Register the SSD fill an [`PoolAccess::Fill`] committed to: the
    /// staged copy becomes visible to this replica immediately (journal
    /// overlay) and to the cluster at the next boundary flush.
    pub fn fill(&mut self, key: ExpertKey, prec: Precision, bytes: u64, ready_at: f64, now: f64) {
        self.lifetime.ssd_fills += 1;
        self.journal_insert(key, prec, bytes, ready_at, now);
    }

    /// Register the in-place upgrade a [`PoolAccess::Upgrade`]
    /// committed to.  Same journal discipline as [`HostPoolHandle::fill`]
    /// — the flush-side replace logic swaps the staged copy in place —
    /// but counted under `replacements`, not `ssd_fills`: the SSD only
    /// carried the byte delta.
    pub fn fill_upgrade(
        &mut self,
        key: ExpertKey,
        prec: Precision,
        bytes: u64,
        ready_at: f64,
        now: f64,
    ) {
        self.lifetime.replacements += 1;
        self.journal_insert(key, prec, bytes, ready_at, now);
    }

    fn journal_insert(
        &mut self,
        key: ExpertKey,
        prec: Precision,
        bytes: u64,
        ready_at: f64,
        now: f64,
    ) {
        let e = self
            .journal
            .inserts
            .entry(key)
            .or_insert(JournalInsert { prec, bytes, ready_at, last_use: now });
        if !e.prec.satisfies(prec) {
            // precision upgrade within the window replaces the copy
            *e = JournalInsert { prec, bytes, ready_at, last_use: now };
        } else {
            e.last_use = e.last_use.max(now);
            if e.prec == prec {
                e.ready_at = e.ready_at.min(ready_at);
            }
        }
    }

    /// Account host-link contention stall (the contended PCIe duration
    /// minus the uncontended one).
    pub fn note_stall(&mut self, stall_s: f64) {
        self.lifetime.stall_s += stall_s.max(0.0);
    }

    /// Live replicas currently sharing the host link.
    pub fn lanes(&self) -> usize {
        self.shared.read().expect("host pool lock poisoned").lanes()
    }

    /// This replica's `(own weight, total live weight)` claim on the
    /// shared host link ([`HostExpertPool::lane_share`]).
    pub fn lane_share(&self) -> (f64, f64) {
        self.shared
            .read()
            .expect("host pool lock poisoned")
            .lane_share(self.replica)
    }

    /// Add this replica's visible staged bytes — frozen shared snapshot
    /// plus its own window journal — into a per-expert summary (the
    /// predictive dispatcher's pool-side residency input).
    pub fn add_resident_expert_bytes(&self, out: &mut [u64]) {
        self.shared
            .read()
            .expect("host pool lock poisoned")
            .add_resident_expert_bytes(self.replica, out);
        for (key, ins) in self.journal.inserts.iter() {
            if let Some(slot) = out.get_mut(key.expert as usize) {
                *slot += ins.bytes;
            }
        }
    }

    /// Apply this replica's window journal to the shared pool.  The
    /// cluster calls this at event boundaries in ascending replica
    /// order (identical serial and parallel); cheap no-op when the
    /// window recorded nothing.
    pub fn flush(&mut self) {
        if self.journal.inserts.is_empty() && self.journal.touches.is_empty() {
            return;
        }
        let journal = std::mem::take(&mut self.journal);
        self.shared
            .write()
            .expect("host pool lock poisoned")
            .apply(self.replica, journal);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn k(l: usize, e: usize) -> ExpertKey {
        ExpertKey::new(l, e)
    }

    fn pool(
        cap: u64,
        policy: PoolPolicyKind,
        replicas: usize,
    ) -> Arc<RwLock<HostExpertPool>> {
        Arc::new(RwLock::new(HostExpertPool::new(
            &HostPoolConfig { capacity_bytes: cap, policy },
            replicas,
        )))
    }

    #[test]
    fn shared_policy_shares_fills_across_replicas() {
        let p = pool(100, PoolPolicyKind::Shared, 2);
        let mut h0 = HostPoolHandle::new(p.clone(), 0);
        let mut h1 = HostPoolHandle::new(p.clone(), 1);
        assert_eq!(h0.acquire(k(0, 0), Precision::Int4, 1.0), PoolAccess::Fill);
        h0.fill(k(0, 0), Precision::Int4, 40, 1.5, 1.0);
        // same replica, same window: the journal overlay serves it
        assert_eq!(
            h0.acquire(k(0, 0), Precision::Int4, 1.6),
            PoolAccess::Hit { ready_at: 1.5 }
        );
        // other replica, same window: the fill is not visible yet
        assert_eq!(h1.acquire(k(0, 0), Precision::Int4, 1.6), PoolAccess::Fill);
        h0.flush();
        // after the boundary flush every replica sees the staged copy
        assert_eq!(
            h1.acquire(k(0, 0), Precision::Int4, 2.0),
            PoolAccess::Hit { ready_at: 1.5 }
        );
        // conservative reuse across precisions, like the VRAM cache
        assert_eq!(
            h1.acquire(k(0, 0), Precision::Int2, 2.1),
            PoolAccess::Hit { ready_at: 1.5 }
        );
        assert_eq!(h0.lifetime.host_hits, 1);
        assert_eq!(h0.lifetime.ssd_fills, 1);
        assert_eq!(h1.lifetime.host_hits, 2);
        assert_eq!(p.read().unwrap().used_bytes(), 40);
    }

    #[test]
    fn static_policy_keeps_shards_private() {
        let p = pool(100, PoolPolicyKind::Static, 2);
        let mut h0 = HostPoolHandle::new(p.clone(), 0);
        let mut h1 = HostPoolHandle::new(p.clone(), 1);
        h0.fill(k(0, 0), Precision::Int4, 40, 1.0, 0.5);
        h0.flush();
        // replica 1's shard never sees replica 0's fill
        assert_eq!(h1.acquire(k(0, 0), Precision::Int4, 2.0), PoolAccess::Fill);
        assert_eq!(h0.acquire(k(0, 0), Precision::Int4, 2.0), PoolAccess::Hit { ready_at: 1.0 });
        // each shard got half the capacity
        let shard_cap = 100 / 2;
        h1.fill(k(9, 9), Precision::Int4, shard_cap + 1, 1.0, 0.5);
        h1.flush();
        let g = p.read().unwrap();
        assert_eq!(g.len(), 1, "oversized static fill must stay transient");
        assert_eq!(g.used_bytes(), 40);
    }

    #[test]
    fn pinned_policy_never_evicts() {
        let p = pool(50, PoolPolicyKind::Pinned, 2);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int4, 40, 1.0, 0.5);
        h.flush();
        // no room: second fill is transient, the pin survives
        h.fill(k(0, 1), Precision::Int4, 40, 2.0, 1.5);
        h.flush();
        let g = p.read().unwrap();
        assert_eq!(g.probe(1, k(0, 0), Precision::Int4), Some((Precision::Int4, 1.0)));
        assert_eq!(g.probe(1, k(0, 1), Precision::Int4), None);
        assert_eq!(g.stats.evictions, 0, "pinned pool must never evict");
        assert_eq!(g.used_bytes(), 40);
    }

    #[test]
    fn shared_lru_evicts_least_recent() {
        let p = pool(80, PoolPolicyKind::Shared, 2);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int4, 40, 1.0, 1.0);
        h.fill(k(0, 1), Precision::Int4, 40, 2.0, 2.0);
        h.flush();
        // touch 0 so 1 becomes LRU
        assert!(matches!(h.acquire(k(0, 0), Precision::Int4, 3.0), PoolAccess::Hit { .. }));
        h.flush();
        h.fill(k(0, 2), Precision::Int4, 40, 4.0, 4.0);
        h.flush();
        let g = p.read().unwrap();
        assert!(g.probe(0, k(0, 0), Precision::Int4).is_some(), "touched entry evicted");
        assert!(g.probe(0, k(0, 1), Precision::Int4).is_none(), "LRU entry kept");
        assert!(g.probe(0, k(0, 2), Precision::Int4).is_some());
        assert_eq!(g.stats.evictions, 1);
    }

    #[test]
    fn precision_upgrade_replaces_in_place() {
        let p = pool(100, PoolPolicyKind::Shared, 1);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int2, 10, 1.0, 1.0);
        h.flush();
        // a higher-precision request finds the staged low copy as an
        // upgrade base: bytes kept, only the delta rides the SSD
        assert_eq!(
            h.acquire(k(0, 0), Precision::Int4, 2.0),
            PoolAccess::Upgrade { ready_at: 1.0, have_bytes: 10 }
        );
        h.fill_upgrade(k(0, 0), Precision::Int4, 40, 2.5, 2.0);
        h.flush();
        let g = p.read().unwrap();
        // ... and the upgrade swapped bytes in place: one copy, no eviction
        assert_eq!(g.len(), 1);
        assert_eq!(g.used_bytes(), 40);
        assert_eq!(g.probe(0, k(0, 0), Precision::Int4), Some((Precision::Int4, 2.5)));
        assert_eq!(g.stats.evictions, 0);
        // counted as a replacement, not demand SSD traffic
        assert_eq!(h.lifetime.replacements, 1);
        assert_eq!(h.lifetime.ssd_fills, 1, "only the original low fill hit the SSD");
    }

    #[test]
    fn window_local_upgrade_uses_the_journal_base() {
        let p = pool(100, PoolPolicyKind::Shared, 1);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        // fill and upgrade within ONE window: the journal overlay is
        // the base, no flush in between
        h.fill(k(0, 0), Precision::Int2, 10, 1.0, 1.0);
        assert_eq!(
            h.acquire(k(0, 0), Precision::Int4, 1.5),
            PoolAccess::Upgrade { ready_at: 1.0, have_bytes: 10 }
        );
        h.fill_upgrade(k(0, 0), Precision::Int4, 40, 2.0, 1.5);
        assert_eq!(h.acquire(k(0, 0), Precision::Int4, 2.1), PoolAccess::Hit { ready_at: 2.0 });
        h.flush();
        let g = p.read().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g.used_bytes(), 40);
    }

    #[test]
    fn duplicate_window_fills_keep_one_copy_and_min_ready() {
        let p = pool(100, PoolPolicyKind::Shared, 2);
        let mut h0 = HostPoolHandle::new(p.clone(), 0);
        let mut h1 = HostPoolHandle::new(p.clone(), 1);
        // both replicas fill the same expert in one window (neither can
        // see the other's in-flight copy — both honestly pay the SSD)
        h0.fill(k(0, 0), Precision::Int4, 40, 3.0, 1.0);
        h1.fill(k(0, 0), Precision::Int4, 40, 2.0, 1.0);
        h0.flush();
        h1.flush();
        let g = p.read().unwrap();
        assert_eq!(g.len(), 1, "flush must keep one staged copy");
        assert_eq!(g.used_bytes(), 40);
        // the earlier completion wins
        assert_eq!(g.probe(0, k(0, 0), Precision::Int4), Some((Precision::Int4, 2.0)));
    }

    #[test]
    fn failed_lanes_return_bandwidth() {
        let p = pool(100, PoolPolicyKind::Shared, 4);
        assert_eq!(p.read().unwrap().lanes(), 4);
        assert_eq!(p.read().unwrap().lane_share(0), (1.0, 4.0));
        p.write().unwrap().fail_lane(1);
        assert_eq!(p.read().unwrap().lanes(), 3);
        assert_eq!(p.read().unwrap().lane_share(0), (1.0, 3.0));
        // failing the same lane again changes nothing
        p.write().unwrap().fail_lane(1);
        assert_eq!(p.read().unwrap().lanes(), 3);
        for r in 0..4 {
            p.write().unwrap().fail_lane(r);
        }
        assert_eq!(p.read().unwrap().lanes(), 1, "lanes must floor at 1");
        // an all-dead link still hands the lone caller a whole share
        assert_eq!(p.read().unwrap().lane_share(0), (1.0, 1.0));
        // out-of-range indices are ignored, not a panic
        p.write().unwrap().fail_lane(99);
    }

    #[test]
    fn weighted_lanes_split_the_link_by_weight() {
        let p = pool(100, PoolPolicyKind::Shared, 3);
        p.write().unwrap().set_lane_weights(&[7.0, 1.0, 1.0]);
        assert_eq!(p.read().unwrap().lane_share(0), (7.0, 9.0));
        assert_eq!(p.read().unwrap().lane_share(1), (1.0, 9.0));
        // a failed fat lane returns its whole weighted share
        p.write().unwrap().fail_lane(0);
        assert_eq!(p.read().unwrap().lane_share(1), (1.0, 2.0));
        assert_eq!(p.read().unwrap().lanes(), 2);
        // degenerate weights clamp to the unit weight
        let q = pool(100, PoolPolicyKind::Shared, 2);
        q.write().unwrap().set_lane_weights(&[f64::NAN, -3.0]);
        assert_eq!(q.read().unwrap().lane_share(0), (1.0, 2.0));
        // a short weight vector pads with unit weights
        let s = pool(100, PoolPolicyKind::Shared, 2);
        s.write().unwrap().set_lane_weights(&[4.0]);
        assert_eq!(s.read().unwrap().lane_share(0), (4.0, 5.0));
        assert_eq!(s.read().unwrap().lane_share(1), (1.0, 5.0));
    }

    #[test]
    fn prestage_counters_resolve_used_and_evicted() {
        let p = pool(80, PoolPolicyKind::Shared, 2);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        {
            let mut g = p.write().unwrap();
            assert!(g.prestage(0, k(0, 0), Precision::Int4, 40, 1.5, 1.0));
            assert!(g.prestage(0, k(0, 1), Precision::Int4, 40, 1.5, 1.1));
            assert_eq!(g.stats.prestaged, 2);
            assert_eq!(g.stats.ssd_fills, 0, "pre-staging is not demand traffic");
        }
        // a demand access lands on the first staged copy -> used
        assert_eq!(h.acquire(k(0, 0), Precision::Int4, 2.0), PoolAccess::Hit { ready_at: 1.5 });
        h.flush();
        {
            let g = p.read().unwrap();
            assert_eq!(g.stats.prestage_used, 1);
            assert_eq!(g.stats.prestage_evicted, 0);
            assert!((g.stats.prestage_accuracy() - 0.5).abs() < 1e-12);
        }
        // capacity pressure evicts the untouched one (0,1 is LRU after
        // the touch above) -> evicted
        h.fill(k(1, 0), Precision::Int4, 40, 3.0, 3.0);
        h.flush();
        let g = p.read().unwrap();
        assert_eq!(g.stats.prestage_evicted, 1);
        assert_eq!(g.stats.prestage_used, 1);
        // re-staging an already-staged copy is a recency touch, not a
        // new pre-stage
        drop(g);
        let mut g = p.write().unwrap();
        assert!(!g.prestage(0, k(0, 0), Precision::Int4, 40, 4.0, 4.0));
        assert_eq!(g.stats.prestaged, 2);
    }

    #[test]
    fn infeasible_prestage_resolves_as_evicted() {
        // pinned pool with the budget already pinned: the pre-stage
        // cannot land, and must not leave an unresolved counter behind
        let p = pool(50, PoolPolicyKind::Pinned, 1);
        let mut h = HostPoolHandle::new(p.clone(), 0);
        h.fill(k(0, 0), Precision::Int4, 40, 1.0, 0.5);
        h.flush();
        let mut g = p.write().unwrap();
        assert!(g.prestage(0, k(0, 1), Precision::Int4, 40, 2.0, 1.5));
        assert_eq!(g.stats.prestaged, 1);
        assert_eq!(g.stats.prestage_evicted, 1);
        assert_eq!(g.stats.evictions, 0, "pinned pool must never evict");
    }

    /// Byte conservation under arbitrary acquire/fill/flush
    /// interleavings, for every policy: tier budgets are never
    /// exceeded, each shard's ledger equals the sum of its staged
    /// entries, shared policies keep one copy per expert, and the
    /// pinned pool never evicts.
    #[test]
    fn prop_pool_conserves_bytes() {
        prop::check("host-pool byte conservation", 40, |rng| {
            let replicas = rng.range(1, 4);
            let policy = PoolPolicyKind::ALL[rng.range(0, 2)];
            let cap = rng.range(50, 300) as u64;
            let p = pool(cap, policy, replicas);
            let mut handles: Vec<HostPoolHandle> =
                (0..replicas).map(|r| HostPoolHandle::new(p.clone(), r)).collect();
            let precs = [Precision::Int2, Precision::Int4, Precision::Int8];
            let mut t = 0.0;
            for _ in 0..rng.range(30, 120) {
                t += rng.f64();
                let r = rng.range(0, replicas - 1);
                let key = k(rng.range(0, 2), rng.range(0, 5));
                let prec = precs[rng.range(0, 2)];
                match handles[r].acquire(key, prec, t) {
                    PoolAccess::Fill => {
                        let bytes = rng.range(5, 60) as u64;
                        handles[r].fill(key, prec, bytes, t + 0.1, t);
                    }
                    PoolAccess::Upgrade { have_bytes, .. } => {
                        // the upgraded copy is never smaller than its base
                        let bytes = have_bytes + rng.range(1, 30) as u64;
                        handles[r].fill_upgrade(key, prec, bytes, t + 0.1, t);
                    }
                    PoolAccess::Hit { .. } => {}
                }
                if rng.f64() < 0.15 {
                    // speculative pre-stage riding the same invariants
                    let bytes = rng.range(5, 60) as u64;
                    p.write().unwrap().prestage(r, key, prec, bytes, t + 0.1, t);
                }
                if rng.f64() < 0.4 {
                    for h in handles.iter_mut() {
                        h.flush();
                    }
                    let g = p.read().unwrap();
                    assert!(g.used_bytes() <= g.capacity(), "pool budget exceeded");
                    for (shard, b) in g.budgets.iter().enumerate() {
                        let sum: u64 = g
                            .map
                            .iter()
                            .filter(|((s, _), _)| *s == shard)
                            .map(|(_, e)| e.bytes)
                            .sum();
                        assert_eq!(b.used(), sum, "shard {shard} ledger drifted");
                        assert!(b.used() <= b.capacity(), "shard {shard} over budget");
                    }
                    if policy != PoolPolicyKind::Static {
                        assert!(
                            g.map.keys().all(|(s, _)| *s == 0),
                            "shared pool grew a second shard"
                        );
                    }
                    if policy == PoolPolicyKind::Pinned {
                        assert_eq!(g.stats.evictions, 0, "pinned pool evicted");
                    }
                }
            }
        });
    }
}

//! Memory-hierarchy and time substrate: virtual clock, resource channels
//! (GPU, CPU, PCIe, NVMe), and byte-accurate VRAM budgeting.
//!
//! The engine co-simulates: numerics run for real through XLA while every
//! scheduled operation advances resource availability on this virtual
//! timeline (DESIGN.md §6).  A transfer issued at `t` on a busy channel
//! queues FIFO behind earlier transfers; compute waits for its inputs'
//! arrival times.  This resource-availability formulation is equivalent
//! to an event-queue DES for our pipeline topology and much cheaper.

pub mod host_pool;
pub mod timeline;
pub mod vram;
pub use host_pool::{HostExpertPool, HostPoolHandle, PoolAccess, PoolStats};

pub use timeline::{BusyTotals, EventKind, Timeline, TraceEvent, TraceMeta, TracePhase};
pub use vram::VramBudget;

//! Byte-accurate VRAM budget tracker used by the expert caches.

use anyhow::{bail, Result};

/// Tracks allocated vs available bytes; refuses over-allocation.
#[derive(Debug, Clone)]
pub struct VramBudget {
    capacity: u64,
    used: u64,
}

impl VramBudget {
    pub fn new(capacity: u64) -> Self {
        VramBudget { capacity, used: 0 }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn free(&self) -> u64 {
        self.capacity - self.used
    }

    pub fn fits(&self, bytes: u64) -> bool {
        bytes <= self.free()
    }

    pub fn alloc(&mut self, bytes: u64) -> Result<()> {
        if !self.fits(bytes) {
            bail!(
                "VRAM over-allocation: want {bytes}, free {} of {}",
                self.free(),
                self.capacity
            );
        }
        self.used += bytes;
        Ok(())
    }

    pub fn release(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.used, "releasing more than allocated");
        self.used = self.used.saturating_sub(bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn alloc_release_cycle() {
        let mut v = VramBudget::new(100);
        assert!(v.alloc(60).is_ok());
        assert_eq!(v.free(), 40);
        assert!(v.alloc(50).is_err());
        v.release(60);
        assert_eq!(v.used(), 0);
        assert!(v.alloc(100).is_ok());
    }

    #[test]
    fn never_exceeds_capacity() {
        prop::check("vram-capacity", 40, |rng| {
            let cap = rng.range(1, 1000) as u64;
            let mut v = VramBudget::new(cap);
            let mut live: Vec<u64> = Vec::new();
            for _ in 0..100 {
                if rng.f64() < 0.6 {
                    let b = rng.range(0, 200) as u64;
                    if v.alloc(b).is_ok() {
                        live.push(b);
                    }
                } else if let Some(b) = live.pop() {
                    v.release(b);
                }
                assert!(v.used() <= v.capacity());
                assert_eq!(v.used(), live.iter().sum::<u64>());
            }
        });
    }
}

//! System configuration: hardware model, paper-scale model dims, policy
//! knobs, and the 12/16/24 GB edge presets from the paper's evaluation.
//!
//! Latency methodology (DESIGN.md §6): numerics always run on the real
//! mini-model via XLA/PJRT, while *time* is virtual — computed from this
//! hardware model applied at **paper scale** (Mixtral-8x7B / Qwen3-30B-A3B
//! dimensions), so TTFT/TPOT magnitudes are comparable to the paper's.
//! The mini model has fewer layers/experts than the paper models, so the
//! expert-cache budget is scaled by the grid ratio and per-layer times by
//! the layer ratio (`layer_scale`).

use crate::quant::Precision;
use anyhow::{bail, Result};

/// Paper-scale model dimensions used by the cost model.
#[derive(Debug, Clone)]
pub struct PaperModel {
    pub name: &'static str,
    pub d_model: usize,
    pub d_ffn: usize,
    pub n_layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_heads: usize,
    /// Non-expert (attention/embed/router) bytes kept resident in VRAM.
    pub non_expert_bytes: u64,
}

impl PaperModel {
    /// Mixtral-8x7B: coarse-grained, 32 layers x 8 experts, top-2.
    pub fn mixtral_8x7b() -> Self {
        PaperModel {
            name: "Mixtral-8x7B",
            d_model: 4096,
            d_ffn: 14336,
            n_layers: 32,
            n_experts: 8,
            top_k: 2,
            n_heads: 32,
            non_expert_bytes: 3_200_000_000, // ~1.6B params bf16
        }
    }

    /// Qwen3-30B-A3B: fine-grained, 48 layers x 128 experts, top-8.
    pub fn qwen3_30b() -> Self {
        PaperModel {
            name: "Qwen3-30B-A3B",
            d_model: 2048,
            d_ffn: 768,
            n_layers: 48,
            n_experts: 128,
            top_k: 8,
            n_heads: 32,
            non_expert_bytes: 3_000_000_000,
        }
    }

    pub fn for_mini(mini_name: &str) -> Result<Self> {
        Ok(match mini_name {
            "mixtral-mini" | "tiny" => Self::mixtral_8x7b(),
            "qwen-mini" => Self::qwen3_30b(),
            _ => bail!("no paper-scale mapping for model {mini_name:?}"),
        })
    }

    /// Parameters in one expert.
    pub fn expert_params(&self) -> u64 {
        (3 * self.d_model * self.d_ffn) as u64
    }
}

/// Edge-device hardware model (RTX-3090-class GPU over PCIe Gen3 x16, as
/// in the paper's testbed, with a software-limited VRAM cap).
#[derive(Debug, Clone)]
pub struct HardwareConfig {
    pub vram_bytes: u64,
    /// Effective host->device bandwidth (PCIe Gen3 x16 ~ 12.8 GB/s).
    pub pcie_gbps: f64,
    /// Per-transfer fixed latency (driver + DMA setup).
    pub pcie_latency_s: f64,
    /// SSD->host bandwidth for SSD-resident experts.
    pub nvme_gbps: f64,
    pub nvme_latency_s: f64,
    /// Effective GPU compute throughput (bf16 FMA, achievable not peak).
    pub gpu_tflops: f64,
    /// GPU memory bandwidth (weights streamed from VRAM during compute).
    pub hbm_gbps: f64,
    /// Effective CPU compute throughput (Fiddler-style host execution).
    pub cpu_gflops: f64,
    /// Fixed kernel-launch / dispatch overhead per GPU op.
    pub kernel_overhead_s: f64,
    /// Aggregate host-memory bandwidth the shared host expert pool can
    /// feed across *all* replicas' PCIe lanes (the co-located edge
    /// deployment runs every replica's host<->device link off one
    /// memory/root-complex budget).  Only consulted when a cluster run
    /// attaches a shared pool (`serve-fleet --host-pool`): each
    /// replica's effective link bandwidth is
    /// `min(pcie_gbps, host_link_gbps * weight / sum(live weights))`
    /// (equal weights reduce to `host_link_gbps / live_replicas`), so a
    /// couple of replicas ride at full lane speed while a wide
    /// co-location contends.  Default 25.6 GB/s: two full PCIe Gen3 x16
    /// lanes' worth.
    pub host_link_gbps: f64,
    /// This replica's relative claim on the shared `host_link_gbps`
    /// budget (the optional `HOST_GBPS` field of `--replica-hw`): live
    /// lanes split the budget proportionally to their weights, so a
    /// replica on a wider root-complex attachment keeps more of the
    /// link under contention.  Default 1.0 — an even split,
    /// bitwise-identical to the unweighted lane model.
    pub host_lane_weight: f64,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        HardwareConfig {
            vram_bytes: 24 * GB,
            pcie_gbps: 12.8e9,
            pcie_latency_s: 30e-6,
            nvme_gbps: 3.2e9,
            nvme_latency_s: 80e-6,
            gpu_tflops: 35.0e12,
            hbm_gbps: 936.0e9,
            cpu_gflops: 150.0e9,
            kernel_overhead_s: 8e-6,
            host_link_gbps: 25.6e9,
            host_lane_weight: 1.0,
        }
    }
}

impl HardwareConfig {
    /// Parse a per-replica hardware spec (the `serve-fleet --replica-hw`
    /// flag): `VRAM_GB[:PCIE_GBPS[:GPU_TFLOPS[:HOST_GBPS]]]` over the
    /// default edge testbed, e.g. `24` (just a VRAM cap), `12:8`
    /// (smaller card on a narrower link), `8:4:10` (a genuinely LITTLE
    /// device), `24:12:35:7` (a fat card whose host attachment claims a
    /// 7-weight share of the shared host link).  Repeating the flag with
    /// different specs models a heterogeneous big.LITTLE edge cluster in
    /// one run.
    pub fn parse_spec(spec: &str) -> Result<HardwareConfig> {
        let mut hw = HardwareConfig::default();
        let mut parts = spec.split(':');
        let vram: u64 = parts
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| anyhow::anyhow!("--replica-hw {spec:?}: VRAM_GB must be an integer"))?;
        if vram == 0 {
            bail!("--replica-hw {spec:?}: VRAM_GB must be > 0");
        }
        hw.vram_bytes = vram * GB;
        if let Some(p) = parts.next() {
            let gbps: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("--replica-hw {spec:?}: PCIE_GBPS must be a number"))?;
            if !gbps.is_finite() || gbps <= 0.0 {
                bail!("--replica-hw {spec:?}: PCIE_GBPS must be > 0");
            }
            hw.pcie_gbps = gbps * 1e9;
        }
        if let Some(p) = parts.next() {
            let tflops: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("--replica-hw {spec:?}: GPU_TFLOPS must be a number"))?;
            if !tflops.is_finite() || tflops <= 0.0 {
                bail!("--replica-hw {spec:?}: GPU_TFLOPS must be > 0");
            }
            hw.gpu_tflops = tflops * 1e12;
        }
        if let Some(p) = parts.next() {
            let w: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("--replica-hw {spec:?}: HOST_GBPS must be a number"))?;
            if !w.is_finite() || w <= 0.0 {
                bail!("--replica-hw {spec:?}: HOST_GBPS must be > 0");
            }
            hw.host_lane_weight = w;
        }
        if parts.next().is_some() {
            bail!("--replica-hw {spec:?}: expected VRAM_GB[:PCIE_GBPS[:GPU_TFLOPS[:HOST_GBPS]]]");
        }
        Ok(hw)
    }
}

pub const GB: u64 = 1_000_000_000;

/// What happens to a replica at a churn event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnKind {
    /// The replica dies: its queued *and* active (mid-prefill /
    /// mid-decode) sessions are evacuated and re-dispatched to the
    /// surviving replicas, restarting from scratch but keeping their
    /// original arrival times (the SLO cost of the failure is real).
    Fail,
    /// The replica is cordoned: it stops receiving dispatches and runs
    /// down everything already dispatched to it (admission queue and
    /// in-flight sessions), then sits idle — a graceful recall.
    Drain,
}

impl ChurnKind {
    pub fn name(self) -> &'static str {
        match self {
            ChurnKind::Fail => "fail",
            ChurnKind::Drain => "drain",
        }
    }
}

/// One scheduled churn event in a cluster run: at virtual time `at`,
/// replica `replica` fails or drains.  Events fire in virtual-time
/// order between scheduler ticks (`crate::serving::run_cluster`); the
/// `serve-fleet` CLI builds these from repeatable `--fail T@R` /
/// `--drain T@R` flags.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnEvent {
    /// Virtual time (seconds) at which the event fires.
    pub at: f64,
    /// Target replica index (`0..replicas`).
    pub replica: usize,
    pub kind: ChurnKind,
}

impl ChurnEvent {
    /// Parse the CLI spec `T@R` (virtual seconds `@` replica index),
    /// e.g. `--fail 12.5@1` or `--drain 0@0`.
    pub fn parse_spec(kind: ChurnKind, spec: &str) -> Result<ChurnEvent> {
        let Some((t, r)) = spec.split_once('@') else {
            bail!("--{} {spec:?}: expected T@R (virtual seconds @ replica index)", kind.name());
        };
        let at: f64 = t
            .parse()
            .map_err(|_| anyhow::anyhow!("--{} {spec:?}: T must be a number", kind.name()))?;
        if !at.is_finite() || at < 0.0 {
            bail!("--{} {spec:?}: T must be finite and >= 0", kind.name());
        }
        let replica: usize = r
            .parse()
            .map_err(|_| anyhow::anyhow!("--{} {spec:?}: R must be a replica index", kind.name()))?;
        Ok(ChurnEvent { at, replica, kind })
    }
}

/// How the shared host expert pool partitions its capacity across the
/// cluster's replicas ([`crate::memory::HostExpertPool`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolPolicyKind {
    /// Static per-replica split: the capacity is sharded `cap / n`
    /// per replica, each shard a private LRU.  No cross-replica reuse —
    /// this is the "independent caches" baseline at equal total budget.
    Static,
    /// One shared LRU over the whole capacity: any replica's fill is
    /// every replica's hit.
    Shared,
    /// Per-expert pinning: first-touch entries stay for the run (an
    /// insert that does not fit is used transiently and dropped); no
    /// eviction churn, at the price of a frozen working set.
    Pinned,
}

impl PoolPolicyKind {
    pub fn parse(name: &str) -> Result<PoolPolicyKind> {
        Ok(match name {
            "static" => PoolPolicyKind::Static,
            "shared" | "lru" => PoolPolicyKind::Shared,
            "pinned" | "pin" => PoolPolicyKind::Pinned,
            _ => bail!("unknown host-pool policy {name:?}; try static, shared, pinned"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PoolPolicyKind::Static => "static",
            PoolPolicyKind::Shared => "shared",
            PoolPolicyKind::Pinned => "pinned",
        }
    }

    pub const ALL: [PoolPolicyKind; 3] =
        [PoolPolicyKind::Static, PoolPolicyKind::Shared, PoolPolicyKind::Pinned];
}

/// Configuration of the cross-replica shared host expert pool (the
/// host-RAM tier between the per-replica VRAM caches and SSD).  `None`
/// on [`ServingConfig::host_pool`] models unbounded host RAM — the
/// pre-pool behaviour, bit for bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HostPoolConfig {
    /// Host-RAM bytes budgeted for staged expert copies, cluster-wide.
    pub capacity_bytes: u64,
    pub policy: PoolPolicyKind,
}

impl HostPoolConfig {
    /// Parse the CLI spec `CAP_GB[:POLICY]` (`serve-fleet --host-pool`),
    /// e.g. `--host-pool 2`, `--host-pool 4:static`,
    /// `--host-pool 0.5:pinned`.
    pub fn parse_spec(spec: &str) -> Result<HostPoolConfig> {
        let mut parts = spec.split(':');
        let gb: f64 = parts
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| anyhow::anyhow!("--host-pool {spec:?}: CAP_GB must be a number"))?;
        if !gb.is_finite() || gb <= 0.0 {
            bail!("--host-pool {spec:?}: CAP_GB must be > 0");
        }
        let policy = match parts.next() {
            Some(p) => PoolPolicyKind::parse(p)
                .map_err(|e| anyhow::anyhow!("--host-pool {spec:?}: {e}"))?,
            None => PoolPolicyKind::Shared,
        };
        if parts.next().is_some() {
            bail!("--host-pool {spec:?}: expected CAP_GB[:POLICY]");
        }
        Ok(HostPoolConfig { capacity_bytes: (gb * GB as f64) as u64, policy })
    }
}

/// Where sub-critical experts land under DyMoE's dynamic quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LowMode {
    /// "4/2": sub-critical experts run at Int2.
    Int2,
    /// "4/0": sub-critical experts are skipped entirely.
    Skip,
}

impl LowMode {
    pub fn precision(self) -> Precision {
        match self {
            LowMode::Int2 => Precision::Int2,
            LowMode::Skip => Precision::Skip,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            LowMode::Int2 => "4/2",
            LowMode::Skip => "4/0",
        }
    }
}

/// DyMoE policy knobs (paper §4).
#[derive(Debug, Clone)]
pub struct PolicyConfig {
    /// Average expert retention ratio `lambda <= r <= 1` (Eq. 4); the
    /// paper's default for the end-to-end runs is 0.75.
    pub retention: f64,
    /// High-precision tier for critical experts.
    pub high: Precision,
    /// Low tier for sub-critical experts (4/2 vs 4/0).
    pub low_mode: LowMode,
    /// Enable the mixed-precision LRU expert cache (§4.4.2).
    pub cache_enabled: bool,
    /// Enable the look-ahead prefetcher (§4.4.1).
    pub prefetch_enabled: bool,
    /// Enable dynamic quantization (importance-based tiering, §4.2-4.3).
    /// When disabled every expert is fetched at `high`.
    pub dyquant_enabled: bool,
    /// Depth-aware scheduling (Eq. 4).  When disabled the retention ratio
    /// is uniform across layers ("Equal" in Fig. 3).
    pub depth_aware: bool,
    /// How many predicted experts to prefetch per layer in decode.
    /// 0 = auto (the model's top_k, which measures best: deeper prefetch
    /// pollutes the cache with mispredictions).
    pub prefetch_depth: usize,
    /// Fraction of prompt tokens treated as heavy-hitters (Eq. 2 top-k).
    pub heavy_hitter_frac: f64,
    /// Experts are SSD-resident (vs host-RAM-resident).
    pub ssd_resident: bool,
}

impl Default for PolicyConfig {
    fn default() -> Self {
        PolicyConfig {
            retention: 0.75,
            high: Precision::Int4,
            low_mode: LowMode::Skip,
            cache_enabled: true,
            prefetch_enabled: true,
            dyquant_enabled: true,
            depth_aware: true,
            prefetch_depth: 0,
            heavy_hitter_frac: 0.2,
            ssd_resident: false,
        }
    }
}

impl PolicyConfig {
    /// The floor `lambda` of the cosine schedule given the target average
    /// retention (integrating Eq. 4 over layers gives mean = (1+lambda)/2).
    pub fn lambda(&self) -> f64 {
        (2.0 * self.retention - 1.0).clamp(0.0, 1.0)
    }
}

/// Fleet-serving knobs consumed by [`crate::serving`]: admission limits
/// and the latency SLOs that define goodput / attainment.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Maximum in-flight (admitted) sessions; later arrivals wait in the
    /// admission queue.
    pub max_sessions: usize,
    /// Time-to-first-token SLO, measured from *arrival* (queue delay
    /// included), in virtual seconds.
    pub ttft_slo_s: f64,
    /// Per-output-token SLO in virtual seconds.
    pub tpot_slo_s: f64,
    /// Largest cross-session decode batch the fleet scheduler may form
    /// per virtual tick (sessions decoding together share expert
    /// fetches).  1 = serial interleaved decode, the pre-batching
    /// behaviour; the `serve-fleet` CLI defaults to batching up to
    /// `max_sessions`.
    pub max_decode_batch: usize,
    /// Per-tick prefill token budget for **chunked prefill**.  0 (the
    /// default) keeps monolithic prefill: each admitted session's whole
    /// prompt runs as one scheduling step, reproducing the pre-chunking
    /// fleet path step for step.  With a positive budget the scheduler
    /// runs token-budget continuous batching: every virtual tick fuses
    /// up to `chunk_tokens` prompt tokens of one prefilling session
    /// with up to `max_decode_batch` decode tokens in a single
    /// per-layer engine pass, bounding how long a long prompt can stall
    /// concurrent decoders (head-of-line blocking).
    pub chunk_tokens: usize,
    /// DyMoE replicas in the edge cluster, each with its own engine,
    /// expert cache, and virtual timeline; a dispatch policy
    /// ([`crate::serving::policy::DispatchKind`] on the fleet config)
    /// routes each arriving request to one of them.  1 (the default) is
    /// the classic single-device fleet, tick for tick.  The per-replica
    /// limits above (`max_sessions`, `max_decode_batch`, `chunk_tokens`)
    /// apply to *each* replica.  The engine slice handed to
    /// `run_cluster` is authoritative for cluster size; a value above 1
    /// that disagrees with it is rejected there (1 means "unset").
    pub replicas: usize,
    /// Scheduled replica failure / drain events
    /// ([`crate::serving::run_cluster`] fires them in virtual-time
    /// order between ticks; the single-replica
    /// [`crate::serving::run_fleet`] entry point has no dispatcher to
    /// re-route evacuees and rejects a non-empty schedule).  Empty (the
    /// default) is the churn-free cluster, tick for tick.
    pub churn: Vec<ChurnEvent>,
    /// Worker threads for the cluster's inter-boundary advance phases
    /// (CLI `serve-fleet --parallel N`).  1 (the default) advances
    /// replicas serially; above 1, [`crate::serving::run_cluster`]
    /// distributes independent replica work over up to this many
    /// [`std::thread::scope`] workers — outcomes are bit-identical to
    /// serial (the determinism suite pins it), only wall-clock changes.
    /// Requires per-replica executors (engines must not share one);
    /// ignored by the single-replica `run_fleet`.
    pub parallel: usize,
    /// Shared host expert pool under the per-replica VRAM caches
    /// (`serve-fleet --host-pool CAP[:POLICY]`): misses resolve
    /// VRAM -> host pool -> SSD, with the host<->device link contended
    /// across live replicas.  `None` (the default) models unbounded
    /// host RAM — every code path stays bitwise-identical to the
    /// pre-pool cluster (the digest-neutrality suite pins it).
    pub host_pool: Option<HostPoolConfig>,
    /// How many predicted experts the **predictive dispatch policy**
    /// routes and pre-stages on (`serve-fleet --probe-depth`, only
    /// consulted under `--dispatch predictive`): the dispatcher runs
    /// the layer-0 gate on the prompt prefix and keeps the top
    /// `probe_depth` experts by routed frequency.  0 (the default) is
    /// auto — the model's top_k, mirroring
    /// [`PolicyConfig::prefetch_depth`].
    pub probe_depth: usize,
    /// SLO relaxation factor for **batch-class** tenants on `--scenario`
    /// runs: batch requests get `ttft_slo_s x scale` / `tpot_slo_s x
    /// scale` as their per-request targets, while interactive requests
    /// keep the fleet SLO above.  Must be `>= 1`; only consulted when a
    /// scenario trace stamps per-request SLOs
    /// ([`crate::serving::Scenario::from_cli`]) — `--arrival` traces
    /// carry no per-request SLO and resolve to the fleet targets, bit
    /// for bit.
    pub batch_slo_scale: f64,
}

impl Default for ServingConfig {
    fn default() -> Self {
        // Edge-interactive targets at paper scale: first token within a
        // few seconds even after queueing, decode around 2 tok/s.
        ServingConfig {
            max_sessions: 8,
            ttft_slo_s: 5.0,
            tpot_slo_s: 0.5,
            max_decode_batch: 1,
            chunk_tokens: 0,
            replicas: 1,
            churn: Vec::new(),
            parallel: 1,
            host_pool: None,
            probe_depth: 0,
            batch_slo_scale: 8.0,
        }
    }
}

/// Full system configuration for one engine instantiation.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub hardware: HardwareConfig,
    pub policy: PolicyConfig,
    pub paper: PaperModel,
    pub seed: u64,
}

impl SystemConfig {
    /// The paper's three edge presets, scaled per DESIGN.md §6.
    pub fn edge_preset(mini_name: &str, vram_gb: u64) -> Result<SystemConfig> {
        let paper = PaperModel::for_mini(mini_name)?;
        let mut hw = HardwareConfig::default();
        hw.vram_bytes = vram_gb * GB;
        Ok(SystemConfig { hardware: hw, policy: PolicyConfig::default(), paper, seed: 0 })
    }

    /// Expert-cache VRAM budget for a mini model with the given grid,
    /// scaled by the mini/paper expert-grid ratio so the same *fraction*
    /// of experts fits as on the paper's hardware.
    pub fn expert_cache_bytes(&self, mini_layers: usize, mini_experts: usize) -> u64 {
        let avail = self
            .hardware
            .vram_bytes
            .saturating_sub(self.paper.non_expert_bytes);
        let grid_ratio = (mini_layers * mini_experts) as f64
            / (self.paper.n_layers * self.paper.n_experts) as f64;
        (avail as f64 * grid_ratio) as u64
    }

    /// Per-layer time multiplier: the mini model has fewer layers than the
    /// paper model; scaling per-layer durations keeps end-to-end TTFT/TPOT
    /// magnitudes comparable to the paper's tables.
    pub fn layer_scale(&self, mini_layers: usize) -> f64 {
        self.paper.n_layers as f64 / mini_layers as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        let c = SystemConfig::edge_preset("mixtral-mini", 16).unwrap();
        assert_eq!(c.hardware.vram_bytes, 16 * GB);
        assert_eq!(c.paper.name, "Mixtral-8x7B");
        let q = SystemConfig::edge_preset("qwen-mini", 12).unwrap();
        assert_eq!(q.paper.n_experts, 128);
        assert!(SystemConfig::edge_preset("nope", 12).is_err());
    }

    #[test]
    fn cache_budget_scales_with_grid() {
        let c = SystemConfig::edge_preset("mixtral-mini", 24).unwrap();
        // mini grid 8x8=64 vs paper 32x8=256 -> ratio 0.25
        let b = c.expert_cache_bytes(8, 8);
        let avail = 24 * GB - c.paper.non_expert_bytes;
        assert_eq!(b, (avail as f64 * 0.25) as u64);
        // budget shrinks with VRAM
        let c12 = SystemConfig::edge_preset("mixtral-mini", 12).unwrap();
        assert!(c12.expert_cache_bytes(8, 8) < b);
    }

    #[test]
    fn lambda_matches_mean_retention() {
        let mut p = PolicyConfig::default();
        p.retention = 0.75;
        assert!((p.lambda() - 0.5).abs() < 1e-9);
        p.retention = 1.0;
        assert!((p.lambda() - 1.0).abs() < 1e-9);
        p.retention = 0.5;
        assert!((p.lambda() - 0.0).abs() < 1e-9);
    }

    #[test]
    fn replica_hw_spec_parses_overrides() {
        let hw = HardwareConfig::parse_spec("24").unwrap();
        assert_eq!(hw.vram_bytes, 24 * GB);
        assert_eq!(hw.pcie_gbps, HardwareConfig::default().pcie_gbps);

        let hw = HardwareConfig::parse_spec("12:8").unwrap();
        assert_eq!(hw.vram_bytes, 12 * GB);
        assert!((hw.pcie_gbps - 8e9).abs() < 1.0);
        assert_eq!(hw.gpu_tflops, HardwareConfig::default().gpu_tflops);

        let hw = HardwareConfig::parse_spec("8:4:10").unwrap();
        assert_eq!(hw.vram_bytes, 8 * GB);
        assert!((hw.pcie_gbps - 4e9).abs() < 1.0);
        assert!((hw.gpu_tflops - 10e12).abs() < 1.0);
        assert_eq!(hw.host_lane_weight, 1.0, "unspecified lane weight must stay even");

        let hw = HardwareConfig::parse_spec("8:4:10:7").unwrap();
        assert_eq!(hw.vram_bytes, 8 * GB);
        assert!((hw.host_lane_weight - 7.0).abs() < 1e-12);

        for bad in [
            "", "0", "x", "8:0", "8:-1", "8:4:0", "8:nan", "8:4:10:0", "8:4:10:-2",
            "8:4:10:nan", "8:4:10:7:9",
        ] {
            assert!(HardwareConfig::parse_spec(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn serving_default_is_single_replica() {
        let s = ServingConfig::default();
        assert_eq!(s.replicas, 1);
        assert!(s.churn.is_empty(), "default serving config must be churn-free");
        assert!(s.host_pool.is_none(), "default serving config must be pool-free");
        assert_eq!(s.probe_depth, 0, "default probe depth must be auto (top_k)");
        assert!(
            (s.batch_slo_scale - 8.0).abs() < 1e-12,
            "default batch SLO relaxation must be 8x the fleet targets"
        );
    }

    #[test]
    fn host_pool_spec_parses_cap_and_policy() {
        let p = HostPoolConfig::parse_spec("2").unwrap();
        assert_eq!(p.capacity_bytes, 2 * GB);
        assert_eq!(p.policy, PoolPolicyKind::Shared);
        let p = HostPoolConfig::parse_spec("4:static").unwrap();
        assert_eq!(p.capacity_bytes, 4 * GB);
        assert_eq!(p.policy, PoolPolicyKind::Static);
        let p = HostPoolConfig::parse_spec("0.5:pinned").unwrap();
        assert_eq!(p.capacity_bytes, GB / 2);
        assert_eq!(p.policy, PoolPolicyKind::Pinned);
        for kind in PoolPolicyKind::ALL {
            assert_eq!(PoolPolicyKind::parse(kind.name()).unwrap(), kind);
        }
        for bad in ["", "0", "-2", "nan", "x", "2:fifo", "2:shared:x"] {
            assert!(HostPoolConfig::parse_spec(bad).is_err(), "{bad:?} accepted");
        }
    }

    #[test]
    fn churn_spec_parses_time_at_replica() {
        let e = ChurnEvent::parse_spec(ChurnKind::Fail, "12.5@1").unwrap();
        assert_eq!(e, ChurnEvent { at: 12.5, replica: 1, kind: ChurnKind::Fail });
        let e = ChurnEvent::parse_spec(ChurnKind::Drain, "0@0").unwrap();
        assert_eq!(e.kind, ChurnKind::Drain);
        assert_eq!(e.at, 0.0);
        assert_eq!(e.replica, 0);
        for bad in ["", "3", "@", "x@1", "3@x", "-1@0", "nan@0", "inf@2", "3@-1"] {
            assert!(
                ChurnEvent::parse_spec(ChurnKind::Fail, bad).is_err(),
                "{bad:?} accepted"
            );
        }
    }

    #[test]
    fn layer_scale_ratio() {
        let c = SystemConfig::edge_preset("mixtral-mini", 16).unwrap();
        assert!((c.layer_scale(8) - 4.0).abs() < 1e-9);
    }
}

//! Latency metrics: TTFT / TPOT recorders with percentile summaries.

/// Collects one latency series and summarizes it.
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }
}

/// TTFT/TPOT aggregate over a request trace.
#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub ttft: Series,
    pub tpot: Series,
}

impl LatencyReport {
    pub fn record(&mut self, ttft: f64, tpot: f64) {
        self.ttft.push(ttft);
        self.tpot.push(tpot);
    }

    pub fn summary_row(&self, name: &str) -> Vec<String> {
        vec![
            name.to_string(),
            format!("{:.4}", self.ttft.mean()),
            format!("{:.4}", self.ttft.percentile(95.0)),
            format!("{:.4}", self.tpot.mean()),
            format!("{:.4}", self.tpot.percentile(95.0)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::default();
        for v in [3.0, 1.0, 2.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn empty_series_is_safe() {
        let s = Series::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn report_row_shape() {
        let mut r = LatencyReport::default();
        r.record(1.0, 0.1);
        let row = r.summary_row("x");
        assert_eq!(row.len(), 5);
        assert_eq!(row[1], "1.0000");
    }
}

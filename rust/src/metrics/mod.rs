//! Latency metrics: TTFT / TPOT recorders with percentile summaries, plus
//! the fleet-level aggregates (queue delay, goodput, SLO attainment) used
//! by the multi-session serving layer ([`crate::serving`]).

/// Collects one latency series and summarizes it.
///
/// Samples are kept in insertion order; a sorted mirror is (re)built
/// lazily on the first order-statistic query after a push and then
/// cached, so N pushes and Q percentile queries cost O(N log N) total
/// instead of the clone-and-sort on *every* call the original
/// implementation did, which dominated experiment post-processing for
/// large traces.
///
/// Explicit edge behavior:
/// * **empty** series: every statistic — `mean`, `percentile`, `min`,
///   `max` — returns `0.0`.  The sentinels are deliberately symmetric
///   and finite: a zero-completion run feeds these straight into JSON
///   output, and `+inf` is not representable there;
/// * **single sample**: every percentile returns that sample;
/// * **NaN** samples are rejected at `push` (debug assert; silently
///   dropped in release), so the sorted order is total and `percentile`
///   can never observe a NaN-poisoned ordering.
#[derive(Debug, Clone, Default)]
pub struct Series {
    samples: Vec<f64>,
    /// Sorted cache; valid iff its length matches `samples` (samples are
    /// append-only, so length is a complete staleness check).
    sorted: std::cell::RefCell<Vec<f64>>,
}

impl Series {
    pub fn push(&mut self, v: f64) {
        debug_assert!(!v.is_nan(), "NaN sample pushed into Series");
        if v.is_nan() {
            return;
        }
        self.samples.push(v);
    }

    fn sorted_samples(&self) -> std::cell::Ref<'_, Vec<f64>> {
        if self.sorted.borrow().len() != self.samples.len() {
            let mut s = self.samples.clone();
            s.sort_unstable_by(|a, b| a.total_cmp(b));
            *self.sorted.borrow_mut() = s;
        }
        self.sorted.borrow()
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples in insertion order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Nearest-rank percentile over `p` in `[0, 100]` (clamped).
    pub fn percentile(&self, p: f64) -> f64 {
        let sorted = self.sorted_samples();
        if sorted.is_empty() {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    pub fn min(&self) -> f64 {
        self.sorted_samples().first().copied().unwrap_or(0.0)
    }

    pub fn max(&self) -> f64 {
        self.sorted_samples().last().copied().unwrap_or(0.0)
    }
}

/// TTFT/TPOT aggregate over a request trace.
#[derive(Debug, Clone, Default)]
pub struct LatencyReport {
    pub ttft: Series,
    pub tpot: Series,
}

impl LatencyReport {
    pub fn record(&mut self, ttft: f64, tpot: f64) {
        self.ttft.push(ttft);
        self.tpot.push(tpot);
    }

    pub fn summary_row(&self, name: &str) -> Vec<String> {
        vec![
            name.to_string(),
            format!("{:.4}", self.ttft.mean()),
            format!("{:.4}", self.ttft.percentile(95.0)),
            format!("{:.4}", self.tpot.mean()),
            format!("{:.4}", self.tpot.percentile(95.0)),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::default();
        for v in [3.0, 1.0, 2.0, 4.0, 5.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        // insertion order preserved for the raw view
        assert_eq!(s.samples(), &[3.0, 1.0, 2.0, 4.0, 5.0]);
    }

    #[test]
    fn empty_series_is_safe() {
        let s = Series::default();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
        // min and max share the same finite sentinel: an asymmetric
        // `+inf` min leaked non-finite floats into JSON reports on
        // zero-completion runs.
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
    }

    #[test]
    fn single_sample_dominates_every_percentile() {
        let mut s = Series::default();
        s.push(2.5);
        for p in [0.0, 1.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 2.5);
        }
    }

    #[test]
    fn percentile_is_clamped_and_sorted_cache_consistent() {
        let mut s = Series::default();
        for v in [9.0, 7.0, 8.0, 1.0] {
            s.push(v);
        }
        assert_eq!(s.percentile(-5.0), 1.0);
        assert_eq!(s.percentile(250.0), 9.0);
        // interleave pushes and queries: the cache must stay coherent
        s.push(0.5);
        assert_eq!(s.percentile(0.0), 0.5);
        assert_eq!(s.min(), 0.5);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn report_row_shape() {
        let mut r = LatencyReport::default();
        r.record(1.0, 0.1);
        let row = r.summary_row("x");
        assert_eq!(row.len(), 5);
        assert_eq!(row[1], "1.0000");
    }
}
